//! Seeded synthetic dataset generators.
//!
//! Each generator mirrors a dataset from the paper's evaluation. GD-SEC's
//! censoring dynamics are driven by per-coordinate gradient scale profiles
//! and per-worker heterogeneity, so generators reproduce those explicitly
//! (documented per function). All generators are deterministic in `seed`.

use super::{Dataset, Features};
use crate::linalg::DenseMat;
use crate::sparse::CsrMat;
use crate::util::rng::Pcg64;

/// MNIST-like regression set (Fig 1 / Fig 9 substitute).
///
/// Real MNIST properties that matter here: 784 pixel features in [0,1],
/// strong center/border variance disparity (border pixels are almost always
/// 0 → tiny coordinate-wise Lipschitz constants → censored early by
/// GD-SEC), and a 10-class label used directly as the regression target.
/// We synthesize 10 smooth "digit prototypes" on the 28×28 grid and add
/// pixel noise modulated by a center-weighted envelope.
pub fn mnist_like(seed: u64, n: usize) -> Dataset {
    let d = 784usize;
    let side = 28usize;
    let mut rng = Pcg64::new(seed, 1);
    // Center-weighted envelope: w(r) = exp(-(r/9)^2), r = distance to center.
    let mut envelope = vec![0.0f64; d];
    for i in 0..side {
        for j in 0..side {
            let dy = i as f64 - 13.5;
            let dx = j as f64 - 13.5;
            let r2 = dx * dx + dy * dy;
            envelope[i * side + j] = (-r2 / 81.0).exp();
        }
    }
    // Ten smooth prototypes: random low-frequency cosine mixtures.
    let mut protos = Vec::with_capacity(10);
    for _ in 0..10 {
        let mut p = vec![0.0f64; d];
        for _ in 0..6 {
            let fx = rng.uniform_in(0.5, 3.0);
            let fy = rng.uniform_in(0.5, 3.0);
            let px = rng.uniform_in(0.0, std::f64::consts::TAU);
            let py = rng.uniform_in(0.0, std::f64::consts::TAU);
            let amp = rng.uniform_in(0.2, 0.6);
            for i in 0..side {
                for j in 0..side {
                    let v = amp
                        * ((fx * j as f64 / side as f64 * std::f64::consts::TAU + px).cos()
                            * (fy * i as f64 / side as f64 * std::f64::consts::TAU + py).cos());
                    p[i * side + j] += v;
                }
            }
        }
        for k in 0..d {
            p[k] = (p[k].max(0.0) * envelope[k]).min(1.0);
        }
        protos.push(p);
    }
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.index(10);
        let mut row = vec![0.0f64; d];
        for k in 0..d {
            let noise = rng.normal() * 0.15 * envelope[k];
            row[k] = (protos[c][k] + noise).clamp(0.0, 1.0);
        }
        rows.push(row);
        y.push(c as f64);
    }
    Dataset::new("mnist-like", Features::Dense(DenseMat::from_rows(&rows)), y)
}

/// The paper's own synthetic logistic-regression recipe (Fig 2), verbatim:
/// M workers, `n_per` samples each, d-dimensional features. For worker `m`
/// (1-indexed), coordinates `50m-49..=50m` ~ U(0,1) (worker-specific
/// features), coordinates `251..=300` ~ U(0,10) (shared high-scale
/// features), all others ~ U(0,0.01). Labels ±1 equiprobable.
/// Samples are laid out worker-contiguously so `Dataset::shard(M)` gives
/// each worker its own block.
pub fn paper_logreg(seed: u64, m_workers: usize, n_per: usize, d: usize) -> Dataset {
    assert!(d >= 300, "paper recipe needs d >= 300");
    let mut rng = Pcg64::new(seed, 2);
    let mut rows = Vec::with_capacity(m_workers * n_per);
    let mut y = Vec::with_capacity(m_workers * n_per);
    for m in 1..=m_workers {
        let lo = 50 * m - 50; // 0-indexed inclusive start of worker block
        let hi = 50 * m; // exclusive end
        for _ in 0..n_per {
            let mut row = vec![0.0f64; d];
            for (j, item) in row.iter_mut().enumerate() {
                *item = if j >= lo && j < hi {
                    rng.uniform_in(0.0, 1.0)
                } else if j >= 250 && j < 300 {
                    rng.uniform_in(0.0, 10.0)
                } else {
                    rng.uniform_in(0.0, 0.01)
                };
            }
            rows.push(row);
            y.push(rng.sign());
        }
    }
    Dataset::new("paper-logreg", Features::Dense(DenseMat::from_rows(&rows)), y)
}

/// DNA-like set (Fig 3 substitute): LIBSVM `dna` is 2000 train samples,
/// 180 binary features (60 positions × 3-letter one-hot-ish encoding),
/// 3 classes. We keep the binary block structure — exactly one hot feature
/// per 3-wide group — and emit a ±1 regression target from a sparse ground
/// truth over a few motif positions plus label noise.
pub fn dna_like(seed: u64, n: usize) -> Dataset {
    let groups = 60usize;
    let d = groups * 3;
    let mut rng = Pcg64::new(seed, 3);
    // Ground-truth weights over 12 motif positions.
    let motif: Vec<usize> = rng.sample_indices(d, 12);
    let w: Vec<f64> = (0..12).map(|_| rng.normal() * 1.5).collect();
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut row = vec![0.0f64; d];
        for g in 0..groups {
            row[g * 3 + rng.index(3)] = 1.0;
        }
        let score: f64 = motif.iter().zip(&w).map(|(&j, &wj)| wj * row[j]).sum();
        rows.push(row);
        y.push(if score + rng.normal() * 0.5 > 0.0 { 1.0 } else { -1.0 });
    }
    Dataset::new("dna-like", Features::Dense(DenseMat::from_rows(&rows)), y)
}

/// COLON-CANCER-like set (Fig 4 substitute): 62 samples × 2000 dense
/// gene-expression features, heavily correlated columns (genes co-express
/// in pathways) and n ≪ d. Correlation comes from a rank-8 factor model:
/// X = F·G + noise, features log-scaled like expression data.
pub fn colon_like(seed: u64) -> Dataset {
    let n = 62usize;
    let d = 2000usize;
    let rank = 8usize;
    let mut rng = Pcg64::new(seed, 4);
    let f: Vec<Vec<f64>> = (0..n).map(|_| rng.normal_vec(rank)).collect();
    let g: Vec<Vec<f64>> = (0..rank).map(|_| rng.normal_vec(d)).collect();
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for fi in f.iter() {
        let mut row = vec![0.0f64; d];
        for (j, item) in row.iter_mut().enumerate() {
            let mut v = 0.0;
            for r in 0..rank {
                v += fi[r] * g[r][j];
            }
            *item = v + rng.normal() * 0.3;
        }
        // Label from the first factor (a "tumor pathway").
        y.push(if fi[0] > 0.0 { 1.0 } else { -1.0 });
        rows.push(row);
    }
    let mut ds = Dataset::new("colon-like", Features::Dense(DenseMat::from_rows(&rows)), y);
    ds.standardize();
    ds
}

/// W2A-like set (Fig 5 substitute): LIBSVM `w2a` is 3470 samples × 300
/// sparse binary features (~11 nnz/row) with ~97%/3% class imbalance.
pub fn w2a_like(seed: u64, n: usize) -> Dataset {
    let d = 300usize;
    let avg_nnz = 11usize;
    let mut rng = Pcg64::new(seed, 5);
    // Popular features get picked more (zipf-ish weights).
    let weights: Vec<f64> = (0..d).map(|j| 1.0 / (1.0 + j as f64).powf(0.8)).collect();
    let truth: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let k = 1 + rng.index(2 * avg_nnz - 1);
        let mut row = vec![0.0f64; d];
        for _ in 0..k {
            row[rng.categorical(&weights)] = 1.0;
        }
        let score: f64 = row.iter().zip(&truth).map(|(x, w)| x * w).sum();
        // Shifted threshold → class imbalance like w2a.
        y.push(if score > 2.5 { 1.0 } else { -1.0 });
        rows.push(row);
    }
    Dataset::new("w2a-like", Features::Dense(DenseMat::from_rows(&rows)), y)
}

/// RCV1-like sparse set (Fig 7 substitute): text tf-idf with power-law
/// feature frequencies. Defaults mirror RCV1-train: d = 47236, ~50 nnz per
/// document. Column popularity ~ Zipf(1.1); values are tf-idf-ish positive
/// reals; stored CSR. The wildly heterogeneous per-coordinate smoothness
/// L^i this induces is exactly what Fig 7's ξ_i = ξ/L^i scaling exploits.
pub fn rcv1_like(seed: u64, n: usize, d: usize, avg_nnz: usize) -> Dataset {
    let mut rng = Pcg64::new(seed, 6);
    // Zipf column sampler via inverse-CDF over precomputed cumulative
    // weights (O(log d) per draw).
    let mut cum = Vec::with_capacity(d);
    let mut total = 0.0f64;
    for j in 0..d {
        total += 1.0 / (1.0 + j as f64).powf(1.1);
        cum.push(total);
    }
    // Sparse ground truth over frequent features.
    let truth_nnz = 200.min(d);
    let truth_idx: Vec<usize> = (0..truth_nnz).map(|_| zipf_draw(&mut rng, &cum)).collect();
    let truth_w: Vec<f64> = (0..truth_nnz).map(|_| rng.normal()).collect();
    let mut rows: Vec<Vec<(u32, f64)>> = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let k = 1 + rng.index(2 * avg_nnz - 1);
        let mut cols: Vec<u32> = (0..k).map(|_| zipf_draw(&mut rng, &cum) as u32).collect();
        cols.sort_unstable();
        cols.dedup();
        let mut row: Vec<(u32, f64)> =
            cols.iter().map(|&c| (c, rng.uniform_in(0.05, 1.0))).collect();
        let mut score = 0.0;
        for &(c, v) in &row {
            for (t, &ti) in truth_idx.iter().enumerate() {
                if ti == c as usize {
                    score += truth_w[t] * v;
                }
            }
        }
        y.push(if score + rng.normal() * 0.1 > 0.0 { 1.0 } else { -1.0 });
        // Row-normalize to unit L2 norm, like the real RCV1 (cosine
        // normalization). Column scale disparity — popular features carry
        // far more mass → heterogeneous L^i — is preserved, which is what
        // Fig 7's ξ_i = ξ/L^i scaling exploits.
        let norm = row.iter().map(|&(_, v)| v * v).sum::<f64>().sqrt();
        if norm > 0.0 {
            for item in row.iter_mut() {
                item.1 /= norm;
            }
        }
        rows.push(row);
    }
    Dataset::new("rcv1-like", Features::Sparse(CsrMat::from_rows(d, &rows)), y)
}

fn zipf_draw(rng: &mut Pcg64, cum: &[f64]) -> usize {
    let t = rng.uniform() * cum[cum.len() - 1];
    match cum.binary_search_by(|c| c.partial_cmp(&t).unwrap()) {
        Ok(i) | Err(i) => i.min(cum.len() - 1),
    }
}

/// CIFAR-10-like regression set (Fig 8 substitute): 3072 dense features
/// (3×32×32), spatially correlated within channels (neighbouring pixels
/// correlate), standardized, labels 0..9 used as regression targets.
pub fn cifar_like(seed: u64, n: usize) -> Dataset {
    let d = 3072usize;
    let side = 32usize;
    let mut rng = Pcg64::new(seed, 7);
    // Class prototypes: per-channel low-frequency fields.
    let mut protos: Vec<Vec<f64>> = Vec::with_capacity(10);
    for _ in 0..10 {
        let mut p = vec![0.0f64; d];
        for ch in 0..3 {
            let fx = rng.uniform_in(0.5, 2.0);
            let fy = rng.uniform_in(0.5, 2.0);
            let ph = rng.uniform_in(0.0, std::f64::consts::TAU);
            let amp = rng.uniform_in(0.5, 1.0);
            for i in 0..side {
                for j in 0..side {
                    p[ch * 1024 + i * side + j] = amp
                        * ((fx * j as f64 / 32.0 * std::f64::consts::TAU
                            + fy * i as f64 / 32.0 * std::f64::consts::TAU
                            + ph)
                            .sin());
                }
            }
        }
        protos.push(p);
    }
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.index(10);
        let mut row = vec![0.0f64; d];
        // Smooth noise: average of iid noise with neighbour (cheap 1D blur).
        let mut prev = 0.0;
        for k in 0..d {
            let e = rng.normal();
            let sm = 0.6 * prev + 0.4 * e;
            prev = sm;
            row[k] = protos[c][k] + 0.5 * sm;
        }
        rows.push(row);
        y.push(c as f64);
    }
    let mut ds = Dataset::new("cifar-like", Features::Dense(DenseMat::from_rows(&rows)), y);
    ds.standardize();
    ds
}

/// Fig 6's engineered coordinate-wise-Lipschitz set, verbatim from the
/// paper: 10 workers × 50 samples, d = 50. Entries ~ U(0, 0.01), then the
/// n-th sample of worker m has its n-th entry replaced by `m · 1.1^n`
/// (1-indexed), producing `L_m^1 < ... < L_m^50` and `L_1 < ... < L_10`.
/// Labels ±1 equiprobable. Samples worker-contiguous for `shard(10)`.
pub fn coord_lipschitz(seed: u64) -> Dataset {
    let m_workers = 10usize;
    let n_per = 50usize;
    let d = 50usize;
    let mut rng = Pcg64::new(seed, 8);
    let mut rows = Vec::with_capacity(m_workers * n_per);
    let mut y = Vec::with_capacity(m_workers * n_per);
    for m in 1..=m_workers {
        for n in 1..=n_per {
            let mut row: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.0, 0.01)).collect();
            row[n - 1] = m as f64 * 1.1f64.powi(n as i32);
            rows.push(row);
            y.push(rng.sign());
        }
    }
    Dataset::new("coord-lipschitz", Features::Dense(DenseMat::from_rows(&rows)), y)
}

/// Synthetic corpus for the end-to-end transformer example: token
/// sequences from a 2nd-order Markov chain with a planted periodic
/// structure, so a small LM has real signal to fit (loss decreases well
/// below the uniform-entropy baseline).
pub fn token_corpus(seed: u64, n_seqs: usize, seq_len: usize, vocab: usize) -> Vec<Vec<u32>> {
    let mut rng = Pcg64::new(seed, 9);
    // Random sparse transition table: each (prev2, prev1) prefers ~4 tokens.
    let table: Vec<[u32; 4]> = (0..vocab * vocab)
        .map(|_| {
            [
                rng.index(vocab) as u32,
                rng.index(vocab) as u32,
                rng.index(vocab) as u32,
                rng.index(vocab) as u32,
            ]
        })
        .collect();
    (0..n_seqs)
        .map(|_| {
            let mut seq = Vec::with_capacity(seq_len);
            let mut p2 = rng.index(vocab);
            let mut p1 = rng.index(vocab);
            for _ in 0..seq_len {
                let next = if rng.bernoulli(0.85) {
                    table[p2 * vocab + p1][rng.index(4)] as usize
                } else {
                    rng.index(vocab)
                };
                seq.push(next as u32);
                p2 = p1;
                p1 = next;
            }
            seq
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Features;

    #[test]
    fn mnist_like_shapes_and_range() {
        let ds = mnist_like(1, 200);
        assert_eq!(ds.n(), 200);
        assert_eq!(ds.d(), 784);
        if let Features::Dense(m) = &ds.x {
            assert!(m.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
        assert!(ds.y.iter().all(|&c| (0.0..10.0).contains(&c)));
        // Border pixels have much lower variance than center pixels.
        if let Features::Dense(m) = &ds.x {
            let var = |j: usize| {
                let mean: f64 = (0..m.rows).map(|i| m.row(i)[j]).sum::<f64>() / m.rows as f64;
                (0..m.rows).map(|i| (m.row(i)[j] - mean).powi(2)).sum::<f64>() / m.rows as f64
            };
            let center = var(13 * 28 + 13);
            let corner = var(0);
            assert!(center > 10.0 * corner.max(1e-12), "center={center} corner={corner}");
        }
    }

    #[test]
    fn paper_logreg_block_structure() {
        let ds = paper_logreg(7, 5, 50, 300);
        assert_eq!(ds.n(), 250);
        let shards = ds.shard(5);
        // Worker 2 (0-indexed 1): its block coords 50..100 are U(0,1);
        // coords 0..50 should be U(0,0.01).
        if let Features::Dense(m) = &shards[1].x {
            let mean_own: f64 =
                (0..m.rows).map(|i| m.row(i)[60]).sum::<f64>() / m.rows as f64;
            let mean_other: f64 =
                (0..m.rows).map(|i| m.row(i)[10]).sum::<f64>() / m.rows as f64;
            let mean_shared: f64 =
                (0..m.rows).map(|i| m.row(i)[270]).sum::<f64>() / m.rows as f64;
            assert!((mean_own - 0.5).abs() < 0.15, "own={mean_own}");
            assert!(mean_other < 0.01, "other={mean_other}");
            assert!((mean_shared - 5.0).abs() < 1.5, "shared={mean_shared}");
        }
    }

    #[test]
    fn dna_like_one_hot_groups() {
        let ds = dna_like(3, 100);
        assert_eq!(ds.d(), 180);
        if let Features::Dense(m) = &ds.x {
            for i in 0..m.rows {
                let row = m.row(i);
                for g in 0..60 {
                    let s: f64 = row[g * 3..g * 3 + 3].iter().sum();
                    assert_eq!(s, 1.0);
                }
            }
        }
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn colon_like_dims() {
        let ds = colon_like(4);
        assert_eq!(ds.n(), 62);
        assert_eq!(ds.d(), 2000);
    }

    #[test]
    fn w2a_like_sparse_binary_imbalanced() {
        let ds = w2a_like(5, 1000);
        assert_eq!(ds.d(), 300);
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        let frac = pos as f64 / 1000.0;
        assert!(frac < 0.25, "positive fraction {frac} should be small (w2a-like imbalance)");
        if let Features::Dense(m) = &ds.x {
            let nnz: usize = m.data.iter().filter(|&&v| v != 0.0).count();
            let per_row = nnz as f64 / 1000.0;
            assert!((5.0..20.0).contains(&per_row), "nnz/row={per_row}");
        }
    }

    #[test]
    fn rcv1_like_sparse_powerlaw() {
        let ds = rcv1_like(6, 500, 5000, 50);
        assert_eq!(ds.d(), 5000);
        if let Features::Sparse(m) = &ds.x {
            let per_row = m.nnz() as f64 / 500.0;
            assert!((20.0..80.0).contains(&per_row), "nnz/row={per_row}");
            // power law: first 1% of columns should hold a large share
            let sums = m.col_sq_sums();
            let head: f64 = sums[..50].iter().sum();
            let total: f64 = sums.iter().sum();
            assert!(head / total > 0.15, "head share {}", head / total);
        } else {
            panic!("expected sparse");
        }
    }

    #[test]
    fn coord_lipschitz_structure() {
        let ds = coord_lipschitz(2);
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.d(), 50);
        if let Features::Dense(m) = &ds.x {
            // worker 3 (1-indexed), sample 10: row index 2*50+9, entry 9 = 3*1.1^10
            let v = m.row(2 * 50 + 9)[9];
            assert!((v - 3.0 * 1.1f64.powi(10)).abs() < 1e-9);
        }
        // coordinate-wise smoothness increases along coordinates
        let l = ds.x.col_sq_sums();
        assert!(l[49] > l[10] && l[10] > l[0]);
    }

    #[test]
    fn cifar_like_standardized() {
        let ds = cifar_like(8, 100);
        assert_eq!(ds.d(), 3072);
        if let Features::Dense(m) = &ds.x {
            let j = 512;
            let mean: f64 = (0..m.rows).map(|i| m.row(i)[j]).sum::<f64>() / m.rows as f64;
            assert!(mean.abs() < 1e-9);
        }
    }

    #[test]
    fn token_corpus_has_structure() {
        let seqs = token_corpus(1, 50, 64, 32);
        assert_eq!(seqs.len(), 50);
        assert!(seqs.iter().all(|s| s.len() == 64 && s.iter().all(|&t| t < 32)));
        // Bigram repetition should exceed uniform chance substantially.
        let mut counts = std::collections::HashMap::new();
        for s in &seqs {
            for w in s.windows(3) {
                *counts.entry((w[0], w[1], w[2])).or_insert(0usize) += 1;
            }
        }
        let max = counts.values().max().copied().unwrap_or(0);
        assert!(max >= 3, "max trigram count {max}");
    }

    #[test]
    fn generators_deterministic() {
        let a = mnist_like(42, 20);
        let b = mnist_like(42, 20);
        if let (Features::Dense(ma), Features::Dense(mb)) = (&a.x, &b.x) {
            assert_eq!(ma.data, mb.data);
        }
        assert_eq!(a.y, b.y);
        let c = mnist_like(43, 20);
        if let (Features::Dense(ma), Features::Dense(mc)) = (&a.x, &c.x) {
            assert_ne!(ma.data, mc.data);
        }
    }
}
