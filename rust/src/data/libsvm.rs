//! LIBSVM text format parser (`label idx:val idx:val ...`, 1-indexed).
//!
//! The paper's real datasets (DNA, COLON-CANCER, W2A, RCV1) ship in this
//! format. The offline image has none of them, so the experiments default
//! to the `synthetic` substitutes — but any real file drops in via
//! `gdsec train --data path.libsvm`, making the substitution reversible.

use super::{Dataset, Features};
use crate::sparse::CsrMat;
use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub enum LibsvmError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
}

impl fmt::Display for LibsvmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LibsvmError::Io(e) => write!(f, "io error: {e}"),
            LibsvmError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
        }
    }
}

impl std::error::Error for LibsvmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LibsvmError::Io(e) => Some(e),
            LibsvmError::Parse { .. } => None,
        }
    }
}

impl From<std::io::Error> for LibsvmError {
    fn from(e: std::io::Error) -> LibsvmError {
        LibsvmError::Io(e)
    }
}

/// Parse LIBSVM text. `min_dim` forces at least that many columns (useful
/// when the tail features never appear in a subset). Feature indices are
/// 1-based in the format and converted to 0-based.
pub fn parse_str(text: &str, name: &str, min_dim: usize) -> Result<Dataset, LibsvmError> {
    let mut rows: Vec<Vec<(u32, f64)>> = Vec::new();
    let mut y: Vec<f64> = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().ok_or(LibsvmError::Parse {
            line: lineno + 1,
            msg: "missing label".to_string(),
        })?;
        let label: f64 = label_tok.parse().map_err(|_| LibsvmError::Parse {
            line: lineno + 1,
            msg: format!("bad label '{label_tok}'"),
        })?;
        let mut row: Vec<(u32, f64)> = Vec::new();
        for tok in parts {
            let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad feature token '{tok}'"),
            })?;
            let idx: usize = idx_s.parse().map_err(|_| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad feature index '{idx_s}'"),
            })?;
            if idx == 0 {
                return Err(LibsvmError::Parse {
                    line: lineno + 1,
                    msg: "feature indices are 1-based".to_string(),
                });
            }
            let val: f64 = val_s.parse().map_err(|_| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad feature value '{val_s}'"),
            })?;
            max_col = max_col.max(idx);
            row.push(((idx - 1) as u32, val));
        }
        row.sort_unstable_by_key(|&(c, _)| c);
        row.dedup_by_key(|&mut (c, _)| c);
        rows.push(row);
        y.push(label);
    }
    let d = max_col.max(min_dim);
    Ok(Dataset::new(name, Features::Sparse(CsrMat::from_rows(d, &rows)), y))
}

/// Parse a LIBSVM file from disk.
pub fn load<P: AsRef<Path>>(path: P, min_dim: usize) -> Result<Dataset, LibsvmError> {
    let name = path
        .as_ref()
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "libsvm".to_string());
    let text = std::fs::read_to_string(path)?;
    parse_str(&text, &name, min_dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let ds = parse_str("+1 1:0.5 3:2\n-1 2:1\n", "t", 0).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        if let Features::Sparse(m) = &ds.x {
            assert_eq!(m.row(0), (&[0u32, 2u32][..], &[0.5, 2.0][..]));
            assert_eq!(m.row(1), (&[1u32][..], &[1.0][..]));
        }
    }

    #[test]
    fn min_dim_and_comments() {
        let ds = parse_str("# comment\n3 1:1\n\n", "t", 10).unwrap();
        assert_eq!(ds.n(), 1);
        assert_eq!(ds.d(), 10);
        assert_eq!(ds.y, vec![3.0]);
    }

    #[test]
    fn unsorted_features_accepted() {
        let ds = parse_str("1 5:1 2:3\n", "t", 0).unwrap();
        if let Features::Sparse(m) = &ds.x {
            assert_eq!(m.row(0).0, &[1u32, 4u32]);
        }
    }

    #[test]
    fn errors_reported_with_line() {
        let e = parse_str("1 0:5\n", "t", 0).unwrap_err();
        assert!(e.to_string().contains("line 1"));
        assert!(parse_str("abc 1:1\n", "t", 0).is_err());
        assert!(parse_str("1 x\n", "t", 0).is_err());
        assert!(parse_str("1 1:zz\n", "t", 0).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gdsec_libsvm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mini.libsvm");
        std::fs::write(&path, "1 1:2.0\n-1 2:3.0\n").unwrap();
        let ds = load(&path, 0).unwrap();
        assert_eq!(ds.name, "mini");
        assert_eq!(ds.n(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
