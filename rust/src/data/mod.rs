//! Datasets: storage, worker sharding, standardization, loading.
//!
//! The paper evaluates on MNIST, CIFAR-10 and four LIBSVM sets (DNA,
//! COLON-CANCER, W2A, RCV1-train). The build image is offline, so
//! `synthetic` provides seeded generators that match each dataset's
//! (N, d), sparsity pattern and feature-scale profile — the properties
//! that drive GD-SEC's censoring behaviour (see DESIGN.md §6). `libsvm`
//! parses the real files when they are available (`--data file.libsvm`).

pub mod libsvm;
pub mod synthetic;

use crate::linalg::DenseMat;
use crate::sparse::CsrMat;

/// Feature matrix: dense row-major or CSR.
#[derive(Debug, Clone)]
pub enum Features {
    Dense(DenseMat),
    Sparse(CsrMat),
}

impl Features {
    pub fn rows(&self) -> usize {
        match self {
            Features::Dense(m) => m.rows,
            Features::Sparse(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Features::Dense(m) => m.cols,
            Features::Sparse(m) => m.cols,
        }
    }

    /// out = X * theta
    pub fn matvec(&self, theta: &[f64], out: &mut [f64]) {
        match self {
            Features::Dense(m) => m.gemv(theta, out),
            Features::Sparse(m) => m.spmv(theta, out),
        }
    }

    /// out += alpha * X^T * r
    pub fn matvec_t_acc(&self, alpha: f64, r: &[f64], out: &mut [f64]) {
        match self {
            Features::Dense(m) => m.gemv_t_acc(alpha, r, out),
            Features::Sparse(m) => m.spmv_t_acc(alpha, r, out),
        }
    }

    /// [`matvec_t_acc`](Self::matvec_t_acc) with the sparse path fanned
    /// over `pool` column blocks ([`CsrMat::spmv_t_acc_pooled`] —
    /// bitwise identical to the serial kernel for any thread count, so
    /// callers may switch freely). The dense path stays the serial
    /// `gemv_t_acc`, which is already column-blocked for cache. Must
    /// not be called from inside a scatter job of the same pool.
    pub fn matvec_t_acc_pooled(
        &self,
        alpha: f64,
        r: &[f64],
        out: &mut [f64],
        pool: &crate::util::pool::Pool,
    ) {
        match self {
            Features::Dense(m) => m.gemv_t_acc(alpha, r, out),
            Features::Sparse(m) => m.spmv_t_acc_pooled(alpha, r, out, pool),
        }
    }

    /// Fused full-batch gradient pass: for every row i compute
    /// `z_i = x_i·θ`, then `out += weight(i, z_i) · x_i` — ONE streaming
    /// pass over X instead of matvec + transposed matvec (halves the
    /// memory traffic of the objective gradient, the workers' hot loop).
    pub fn fused_grad_pass<F: FnMut(usize, f64) -> f64>(
        &self,
        theta: &[f64],
        out: &mut [f64],
        weight: F,
    ) {
        self.fused_grad_pass_range(theta, out, 0, self.rows(), weight)
    }

    /// [`fused_grad_pass`](Self::fused_grad_pass) restricted to rows
    /// `[start, end)` — the unit of the intra-worker row-split
    /// (`objectives::GradSplit`): disjoint row ranges accumulate into
    /// private buffers that the caller folds in ascending-range order.
    /// `weight` still receives the ABSOLUTE row index.
    pub fn fused_grad_pass_range<F: FnMut(usize, f64) -> f64>(
        &self,
        theta: &[f64],
        out: &mut [f64],
        start: usize,
        end: usize,
        mut weight: F,
    ) {
        debug_assert!(start <= end && end <= self.rows());
        match self {
            Features::Dense(m) => {
                for i in start..end {
                    let row = m.row(i);
                    let z = crate::linalg::dot(row, theta);
                    let w = weight(i, z);
                    if w != 0.0 {
                        crate::linalg::axpy(w, row, out);
                    }
                }
            }
            Features::Sparse(m) => {
                for i in start..end {
                    let (cols, vals) = m.row(i);
                    let mut z = 0.0;
                    for k in 0..cols.len() {
                        z += vals[k] * theta[cols[k] as usize];
                    }
                    let w = weight(i, z);
                    if w != 0.0 {
                        for k in 0..cols.len() {
                            out[cols[k] as usize] += w * vals[k];
                        }
                    }
                }
            }
        }
    }

    /// sigma_max(X)^2 via power iteration.
    pub fn spectral_sq(&self, iters: usize) -> f64 {
        match self {
            Features::Dense(m) => crate::linalg::power_iter_ata(m, iters),
            Features::Sparse(m) => m.power_iter_ata(iters),
        }
    }

    /// [`spectral_sq`](Self::spectral_sq) with the sparse path's
    /// transposed accumulation fanned over `pool`
    /// ([`CsrMat::power_iter_ata_pooled`] — bitwise identical to the
    /// serial walk for any thread count). The dense path keeps the
    /// serial column-blocked kernel. Must not be called from inside a
    /// scatter job of the same pool.
    pub fn spectral_sq_pooled(&self, iters: usize, pool: &crate::util::pool::Pool) -> f64 {
        match self {
            Features::Dense(m) => crate::linalg::power_iter_ata(m, iters),
            Features::Sparse(m) => m.power_iter_ata_pooled(iters, pool),
        }
    }

    /// Contiguous row blocks greedily filled to an `nnz` budget — the
    /// work-balanced lane unit of the engine's nested fan-out. Sparse
    /// shards cut on true nnz ([`CsrMat::split_rows_by_nnz`]); dense
    /// shards weigh every row at `cols` stored values, so the budget
    /// degenerates to an equal row count.
    pub fn split_rows_by_nnz(&self, budget: usize) -> Vec<(usize, usize)> {
        match self {
            Features::Sparse(m) => m.split_rows_by_nnz(budget),
            Features::Dense(m) => {
                if m.rows == 0 {
                    return Vec::new();
                }
                let per_row = m.cols.max(1);
                let rows_per_block = (budget.max(1) / per_row).max(1);
                (0..m.rows)
                    .step_by(rows_per_block)
                    .map(|s| (s, (s + rows_per_block).min(m.rows)))
                    .collect()
            }
        }
    }

    /// Per-column sums of squared entries (coordinate-wise smoothness).
    pub fn col_sq_sums(&self) -> Vec<f64> {
        match self {
            Features::Dense(m) => {
                let mut out = vec![0.0; m.cols];
                for i in 0..m.rows {
                    let row = m.row(i);
                    for j in 0..m.cols {
                        out[j] += row[j] * row[j];
                    }
                }
                out
            }
            Features::Sparse(m) => m.col_sq_sums(),
        }
    }

    /// Max squared row norm (logistic-loss smoothness bound ingredient).
    pub fn max_row_nrm2_sq(&self) -> f64 {
        match self {
            Features::Dense(m) => {
                (0..m.rows).map(|i| crate::linalg::nrm2_sq(m.row(i))).fold(0.0, f64::max)
            }
            Features::Sparse(m) => (0..m.rows).map(|i| m.row_nrm2_sq(i)).fold(0.0, f64::max),
        }
    }

    /// Contiguous row slice.
    pub fn row_slice(&self, start: usize, end: usize) -> Features {
        match self {
            Features::Dense(m) => {
                let mut out = DenseMat::zeros(end - start, m.cols);
                out.data.copy_from_slice(&m.data[start * m.cols..end * m.cols]);
                Features::Dense(out)
            }
            Features::Sparse(m) => Features::Sparse(m.row_slice(start, end)),
        }
    }
}

/// A labeled dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub x: Features,
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn new(name: &str, x: Features, y: Vec<f64>) -> Dataset {
        assert_eq!(x.rows(), y.len(), "feature/label length mismatch");
        Dataset { name: name.to_string(), x, y }
    }

    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// Standardize columns in place: dense → zero-mean unit-std per column;
    /// sparse → scale-only (unit column RMS) to preserve sparsity, as is
    /// standard for RCV1-style data.
    pub fn standardize(&mut self) {
        match &mut self.x {
            Features::Dense(m) => {
                for j in 0..m.cols {
                    let mut mean = 0.0;
                    for i in 0..m.rows {
                        mean += m.row(i)[j];
                    }
                    mean /= m.rows as f64;
                    let mut var = 0.0;
                    for i in 0..m.rows {
                        let v = m.row(i)[j] - mean;
                        var += v * v;
                    }
                    var /= m.rows as f64;
                    let std = var.sqrt().max(1e-12);
                    for i in 0..m.rows {
                        let v = &mut m.row_mut(i)[j];
                        *v = (*v - mean) / std;
                    }
                }
            }
            Features::Sparse(m) => {
                let n = m.rows as f64;
                let mut scale = m.col_sq_sums();
                for s in scale.iter_mut() {
                    *s = if *s > 0.0 { (n / *s).sqrt() } else { 1.0 };
                }
                for k in 0..m.values.len() {
                    m.values[k] *= scale[m.indices[k] as usize];
                }
            }
        }
    }

    /// Split evenly into `m` contiguous shards (first `n % m` shards get one
    /// extra sample), mirroring the paper's "evenly split among workers".
    pub fn shard(&self, m: usize) -> Vec<Shard> {
        assert!(m >= 1);
        let n = self.n();
        let base = n / m;
        let extra = n % m;
        let mut shards = Vec::with_capacity(m);
        let mut start = 0;
        for w in 0..m {
            let len = base + usize::from(w < extra);
            let end = start + len;
            shards.push(Shard {
                worker: w,
                x: self.x.row_slice(start, end),
                y: self.y[start..end].to_vec(),
            });
            start = end;
        }
        assert_eq!(start, n);
        shards
    }
}

/// One worker's local data shard.
#[derive(Debug, Clone)]
pub struct Shard {
    pub worker: usize,
    pub x: Features,
    pub y: Vec<f64>,
}

impl Shard {
    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dense() -> Dataset {
        let m = DenseMat::from_rows(&[
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
            vec![4.0, 40.0],
            vec![5.0, 50.0],
        ]);
        Dataset::new("tiny", Features::Dense(m), vec![1.0, -1.0, 1.0, -1.0, 1.0])
    }

    #[test]
    fn sharding_covers_all_rows() {
        let d = tiny_dense();
        let shards = d.shard(2);
        assert_eq!(shards[0].n(), 3);
        assert_eq!(shards[1].n(), 2);
        assert_eq!(shards.iter().map(|s| s.n()).sum::<usize>(), d.n());
        // shard 1 rows are rows 3,4 of the original
        if let Features::Dense(m) = &shards[1].x {
            assert_eq!(m.row(0), &[4.0, 40.0]);
        } else {
            panic!("expected dense");
        }
    }

    #[test]
    fn shard_more_workers_than_rows() {
        let d = tiny_dense();
        let shards = d.shard(7);
        assert_eq!(shards.len(), 7);
        assert_eq!(shards.iter().map(|s| s.n()).sum::<usize>(), 5);
        assert_eq!(shards[6].n(), 0);
    }

    #[test]
    fn standardize_dense() {
        let mut d = tiny_dense();
        d.standardize();
        if let Features::Dense(m) = &d.x {
            for j in 0..2 {
                let mean: f64 = (0..5).map(|i| m.row(i)[j]).sum::<f64>() / 5.0;
                let var: f64 = (0..5).map(|i| (m.row(i)[j] - mean).powi(2)).sum::<f64>() / 5.0;
                assert!(mean.abs() < 1e-12);
                assert!((var - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn standardize_sparse_preserves_zeros() {
        let m = CsrMat::from_rows(3, &[vec![(0, 2.0)], vec![(0, 2.0), (2, 4.0)], vec![]]);
        let mut d =
            Dataset::new("sp", Features::Sparse(m), vec![1.0, 1.0, -1.0]);
        d.standardize();
        if let Features::Sparse(m) = &d.x {
            assert_eq!(m.nnz(), 3);
            // col 0: sum sq = 8, n=3 -> scale sqrt(3/8); values 2*sqrt(3/8)
            let expect = 2.0 * (3.0f64 / 8.0).sqrt();
            assert!((m.values[0] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_roundtrip_dense_vs_sparse() {
        let dense = DenseMat::from_rows(&[vec![1.0, 0.0, 2.0], vec![0.0, 3.0, 0.0]]);
        let sparse = CsrMat::from_rows(3, &[vec![(0, 1.0), (2, 2.0)], vec![(1, 3.0)]]);
        let fd = Features::Dense(dense);
        let fs = Features::Sparse(sparse);
        let theta = vec![0.5, -1.0, 2.0];
        let mut o1 = vec![0.0; 2];
        let mut o2 = vec![0.0; 2];
        fd.matvec(&theta, &mut o1);
        fs.matvec(&theta, &mut o2);
        assert_eq!(o1, o2);
        assert_eq!(fd.col_sq_sums(), fs.col_sq_sums());
        assert_eq!(fd.max_row_nrm2_sq(), fs.max_row_nrm2_sq());
    }
}
