//! Experiment configuration: typed structs parsed from CLI options and/or
//! simple `key = value` config files (no TOML dependency in the offline
//! image; the subset we parse is TOML-compatible for flat scalar keys).

use crate::algo::gdsec::Xi;
use crate::objectives::ObjectiveKind;
use crate::util::cli::{Args, CliError};
use std::collections::BTreeMap;

/// Fully-resolved run configuration for the `gdsec train` subcommand.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub algo: String,
    pub objective: ObjectiveKind,
    pub dataset: String,
    pub data_path: Option<String>,
    pub workers: usize,
    pub iters: usize,
    pub seed: u64,
    /// Step size; None = auto (1/L).
    pub alpha: Option<f64>,
    pub beta: f64,
    /// ξ as the paper reports it (we store ξ, thresholds use ξ/M).
    pub xi: f64,
    /// Scale ξ_i = ξ/L^i per coordinate (Fig 7 mode).
    pub xi_per_coord: bool,
    pub lambda: Option<f64>,
    pub batch: usize,
    pub eval_every: usize,
    pub out_csv: Option<String>,
    /// Participation fraction (1.0 = all workers each round).
    pub participation: f64,
    pub scheduler: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algo: "gdsec".to_string(),
            objective: ObjectiveKind::LogReg,
            dataset: "paper-logreg".to_string(),
            data_path: None,
            workers: 5,
            iters: 500,
            seed: 42,
            alpha: None,
            beta: 0.01,
            xi: 400.0,
            xi_per_coord: false,
            lambda: None,
            batch: 0,
            eval_every: 1,
            out_csv: None,
            participation: 1.0,
            scheduler: "all".to_string(),
        }
    }
}

impl RunConfig {
    /// Overlay CLI options onto this config.
    pub fn apply_args(&mut self, args: &Args) -> Result<(), CliError> {
        if let Some(v) = args.get("algo") {
            self.algo = v.to_string();
        }
        if let Some(v) = args.get("objective") {
            self.objective = ObjectiveKind::parse(v)
                .ok_or_else(|| CliError(format!("unknown objective '{v}'")))?;
        }
        if let Some(v) = args.get("dataset") {
            self.dataset = v.to_string();
        }
        if let Some(v) = args.get("data") {
            self.data_path = Some(v.to_string());
        }
        self.workers = args.get_usize("workers", self.workers)?;
        self.iters = args.get_usize("iters", self.iters)?;
        self.seed = args.get_u64("seed", self.seed)?;
        if let Some(v) = args.get("alpha") {
            self.alpha = Some(
                v.parse().map_err(|_| CliError(format!("--alpha: bad number '{v}'")))?,
            );
        }
        self.beta = args.get_f64("beta", self.beta)?;
        self.xi = args.get_f64("xi", self.xi)?;
        if args.flag("xi-per-coord") {
            self.xi_per_coord = true;
        }
        if let Some(v) = args.get("lambda") {
            self.lambda = Some(
                v.parse().map_err(|_| CliError(format!("--lambda: bad number '{v}'")))?,
            );
        }
        self.batch = args.get_usize("batch", self.batch)?;
        self.eval_every = args.get_usize("eval-every", self.eval_every)?;
        if let Some(v) = args.get("out") {
            self.out_csv = Some(v.to_string());
        }
        self.participation = args.get_f64("participation", self.participation)?;
        if let Some(v) = args.get("scheduler") {
            self.scheduler = v.to_string();
        }
        Ok(())
    }

    /// Resolve the ξ thresholds for a problem (uniform or Lipschitz-scaled).
    pub fn resolve_xi(&self, prob: &crate::objectives::Problem) -> Xi {
        if self.xi_per_coord {
            Xi::scaled_by_lipschitz(self.xi, &prob.coord_lipschitz())
        } else {
            Xi::Uniform(self.xi)
        }
    }
}

/// Parse a flat `key = value` config file (comments with `#`).
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut map = BTreeMap::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('[') {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        map.insert(
            k.trim().to_string(),
            v.trim().trim_matches('"').to_string(),
        );
    }
    Ok(map)
}

/// Load a config file and overlay it on defaults, then CLI args on top.
pub fn load(path: Option<&str>, args: &Args) -> Result<RunConfig, String> {
    let mut cfg = RunConfig::default();
    if let Some(p) = path {
        let text = std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))?;
        let kv = parse_kv(&text)?;
        let mut synth: Vec<String> = Vec::new();
        for (k, v) in &kv {
            synth.push(format!("--{k}={v}"));
        }
        let file_args = Args::parse(&synth, false).map_err(|e| e.to_string())?;
        cfg.apply_args(&file_args).map_err(|e| e.to_string())?;
    }
    cfg.apply_args(args).map_err(|e| e.to_string())?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    #[test]
    fn defaults_then_cli_overlay() {
        let args = Args::parse(
            &["--algo=gd".into(), "--iters".into(), "100".into(), "--xi".into(), "80".into()],
            false,
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.algo, "gd");
        assert_eq!(cfg.iters, 100);
        assert_eq!(cfg.xi, 80.0);
        assert_eq!(cfg.workers, 5); // default untouched
    }

    #[test]
    fn kv_file_parses() {
        let kv = parse_kv("# comment\nalgo = \"gdsec\"\niters = 250\n\n[section]\nxi = 9\n")
            .unwrap();
        assert_eq!(kv.get("algo").unwrap(), "gdsec");
        assert_eq!(kv.get("iters").unwrap(), "250");
        assert_eq!(kv.get("xi").unwrap(), "9");
    }

    #[test]
    fn kv_rejects_garbage() {
        assert!(parse_kv("not a kv line\n").is_err());
    }

    #[test]
    fn bad_objective_rejected() {
        let args = Args::parse(&["--objective=banana".into()], false).unwrap();
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_args(&args).is_err());
    }
}
