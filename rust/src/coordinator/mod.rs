//! The distributed GD-SEC runtime: a leader (server) thread coordinating
//! M worker threads over framed byte-counted links — the L3 system
//! contribution of the paper, in deployable shape.
//!
//! Design (the paper's synchronous federated protocol [50]/[51], grown a
//! semi-synchronous quorum mode for the straggler-dominated wireless
//! setting it targets):
//! * the server broadcasts θ^k to every worker each round with an
//!   active-this-round flag from the [`scheduler`], optionally
//!   intersected with a seeded cross-device cohort draw
//!   ([`scheduler::CohortPlan`], `GDSEC_COHORT`) — and with a cohort
//!   active, per-worker server state lives in an evictable
//!   [`StateStore`] (`GDSEC_EVICT_ROUNDS`), so resident ledger memory
//!   is O(active cohort · d), not O(M·d) (the thread-free
//!   [`federated`] harness drives the same store at M = 10k);
//! * active workers reply with either an RLE-coded sparse update or an
//!   explicit `Silence` control frame (payload-bit cost 0, matching the
//!   paper's accounting; the frame header is reported as overhead);
//! * the gather is an event-driven [`round::RoundState`]: replies are
//!   admitted in arrival order and routed by their round id, the model
//!   step fires once a configurable [`round::Quorum`] has reported
//!   (fixed K, or adapted online to the observed delay distribution by
//!   [`scheduler::QuorumController`]), and the cut's late updates are
//!   **folded into a later round's aggregation** — at the delivery age
//!   their excess delay spans, hard-bounded by the
//!   [`CoordConfig::stale_window`] (LAQ-style bounded multi-round
//!   staleness) — instead of being dropped, or, in the strictly
//!   synchronous pre-quorum protocol, silently misattributed to the
//!   wrong round after a timeout;
//! * straggler ordering is **virtual**: a seeded
//!   [`transport::DelayPlan`] ranks replies deterministically, so quorum
//!   trajectories are reproducible in CI (no wall-clock races);
//! * faults are first-class: a seeded [`transport::FaultPlan`]
//!   (`GDSEC_FAULTS`) drops or corrupts uplink frames and
//!   crashes/restarts workers deterministically. The server tracks a
//!   per-worker liveness state machine (Active → Suspect → Dead, with
//!   exponential-backoff probe rounds between strikes) and
//!   re-admits a restarted worker through an explicit `Join` handshake:
//!   the worker's parked stale updates are evicted, its share of the
//!   server's error-correction state variable h is retired, and its
//!   first post-rejoin reply is a fresh full update from zeroed local
//!   state — so a rejoin is a clean enrollment, not a replay of
//!   pre-crash memory. While workers are dead, [`DegradePolicy`] decides
//!   whether aggregation renormalizes to the survivors or freezes the
//!   lost contributions in place;
//! * aggregation is performed in worker-id order (stale folds first, in
//!   (round, worker) order) so the synchronous trajectory
//!   (`quorum = All`) is bit-for-bit equal to the single-threaded
//!   reference ([`crate::algo::gdsec::run`]) — pinned by integration
//!   tests, including under injected delays.

pub mod deploy;
pub mod protocol;
pub mod round;
pub mod scheduler;
pub mod tcp;
pub mod transport;
pub mod worker;

pub mod federated;

use crate::algo::gdsec::GdSecConfig;
use crate::algo::trace::{stale_age_bin, Trace, TraceRow, STALE_AGE_BINS};
use crate::compress::SparseUpdate;
use crate::util::pool::Pool;
use crate::util::shard::{ShardApply, ShardPlan, ShareBook};
use crate::util::state_store::{evict_rounds_from_env, StateStore, DEFAULT_EVICT_ROUNDS};
use protocol::Msg;
use round::{
    delivery_age, evict_worker, in_sorted, split_due, Admit, Quorum, RoundState, StaleUpdate,
};
use scheduler::{CohortPlan, QuorumController, Scheduler};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};
use transport::{duplex, DelayPlan, FaultPlan, Recv, RecvStatus, Transport, TransportKind};
use worker::ProviderFactory;

/// What the server does with a dead worker's standing contribution while
/// it is down (graceful degradation policy, `GDSEC_DEGRADE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DegradePolicy {
    /// Keep the dead worker's share of the state variable h in place and
    /// fold its already-parked stale updates as they come due: the
    /// server keeps descending along the last gradient memory the worker
    /// left behind. Cheapest, bitwise-neutral for live workers, and the
    /// pre-fault-tolerance behavior — the default.
    #[default]
    Freeze,
    /// Evict the dead worker's parked updates, withdraw its share of h,
    /// and rescale each round's aggregate by M/live so the step stays an
    /// (approximately) unbiased mean over the survivors. Changes the
    /// trajectory the moment a worker dies, so tests that pin bitwise
    /// parity must pin `Freeze`.
    Renormalize,
}

impl DegradePolicy {
    /// Honor the `GDSEC_DEGRADE` env override (`freeze` | `renorm`).
    pub fn from_env() -> DegradePolicy {
        match std::env::var("GDSEC_DEGRADE").ok().as_deref() {
            None | Some("") | Some("freeze") => DegradePolicy::Freeze,
            Some("renorm") | Some("renormalize") => DegradePolicy::Renormalize,
            Some(other) => panic!("GDSEC_DEGRADE must be `freeze` or `renorm`, got {other:?}"),
        }
    }
}

/// Parse a `GDSEC_RECV_TIMEOUT_MS` value. Loud on garbage AND on zero —
/// a zero deadline would strike out the entire fleet on the first
/// gather, which is never what a tightened CI timeout meant.
fn parse_recv_timeout_ms(s: &str) -> Duration {
    let ms: u64 = s.trim().parse().unwrap_or_else(|e| {
        panic!("GDSEC_RECV_TIMEOUT_MS must be integer milliseconds, got {s:?} ({e})")
    });
    assert!(ms > 0, "GDSEC_RECV_TIMEOUT_MS must be positive, got {s:?}");
    Duration::from_millis(ms)
}

/// The `GDSEC_RECV_TIMEOUT_MS` override for the per-round receive
/// deadline (30 s when unset).
fn recv_timeout_from_env() -> Duration {
    match std::env::var("GDSEC_RECV_TIMEOUT_MS") {
        Ok(s) => parse_recv_timeout_ms(&s),
        Err(_) => Duration::from_secs(30),
    }
}

/// Coordinator configuration.
pub struct CoordConfig {
    pub gdsec: GdSecConfig,
    pub iters: usize,
    pub scheduler: Scheduler,
    /// Per-round worker receive deadline. Default honors the
    /// `GDSEC_RECV_TIMEOUT_MS` env override (30 s otherwise) — the CI
    /// fault matrix shortens it so a scripted crash costs one brief
    /// timeout instead of a 30-second stall.
    pub recv_timeout: Duration,
    /// Consecutive timeouts before a worker is declared dead.
    pub dead_after: u32,
    /// Optional exact evaluator f(θ) for rounds with partial
    /// participation (otherwise fval is the sum of reported local losses,
    /// which requires full participation; partial rounds record NaN).
    pub evaluator: Option<Arc<dyn Fn(&[f64]) -> f64 + Send + Sync>>,
    /// Problem/trace labels.
    pub problem_name: String,
    pub fstar: f64,
    /// Initial iterate θ^0 (zeros when None) — the e2e transformer run
    /// starts from the compiled jax initialization.
    pub init_theta: Option<Vec<f64>>,
    /// Pool for the server-side column-blocked aggregation + step
    /// (defaults to the process-wide persistent pool). Thread count does
    /// not affect the trajectory: every θ_j sees updates in worker-id
    /// order regardless of which block owns it.
    pub pool: Pool,
    /// Uplink update codec. The default is
    /// [`protocol::WireFormat::Adaptive`]: a 1-byte tag plus the cheaper
    /// of sparse-RLE and dense (weak-censoring rounds — notably the
    /// dense first round — are capped at `8 + 32·d` payload bits; the
    /// tag is accounted). `Sparse` reproduces the paper's format
    /// exactly. Overridable via `GDSEC_WIRE`.
    pub wire: protocol::WireFormat,
    /// Round quorum: how many live scheduled workers must report before
    /// the server steps θ ([`Quorum::All`] = the paper's synchronous
    /// protocol, bitwise identical to the serial reference;
    /// [`Quorum::Adaptive`] picks K online from the observed delay
    /// distribution via [`scheduler::QuorumController`]). Default honors
    /// the `GDSEC_QUORUM` env override.
    pub quorum: Quorum,
    /// Deterministic virtual straggler schedule for quorum cuts (see
    /// [`DelayPlan`]); irrelevant when `quorum` is `All`.
    pub delay: DelayPlan,
    /// Staleness window S (≥ 1): the hard bound on how many rounds late
    /// a transmitted update may fold. A cut-late update is parked until
    /// its [`round::delivery_age`] comes due (1 with S = 1 — the PR 4
    /// behavior); a physically-late delivery older than S is dropped
    /// ([`round::Admit::Expired`]); workers reply to backlog broadcasts
    /// within S instead of discarding them. Default honors
    /// `GDSEC_STALE_WINDOW`.
    pub stale_window: usize,
    /// Deterministic fault injection: seeded link-level frame
    /// drops/corruptions plus scripted worker crash/restart rounds.
    /// Default honors the `GDSEC_FAULTS` env override (see
    /// [`FaultPlan::parse`] for the spec grammar); tests that pin exact
    /// trajectories pin `FaultPlan::default()`.
    pub faults: FaultPlan,
    /// Graceful-degradation policy while workers are dead. Default
    /// honors `GDSEC_DEGRADE`.
    pub degrade: DegradePolicy,
    /// Cross-device cohort sampling: when set, each round's scheduled
    /// set is intersected with a seeded uniform cohort draw
    /// ([`CohortPlan`]) before liveness filtering and the quorum clamp.
    /// `None` = full participation (today's behavior, bit-for-bit).
    /// Default honors the `GDSEC_COHORT` env override; tests that pin
    /// exact trajectories pin `None`.
    pub cohort: Option<CohortPlan>,
    /// Idle horizon (rounds) before a worker's h-share ledger slab is
    /// evicted from the server's [`StateStore`] — resident per-worker
    /// state becomes O(active cohort · d), not O(M·d). `None` defers to
    /// the driver default: [`DEFAULT_EVICT_ROUNDS`] when a cohort is
    /// configured, always-resident otherwise (the pre-store dense
    /// ledger, allocation-for-allocation). Default honors
    /// `GDSEC_EVICT_ROUNDS`.
    pub evict_after: Option<u32>,
    /// Link backend for [`Coordinator::spawn`]: seeded in-memory
    /// channels (`Virtual`, the CI-deterministic default — quorum cuts
    /// rank the virtual [`DelayPlan`]) or real loopback TCP sockets
    /// (`Tcp` — quorum cuts and [`QuorumController::observe`] use
    /// measured wall-clock reply delays). Default honors the
    /// `GDSEC_TRANSPORT` env override; tests that pin exact trajectories
    /// pin `Virtual`.
    pub transport: TransportKind,
}

impl CoordConfig {
    pub fn new(gdsec: GdSecConfig, iters: usize) -> CoordConfig {
        CoordConfig {
            gdsec,
            iters,
            scheduler: Scheduler::All,
            recv_timeout: recv_timeout_from_env(),
            dead_after: 1,
            evaluator: None,
            problem_name: String::new(),
            fstar: 0.0,
            init_theta: None,
            pool: Pool::global().clone(),
            wire: protocol::WireFormat::from_env(),
            quorum: Quorum::from_env(),
            delay: DelayPlan::default(),
            stale_window: crate::algo::engine::stale_window_from_env(),
            faults: FaultPlan::from_env(),
            degrade: DegradePolicy::from_env(),
            cohort: CohortPlan::from_env(),
            evict_after: evict_rounds_from_env(),
            transport: TransportKind::from_env(),
        }
    }

    /// The effective ledger-eviction horizon: the explicit config value,
    /// else [`DEFAULT_EVICT_ROUNDS`] when a cohort is sampled (the
    /// cross-device regime the store exists for), else always-resident.
    pub fn effective_horizon(&self) -> Option<u32> {
        self.evict_after.or(if self.cohort.is_some() { Some(DEFAULT_EVICT_ROUNDS) } else { None })
    }
}

/// Per-round metrics beyond the paper's payload-bit metric.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundMetrics {
    pub round: usize,
    pub payload_bits: u64,
    pub overhead_bits: u64,
    pub downlink_bits: u64,
    pub transmissions: u64,
    pub wall_us: u64,
    /// Stale updates folded into THIS round's aggregation (parked by an
    /// earlier quorum cut and now due, or physically delivered late
    /// within the staleness window).
    pub stale_folded: u64,
    /// Staleness-age histogram of those folds
    /// ([`crate::algo::trace::stale_age_bin`]): ages 1, 2, 3, ≥ 4.
    /// Ages are hard-bounded by [`CoordConfig::stale_window`], so bins
    /// past the window stay 0.
    pub stale_age_hist: [u64; STALE_AGE_BINS],
    /// Updates that arrived older than the staleness window and were
    /// dropped un-folded (their bits were still charged at
    /// transmission).
    pub stale_expired: u64,
    /// Replies beyond this round's quorum cut (their updates are parked
    /// until their delivery age comes due).
    pub late: u64,
    /// The quorum size K this round was cut at (after liveness/cohort
    /// clamping) — with [`Quorum::Adaptive`] this is the controller's
    /// online decision, the per-round signal the wall-clock trace reads.
    pub quorum_k: u64,
    /// Delay of the slowest reply the quorum actually waited for: virtual
    /// [`DelayPlan`] units on the in-memory transport, measured
    /// **microseconds since broadcast** on TCP. The sum over rounds is
    /// the quantity a straggler inflates in synchronous mode and a
    /// quorum cut bounds.
    pub virtual_units: u64,
    /// Workers dead at the end of this round's gather (a level, not a
    /// per-round count — a re-admitted worker leaves it).
    pub dead: u64,
    /// Crash → restart re-admission handshakes completed this round.
    pub rejoined: u64,
    /// Uplink frames the fault-injected link dropped this round (full
    /// frame bits charged as overhead; the sender still paid them).
    pub dropped_frames: u64,
    /// Uplink frames that failed to decode this round (link corruption
    /// or genuinely malformed bytes) — each costs its worker a liveness
    /// strike, exactly like a timeout.
    pub corrupt_frames: u64,
}

/// Result of a coordinated run.
pub struct CoordOutcome {
    pub trace: Trace,
    pub rounds: Vec<RoundMetrics>,
    /// Worker ids still dead when the run ended (a worker that died and
    /// was later re-admitted is not listed).
    pub dead_workers: Vec<usize>,
    /// Total uplink frame bytes (headers + payloads + silence frames).
    pub uplink_frame_bytes: u64,
    pub downlink_frame_bytes: u64,
    /// Ledger slabs evicted from the server's [`StateStore`] (0 in
    /// always-resident mode).
    pub state_evictions: u64,
    /// Evicted ledgers rehydrated bitwise on re-admission to the cohort.
    pub state_restores: u64,
    /// High-water resident bytes of per-worker ledger state (slabs +
    /// parked compact images; see
    /// [`StateStore::resident_bytes`]).
    pub peak_state_bytes: usize,
}

/// Server-side per-worker liveness. `Suspect` carries an
/// exponential-backoff probe schedule: between probes the server does
/// not wait on the worker (its frames queue on the link), bounding the
/// per-round timeout cost a flapping worker can inflict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Life {
    Active,
    /// Struck at least once; waited on again at round `next_probe`.
    Suspect { strikes: u32, backoff: usize, next_probe: usize },
    /// `Join` accepted; flips to Active once the next broadcast (its
    /// fresh enrollment snapshot) is delivered.
    Rejoining,
    Dead,
}

impl Life {
    /// Is this worker waited on in round `k`'s gather?
    fn waited(&self, k: usize) -> bool {
        match self {
            Life::Active | Life::Rejoining => true,
            Life::Suspect { next_probe, .. } => k >= *next_probe,
            Life::Dead => false,
        }
    }

    fn is_dead(&self) -> bool {
        matches!(self, Life::Dead)
    }
}

/// One liveness strike (timeout, dropped frame, or undecodable frame)
/// against `life` during round `k`. Returns true when the worker just
/// died. With `dead_after ≤ 2` the probe schedule degenerates to
/// consecutive rounds, matching the pre-lifecycle strike counter.
fn strike(life: &mut Life, k: usize, dead_after: u32) -> bool {
    match *life {
        Life::Active | Life::Rejoining => {
            if dead_after <= 1 {
                *life = Life::Dead;
                true
            } else {
                *life = Life::Suspect { strikes: 1, backoff: 1, next_probe: k + 1 };
                false
            }
        }
        Life::Suspect { strikes, backoff, .. } => {
            if strikes + 1 >= dead_after {
                *life = Life::Dead;
                true
            } else {
                let backoff = (backoff * 2).min(8);
                *life = Life::Suspect { strikes: strikes + 1, backoff, next_probe: k + backoff };
                false
            }
        }
        Life::Dead => false,
    }
}

/// Remove a just-died worker's standing contribution under
/// [`DegradePolicy::Renormalize`]: evict its parked stale updates and
/// withdraw its h-share ledger from the [`StateStore`] — wherever it
/// lives (resident slab or evicted compact image). Under `Freeze` this
/// is a no-op — the dead worker's parked updates still fold when due
/// and its h-share keeps steering the descent (the pre-fault-tolerance
/// behavior).
fn retire(
    w: usize,
    degrade: DegradePolicy,
    state_variable: bool,
    stale: &mut Vec<StaleUpdate>,
    h: &mut [f64],
    store: &mut StateStore,
) {
    if degrade != DegradePolicy::Renormalize {
        return;
    }
    evict_worker(stale, w);
    if state_variable {
        store.withdraw(w, h);
    }
}

/// EC-safe re-admission on a `Join` frame: drop every parked update the
/// worker left behind, withdraw its h-share ledger (the worker restarts
/// with h_m = e_m = 0, so the server must forget the matching memory —
/// under either degrade policy, and whether the ledger is a resident
/// slab or an evicted compact image), and mark it [`Life::Rejoining`]
/// so the next delivered broadcast becomes its fresh enrollment
/// snapshot. The caller counts the rejoin.
fn readmit(
    w: usize,
    life: &mut [Life],
    state_variable: bool,
    stale: &mut Vec<StaleUpdate>,
    h: &mut [f64],
    store: &mut StateStore,
) {
    life[w] = Life::Rejoining;
    evict_worker(stale, w);
    if state_variable {
        store.withdraw(w, h);
    }
}

/// Book β·(scaled) update into one worker's h-share ledger — the serial
/// reference for the sharded fold's in-pass booking (kept as the test
/// oracle for [`withdraw_share`]; production rounds book through
/// [`ShardPlan::fold`], one pass over each shard's owned slices).
#[cfg(test)]
fn book_one(share: &mut [f64], bs: f64, u: &SparseUpdate) {
    for (&ix, &v) in u.idx.iter().zip(u.val.iter()) {
        share[ix as usize] += bs * v as f64;
    }
}

/// The round id at bytes 2..6 of a frame header (0 for runts) — the
/// fault plan keys drop/corrupt draws on the round the reply answers, so
/// injection stays deterministic under retries and backlogs.
fn frame_round(frame: &[u8]) -> u32 {
    match frame {
        [_, _, a, b, c, d, ..] => u32::from_le_bytes([*a, *b, *c, *d]),
        _ => 0,
    }
}

/// The leader. Owns the server side of every link.
pub struct Coordinator {
    cfg: CoordConfig,
    ends: Vec<Box<dyn Transport>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    d: usize,
    /// When true, quorum cuts and [`QuorumController::observe`] use
    /// measured wall-clock reply delays (µs since broadcast) instead of
    /// the virtual [`DelayPlan`] — set for real transports.
    measured: bool,
    /// Mid-run transport replacements (TCP reconnects): each delivered
    /// `(worker, transport)` swaps the worker's link and re-admits it
    /// through the Join path.
    newcomers: Option<Receiver<(usize, Box<dyn Transport>)>>,
}

impl Coordinator {
    /// Spawn one worker thread per provider factory. Factories run on
    /// their worker's thread so non-`Send` PJRT state never migrates.
    /// `dim` is the model dimension (known from the problem or manifest).
    /// Each worker gets its scripted crash/restart schedule from
    /// [`CoordConfig::faults`]; the link-level drop/corrupt draws stay
    /// server-side.
    ///
    /// [`CoordConfig::transport`] picks the link backend: `Virtual`
    /// wires in-memory duplex channels (the historical behavior,
    /// bit-for-bit); `Tcp` binds an ephemeral loopback listener and has
    /// every worker thread connect a real socket through the same
    /// hello/accept handshake the multi-process binaries use.
    pub fn spawn(cfg: CoordConfig, dim: usize, factories: Vec<ProviderFactory>) -> Coordinator {
        assert!(!factories.is_empty());
        let m = factories.len();
        match cfg.transport {
            TransportKind::Virtual => {
                let mut ends: Vec<Box<dyn Transport>> = Vec::with_capacity(m);
                let mut handles = Vec::with_capacity(m);
                for (w, factory) in factories.into_iter().enumerate() {
                    let (server_end, worker_end) = duplex();
                    let wcfg = cfg.gdsec.clone();
                    let wire = cfg.wire;
                    let sw = cfg.stale_window;
                    let faults = cfg.faults.faults_for(w);
                    handles.push(std::thread::spawn(move || {
                        let _ = worker::worker_loop(
                            w as u32, m, wcfg, factory, worker_end, faults, wire, sw,
                        );
                    }));
                    ends.push(Box::new(server_end));
                }
                Coordinator { cfg, ends, handles, d: dim, measured: false, newcomers: None }
            }
            TransportKind::Tcp => {
                let listener =
                    std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback listener");
                let addr = listener.local_addr().unwrap();
                let mut handles = Vec::with_capacity(m);
                for (w, factory) in factories.into_iter().enumerate() {
                    let wcfg = cfg.gdsec.clone();
                    let wire = cfg.wire;
                    let sw = cfg.stale_window;
                    let faults = cfg.faults.faults_for(w);
                    handles.push(std::thread::spawn(move || {
                        let mut end =
                            tcp::TcpTransport::connect(addr).expect("worker connect to server");
                        assert!(tcp::send_hello(&mut end, w as u32, 0));
                        let _ = worker::worker_loop(
                            w as u32, m, wcfg, factory, end, faults, wire, sw,
                        );
                    }));
                }
                let ends: Vec<Box<dyn Transport>> = tcp::accept_fleet(&listener, m)
                    .into_iter()
                    .map(|t| Box::new(t) as Box<dyn Transport>)
                    .collect();
                let newcomers = Some(tcp::spawn_acceptor(listener, m));
                Coordinator { cfg, ends, handles, d: dim, measured: true, newcomers }
            }
        }
    }

    /// Assemble a coordinator over pre-connected transports — the
    /// multi-process server binary's entry point (workers live in other
    /// processes, so there are no threads to join). `ends[w]` must be
    /// worker w's link, already past the hello handshake. `measured`
    /// selects wall-clock quorum delays; `newcomers` (if any) delivers
    /// replacement links for reconnecting workers.
    pub fn from_transports(
        cfg: CoordConfig,
        dim: usize,
        ends: Vec<Box<dyn Transport>>,
        newcomers: Option<Receiver<(usize, Box<dyn Transport>)>>,
        measured: bool,
    ) -> Coordinator {
        assert!(!ends.is_empty());
        Coordinator { cfg, ends, handles: Vec::new(), d: dim, measured, newcomers }
    }

    /// Run the protocol to completion and join the workers. With
    /// `quorum = All` this is the paper's synchronous loop, bit-for-bit;
    /// with a smaller quorum the round state machine applies the first K
    /// virtual arrivals and folds the rest into the next round.
    pub fn run(mut self) -> CoordOutcome {
        let d = self.d;
        let m = self.ends.len();
        let iters = self.cfg.iters;
        let sv = self.cfg.gdsec.state_variable;
        let degrade = self.cfg.degrade;
        let mut trace = Trace::new("GD-SEC(dist)", &self.cfg.problem_name, self.cfg.fstar);
        let mut rounds: Vec<RoundMetrics> = Vec::with_capacity(iters);
        let mut life = vec![Life::Active; m];
        // Per-worker attribution ledger for the server's state variable:
        // the store's slab for worker w records exactly the β-scaled
        // mass its folded updates added to h, so death (Renormalize)
        // and re-admission can withdraw that worker's memory without
        // touching anyone else's. With no cohort/eviction configured
        // this is the dense always-resident ledger (bit-for-bit and
        // allocation-for-allocation the historical `Vec<Vec<f64>>`);
        // under an eviction horizon only recently-active workers' slabs
        // stay resident — O(active cohort · d), not O(M·d).
        let horizon = self.cfg.effective_horizon();
        let mut store =
            if sv { StateStore::new(d, m, horizon) } else { StateStore::resident(0, 0) };
        let mut cohort = self.cfg.cohort.take();

        let mut theta = self.cfg.init_theta.take().unwrap_or_else(|| vec![0.0; d]);
        assert_eq!(theta.len(), d, "init_theta dimension mismatch");
        let mut h = vec![0.0; d];
        let mut agg = vec![0.0; d];
        let mut sched = std::mem::replace(&mut self.cfg.scheduler, Scheduler::All);
        let window = self.cfg.stale_window.max(1);
        // Online quorum decisions: fixed policies pass through k_of,
        // Adaptive tracks the per-worker delay EMA (fed after every
        // gather) and cuts at the target tail quantile.
        let mut ctrl = QuorumController::new(self.cfg.quorum, m);

        // Transmitted updates the server holds past their round — parked
        // by a quorum cut or physically delivered late — folded into the
        // apply of their due round `round + age` in (round, worker)
        // order, where the delivery age models how many cut-lengths the
        // reply's excess delay spans, hard-bounded by the staleness
        // window S. Error correction keeps this principled: the worker
        // already moved its h_m/e_m when it transmitted, so the server
        // folding `age` rounds late is the same Eq. 6 step, delayed
        // (LAQ-style bounded staleness). An update still parked when the
        // loop ends is an in-flight transmission at shutdown: dropped
        // like any frame in the pipe, its bits already charged — the
        // trace's last row reflects the θ the server actually served.
        let mut stale: Vec<StaleUpdate> = Vec::new();
        // Round-persistent scratch: the due split, the quorum cut's
        // parked updates, and the coordinate-shard plan all reuse their
        // capacity across rounds (the zero-alloc steady-state pin covers
        // this loop).
        let mut due: Vec<StaleUpdate> = Vec::new();
        let mut parked: Vec<StaleUpdate> = Vec::new();
        let mut plan = ShardPlan::new();
        // Receive scratch: the gather loop's frames land here via the
        // transport's `recv_into` seam, so the virtual steady state
        // allocates nothing per frame (covered by the zero-alloc pin).
        let mut frame_buf: Vec<u8> = Vec::new();
        // Measured wall-clock reply delays (µs since this round's
        // broadcast), the real-transport replacement for the virtual
        // DelayPlan in quorum cuts and controller observations.
        let mut measured_us = vec![0u64; m];
        let measured = self.measured;

        let (mut cum_bits, mut cum_tx, mut cum_entries, mut cum_stale) = (0u64, 0u64, 0u64, 0u64);
        let mut cum_stale_ages = [0u64; STALE_AGE_BINS];
        let (mut cum_rejoined, mut cum_dropped, mut cum_corrupt) = (0u64, 0u64, 0u64);
        // One extra eval round so the final iterate's objective is recorded
        // (round k's reports evaluate θ^k, the iterate after k−1 updates).
        for k in 1..=iters + 1 {
            let t0 = Instant::now();
            let eval_only = k == iters + 1;
            let mut active =
                if eval_only { (0..m).collect::<Vec<_>>() } else { sched.active(k, m) };
            // Cohort sampling composes with (not replaces) the
            // scheduler: the round's participants are the scheduled
            // workers that also drew into this round's seeded cohort.
            // The final eval round stays full so the last recorded
            // iterate is everyone's objective.
            if !eval_only {
                if let Some(cp) = &mut cohort {
                    cp.sample(k, m);
                    active.retain(|&w| cp.contains(w));
                }
            }
            let mut metrics = RoundMetrics { round: k, ..Default::default() };

            // Reconnected workers first (TCP only): a worker process
            // that lost its socket reconnects through the acceptor, and
            // its hello — a `Join` frame — IS the re-admission
            // handshake. Swap in the fresh link and enroll it exactly
            // like a channel-delivered Join.
            if let Some(rx) = &self.newcomers {
                while let Ok((w, end)) = rx.try_recv() {
                    if w < m {
                        self.ends[w] = end;
                        readmit(w, &mut life, sv, &mut stale, &mut h, &mut store);
                        metrics.rejoined += 1;
                    }
                }
            }

            // Drain dead workers' links. A dead worker may still be a
            // live process replying to broadcasts; those frames are
            // discarded (full frame bits as overhead — the sender paid
            // them) EXCEPT a `Join`, which re-admits the worker. No
            // fault injection here: the re-admission control path must
            // not be flaky, or a lossy link could wedge a restarted
            // worker out of the fleet forever.
            for w in 0..m {
                if life[w] != Life::Dead {
                    continue;
                }
                while let Some(Recv::Frame(frame)) = self.ends[w].try_recv() {
                    metrics.overhead_bits += frame.len() as u64 * 8;
                    if life[w] == Life::Dead
                        && matches!(protocol::decode(&frame, d as u32), Ok(Msg::Join { .. }))
                    {
                        readmit(w, &mut life, sv, &mut stale, &mut h, &mut store);
                        metrics.rejoined += 1;
                    }
                }
            }

            let full_round = active.len() == m && life.iter().all(|l| *l == Life::Active);
            // Quorum size is relative to the workers actually expected to
            // report: scheduled this round AND waited on by the liveness
            // machine (Active, Rejoining, or a Suspect whose probe round
            // has come). Decided from the PRE-round delay estimates (the
            // controller is fed after the gather below) — the same
            // decide-K → cut → observe logic as the engine-side
            // QuorumSim. (The in-flight MODELS differ: here a cut-late
            // worker keeps computing and replying while its parked
            // update is in transit — the links pipeline — so it is
            // observed every round; the sim's workers sit out their
            // delivery age. Trajectories are not cross-pinned between
            // the two drivers except at Quorum::All.)
            let expected_ids: Vec<usize> =
                active.iter().copied().filter(|&w| life[w].waited(k)).collect();
            let k_quorum = ctrl.k_for(&expected_ids);

            // Broadcast θ^k with per-worker active flags — to EVERY
            // worker, dead ones included: a crashed worker's process
            // drains broadcasts while down, and the first broadcast
            // delivered after its `Join` is its fresh enrollment
            // snapshot (it replies with a full update from zeroed local
            // state).
            for (w, end) in self.ends.iter_mut().enumerate() {
                let msg = Msg::Broadcast {
                    round: k as u32,
                    theta: theta.clone(),
                    active: in_sorted(&active, w),
                };
                let frame = protocol::encode(&msg, d as u32);
                metrics.downlink_bits += frame.len() as u64 * 8;
                let delivered = end.send(frame);
                if !delivered && life[w] != Life::Dead {
                    life[w] = Life::Dead;
                    retire(w, degrade, sv, &mut stale, &mut h, &mut store);
                } else if delivered && life[w] == Life::Rejoining {
                    life[w] = Life::Active;
                }
            }
            // Wall-clock reference for measured reply delays: this
            // round's broadcast completion.
            let bcast_done = Instant::now();
            measured_us.fill(0);

            // Event-driven gather: admit frames in arrival order until
            // every waited-on worker resolves (fresh reply, strike-out,
            // or death). Round-id routing sends an older round's update
            // to the stale pool instead of misreading it as this round's
            // reply — and keeps waiting for that worker's fresh frame
            // within the same deadline. Fault injection happens here, at
            // the receive edge: a dropped frame is charged and never
            // seen (a strike, like a timeout); a corrupted frame is
            // decoded from flipped bytes and strikes when it fails.
            let mut rs = RoundState::new(k as u32, m, window as u32);
            let mut arrived_stale_entries = 0u64;
            for &w in &expected_ids {
                if life[w].is_dead() {
                    continue; // died during this round's broadcast
                }
                let deadline = Instant::now() + self.cfg.recv_timeout;
                loop {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    match self.ends[w].recv_into(&mut frame_buf, remaining) {
                        RecvStatus::Frame => {
                            let frame = &mut frame_buf;
                            let frame_bits = frame.len() as u64 * 8;
                            let fround = frame_round(frame);
                            if self.cfg.faults.drops(w, fround) {
                                metrics.dropped_frames += 1;
                                metrics.overhead_bits += frame_bits;
                                if strike(&mut life[w], k, self.cfg.dead_after) {
                                    retire(w, degrade, sv, &mut stale, &mut h, &mut store);
                                }
                                break;
                            }
                            if self.cfg.faults.corrupts(w, fround) {
                                frame[0] ^= 0xFF;
                            }
                            match protocol::decode(frame, d as u32) {
                                Ok(msg @ (Msg::Update { .. } | Msg::Silence { .. })) => {
                                    // Codec-exact for either wire format
                                    // (the adaptive tag byte is real
                                    // payload; silence payloads cost 0).
                                    // Everything that is not payload —
                                    // header + reported loss — is
                                    // overhead, so payload + overhead
                                    // equals the frame exactly.
                                    let payload = protocol::update_payload_bits(&frame);
                                    metrics.payload_bits += payload;
                                    metrics.overhead_bits += frame_bits - payload;
                                    if matches!(msg, Msg::Update { .. }) {
                                        metrics.transmissions += 1;
                                    }
                                    let was_stale_round = match &msg {
                                        Msg::Update { round, .. }
                                        | Msg::Silence { round, .. } => (*round as usize) < k,
                                        _ => unreachable!(),
                                    };
                                    match rs.admit(w, msg) {
                                        Admit::Fresh => {
                                            // Only a FRESH reply restores
                                            // full liveness: a worker
                                            // forever delivering last
                                            // round's update one round
                                            // late must still accrue
                                            // strikes, or `dead_after` is
                                            // defeated.
                                            life[w] = Life::Active;
                                            if measured {
                                                measured_us[w] = bcast_done
                                                    .elapsed()
                                                    .as_micros()
                                                    as u64;
                                            }
                                            break;
                                        }
                                        Admit::Stale(su) => {
                                            arrived_stale_entries += su.update.nnz() as u64;
                                            stale.push(su);
                                            continue; // fresh reply still due
                                        }
                                        Admit::Expired(su) => {
                                            // Older than the staleness
                                            // window: bits charged,
                                            // contribution dropped — the
                                            // window is a hard bound.
                                            arrived_stale_entries += su.update.nnz() as u64;
                                            metrics.stale_expired += 1;
                                            continue; // fresh reply still due
                                        }
                                        Admit::Ignored if was_stale_round => continue,
                                        Admit::Ignored => break,
                                    }
                                }
                                Ok(Msg::Join { .. }) => {
                                    // A crash + restart that fit inside
                                    // the strike window: the server never
                                    // declared the worker dead, but the
                                    // worker's state is gone. Re-admit
                                    // from any state; no strike — a Join
                                    // proves liveness.
                                    metrics.overhead_bits += frame_bits;
                                    readmit(w, &mut life, sv, &mut stale, &mut h, &mut store);
                                    metrics.rejoined += 1;
                                    break;
                                }
                                Ok(_) => {
                                    // Protocol-valid but senseless here
                                    // (e.g. an echoed broadcast): treat
                                    // as silent, no strike.
                                    metrics.overhead_bits += frame_bits;
                                    break;
                                }
                                Err(_) => {
                                    // Corrupted on the link or genuinely
                                    // malformed: the bytes were paid for
                                    // but carry nothing, and the worker
                                    // is charged a strike — an endless
                                    // babbler must strike out just like
                                    // an endless timeout.
                                    metrics.corrupt_frames += 1;
                                    metrics.overhead_bits += frame_bits;
                                    if strike(&mut life[w], k, self.cfg.dead_after) {
                                        retire(w, degrade, sv, &mut stale, &mut h, &mut store);
                                    }
                                    break;
                                }
                            }
                        }
                        RecvStatus::Timeout => {
                            if strike(&mut life[w], k, self.cfg.dead_after) {
                                retire(w, degrade, sv, &mut stale, &mut h, &mut store);
                            }
                            break;
                        }
                        RecvStatus::Disconnected => {
                            life[w] = Life::Dead;
                            retire(w, degrade, sv, &mut stale, &mut h, &mut store);
                            break;
                        }
                    }
                }
            }
            // Feed the observed arrivals to the adaptive controller
            // (every replier, cut-late ones included — their delay is
            // the straggler signal the next round's K needs): measured
            // wall-clock µs on a real transport, seeded virtual units
            // otherwise (CI-deterministic).
            for &w in &expected_ids {
                if rs.replied(w) {
                    let units =
                        if measured { measured_us[w] } else { self.cfg.delay.delay(w, k) };
                    ctrl.observe(w, units);
                }
            }
            metrics.quorum_k = k_quorum as u64;
            metrics.dead = life.iter().filter(|l| l.is_dead()).count() as u64;

            // Record the objective of θ^k (the pre-update iterate), paired
            // with the bits accumulated through round k−1 — exactly the
            // serial reference's row k−1.
            let fval = if full_round && rs.local_f().iter().all(|f| f.is_some()) {
                rs.local_f().iter().map(|f| f.unwrap()).sum()
            } else if let Some(eval) = &self.cfg.evaluator {
                eval(&theta)
            } else {
                f64::NAN
            };
            trace.push(TraceRow {
                iter: k - 1,
                fval,
                bits: cum_bits,
                transmissions: cum_tx,
                entries: cum_entries,
                stale: cum_stale,
                stale_ages: cum_stale_ages,
                dead: metrics.dead,
                rejoined: cum_rejoined,
                dropped_frames: cum_dropped,
                corrupt_frames: cum_corrupt,
            });

            if eval_only {
                metrics.wall_us = t0.elapsed().as_micros() as u64;
                rounds.push(metrics);
                break;
            }

            // Wire accounting happens at transmission time — late updates
            // still paid their bits this round even though they fold next
            // round.
            for u in rs.updates().iter().flatten() {
                cum_entries += u.nnz() as u64;
            }
            cum_entries += arrived_stale_entries;
            cum_bits += metrics.payload_bits;
            cum_tx += metrics.transmissions;
            cum_rejoined += metrics.rejoined;
            cum_dropped += metrics.dropped_frames;
            cum_corrupt += metrics.corrupt_frames;

            // Cut the round at the quorum (virtual arrival order — seeded
            // delays, then worker id — so the trajectory is deterministic
            // for any thread schedule) and park the late updates with the
            // delivery age their excess delay spans (due at round
            // `k + age`, hard-bounded by the staleness window).
            let cut = if measured {
                rs.cut_by(k_quorum, |w| measured_us[w])
            } else {
                rs.cut(k_quorum, &self.cfg.delay)
            };
            metrics.virtual_units = cut.units;
            metrics.late = cut.late.len() as u64;
            for &w in &cut.late {
                if let Some(u) = rs.take_update(w) {
                    let delay =
                        if measured { measured_us[w] } else { self.cfg.delay.delay(w, k) };
                    let age = delivery_age(delay, cut.units, window);
                    parked.push(StaleUpdate { round: k as u32, worker: w, age, update: u });
                }
            }

            // Aggregate and step, fanned over the coordinate shards: the
            // pool's DUE stale entries (round + age ≤ k) fold first in
            // (round, worker) order, then this round's on-time updates
            // in worker-id order — every element sees the same fixed
            // sequence at any shard and thread count, so with
            // `quorum = All` (stale always empty) the bits equal the
            // serial loop's exactly (pinned by the integration tests).
            // Not-yet-due entries stay in the pool for a later round
            // (with S = 1 everything is due immediately — the PR 4
            // behavior).
            split_due(&mut stale, k, &mut due);
            debug_assert!(due.iter().all(|s| s.age as usize <= window));
            metrics.stale_folded = due.len() as u64;
            for s in &due {
                metrics.stale_age_hist[stale_age_bin(s.age)] += 1;
                cum_stale_ages[stale_age_bin(s.age)] += 1;
            }
            // Graceful degradation: under Renormalize the fold rescales
            // by M/live so the step approximates the survivors' mean;
            // under Freeze the scale is exactly 1.0 and the arithmetic
            // below is bit-identical to the fault-free path.
            let live = life.iter().filter(|l| !l.is_dead()).count();
            let fold_scale = if degrade == DegradePolicy::Renormalize {
                m as f64 / live.max(1) as f64
            } else {
                1.0
            };
            let bs = self.cfg.gdsec.beta * fold_scale;
            // Ledger residency for this fold: reclaim slabs idle past
            // the horizon, then admit every staging worker (rehydrating
            // evicted ledgers bitwise) — both no-ops in always-resident
            // mode, so the full-participation path is untouched.
            if sv {
                store.evict_idle(k as u32);
                for s in &due {
                    store.stage(s.worker, k as u32, &s.update.idx);
                }
                for (w, u) in rs.updates().iter().enumerate() {
                    if let Some(u) = u {
                        store.stage(w, k as u32, &u.idx);
                    }
                }
            }
            let (slabs, slot_of) = store.book_view();
            plan.fold(
                &self.cfg.pool,
                due.iter()
                    .map(|s| (s.worker, &s.update))
                    .chain(
                        rs.updates()
                            .iter()
                            .enumerate()
                            .filter_map(|(w, u)| u.as_ref().map(|u| (w, u))),
                    ),
                ShardApply {
                    theta: &mut theta,
                    h: &mut h,
                    agg: &mut agg,
                    theta_prev: None,
                    alpha: self.cfg.gdsec.alpha,
                    beta: self.cfg.gdsec.beta,
                    state_variable: sv,
                    fold_scale,
                    staged_agg: false,
                    shares: sv.then_some(ShareBook { slabs, slot_of, scale: bs }),
                },
            );
            cum_stale += due.len() as u64;
            stale.append(&mut parked);
            metrics.wall_us = t0.elapsed().as_micros() as u64;
            rounds.push(metrics);
        }

        // Shutdown and join.
        for end in self.ends.iter_mut() {
            let _ = end.send(protocol::encode(&Msg::Shutdown, d as u32));
        }
        let mut uplink_bytes = 0u64;
        let mut downlink_bytes = 0u64;
        for end in &self.ends {
            uplink_bytes += end.rcvd_stats().bytes();
            downlink_bytes += end.sent_stats().bytes();
        }
        for hnd in self.handles.drain(..) {
            let _ = hnd.join();
        }
        CoordOutcome {
            trace,
            rounds,
            dead_workers: life
                .iter()
                .enumerate()
                .filter_map(|(w, l)| l.is_dead().then_some(w))
                .collect(),
            uplink_frame_bytes: uplink_bytes,
            downlink_frame_bytes: downlink_bytes,
            state_evictions: store.evictions(),
            state_restores: store.restores(),
            peak_state_bytes: store.peak_resident_bytes(),
        }
    }
}

/// Shared setup for the native-provider convenience runners: fstar
/// estimate, one [`worker::NativeProvider`] factory per local shard, and
/// a [`CoordConfig`] wired with the problem's exact evaluator.
fn native_setup(
    prob: &crate::objectives::Problem,
    gdsec: GdSecConfig,
    iters: usize,
    sched: Scheduler,
) -> (CoordConfig, Vec<ProviderFactory>) {
    let fstar = prob.estimate_fstar(crate::algo::gdsec::fstar_iters(iters));
    let factories: Vec<ProviderFactory> = prob
        .locals
        .iter()
        .map(|l| {
            let local = l.clone();
            Box::new(move || {
                Box::new(worker::NativeProvider::new(local)) as Box<dyn worker::GradProvider>
            }) as ProviderFactory
        })
        .collect();
    let prob2 = prob.clone();
    let mut cfg = CoordConfig::new(gdsec, iters);
    cfg.scheduler = sched;
    cfg.problem_name = prob.name.clone();
    cfg.fstar = fstar;
    cfg.evaluator = Some(Arc::new(move |theta: &[f64]| prob2.value(theta)));
    (cfg, factories)
}

/// Convenience: run distributed GD-SEC over a [`crate::objectives::Problem`]
/// with native gradient providers. Honors the `GDSEC_QUORUM`,
/// `GDSEC_FAULTS`, `GDSEC_DEGRADE`, `GDSEC_COHORT`, and
/// `GDSEC_EVICT_ROUNDS` env overrides (the CI matrix runs the
/// integration suite under each); use [`run_native_opts`] to pin them.
pub fn run_native(
    prob: &crate::objectives::Problem,
    gdsec: GdSecConfig,
    iters: usize,
    sched: Scheduler,
) -> CoordOutcome {
    let (cfg, factories) = native_setup(prob, gdsec, iters, sched);
    Coordinator::spawn(cfg, prob.d, factories).run()
}

/// [`run_native`] with an explicit quorum policy and virtual delay
/// schedule, and the fault plan, degradation policy, cohort sampler,
/// ledger-eviction horizon, and transport (virtual) pinned (parity
/// tests pin `Quorum::All`; straggler tests inject deterministic
/// [`DelayPlan`]s — either way the trajectory must not depend on the CI
/// fault/cohort/transport environment).
pub fn run_native_opts(
    prob: &crate::objectives::Problem,
    gdsec: GdSecConfig,
    iters: usize,
    sched: Scheduler,
    quorum: Quorum,
    delay: DelayPlan,
) -> CoordOutcome {
    let (mut cfg, factories) = native_setup(prob, gdsec, iters, sched);
    cfg.quorum = quorum;
    cfg.delay = delay;
    cfg.faults = FaultPlan::default();
    cfg.degrade = DegradePolicy::Freeze;
    cfg.cohort = None;
    cfg.evict_after = None;
    cfg.transport = TransportKind::Virtual;
    Coordinator::spawn(cfg, prob.d, factories).run()
}

pub use worker::NativeProvider;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::objectives::Problem;

    #[test]
    fn stale_only_worker_accrues_strikes_and_dies() {
        // Regression for the strike-reset bug: clearing
        // `timeout_strikes` on ANY delivered frame let a worker that
        // forever re-sends the previous round's update one round late
        // evade `dead_after` indefinitely (each round: stale frame ⇒
        // strikes reset ⇒ timeout ⇒ strikes = 1, forever). Strikes must
        // only clear on a FRESH reply, so this worker dies after
        // `dead_after` rounds of stale-only deliveries.
        let prob = Problem::linear(synthetic::dna_like(3, 30), 1, 0.1);
        let d = prob.d;
        let (server_end, mut worker_end) = duplex();
        // Scripted worker: fresh at round 1, then forever one round late.
        let handle = std::thread::spawn(move || {
            let mut up = SparseUpdate::empty(d);
            up.idx.push(0);
            up.val.push(0.001);
            loop {
                let frame = match worker_end.recv() {
                    Recv::Frame(f) => f,
                    _ => return,
                };
                match protocol::decode(&frame, d as u32) {
                    Ok(Msg::Shutdown) => return,
                    Ok(Msg::Broadcast { round, .. }) => {
                        let tag = if round <= 1 { round } else { round - 1 };
                        let reply = Msg::Update {
                            round: tag,
                            worker: 0,
                            update: up.clone(),
                            local_f: 0.0,
                        };
                        if !worker_end.send(protocol::encode(&reply, d as u32)) {
                            return;
                        }
                    }
                    _ => {}
                }
            }
        });
        let prob2 = prob.clone();
        let mut cfg = CoordConfig::new(GdSecConfig::default(), 6);
        cfg.recv_timeout = Duration::from_millis(50);
        cfg.dead_after = 2;
        cfg.quorum = Quorum::All;
        cfg.stale_window = 4;
        cfg.faults = FaultPlan::default();
        cfg.degrade = DegradePolicy::Freeze;
        cfg.cohort = None;
        cfg.evict_after = None;
        cfg.problem_name = prob.name.clone();
        cfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
        let coord = Coordinator {
            cfg,
            ends: vec![Box::new(server_end)],
            handles: vec![handle],
            d,
            measured: false,
            newcomers: None,
        };
        let out = coord.run();
        assert_eq!(out.dead_workers, vec![0], "stale-only worker evaded dead_after");
        // Its stale deliveries were still folded (bits + contribution
        // accounted) before death — staleness tolerance is not the same
        // thing as liveness.
        assert!(out.trace.total_stale() >= 1);
    }

    #[test]
    fn strike_schedule_matches_legacy_for_small_dead_after() {
        // dead_after = 1: first strike kills.
        let mut l = Life::Active;
        assert!(strike(&mut l, 5, 1));
        assert_eq!(l, Life::Dead);
        // dead_after = 2: Suspect probes the very next round, dies on the
        // second consecutive strike — the legacy counter's timing.
        let mut l = Life::Active;
        assert!(!strike(&mut l, 5, 2));
        assert!(l.waited(6));
        assert!(strike(&mut l, 6, 2));
        assert_eq!(l, Life::Dead);
        // dead_after = 4: backoff doubles (1, 2, 4 rounds between probes)
        // and the worker is not waited on between probes.
        let mut l = Life::Active;
        assert!(!strike(&mut l, 1, 4));
        assert!(l.waited(2));
        assert!(!strike(&mut l, 2, 4));
        assert!(!l.waited(3));
        assert!(l.waited(4));
        assert!(!strike(&mut l, 4, 4));
        assert!(!l.waited(7));
        assert!(l.waited(8));
        assert!(strike(&mut l, 8, 4));
        assert!(l.is_dead());
        // Dead workers never strike again and are never waited on.
        assert!(!strike(&mut l, 9, 4));
        assert!(!l.waited(100));
    }

    #[test]
    fn withdraw_share_is_exact_and_isolated() {
        let mut h = vec![0.0f64; 4];
        let mut store = StateStore::resident(4, 2);
        let mut u0 = SparseUpdate::empty(4);
        u0.idx.extend_from_slice(&[0, 2]);
        u0.val.extend_from_slice(&[1.5, -0.25]);
        let mut u1 = SparseUpdate::empty(4);
        u1.idx.extend_from_slice(&[2, 3]);
        u1.val.extend_from_slice(&[0.125, 2.0]);
        // Book both workers the way the fold does (h += β·u, per worker).
        let beta = 0.5;
        {
            let (slabs, slot) = store.book_view();
            assert!(slot.is_none());
            book_one(&mut slabs[0], beta, &u0);
            book_one(&mut slabs[1], beta, &u1);
            for w in 0..2 {
                for j in 0..4 {
                    h[j] += slabs[w][j];
                }
            }
        }
        let mut h1_expected = vec![0.0f64; 4];
        store.ledger_dense(1, &mut h1_expected);
        store.withdraw(0, &mut h);
        // Worker 0's memory is gone exactly; worker 1's is intact.
        let mut l0 = vec![1.0f64; 4];
        let mut l1 = vec![0.0f64; 4];
        store.ledger_dense(0, &mut l0);
        store.ledger_dense(1, &mut l1);
        for j in 0..4 {
            assert_eq!(h[j].to_bits(), h1_expected[j].to_bits());
            assert_eq!(l0[j].to_bits(), 0.0f64.to_bits());
            assert_eq!(l1[j].to_bits(), h1_expected[j].to_bits());
        }
        // Withdrawing with an empty store (state_variable off) is a
        // no-op, not a panic.
        let mut none = StateStore::resident(0, 0);
        none.withdraw(0, &mut h);
    }

    #[test]
    fn frame_round_reads_header() {
        let frame = protocol::encode(&Msg::Join { round: 7, worker: 3 }, 4);
        assert_eq!(frame_round(&frame), 7);
        assert_eq!(frame_round(&[0xA5, 2]), 0); // runt
    }

    #[test]
    fn recv_timeout_parses_and_rejects_garbage_and_zero() {
        assert_eq!(parse_recv_timeout_ms("250"), Duration::from_millis(250));
        assert_eq!(parse_recv_timeout_ms(" 5000 "), Duration::from_secs(5));
        for bad in ["", "abc", "-3", "1.5"] {
            let r = std::panic::catch_unwind(|| parse_recv_timeout_ms(bad));
            assert!(r.is_err(), "{bad:?} must panic");
        }
    }

    #[test]
    #[should_panic(expected = "must be positive, got \"0\"")]
    fn recv_timeout_zero_panics_with_value() {
        parse_recv_timeout_ms("0");
    }

    #[test]
    fn degrade_policy_parses() {
        assert_eq!(DegradePolicy::default(), DegradePolicy::Freeze);
        // from_env reads the ambient var; only exercise the default path
        // here (the parse arms are covered by construction above —
        // setting env vars in-process races parallel tests).
        if std::env::var("GDSEC_DEGRADE").is_err() {
            assert_eq!(DegradePolicy::from_env(), DegradePolicy::Freeze);
        }
    }
}
