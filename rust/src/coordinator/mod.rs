//! The distributed GD-SEC runtime: a leader (server) thread coordinating
//! M worker threads over framed byte-counted links — the L3 system
//! contribution of the paper, in deployable shape.
//!
//! Design (the paper's synchronous federated protocol [50]/[51], grown a
//! semi-synchronous quorum mode for the straggler-dominated wireless
//! setting it targets):
//! * the server broadcasts θ^k to every worker each round with an
//!   active-this-round flag from the [`scheduler`];
//! * active workers reply with either an RLE-coded sparse update or an
//!   explicit `Silence` control frame (payload-bit cost 0, matching the
//!   paper's accounting; the frame header is reported as overhead);
//! * the gather is an event-driven [`round::RoundState`]: replies are
//!   admitted in arrival order and routed by their round id, the model
//!   step fires once a configurable [`round::Quorum`] has reported
//!   (fixed K, or adapted online to the observed delay distribution by
//!   [`scheduler::QuorumController`]), and the cut's late updates are
//!   **folded into a later round's aggregation** — at the delivery age
//!   their excess delay spans, hard-bounded by the
//!   [`CoordConfig::stale_window`] (LAQ-style bounded multi-round
//!   staleness) — instead of being dropped, or, in the strictly
//!   synchronous pre-quorum protocol, silently misattributed to the
//!   wrong round after a timeout;
//! * straggler ordering is **virtual**: a seeded
//!   [`transport::DelayPlan`] ranks replies deterministically, so quorum
//!   trajectories are reproducible in CI (no wall-clock races);
//! * crashes are handled by a receive timeout: a worker that misses a
//!   deadline is treated as silent and marked dead after `dead_after`
//!   consecutive timeouts (failure injection in tests);
//! * aggregation is performed in worker-id order (stale folds first, in
//!   (round, worker) order) so the synchronous trajectory
//!   (`quorum = All`) is bit-for-bit equal to the single-threaded
//!   reference ([`crate::algo::gdsec::run`]) — pinned by integration
//!   tests, including under injected delays.

pub mod protocol;
pub mod round;
pub mod scheduler;
pub mod transport;
pub mod worker;

use crate::algo::gdsec::GdSecConfig;
use crate::algo::trace::{stale_age_bin, Trace, TraceRow, STALE_AGE_BINS};
use crate::compress::SparseUpdate;
use crate::linalg;
use crate::util::pool::Pool;
use protocol::Msg;
use round::{delivery_age, Admit, Quorum, RoundState, StaleUpdate};
use scheduler::{QuorumController, Scheduler};
use std::sync::Arc;
use std::time::{Duration, Instant};
use transport::{duplex, DelayPlan, Recv, ServerEnd};
use worker::{FailurePlan, ProviderFactory};

/// Coordinator configuration.
pub struct CoordConfig {
    pub gdsec: GdSecConfig,
    pub iters: usize,
    pub scheduler: Scheduler,
    /// Per-round worker receive deadline.
    pub recv_timeout: Duration,
    /// Consecutive timeouts before a worker is declared dead.
    pub dead_after: u32,
    /// Optional exact evaluator f(θ) for rounds with partial
    /// participation (otherwise fval is the sum of reported local losses,
    /// which requires full participation; partial rounds record NaN).
    pub evaluator: Option<Arc<dyn Fn(&[f64]) -> f64 + Send + Sync>>,
    /// Problem/trace labels.
    pub problem_name: String,
    pub fstar: f64,
    /// Initial iterate θ^0 (zeros when None) — the e2e transformer run
    /// starts from the compiled jax initialization.
    pub init_theta: Option<Vec<f64>>,
    /// Pool for the server-side column-blocked aggregation + step
    /// (defaults to the process-wide persistent pool). Thread count does
    /// not affect the trajectory: every θ_j sees updates in worker-id
    /// order regardless of which block owns it.
    pub pool: Pool,
    /// Uplink update codec. The default is
    /// [`protocol::WireFormat::Adaptive`]: a 1-byte tag plus the cheaper
    /// of sparse-RLE and dense (weak-censoring rounds — notably the
    /// dense first round — are capped at `8 + 32·d` payload bits; the
    /// tag is accounted). `Sparse` reproduces the paper's format
    /// exactly. Overridable via `GDSEC_WIRE`.
    pub wire: protocol::WireFormat,
    /// Round quorum: how many live scheduled workers must report before
    /// the server steps θ ([`Quorum::All`] = the paper's synchronous
    /// protocol, bitwise identical to the serial reference;
    /// [`Quorum::Adaptive`] picks K online from the observed delay
    /// distribution via [`scheduler::QuorumController`]). Default honors
    /// the `GDSEC_QUORUM` env override.
    pub quorum: Quorum,
    /// Deterministic virtual straggler schedule for quorum cuts (see
    /// [`DelayPlan`]); irrelevant when `quorum` is `All`.
    pub delay: DelayPlan,
    /// Staleness window S (≥ 1): the hard bound on how many rounds late
    /// a transmitted update may fold. A cut-late update is parked until
    /// its [`round::delivery_age`] comes due (1 with S = 1 — the PR 4
    /// behavior); a physically-late delivery older than S is dropped
    /// ([`round::Admit::Expired`]); workers reply to backlog broadcasts
    /// within S instead of discarding them. Default honors
    /// `GDSEC_STALE_WINDOW`.
    pub stale_window: usize,
}

impl CoordConfig {
    pub fn new(gdsec: GdSecConfig, iters: usize) -> CoordConfig {
        CoordConfig {
            gdsec,
            iters,
            scheduler: Scheduler::All,
            recv_timeout: Duration::from_secs(30),
            dead_after: 1,
            evaluator: None,
            problem_name: String::new(),
            fstar: 0.0,
            init_theta: None,
            pool: Pool::global().clone(),
            wire: protocol::WireFormat::from_env(),
            quorum: Quorum::from_env(),
            delay: DelayPlan::default(),
            stale_window: crate::algo::engine::stale_window_from_env(),
        }
    }
}

/// Per-round metrics beyond the paper's payload-bit metric.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundMetrics {
    pub round: usize,
    pub payload_bits: u64,
    pub overhead_bits: u64,
    pub downlink_bits: u64,
    pub transmissions: u64,
    pub wall_us: u64,
    /// Stale updates folded into THIS round's aggregation (parked by an
    /// earlier quorum cut and now due, or physically delivered late
    /// within the staleness window).
    pub stale_folded: u64,
    /// Staleness-age histogram of those folds
    /// ([`crate::algo::trace::stale_age_bin`]): ages 1, 2, 3, ≥ 4.
    /// Ages are hard-bounded by [`CoordConfig::stale_window`], so bins
    /// past the window stay 0.
    pub stale_age_hist: [u64; STALE_AGE_BINS],
    /// Updates that arrived older than the staleness window and were
    /// dropped un-folded (their bits were still charged at
    /// transmission).
    pub stale_expired: u64,
    /// Replies beyond this round's quorum cut (their updates are parked
    /// until their delivery age comes due).
    pub late: u64,
    /// Wall-clock proxy under the virtual [`DelayPlan`]: the largest
    /// delay among the replies the quorum actually waited for. The sum
    /// over rounds is the quantity a straggler inflates in synchronous
    /// mode and a quorum cut bounds.
    pub virtual_units: u64,
}

/// Result of a coordinated run.
pub struct CoordOutcome {
    pub trace: Trace,
    pub rounds: Vec<RoundMetrics>,
    /// Worker ids declared dead during the run.
    pub dead_workers: Vec<usize>,
    /// Total uplink frame bytes (headers + payloads + silence frames).
    pub uplink_frame_bytes: u64,
    pub downlink_frame_bytes: u64,
}

/// The leader. Owns the server side of every link.
pub struct Coordinator {
    cfg: CoordConfig,
    ends: Vec<ServerEnd>,
    handles: Vec<std::thread::JoinHandle<()>>,
    d: usize,
}

impl Coordinator {
    /// Spawn one worker thread per provider factory. Factories run on
    /// their worker's thread so non-`Send` PJRT state never migrates.
    /// `dim` is the model dimension (known from the problem or manifest).
    pub fn spawn(
        cfg: CoordConfig,
        dim: usize,
        factories: Vec<ProviderFactory>,
        failures: Vec<FailurePlan>,
    ) -> Coordinator {
        assert!(!factories.is_empty());
        assert_eq!(factories.len(), failures.len());
        let m = factories.len();
        let mut ends = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        for (w, (factory, failure)) in factories.into_iter().zip(failures).enumerate() {
            let (server_end, worker_end) = duplex();
            let wcfg = cfg.gdsec.clone();
            let wire = cfg.wire;
            let sw = cfg.stale_window;
            handles.push(std::thread::spawn(move || {
                worker::worker_loop(w as u32, m, wcfg, factory, worker_end, failure, wire, sw)
            }));
            ends.push(server_end);
        }
        Coordinator { cfg, ends, handles, d: dim }
    }

    /// Run the protocol to completion and join the workers. With
    /// `quorum = All` this is the paper's synchronous loop, bit-for-bit;
    /// with a smaller quorum the round state machine applies the first K
    /// virtual arrivals and folds the rest into the next round.
    pub fn run(mut self) -> CoordOutcome {
        let d = self.d;
        let m = self.ends.len();
        let iters = self.cfg.iters;
        let mut trace = Trace::new("GD-SEC(dist)", &self.cfg.problem_name, self.cfg.fstar);
        let mut rounds: Vec<RoundMetrics> = Vec::with_capacity(iters);
        let mut dead = vec![false; m];
        let mut timeout_strikes = vec![0u32; m];

        let mut theta = self.cfg.init_theta.take().unwrap_or_else(|| vec![0.0; d]);
        assert_eq!(theta.len(), d, "init_theta dimension mismatch");
        let mut h = vec![0.0; d];
        let mut agg = vec![0.0; d];
        let mut sched = std::mem::replace(&mut self.cfg.scheduler, Scheduler::All);
        let window = self.cfg.stale_window.max(1);
        // Online quorum decisions: fixed policies pass through k_of,
        // Adaptive tracks the per-worker delay EMA (fed after every
        // gather) and cuts at the target tail quantile.
        let mut ctrl = QuorumController::new(self.cfg.quorum, m);

        // Transmitted updates the server holds past their round — parked
        // by a quorum cut or physically delivered late — folded into the
        // apply of their due round `round + age` in (round, worker)
        // order, where the delivery age models how many cut-lengths the
        // reply's excess delay spans, hard-bounded by the staleness
        // window S. Error correction keeps this principled: the worker
        // already moved its h_m/e_m when it transmitted, so the server
        // folding `age` rounds late is the same Eq. 6 step, delayed
        // (LAQ-style bounded staleness). An update still parked when the
        // loop ends is an in-flight transmission at shutdown: dropped
        // like any frame in the pipe, its bits already charged — the
        // trace's last row reflects the θ the server actually served.
        let mut stale: Vec<StaleUpdate> = Vec::new();

        let (mut cum_bits, mut cum_tx, mut cum_entries, mut cum_stale) = (0u64, 0u64, 0u64, 0u64);
        let mut cum_stale_ages = [0u64; STALE_AGE_BINS];
        // One extra eval round so the final iterate's objective is recorded
        // (round k's reports evaluate θ^k, the iterate after k−1 updates).
        for k in 1..=iters + 1 {
            let t0 = Instant::now();
            let eval_only = k == iters + 1;
            let active =
                if eval_only { (0..m).collect::<Vec<_>>() } else { sched.active(k, m) };
            let full_round = active.len() == m && !dead.iter().any(|&x| x);
            // Quorum size is relative to the workers actually expected to
            // report: live AND scheduled this round. Decided from the
            // PRE-round delay estimates (the controller is fed after the
            // gather below) — the same decide-K → cut → observe logic as
            // the engine-side QuorumSim. (The in-flight MODELS differ:
            // here a cut-late worker keeps computing and replying while
            // its parked update is in transit — the links pipeline — so
            // it is observed every round; the sim's workers sit out
            // their delivery age. Trajectories are not cross-pinned
            // between the two drivers except at Quorum::All.)
            let expected_ids: Vec<usize> =
                active.iter().copied().filter(|&w| !dead[w]).collect();
            let k_quorum = ctrl.k_for(&expected_ids);
            let mut metrics = RoundMetrics { round: k, ..Default::default() };

            // Broadcast θ^k with per-worker active flags.
            for (w, end) in self.ends.iter().enumerate() {
                if dead[w] {
                    continue;
                }
                let msg = Msg::Broadcast {
                    round: k as u32,
                    theta: theta.clone(),
                    active: active.contains(&w),
                };
                let frame = protocol::encode(&msg, d as u32);
                metrics.downlink_bits += frame.len() as u64 * 8;
                if !end.tx.send(frame) {
                    dead[w] = true;
                }
            }

            // Event-driven gather: admit frames in arrival order until
            // every live active worker resolves (fresh reply, timeout, or
            // death). Round-id routing sends an older round's update to
            // the stale pool instead of misreading it as this round's
            // reply — and keeps waiting for that worker's fresh frame
            // within the same deadline.
            let mut rs = RoundState::new(k as u32, m, window as u32);
            let mut arrived_stale_entries = 0u64;
            for &w in &active {
                if dead[w] {
                    continue;
                }
                let deadline = Instant::now() + self.cfg.recv_timeout;
                loop {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    match self.ends[w].rx.recv_timeout(remaining) {
                        Recv::Frame(frame) => {
                            metrics.overhead_bits += protocol::HEADER_LEN as u64 * 8;
                            match protocol::decode(&frame, d as u32) {
                                Ok(msg @ (Msg::Update { .. } | Msg::Silence { .. })) => {
                                    // Codec-exact for either wire format
                                    // (the adaptive tag byte is real
                                    // payload; silence payloads cost 0).
                                    metrics.payload_bits += protocol::update_payload_bits(&frame);
                                    metrics.overhead_bits += 64; // reported loss
                                    if matches!(msg, Msg::Update { .. }) {
                                        metrics.transmissions += 1;
                                    }
                                    let was_stale_round = match &msg {
                                        Msg::Update { round, .. }
                                        | Msg::Silence { round, .. } => (*round as usize) < k,
                                        _ => unreachable!(),
                                    };
                                    match rs.admit(w, msg) {
                                        Admit::Fresh => {
                                            // Only a FRESH reply clears the
                                            // strike count: a worker
                                            // forever delivering last
                                            // round's update one round
                                            // late must still accrue
                                            // strikes, or `dead_after` is
                                            // defeated.
                                            timeout_strikes[w] = 0;
                                            break;
                                        }
                                        Admit::Stale(su) => {
                                            arrived_stale_entries += su.update.nnz() as u64;
                                            stale.push(su);
                                            continue; // fresh reply still due
                                        }
                                        Admit::Expired(su) => {
                                            // Older than the staleness
                                            // window: bits charged,
                                            // contribution dropped — the
                                            // window is a hard bound.
                                            arrived_stale_entries += su.update.nnz() as u64;
                                            metrics.stale_expired += 1;
                                            continue; // fresh reply still due
                                        }
                                        Admit::Ignored if was_stale_round => continue,
                                        Admit::Ignored => break,
                                    }
                                }
                                _ => break, // malformed/unexpected: treat as silent
                            }
                        }
                        Recv::Timeout => {
                            timeout_strikes[w] += 1;
                            if timeout_strikes[w] >= self.cfg.dead_after {
                                dead[w] = true;
                            }
                            break;
                        }
                        Recv::Disconnected => {
                            dead[w] = true;
                            break;
                        }
                    }
                }
            }
            // Feed the observed virtual arrivals to the adaptive
            // controller (every replier, cut-late ones included — their
            // delay is the straggler signal the next round's K needs).
            for &w in &expected_ids {
                if rs.replied(w) {
                    ctrl.observe(w, self.cfg.delay.delay(w, k));
                }
            }

            // Record the objective of θ^k (the pre-update iterate), paired
            // with the bits accumulated through round k−1 — exactly the
            // serial reference's row k−1.
            let fval = if full_round && rs.local_f().iter().all(|f| f.is_some()) {
                rs.local_f().iter().map(|f| f.unwrap()).sum()
            } else if let Some(eval) = &self.cfg.evaluator {
                eval(&theta)
            } else {
                f64::NAN
            };
            trace.push(TraceRow {
                iter: k - 1,
                fval,
                bits: cum_bits,
                transmissions: cum_tx,
                entries: cum_entries,
                stale: cum_stale,
                stale_ages: cum_stale_ages,
            });

            if eval_only {
                metrics.wall_us = t0.elapsed().as_micros() as u64;
                rounds.push(metrics);
                break;
            }

            // Wire accounting happens at transmission time — late updates
            // still paid their bits this round even though they fold next
            // round.
            for u in rs.updates().iter().flatten() {
                cum_entries += u.nnz() as u64;
            }
            cum_entries += arrived_stale_entries;
            cum_bits += metrics.payload_bits;
            cum_tx += metrics.transmissions;

            // Cut the round at the quorum (virtual arrival order — seeded
            // delays, then worker id — so the trajectory is deterministic
            // for any thread schedule) and park the late updates with the
            // delivery age their excess delay spans (due at round
            // `k + age`, hard-bounded by the staleness window).
            let cut = rs.cut(k_quorum, &self.cfg.delay);
            metrics.virtual_units = cut.units;
            metrics.late = cut.late.len() as u64;
            let mut parked: Vec<StaleUpdate> = Vec::new();
            for &w in &cut.late {
                if let Some(u) = rs.take_update(w) {
                    let age = delivery_age(self.cfg.delay.delay(w, k), cut.units, window);
                    parked.push(StaleUpdate { round: k as u32, worker: w, age, update: u });
                }
            }

            // Aggregate and step, fanned over contiguous column blocks:
            // the pool's DUE stale entries (round + age ≤ k) fold first
            // in (round, worker) order, then this round's on-time
            // updates in worker-id order — every element sees the same
            // fixed sequence at any thread count, so with `quorum = All`
            // (stale always empty) the bits equal the serial loop's
            // exactly (pinned by the integration tests). Not-yet-due
            // entries stay in the pool for a later round (with S = 1
            // everything is due immediately — the PR 4 behavior).
            stale.sort_by_key(|s| (s.round, s.worker));
            let (due, pending): (Vec<StaleUpdate>, Vec<StaleUpdate>) =
                stale.drain(..).partition(|s| (s.round + s.age) as usize <= k);
            debug_assert!(due.iter().all(|s| s.age as usize <= window));
            metrics.stale_folded = due.len() as u64;
            for s in &due {
                metrics.stale_age_hist[stale_age_bin(s.age)] += 1;
                cum_stale_ages[stale_age_bin(s.age)] += 1;
            }
            apply_round_blocked(
                &mut theta,
                &mut h,
                &mut agg,
                &due,
                rs.updates(),
                &self.cfg.gdsec,
                &self.cfg.pool,
            );
            cum_stale += due.len() as u64;
            stale = pending;
            stale.append(&mut parked);
            metrics.wall_us = t0.elapsed().as_micros() as u64;
            rounds.push(metrics);
        }

        // Shutdown and join.
        for end in &self.ends {
            let _ = end.tx.send(protocol::encode(&Msg::Shutdown, d as u32));
        }
        let mut uplink_bytes = 0u64;
        let mut downlink_bytes = 0u64;
        for end in &self.ends {
            uplink_bytes += end.up_stats.bytes();
            downlink_bytes += end.down_stats.bytes();
        }
        for hnd in self.handles.drain(..) {
            let _ = hnd.join();
        }
        CoordOutcome {
            trace,
            rounds,
            dead_workers: dead
                .iter()
                .enumerate()
                .filter_map(|(w, &dd)| dd.then_some(w))
                .collect(),
            uplink_frame_bytes: uplink_bytes,
            downlink_frame_bytes: downlink_bytes,
        }
    }
}

/// The server's per-round work — zero + aggregate the worker updates and
/// apply θ^{k+1} = θ^k − α(h + Δ̂), h += β·Δ̂ — fanned over contiguous
/// column blocks of (θ, h, agg). Each block zeroes its agg slice, folds
/// the stale pool's in-range entries in (round, worker) order, then the
/// fresh updates' in worker-id order
/// ([`SparseUpdate::add_range_into`]), and steps its θ/h slice, keeping
/// the working set cache-resident at RCV1 scale. Blocks are cut by the
/// canonical [`Pool::block_width`] (the same contract as
/// [`Pool::scatter_blocks`]; three zipped slices keep the hand-rolled
/// scatter here). Per element the operation sequence is identical to the
/// serial loop, so the trajectory is bit-for-bit
/// thread-count-independent.
fn apply_round_blocked(
    theta: &mut [f64],
    h: &mut [f64],
    agg: &mut [f64],
    stale: &[StaleUpdate],
    updates: &[Option<SparseUpdate>],
    cfg: &GdSecConfig,
    pool: &Pool,
) {
    let d = theta.len();
    if d == 0 {
        return;
    }
    struct Block<'a> {
        j0: usize,
        theta: &'a mut [f64],
        h: &'a mut [f64],
        agg: &'a mut [f64],
    }
    let chunk = pool.block_width(d);
    let mut blocks: Vec<Block<'_>> = theta
        .chunks_mut(chunk)
        .zip(h.chunks_mut(chunk))
        .zip(agg.chunks_mut(chunk))
        .enumerate()
        .map(|(b, ((tc, hc), ac))| Block { j0: b * chunk, theta: tc, h: hc, agg: ac })
        .collect();
    pool.scatter(&mut blocks, |_, blk| {
        linalg::zero(blk.agg);
        for s in stale {
            s.update.add_range_into(blk.j0, blk.agg);
        }
        for u in updates.iter().flatten() {
            u.add_range_into(blk.j0, blk.agg);
        }
        if cfg.state_variable {
            for j in 0..blk.theta.len() {
                blk.theta[j] -= cfg.alpha * (blk.h[j] + blk.agg[j]);
                blk.h[j] += cfg.beta * blk.agg[j];
            }
        } else {
            for j in 0..blk.theta.len() {
                blk.theta[j] -= cfg.alpha * blk.agg[j];
            }
        }
    });
}

/// Convenience: run distributed GD-SEC over a [`crate::objectives::Problem`]
/// with native gradient providers. Quorum honors the `GDSEC_QUORUM` env
/// override (the CI matrix runs the integration suite once with
/// `quorum < M`); use [`run_native_opts`] to pin it.
pub fn run_native(
    prob: &crate::objectives::Problem,
    gdsec: GdSecConfig,
    iters: usize,
    sched: Scheduler,
) -> CoordOutcome {
    run_native_opts(prob, gdsec, iters, sched, Quorum::from_env(), DelayPlan::default())
}

/// [`run_native`] with an explicit quorum policy and virtual delay
/// schedule (parity tests pin `Quorum::All`; straggler tests inject
/// deterministic [`DelayPlan`]s).
pub fn run_native_opts(
    prob: &crate::objectives::Problem,
    gdsec: GdSecConfig,
    iters: usize,
    sched: Scheduler,
    quorum: Quorum,
    delay: DelayPlan,
) -> CoordOutcome {
    let fstar = prob.estimate_fstar(crate::algo::gdsec::fstar_iters(iters));
    let factories: Vec<ProviderFactory> = prob
        .locals
        .iter()
        .map(|l| {
            let local = l.clone();
            Box::new(move || {
                Box::new(worker::NativeProvider::new(local)) as Box<dyn worker::GradProvider>
            }) as ProviderFactory
        })
        .collect();
    let failures = vec![FailurePlan::default(); factories.len()];
    let prob2 = prob.clone();
    let mut cfg = CoordConfig::new(gdsec, iters);
    cfg.scheduler = sched;
    cfg.problem_name = prob.name.clone();
    cfg.fstar = fstar;
    cfg.evaluator = Some(Arc::new(move |theta: &[f64]| prob2.value(theta)));
    cfg.quorum = quorum;
    cfg.delay = delay;
    Coordinator::spawn(cfg, prob.d, factories, failures).run()
}

pub use worker::NativeProvider;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::objectives::Problem;

    #[test]
    fn stale_only_worker_accrues_strikes_and_dies() {
        // Regression for the strike-reset bug: clearing
        // `timeout_strikes` on ANY delivered frame let a worker that
        // forever re-sends the previous round's update one round late
        // evade `dead_after` indefinitely (each round: stale frame ⇒
        // strikes reset ⇒ timeout ⇒ strikes = 1, forever). Strikes must
        // only clear on a FRESH reply, so this worker dies after
        // `dead_after` rounds of stale-only deliveries.
        let prob = Problem::linear(synthetic::dna_like(3, 30), 1, 0.1);
        let d = prob.d;
        let (server_end, worker_end) = duplex();
        // Scripted worker: fresh at round 1, then forever one round late.
        let handle = std::thread::spawn(move || {
            let mut up = SparseUpdate::empty(d);
            up.idx.push(0);
            up.val.push(0.001);
            loop {
                let frame = match worker_end.rx.recv() {
                    Recv::Frame(f) => f,
                    _ => return,
                };
                match protocol::decode(&frame, d as u32) {
                    Ok(Msg::Shutdown) => return,
                    Ok(Msg::Broadcast { round, .. }) => {
                        let tag = if round <= 1 { round } else { round - 1 };
                        let reply = Msg::Update {
                            round: tag,
                            worker: 0,
                            update: up.clone(),
                            local_f: 0.0,
                        };
                        if !worker_end.tx.send(protocol::encode(&reply, d as u32)) {
                            return;
                        }
                    }
                    _ => {}
                }
            }
        });
        let prob2 = prob.clone();
        let mut cfg = CoordConfig::new(GdSecConfig::default(), 6);
        cfg.recv_timeout = Duration::from_millis(50);
        cfg.dead_after = 2;
        cfg.quorum = Quorum::All;
        cfg.stale_window = 4;
        cfg.problem_name = prob.name.clone();
        cfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
        let coord = Coordinator { cfg, ends: vec![server_end], handles: vec![handle], d };
        let out = coord.run();
        assert_eq!(out.dead_workers, vec![0], "stale-only worker evaded dead_after");
        // Its stale deliveries were still folded (bits + contribution
        // accounted) before death — staleness tolerance is not the same
        // thing as liveness.
        assert!(out.trace.total_stale() >= 1);
    }
}
