//! The distributed GD-SEC runtime: a leader (server) thread coordinating
//! M worker threads over framed byte-counted links — the L3 system
//! contribution of the paper, in deployable shape.
//!
//! Design (mirrors the synchronous federated protocol the paper assumes,
//! [50]/[51]):
//! * the server broadcasts θ^k to every worker each round with an
//!   active-this-round flag from the [`scheduler`];
//! * active workers reply with either an RLE-coded sparse update or an
//!   explicit `Silence` control frame (payload-bit cost 0, matching the
//!   paper's accounting; the frame header is reported as overhead);
//! * stragglers/crashes are handled by a receive timeout: a worker that
//!   misses a deadline is treated as silent and marked dead after
//!   `dead_after` consecutive timeouts (failure injection in tests);
//! * aggregation is performed in worker-id order so the trajectory is
//!   bit-for-bit equal to the single-threaded reference
//!   ([`crate::algo::gdsec::run`]) — pinned by integration tests.

pub mod protocol;
pub mod scheduler;
pub mod transport;
pub mod worker;

use crate::algo::gdsec::GdSecConfig;
use crate::algo::trace::{Trace, TraceRow};
use crate::compress::SparseUpdate;
use crate::linalg;
use crate::util::pool::Pool;
use protocol::Msg;
use scheduler::Scheduler;
use std::sync::Arc;
use std::time::{Duration, Instant};
use transport::{duplex, Recv, ServerEnd};
use worker::{FailurePlan, ProviderFactory};

/// Coordinator configuration.
pub struct CoordConfig {
    pub gdsec: GdSecConfig,
    pub iters: usize,
    pub scheduler: Scheduler,
    /// Per-round worker receive deadline.
    pub recv_timeout: Duration,
    /// Consecutive timeouts before a worker is declared dead.
    pub dead_after: u32,
    /// Optional exact evaluator f(θ) for rounds with partial
    /// participation (otherwise fval is the sum of reported local losses,
    /// which requires full participation; partial rounds record NaN).
    pub evaluator: Option<Arc<dyn Fn(&[f64]) -> f64 + Send + Sync>>,
    /// Problem/trace labels.
    pub problem_name: String,
    pub fstar: f64,
    /// Initial iterate θ^0 (zeros when None) — the e2e transformer run
    /// starts from the compiled jax initialization.
    pub init_theta: Option<Vec<f64>>,
    /// Pool for the server-side column-blocked aggregation + step
    /// (defaults to the process-wide persistent pool). Thread count does
    /// not affect the trajectory: every θ_j sees updates in worker-id
    /// order regardless of which block owns it.
    pub pool: Pool,
    /// Uplink update codec. The default is the paper's sparse format;
    /// [`protocol::WireFormat::Adaptive`] adds a 1-byte tag and falls
    /// back to dense when RLE would cost more (the tag is accounted in
    /// the reported payload bits).
    pub wire: protocol::WireFormat,
}

impl CoordConfig {
    pub fn new(gdsec: GdSecConfig, iters: usize) -> CoordConfig {
        CoordConfig {
            gdsec,
            iters,
            scheduler: Scheduler::All,
            recv_timeout: Duration::from_secs(30),
            dead_after: 1,
            evaluator: None,
            problem_name: String::new(),
            fstar: 0.0,
            init_theta: None,
            pool: Pool::global().clone(),
            wire: protocol::WireFormat::default(),
        }
    }
}

/// Per-round metrics beyond the paper's payload-bit metric.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundMetrics {
    pub round: usize,
    pub payload_bits: u64,
    pub overhead_bits: u64,
    pub downlink_bits: u64,
    pub transmissions: u64,
    pub wall_us: u64,
}

/// Result of a coordinated run.
pub struct CoordOutcome {
    pub trace: Trace,
    pub rounds: Vec<RoundMetrics>,
    /// Worker ids declared dead during the run.
    pub dead_workers: Vec<usize>,
    /// Total uplink frame bytes (headers + payloads + silence frames).
    pub uplink_frame_bytes: u64,
    pub downlink_frame_bytes: u64,
}

/// The leader. Owns the server side of every link.
pub struct Coordinator {
    cfg: CoordConfig,
    ends: Vec<ServerEnd>,
    handles: Vec<std::thread::JoinHandle<()>>,
    d: usize,
}

impl Coordinator {
    /// Spawn one worker thread per provider factory. Factories run on
    /// their worker's thread so non-`Send` PJRT state never migrates.
    /// `dim` is the model dimension (known from the problem or manifest).
    pub fn spawn(
        cfg: CoordConfig,
        dim: usize,
        factories: Vec<ProviderFactory>,
        failures: Vec<FailurePlan>,
    ) -> Coordinator {
        assert!(!factories.is_empty());
        assert_eq!(factories.len(), failures.len());
        let m = factories.len();
        let mut ends = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        for (w, (factory, failure)) in factories.into_iter().zip(failures).enumerate() {
            let (server_end, worker_end) = duplex();
            let wcfg = cfg.gdsec.clone();
            let wire = cfg.wire;
            handles.push(std::thread::spawn(move || {
                worker::worker_loop(w as u32, m, wcfg, factory, worker_end, failure, wire)
            }));
            ends.push(server_end);
        }
        Coordinator { cfg, ends, handles, d: dim }
    }

    /// Run the synchronous protocol to completion and join the workers.
    pub fn run(mut self) -> CoordOutcome {
        let d = self.d;
        let m = self.ends.len();
        let iters = self.cfg.iters;
        let mut trace = Trace::new("GD-SEC(dist)", &self.cfg.problem_name, self.cfg.fstar);
        let mut rounds: Vec<RoundMetrics> = Vec::with_capacity(iters);
        let mut dead = vec![false; m];
        let mut timeout_strikes = vec![0u32; m];

        let mut theta = self.cfg.init_theta.take().unwrap_or_else(|| vec![0.0; d]);
        assert_eq!(theta.len(), d, "init_theta dimension mismatch");
        let mut h = vec![0.0; d];
        let mut agg = vec![0.0; d];
        let mut sched = std::mem::replace(&mut self.cfg.scheduler, Scheduler::All);

        let (mut cum_bits, mut cum_tx, mut cum_entries) = (0u64, 0u64, 0u64);
        // One extra eval round so the final iterate's objective is recorded
        // (round k's reports evaluate θ^k, the iterate after k−1 updates).
        for k in 1..=iters + 1 {
            let t0 = Instant::now();
            let eval_only = k == iters + 1;
            let active =
                if eval_only { (0..m).collect::<Vec<_>>() } else { sched.active(k, m) };
            let full_round = active.len() == m && !dead.iter().any(|&x| x);
            let mut metrics = RoundMetrics { round: k, ..Default::default() };

            // Broadcast θ^k with per-worker active flags.
            for (w, end) in self.ends.iter().enumerate() {
                if dead[w] {
                    continue;
                }
                let msg = Msg::Broadcast {
                    round: k as u32,
                    theta: theta.clone(),
                    active: active.contains(&w),
                };
                let frame = protocol::encode(&msg, d as u32);
                metrics.downlink_bits += frame.len() as u64 * 8;
                if !end.tx.send(frame) {
                    dead[w] = true;
                }
            }

            // Collect replies from live active workers.
            let mut updates: Vec<Option<SparseUpdate>> = vec![None; m];
            let mut local_f: Vec<Option<f64>> = vec![None; m];
            for &w in &active {
                if dead[w] {
                    continue;
                }
                match self.ends[w].rx.recv_timeout(self.cfg.recv_timeout) {
                    Recv::Frame(frame) => {
                        timeout_strikes[w] = 0;
                        metrics.overhead_bits += protocol::HEADER_LEN as u64 * 8;
                        match protocol::decode(&frame, d as u32) {
                            Ok(Msg::Update { update, local_f: f, .. }) => {
                                // Codec-exact for either wire format (the
                                // adaptive tag byte is real payload).
                                metrics.payload_bits += protocol::update_payload_bits(&frame);
                                metrics.transmissions += 1;
                                metrics.overhead_bits += 64; // reported loss
                                local_f[w] = Some(f);
                                updates[w] = Some(update);
                            }
                            Ok(Msg::Silence { local_f: f, .. }) => {
                                metrics.overhead_bits += 64;
                                local_f[w] = Some(f);
                            }
                            _ => {} // malformed/unexpected: treat as silent
                        }
                    }
                    Recv::Timeout => {
                        timeout_strikes[w] += 1;
                        if timeout_strikes[w] >= self.cfg.dead_after {
                            dead[w] = true;
                        }
                    }
                    Recv::Disconnected => {
                        dead[w] = true;
                    }
                }
            }

            // Record the objective of θ^k (the pre-update iterate), paired
            // with the bits accumulated through round k−1 — exactly the
            // serial reference's row k−1.
            let fval = if full_round && local_f.iter().all(|f| f.is_some()) {
                local_f.iter().map(|f| f.unwrap()).sum()
            } else if let Some(eval) = &self.cfg.evaluator {
                eval(&theta)
            } else {
                f64::NAN
            };
            trace.push(TraceRow {
                iter: k - 1,
                fval,
                bits: cum_bits,
                transmissions: cum_tx,
                entries: cum_entries,
            });

            if eval_only {
                metrics.wall_us = t0.elapsed().as_micros() as u64;
                rounds.push(metrics);
                break;
            }

            // Aggregate in worker-id order (determinism) and step, fanned
            // over contiguous column blocks: every element still sees the
            // updates in worker order, so any thread count produces the
            // serial loop's bits exactly (the integration tests pin this
            // against the single-threaded reference).
            for u in updates.iter().flatten() {
                cum_entries += u.nnz() as u64;
            }
            cum_bits += metrics.payload_bits;
            cum_tx += metrics.transmissions;
            apply_round_blocked(
                &mut theta,
                &mut h,
                &mut agg,
                &updates,
                &self.cfg.gdsec,
                &self.cfg.pool,
            );
            metrics.wall_us = t0.elapsed().as_micros() as u64;
            rounds.push(metrics);
        }

        // Shutdown and join.
        for end in &self.ends {
            let _ = end.tx.send(protocol::encode(&Msg::Shutdown, d as u32));
        }
        let mut uplink_bytes = 0u64;
        let mut downlink_bytes = 0u64;
        for end in &self.ends {
            uplink_bytes += end.up_stats.bytes();
            downlink_bytes += end.down_stats.bytes();
        }
        for hnd in self.handles.drain(..) {
            let _ = hnd.join();
        }
        CoordOutcome {
            trace,
            rounds,
            dead_workers: dead
                .iter()
                .enumerate()
                .filter_map(|(w, &dd)| dd.then_some(w))
                .collect(),
            uplink_frame_bytes: uplink_bytes,
            downlink_frame_bytes: downlink_bytes,
        }
    }
}

/// The server's per-round work — zero + aggregate the worker updates and
/// apply θ^{k+1} = θ^k − α(h + Δ̂), h += β·Δ̂ — fanned over contiguous
/// column blocks of (θ, h, agg). Each block zeroes its agg slice, folds
/// the updates' in-range entries in worker-id order
/// ([`SparseUpdate::add_range_into`]), and steps its θ/h slice, keeping
/// the working set cache-resident at RCV1 scale. Blocks are cut by the
/// canonical [`Pool::block_width`] (the same contract as
/// [`Pool::scatter_blocks`]; three zipped slices keep the hand-rolled
/// scatter here). Per element the operation sequence is identical to the
/// serial loop, so the trajectory is bit-for-bit
/// thread-count-independent.
fn apply_round_blocked(
    theta: &mut [f64],
    h: &mut [f64],
    agg: &mut [f64],
    updates: &[Option<SparseUpdate>],
    cfg: &GdSecConfig,
    pool: &Pool,
) {
    let d = theta.len();
    if d == 0 {
        return;
    }
    struct Block<'a> {
        j0: usize,
        theta: &'a mut [f64],
        h: &'a mut [f64],
        agg: &'a mut [f64],
    }
    let chunk = pool.block_width(d);
    let mut blocks: Vec<Block<'_>> = theta
        .chunks_mut(chunk)
        .zip(h.chunks_mut(chunk))
        .zip(agg.chunks_mut(chunk))
        .enumerate()
        .map(|(b, ((tc, hc), ac))| Block { j0: b * chunk, theta: tc, h: hc, agg: ac })
        .collect();
    pool.scatter(&mut blocks, |_, blk| {
        linalg::zero(blk.agg);
        for u in updates.iter().flatten() {
            u.add_range_into(blk.j0, blk.agg);
        }
        if cfg.state_variable {
            for j in 0..blk.theta.len() {
                blk.theta[j] -= cfg.alpha * (blk.h[j] + blk.agg[j]);
                blk.h[j] += cfg.beta * blk.agg[j];
            }
        } else {
            for j in 0..blk.theta.len() {
                blk.theta[j] -= cfg.alpha * blk.agg[j];
            }
        }
    });
}

/// Convenience: run distributed GD-SEC over a [`crate::objectives::Problem`]
/// with native gradient providers.
pub fn run_native(
    prob: &crate::objectives::Problem,
    gdsec: GdSecConfig,
    iters: usize,
    sched: Scheduler,
) -> CoordOutcome {
    let fstar = prob.estimate_fstar(crate::algo::gdsec::fstar_iters(iters));
    let factories: Vec<ProviderFactory> = prob
        .locals
        .iter()
        .map(|l| {
            let local = l.clone();
            Box::new(move || {
                Box::new(worker::NativeProvider::new(local)) as Box<dyn worker::GradProvider>
            }) as ProviderFactory
        })
        .collect();
    let failures = vec![FailurePlan::default(); factories.len()];
    let prob2 = prob.clone();
    let mut cfg = CoordConfig::new(gdsec, iters);
    cfg.scheduler = sched;
    cfg.problem_name = prob.name.clone();
    cfg.fstar = fstar;
    cfg.evaluator = Some(Arc::new(move |theta: &[f64]| prob2.value(theta)));
    Coordinator::spawn(cfg, prob.d, factories, failures).run()
}

pub use worker::NativeProvider;
