//! Link abstraction for the coordinator: the [`Transport`] trait, the
//! in-process [`VirtualTransport`] default (framed links over
//! `std::sync::mpsc` with exact per-link byte counters), and the seeded
//! delay/fault injection plans. The real-socket backend lives in
//! [`super::tcp`]; `GDSEC_TRANSPORT` selects between them.
//!
//! Substitution note (DESIGN.md §6): the paper's setting is a wireless
//! uplink; what its evaluation measures is *transmitted bits*. This
//! transport counts the bytes of every frame actually serialized onto the
//! link, and can additionally model a per-round uplink byte budget
//! (Fig 8's bandwidth-limited regime is driven by the scheduler on top).

use crate::util::rng::{Pcg64, SplitMix64};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

/// Deterministic delay-injection harness for the semi-synchronous
/// quorum rounds: a seeded per-(worker, round) schedule of **virtual**
/// compute/uplink delays, in abstract time units (never wall-clock).
///
/// The coordinator's round state machine ranks the round's replies by
/// `(delay(w, k), w)` and cuts the quorum there, so straggler
/// trajectories are bit-for-bit reproducible in CI — no sleeps, no
/// scheduler races. The per-round wall-clock proxy reported in
/// [`crate::coordinator::RoundMetrics::virtual_units`] is the largest
/// delay among the replies the server actually waited for.
#[derive(Debug, Clone, Default)]
pub enum DelayPlan {
    /// No injected delays: every reply ties at 0 units and the cut falls
    /// back to worker-id order.
    #[default]
    None,
    /// Fixed per-worker delay, identical every round (index = worker
    /// id; missing workers default to 0). `PerWorker(vec![0, 0, 900])`
    /// models one hard straggler.
    PerWorker(Vec<u64>),
    /// Seeded pseudo-random delay in `[lo, hi)` drawn independently per
    /// (worker, round) — i.i.d. jitter, reproducible from the seed.
    Jitter { seed: u64, lo: u64, hi: u64 },
    /// Piecewise-constant per-worker delays: each `(start_round,
    /// units)` phase applies from its start round (1-based, inclusive)
    /// until the next phase begins. Rounds before the first phase, and
    /// workers past a phase's vector, default to 0. Models straggler
    /// sets that drift over a run — the regime a delay-adaptive quorum
    /// exists for (a fixed K is wrong in at least one phase).
    Phased(Vec<(usize, Vec<u64>)>),
}

impl DelayPlan {
    /// Virtual delay units for worker `w`'s reply in round `k`.
    pub fn delay(&self, w: usize, k: usize) -> u64 {
        match self {
            DelayPlan::None => 0,
            DelayPlan::PerWorker(units) => units.get(w).copied().unwrap_or(0),
            DelayPlan::Phased(phases) => phases
                .iter()
                .rev()
                .find(|(start, _)| k >= *start)
                .map_or(0, |(_, units)| units.get(w).copied().unwrap_or(0)),
            DelayPlan::Jitter { seed, lo, hi } => {
                if hi <= lo {
                    return *lo;
                }
                // Stateless: one child stream per (worker, round) cell.
                let cell = SplitMix64::child(*seed, ((w as u64) << 32) ^ k as u64);
                lo + Pcg64::seeded(cell).below(hi - lo)
            }
        }
    }
}

/// Per-worker scripted fault schedule (one slot of a [`FaultPlan`]).
///
/// `crash_at` without `restart_at` is a permanent death: the worker goes
/// dark from that round on (the old `FailurePlan::silent_from_round`).
/// With `restart_at` set, the worker stays dark through `restart_at - 1`
/// and announces itself for re-admission at the first broadcast it sees
/// from round `restart_at` onward.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerFaults {
    /// Rounds whose uplink reply the link drops (scripted).
    pub drop_rounds: Vec<u32>,
    /// Rounds whose uplink reply the link corrupts (scripted).
    pub corrupt_rounds: Vec<u32>,
    /// First round the worker is crashed for (1-based, inclusive).
    pub crash_at: Option<u32>,
    /// First round the worker asks to rejoin from (1-based, inclusive).
    pub restart_at: Option<u32>,
}

impl WorkerFaults {
    pub fn is_none(&self) -> bool {
        self.drop_rounds.is_empty()
            && self.corrupt_rounds.is_empty()
            && self.crash_at.is_none()
            && self.restart_at.is_none()
    }

    /// Is the worker crashed (dark) during round `k`?
    pub fn crashed(&self, k: u32) -> bool {
        match (self.crash_at, self.restart_at) {
            (Some(c), Some(r)) => k >= c && k < r,
            (Some(c), None) => k >= c,
            _ => false,
        }
    }
}

// Distinct SplitMix64 stream tags so the drop and corrupt draws for the
// same (worker, round) cell are independent (and independent of
// `DelayPlan::Jitter`, which uses the raw seed).
const FAULT_STREAM_DROP: u64 = 0x6472_6f70; // "drop"
const FAULT_STREAM_CORRUPT: u64 = 0x636f_7272; // "corr"

/// Deterministic fault-injection harness, sibling of [`DelayPlan`]: a
/// seeded, wall-clock-free schedule of frame drops, payload corruption,
/// crashes, and restarts, reproducible from `(seed, worker, round)`
/// alone.
///
/// Drops and corruption are applied by the *server* at receive time
/// (keyed by the gather round), so a "dropped" frame costs the link its
/// bytes but never reaches `protocol::decode`, and a "corrupt" frame
/// arrives with its magic byte flipped — exercising the same strike path
/// a genuinely malformed frame takes. Crash/restart schedules are
/// shipped to the worker thread via [`FaultPlan::faults_for`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed for the probabilistic drop/corrupt draws.
    pub seed: u64,
    /// Per-(worker, round) i.i.d. frame-drop probability.
    pub drop_p: f64,
    /// Per-(worker, round) i.i.d. frame-corruption probability.
    pub corrupt_p: f64,
    /// Scripted per-worker schedules (index = worker id; missing workers
    /// have no scripted faults).
    pub workers: Vec<WorkerFaults>,
}

impl FaultPlan {
    /// Fast path: a default plan injects nothing.
    pub fn is_none(&self) -> bool {
        self.drop_p == 0.0 && self.corrupt_p == 0.0 && self.workers.iter().all(|w| w.is_none())
    }

    fn chance(&self, stream: u64, w: usize, k: u32, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        // Stateless, like DelayPlan::Jitter: one child stream per
        // (worker, round) cell, tagged per fault kind.
        let cell = SplitMix64::child(self.seed ^ stream, ((w as u64) << 32) ^ k as u64);
        Pcg64::seeded(cell).uniform() < p
    }

    /// Does the link drop worker `w`'s reply for round `k`?
    pub fn drops(&self, w: usize, k: u32) -> bool {
        self.workers.get(w).is_some_and(|f| f.drop_rounds.contains(&k))
            || self.chance(FAULT_STREAM_DROP, w, k, self.drop_p)
    }

    /// Does the link corrupt worker `w`'s reply for round `k`?
    pub fn corrupts(&self, w: usize, k: u32) -> bool {
        self.workers.get(w).is_some_and(|f| f.corrupt_rounds.contains(&k))
            || self.chance(FAULT_STREAM_CORRUPT, w, k, self.corrupt_p)
    }

    /// Clone worker `w`'s scripted schedule for its thread (crash and
    /// restart rounds; the link-level drop/corrupt draws stay
    /// server-side).
    pub fn faults_for(&self, w: usize) -> WorkerFaults {
        self.workers.get(w).cloned().unwrap_or_default()
    }

    fn worker_mut(&mut self, w: usize) -> &mut WorkerFaults {
        if self.workers.len() <= w {
            self.workers.resize(w + 1, WorkerFaults::default());
        }
        &mut self.workers[w]
    }

    /// Parse a `GDSEC_FAULTS` spec: comma-separated clauses, e.g.
    /// `seed=7,drop=0.05,corrupt=0.01,crash=1@3,restart=1@6,drop=2@4`.
    ///
    /// `drop=`/`corrupt=` take either a probability (`drop=0.05`, all
    /// workers, i.i.d. per round) or a scripted `worker@round` cell
    /// (`drop=2@4`). `crash=W@R` / `restart=W@R` are always scripted.
    /// Panics on a malformed spec so CI misconfiguration is loud.
    pub fn parse(spec: &str) -> FaultPlan {
        let mut plan = FaultPlan::default();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (key, val) = clause
                .split_once('=')
                .unwrap_or_else(|| panic!("GDSEC_FAULTS clause without '=': {clause:?}"));
            let at = |val: &str| -> (usize, u32) {
                let (w, r) = val
                    .split_once('@')
                    .unwrap_or_else(|| panic!("GDSEC_FAULTS {key}={val}: expected worker@round"));
                let w: usize = w.parse().unwrap_or_else(|_| panic!("bad worker id {w:?}"));
                let r: u32 = r.parse().unwrap_or_else(|_| panic!("bad round {r:?}"));
                assert!(r > 0, "GDSEC_FAULTS rounds are 1-based ({clause:?})");
                (w, r)
            };
            match key {
                "seed" => plan.seed = val.parse().unwrap_or_else(|_| panic!("bad seed {val:?}")),
                "drop" | "corrupt" if !val.contains('@') => {
                    let p: f64 = val.parse().unwrap_or_else(|_| panic!("bad prob {val:?}"));
                    assert!((0.0..=1.0).contains(&p), "GDSEC_FAULTS {key} prob out of [0,1]");
                    if key == "drop" {
                        plan.drop_p = p;
                    } else {
                        plan.corrupt_p = p;
                    }
                }
                "drop" => {
                    let (w, r) = at(val);
                    plan.worker_mut(w).drop_rounds.push(r);
                }
                "corrupt" => {
                    let (w, r) = at(val);
                    plan.worker_mut(w).corrupt_rounds.push(r);
                }
                "crash" => {
                    let (w, r) = at(val);
                    plan.worker_mut(w).crash_at = Some(r);
                }
                "restart" => {
                    let (w, r) = at(val);
                    plan.worker_mut(w).restart_at = Some(r);
                }
                other => panic!("unknown GDSEC_FAULTS clause {other:?}"),
            }
        }
        for (w, f) in plan.workers.iter().enumerate() {
            if let (Some(c), Some(r)) = (f.crash_at, f.restart_at) {
                assert!(r > c, "GDSEC_FAULTS: worker {w} restart round {r} <= crash round {c}");
            }
        }
        plan
    }

    /// Plan from the `GDSEC_FAULTS` environment variable (default: none).
    pub fn from_env() -> FaultPlan {
        match std::env::var("GDSEC_FAULTS") {
            Ok(spec) => FaultPlan::parse(&spec),
            Err(_) => FaultPlan::default(),
        }
    }
}

/// Shared byte counters for one direction of one link.
#[derive(Debug, Default)]
pub struct LinkStats {
    pub frames: AtomicU64,
    pub bytes: AtomicU64,
}

impl LinkStats {
    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

/// Receive outcome distinguishing timeout (possible peer failure) from
/// disconnect.
#[derive(Debug)]
pub enum Recv {
    Frame(Vec<u8>),
    Timeout,
    Disconnected,
}

/// Outcome of the buffer-reuse receive path ([`Transport::recv_into`]):
/// like [`Recv`] but the frame bytes land in the caller's buffer instead
/// of a freshly allocated `Vec` — the server gather loop's steady state
/// stays allocation-free on the virtual transport.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvStatus {
    Frame,
    Timeout,
    Disconnected,
}

/// One full-duplex framed link endpoint — the contract the coordinator
/// and the worker loop are written against.
///
/// Two backends implement it: [`VirtualTransport`] (in-memory `mpsc`
/// channels, the CI-deterministic default — bitwise identical to the
/// pre-trait `TxLink`/`RxLink` pair) and
/// [`super::tcp::TcpTransport`] (length-framed `std::net::TcpStream`,
/// the real multi-process deployment path). Frames are the exact byte
/// strings `protocol::encode` produces; a backend must deliver them
/// whole and unmodified, so `protocol::decode` is transport-agnostic.
///
/// Byte accounting: `sent_stats`/`rcvd_stats` count *frame* bytes only —
/// a backend's own framing overhead (e.g. TCP's 4-byte length prefix) is
/// excluded, so the paper's transmitted-bit metric is identical across
/// backends for identical trajectories (pinned by the loopback
/// multi-process CI run).
///
/// Peer loss MUST surface as [`Recv::Disconnected`] (sticky): the
/// coordinator maps it onto the liveness-machine strike path, and a
/// restarted worker re-enters through the existing `Msg::Join`
/// re-admission handshake.
pub trait Transport: Send {
    /// Serialize a frame onto the link. Returns false if the peer is
    /// gone. The frame's bytes are counted against `sent_stats` whether
    /// or not the peer still listens (the sender paid for them).
    fn send(&mut self, frame: Vec<u8>) -> bool;

    /// Block until a frame arrives or the peer disconnects.
    fn recv(&mut self) -> Recv;

    /// Block with a deadline; [`Recv::Timeout`] when it expires.
    fn recv_timeout(&mut self, timeout: Duration) -> Recv;

    /// Non-blocking receive: `None` when the link is empty (the worker
    /// loop uses this to skip to the newest queued θ broadcast when the
    /// server has raced ahead after a quorum cut).
    fn try_recv(&mut self) -> Option<Recv>;

    /// Buffer-reuse receive: on [`RecvStatus::Frame`] the frame bytes
    /// replace `buf`'s contents (capacity reused — allocation-free once
    /// warm on the virtual backend). `buf` is unspecified otherwise.
    fn recv_into(&mut self, buf: &mut Vec<u8>, timeout: Duration) -> RecvStatus;

    /// Byte/frame counters for frames this endpoint sent.
    fn sent_stats(&self) -> &Arc<LinkStats>;

    /// Byte/frame counters for frames arriving at this endpoint. On the
    /// virtual backend this handle is shared with the peer's
    /// `sent_stats` (counted at send time — in-flight frames at
    /// shutdown are included, exactly the historical `up_stats`
    /// accounting); the TCP backend counts at frame reassembly.
    fn rcvd_stats(&self) -> &Arc<LinkStats>;
}

/// Which [`Transport`] backend a coordinator run wires its workers with
/// (`GDSEC_TRANSPORT`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Seeded in-memory channels ([`VirtualTransport`]): deterministic
    /// virtual [`DelayPlan`] straggler ordering, the CI mode. Default.
    #[default]
    Virtual,
    /// Real loopback TCP sockets between the coordinator and its worker
    /// threads ([`super::tcp::TcpTransport`]): quorum decisions rank
    /// *measured wall-clock* reply delays, so trajectories with K < M
    /// are machine-dependent (bitwise parity still holds at
    /// `Quorum::All`, where no reply is ever cut).
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "virtual" | "channel" => Ok(TransportKind::Virtual),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(format!("expected `virtual` or `tcp`, got {other:?}")),
        }
    }

    /// Honor the `GDSEC_TRANSPORT` env override (`virtual` | `tcp`).
    /// Panics on garbage so a misconfigured CI leg is loud, never a
    /// silently-virtual "TCP" run.
    pub fn from_env() -> TransportKind {
        match std::env::var("GDSEC_TRANSPORT") {
            Ok(s) => TransportKind::parse(&s)
                .unwrap_or_else(|e| panic!("GDSEC_TRANSPORT: {e}")),
            Err(_) => TransportKind::default(),
        }
    }
}

/// The default [`Transport`]: framed links over `std::sync::mpsc`,
/// bitwise identical to the pre-trait `TxLink`/`RxLink` implementation.
/// Ordering, timeout semantics, and byte accounting are exactly the
/// channel pair's, so every seeded `DelayPlan`/`FaultPlan` trajectory is
/// unchanged by the trait refactor (pinned by running the coordinator
/// integration suite under `GDSEC_TRANSPORT=virtual`).
pub struct VirtualTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    sent: Arc<LinkStats>,
    rcvd: Arc<LinkStats>,
}

impl Transport for VirtualTransport {
    fn send(&mut self, frame: Vec<u8>) -> bool {
        self.sent.frames.fetch_add(1, Ordering::Relaxed);
        self.sent.bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.tx.send(frame).is_ok()
    }

    fn recv(&mut self) -> Recv {
        match self.rx.recv() {
            Ok(f) => Recv::Frame(f),
            Err(_) => Recv::Disconnected,
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Recv {
        match self.rx.recv_timeout(timeout) {
            Ok(f) => Recv::Frame(f),
            Err(RecvTimeoutError::Timeout) => Recv::Timeout,
            Err(RecvTimeoutError::Disconnected) => Recv::Disconnected,
        }
    }

    fn try_recv(&mut self) -> Option<Recv> {
        match self.rx.try_recv() {
            Ok(f) => Some(Recv::Frame(f)),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Recv::Disconnected),
        }
    }

    fn recv_into(&mut self, buf: &mut Vec<u8>, timeout: Duration) -> RecvStatus {
        match self.rx.recv_timeout(timeout) {
            Ok(f) => {
                // Copy into the caller's warm buffer; the channel-owned
                // Vec (allocated at the SEND side) is dropped here, so
                // the receive path itself performs no allocation.
                buf.clear();
                buf.extend_from_slice(&f);
                RecvStatus::Frame
            }
            Err(RecvTimeoutError::Timeout) => RecvStatus::Timeout,
            Err(RecvTimeoutError::Disconnected) => RecvStatus::Disconnected,
        }
    }

    fn sent_stats(&self) -> &Arc<LinkStats> {
        &self.sent
    }

    fn rcvd_stats(&self) -> &Arc<LinkStats> {
        &self.rcvd
    }
}

/// Build the two ends of a server↔worker duplex link:
/// (server side, worker side). The downlink counters are shared between
/// the server's `sent_stats` and the worker's `rcvd_stats` (and the
/// uplink counters vice versa) — counted once, at send time.
pub fn duplex() -> (VirtualTransport, VirtualTransport) {
    let (down_tx, down_rx) = channel();
    let (up_tx, up_rx) = channel();
    let down_stats = Arc::new(LinkStats::default());
    let up_stats = Arc::new(LinkStats::default());
    (
        VirtualTransport {
            tx: down_tx,
            rx: up_rx,
            sent: down_stats.clone(),
            rcvd: up_stats.clone(),
        },
        VirtualTransport { tx: up_tx, rx: down_rx, sent: up_stats, rcvd: down_stats },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_bytes_and_frames() {
        let (mut server, mut worker) = duplex();
        assert!(server.send(vec![1, 2, 3]));
        assert!(server.send(vec![4; 10]));
        match worker.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![1, 2, 3]),
            other => panic!("{other:?}"),
        }
        // Counted at send time, shared with the peer's receive handle.
        assert_eq!(server.sent_stats().frames(), 2);
        assert_eq!(server.sent_stats().bytes(), 13);
        assert_eq!(worker.rcvd_stats().frames(), 2);
        assert_eq!(worker.rcvd_stats().bytes(), 13);
    }

    #[test]
    fn timeout_and_disconnect() {
        let (server, mut worker) = duplex();
        match worker.recv_timeout(Duration::from_millis(5)) {
            Recv::Timeout => {}
            other => panic!("expected timeout, got {other:?}"),
        }
        drop(server);
        match worker.recv() {
            Recv::Disconnected => {}
            other => panic!("expected disconnect, got {other:?}"),
        }
    }

    #[test]
    fn duplex_cross_talk() {
        let (mut server, mut worker) = duplex();
        assert!(server.send(vec![9]));
        match worker.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![9]),
            other => panic!("{other:?}"),
        }
        assert!(worker.send(vec![7, 7]));
        match server.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![7, 7]),
            other => panic!("{other:?}"),
        }
        assert_eq!(server.sent_stats().bytes(), 1); // downlink
        assert_eq!(server.rcvd_stats().bytes(), 2); // uplink
        assert_eq!(worker.sent_stats().bytes(), 2);
        assert_eq!(worker.rcvd_stats().bytes(), 1);
    }

    #[test]
    fn send_to_dropped_peer_fails() {
        let (mut server, worker) = duplex();
        drop(worker);
        assert!(!server.send(vec![1]));
        // The frame was still paid for at the sender.
        assert_eq!(server.sent_stats().bytes(), 1);
    }

    #[test]
    fn try_recv_empty_frame_disconnect() {
        let (mut server, mut worker) = duplex();
        assert!(worker.try_recv().is_none());
        server.send(vec![1]);
        assert!(matches!(worker.try_recv(), Some(Recv::Frame(_))));
        drop(server);
        assert!(matches!(worker.try_recv(), Some(Recv::Disconnected)));
    }

    #[test]
    fn recv_into_reuses_buffer_and_reports_status() {
        let (mut server, mut worker) = duplex();
        let mut buf = vec![0xEE; 64]; // stale contents must be replaced
        assert_eq!(
            worker.recv_into(&mut buf, Duration::from_millis(5)),
            RecvStatus::Timeout
        );
        server.send(vec![3, 1, 4, 1, 5]);
        server.send(vec![9, 2]);
        assert_eq!(
            worker.recv_into(&mut buf, Duration::from_millis(100)),
            RecvStatus::Frame
        );
        assert_eq!(buf, vec![3, 1, 4, 1, 5]);
        let cap = buf.capacity();
        assert_eq!(
            worker.recv_into(&mut buf, Duration::from_millis(100)),
            RecvStatus::Frame
        );
        assert_eq!(buf, vec![9, 2]);
        assert_eq!(buf.capacity(), cap, "warm buffer must not reallocate");
        drop(server);
        assert_eq!(
            worker.recv_into(&mut buf, Duration::from_millis(5)),
            RecvStatus::Disconnected
        );
    }

    #[test]
    fn transport_kind_parses_and_defaults() {
        assert_eq!(TransportKind::parse("virtual"), Ok(TransportKind::Virtual));
        assert_eq!(TransportKind::parse(" TCP "), Ok(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("channel"), Ok(TransportKind::Virtual));
        assert_eq!(TransportKind::parse(""), Ok(TransportKind::Virtual));
        assert_eq!(TransportKind::default(), TransportKind::Virtual);
        assert!(TransportKind::parse("udp").is_err());
        assert!(TransportKind::parse("quantum").unwrap_err().contains("quantum"));
    }

    #[test]
    fn delay_plan_deterministic_and_bounded() {
        assert_eq!(DelayPlan::None.delay(3, 7), 0);
        let pw = DelayPlan::PerWorker(vec![5, 0, 900]);
        assert_eq!(pw.delay(2, 1), 900);
        assert_eq!(pw.delay(2, 99), 900); // round-independent
        assert_eq!(pw.delay(7, 1), 0); // out of range ⇒ 0
        let j = DelayPlan::Jitter { seed: 42, lo: 10, hi: 20 };
        let mut varies = false;
        for w in 0..4 {
            for k in 1..50 {
                let d = j.delay(w, k);
                assert!((10..20).contains(&d), "jitter {d} out of [10,20)");
                assert_eq!(d, j.delay(w, k), "jitter not deterministic");
                varies |= d != j.delay(w, k + 1);
            }
        }
        assert!(varies, "jitter constant across rounds");
        // Degenerate range collapses to lo.
        let flat = DelayPlan::Jitter { seed: 1, lo: 3, hi: 3 };
        assert_eq!(flat.delay(0, 1), 3);
    }

    #[test]
    fn phased_plan_switches_at_phase_starts() {
        let p = DelayPlan::Phased(vec![
            (1, vec![2, 2, 40]),
            (10, vec![2, 40, 40]),
        ]);
        assert_eq!(p.delay(2, 1), 40);
        assert_eq!(p.delay(1, 9), 2);
        assert_eq!(p.delay(1, 10), 40); // switch round is inclusive
        assert_eq!(p.delay(1, 99), 40);
        assert_eq!(p.delay(7, 5), 0); // worker past the vector ⇒ 0
        // Rounds before the first phase default to 0.
        let late_start = DelayPlan::Phased(vec![(5, vec![9])]);
        assert_eq!(late_start.delay(0, 4), 0);
        assert_eq!(late_start.delay(0, 5), 9);
    }

    #[test]
    fn fault_plan_default_injects_nothing() {
        let p = FaultPlan::default();
        assert!(p.is_none());
        for w in 0..4 {
            for k in 1..50 {
                assert!(!p.drops(w, k));
                assert!(!p.corrupts(w, k));
            }
            assert!(p.faults_for(w).is_none());
        }
    }

    #[test]
    fn fault_plan_scripted_cells_fire_exactly() {
        let p = FaultPlan::parse("drop=1@4,corrupt=2@7,crash=0@3,restart=0@6");
        assert!(p.drops(1, 4) && !p.drops(1, 5) && !p.drops(0, 4));
        assert!(p.corrupts(2, 7) && !p.corrupts(2, 6));
        let f = p.faults_for(0);
        assert_eq!((f.crash_at, f.restart_at), (Some(3), Some(6)));
        assert!(!f.crashed(2) && f.crashed(3) && f.crashed(5) && !f.crashed(6));
        // Permanent crash: no restart round.
        let perm = FaultPlan::parse("crash=1@10").faults_for(1);
        assert!(perm.crashed(10) && perm.crashed(1000));
    }

    #[test]
    fn fault_plan_seeded_draws_deterministic_and_rate_plausible() {
        let p = FaultPlan::parse("seed=42,drop=0.3,corrupt=0.1");
        let q = FaultPlan::parse("seed=42,drop=0.3,corrupt=0.1");
        let mut drops = 0u32;
        let mut corrupts = 0u32;
        let n = 4 * 500;
        for w in 0..4 {
            for k in 1..=500 {
                assert_eq!(p.drops(w, k), q.drops(w, k), "drop draw not deterministic");
                assert_eq!(p.corrupts(w, k), q.corrupts(w, k));
                drops += p.drops(w, k) as u32;
                corrupts += p.corrupts(w, k) as u32;
            }
        }
        let (dr, cr) = (drops as f64 / n as f64, corrupts as f64 / n as f64);
        assert!((dr - 0.3).abs() < 0.05, "drop rate {dr}");
        assert!((cr - 0.1).abs() < 0.05, "corrupt rate {cr}");
        // Different seeds give different draw patterns.
        let r = FaultPlan::parse("seed=43,drop=0.3");
        assert!((1..=500).any(|k| p.drops(0, k) != r.drops(0, k)));
    }

    #[test]
    #[should_panic(expected = "restart round")]
    fn fault_plan_rejects_restart_before_crash() {
        FaultPlan::parse("crash=0@6,restart=0@3");
    }

    #[test]
    fn cross_thread() {
        let (mut server, mut worker) = duplex();
        let h = std::thread::spawn(move || {
            if let Recv::Frame(f) = worker.recv() {
                worker.send(f);
            }
        });
        server.send(vec![5, 5, 5]);
        match server.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![5, 5, 5]),
            other => panic!("{other:?}"),
        }
        h.join().unwrap();
    }
}
