//! In-process transport: framed links over `std::sync::mpsc` with exact
//! per-link byte counters and optional simulated bandwidth.
//!
//! Substitution note (DESIGN.md §6): the paper's setting is a wireless
//! uplink; what its evaluation measures is *transmitted bits*. This
//! transport counts the bytes of every frame actually serialized onto the
//! link, and can additionally model a per-round uplink byte budget
//! (Fig 8's bandwidth-limited regime is driven by the scheduler on top).

use crate::util::rng::{Pcg64, SplitMix64};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

/// Deterministic delay-injection harness for the semi-synchronous
/// quorum rounds: a seeded per-(worker, round) schedule of **virtual**
/// compute/uplink delays, in abstract time units (never wall-clock).
///
/// The coordinator's round state machine ranks the round's replies by
/// `(delay(w, k), w)` and cuts the quorum there, so straggler
/// trajectories are bit-for-bit reproducible in CI — no sleeps, no
/// scheduler races. The per-round wall-clock proxy reported in
/// [`crate::coordinator::RoundMetrics::virtual_units`] is the largest
/// delay among the replies the server actually waited for.
#[derive(Debug, Clone, Default)]
pub enum DelayPlan {
    /// No injected delays: every reply ties at 0 units and the cut falls
    /// back to worker-id order.
    #[default]
    None,
    /// Fixed per-worker delay, identical every round (index = worker
    /// id; missing workers default to 0). `PerWorker(vec![0, 0, 900])`
    /// models one hard straggler.
    PerWorker(Vec<u64>),
    /// Seeded pseudo-random delay in `[lo, hi)` drawn independently per
    /// (worker, round) — i.i.d. jitter, reproducible from the seed.
    Jitter { seed: u64, lo: u64, hi: u64 },
    /// Piecewise-constant per-worker delays: each `(start_round,
    /// units)` phase applies from its start round (1-based, inclusive)
    /// until the next phase begins. Rounds before the first phase, and
    /// workers past a phase's vector, default to 0. Models straggler
    /// sets that drift over a run — the regime a delay-adaptive quorum
    /// exists for (a fixed K is wrong in at least one phase).
    Phased(Vec<(usize, Vec<u64>)>),
}

impl DelayPlan {
    /// Virtual delay units for worker `w`'s reply in round `k`.
    pub fn delay(&self, w: usize, k: usize) -> u64 {
        match self {
            DelayPlan::None => 0,
            DelayPlan::PerWorker(units) => units.get(w).copied().unwrap_or(0),
            DelayPlan::Phased(phases) => phases
                .iter()
                .rev()
                .find(|(start, _)| k >= *start)
                .map_or(0, |(_, units)| units.get(w).copied().unwrap_or(0)),
            DelayPlan::Jitter { seed, lo, hi } => {
                if hi <= lo {
                    return *lo;
                }
                // Stateless: one child stream per (worker, round) cell.
                let cell = SplitMix64::child(*seed, ((w as u64) << 32) ^ k as u64);
                lo + Pcg64::seeded(cell).below(hi - lo)
            }
        }
    }
}

/// Shared byte counters for one direction of one link.
#[derive(Debug, Default)]
pub struct LinkStats {
    pub frames: AtomicU64,
    pub bytes: AtomicU64,
}

impl LinkStats {
    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

/// Sending half of a link.
pub struct TxLink {
    tx: Sender<Vec<u8>>,
    stats: Arc<LinkStats>,
}

impl TxLink {
    /// Serialize a frame onto the link. Returns false if the peer is gone.
    pub fn send(&self, frame: Vec<u8>) -> bool {
        self.stats.frames.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.tx.send(frame).is_ok()
    }
}

/// Receiving half of a link.
pub struct RxLink {
    rx: Receiver<Vec<u8>>,
}

/// Receive outcome distinguishing timeout (possible peer failure) from
/// disconnect.
#[derive(Debug)]
pub enum Recv {
    Frame(Vec<u8>),
    Timeout,
    Disconnected,
}

impl RxLink {
    pub fn recv(&self) -> Recv {
        match self.rx.recv() {
            Ok(f) => Recv::Frame(f),
            Err(_) => Recv::Disconnected,
        }
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Recv {
        match self.rx.recv_timeout(timeout) {
            Ok(f) => Recv::Frame(f),
            Err(RecvTimeoutError::Timeout) => Recv::Timeout,
            Err(RecvTimeoutError::Disconnected) => Recv::Disconnected,
        }
    }

    /// Non-blocking receive: `None` when the link is empty (the worker
    /// loop uses this to skip to the newest queued θ broadcast when the
    /// server has raced ahead after a quorum cut).
    pub fn try_recv(&self) -> Option<Recv> {
        match self.rx.try_recv() {
            Ok(f) => Some(Recv::Frame(f)),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Recv::Disconnected),
        }
    }
}

/// Create a unidirectional link; stats are shared between both halves and
/// any observer.
pub fn link() -> (TxLink, RxLink, Arc<LinkStats>) {
    let (tx, rx) = channel();
    let stats = Arc::new(LinkStats::default());
    (TxLink { tx, stats: stats.clone() }, RxLink { rx }, stats)
}

/// Full-duplex endpoint pair for one worker: (server side, worker side).
pub struct ServerEnd {
    pub tx: TxLink,
    pub rx: RxLink,
    pub up_stats: Arc<LinkStats>,
    pub down_stats: Arc<LinkStats>,
}

pub struct WorkerEnd {
    pub tx: TxLink,
    pub rx: RxLink,
}

/// Build the two ends of a server↔worker duplex link.
pub fn duplex() -> (ServerEnd, WorkerEnd) {
    let (down_tx, down_rx, down_stats) = link();
    let (up_tx, up_rx, up_stats) = link();
    (
        ServerEnd { tx: down_tx, rx: up_rx, up_stats, down_stats },
        WorkerEnd { tx: up_tx, rx: down_rx },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_bytes_and_frames() {
        let (tx, rx, stats) = link();
        assert!(tx.send(vec![1, 2, 3]));
        assert!(tx.send(vec![4; 10]));
        match rx.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![1, 2, 3]),
            other => panic!("{other:?}"),
        }
        assert_eq!(stats.frames(), 2);
        assert_eq!(stats.bytes(), 13);
    }

    #[test]
    fn timeout_and_disconnect() {
        let (tx, rx, _stats) = link();
        match rx.recv_timeout(Duration::from_millis(5)) {
            Recv::Timeout => {}
            other => panic!("expected timeout, got {other:?}"),
        }
        drop(tx);
        match rx.recv() {
            Recv::Disconnected => {}
            other => panic!("expected disconnect, got {other:?}"),
        }
    }

    #[test]
    fn duplex_cross_talk() {
        let (server, worker) = duplex();
        assert!(server.tx.send(vec![9]));
        match worker.rx.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![9]),
            other => panic!("{other:?}"),
        }
        assert!(worker.tx.send(vec![7, 7]));
        match server.rx.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![7, 7]),
            other => panic!("{other:?}"),
        }
        assert_eq!(server.down_stats.bytes(), 1);
        assert_eq!(server.up_stats.bytes(), 2);
    }

    #[test]
    fn send_to_dropped_peer_fails() {
        let (tx, rx, _) = link();
        drop(rx);
        assert!(!tx.send(vec![1]));
    }

    #[test]
    fn try_recv_empty_frame_disconnect() {
        let (tx, rx, _) = link();
        assert!(rx.try_recv().is_none());
        tx.send(vec![1]);
        assert!(matches!(rx.try_recv(), Some(Recv::Frame(_))));
        drop(tx);
        assert!(matches!(rx.try_recv(), Some(Recv::Disconnected)));
    }

    #[test]
    fn delay_plan_deterministic_and_bounded() {
        assert_eq!(DelayPlan::None.delay(3, 7), 0);
        let pw = DelayPlan::PerWorker(vec![5, 0, 900]);
        assert_eq!(pw.delay(2, 1), 900);
        assert_eq!(pw.delay(2, 99), 900); // round-independent
        assert_eq!(pw.delay(7, 1), 0); // out of range ⇒ 0
        let j = DelayPlan::Jitter { seed: 42, lo: 10, hi: 20 };
        let mut varies = false;
        for w in 0..4 {
            for k in 1..50 {
                let d = j.delay(w, k);
                assert!((10..20).contains(&d), "jitter {d} out of [10,20)");
                assert_eq!(d, j.delay(w, k), "jitter not deterministic");
                varies |= d != j.delay(w, k + 1);
            }
        }
        assert!(varies, "jitter constant across rounds");
        // Degenerate range collapses to lo.
        let flat = DelayPlan::Jitter { seed: 1, lo: 3, hi: 3 };
        assert_eq!(flat.delay(0, 1), 3);
    }

    #[test]
    fn phased_plan_switches_at_phase_starts() {
        let p = DelayPlan::Phased(vec![
            (1, vec![2, 2, 40]),
            (10, vec![2, 40, 40]),
        ]);
        assert_eq!(p.delay(2, 1), 40);
        assert_eq!(p.delay(1, 9), 2);
        assert_eq!(p.delay(1, 10), 40); // switch round is inclusive
        assert_eq!(p.delay(1, 99), 40);
        assert_eq!(p.delay(7, 5), 0); // worker past the vector ⇒ 0
        // Rounds before the first phase default to 0.
        let late_start = DelayPlan::Phased(vec![(5, vec![9])]);
        assert_eq!(late_start.delay(0, 4), 0);
        assert_eq!(late_start.delay(0, 5), 9);
    }

    #[test]
    fn cross_thread() {
        let (server, worker) = duplex();
        let h = std::thread::spawn(move || {
            if let Recv::Frame(f) = worker.rx.recv() {
                worker.tx.send(f);
            }
        });
        server.tx.send(vec![5, 5, 5]);
        match server.rx.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![5, 5, 5]),
            other => panic!("{other:?}"),
        }
        h.join().unwrap();
    }
}
