//! In-process transport: framed links over `std::sync::mpsc` with exact
//! per-link byte counters and optional simulated bandwidth.
//!
//! Substitution note (DESIGN.md §6): the paper's setting is a wireless
//! uplink; what its evaluation measures is *transmitted bits*. This
//! transport counts the bytes of every frame actually serialized onto the
//! link, and can additionally model a per-round uplink byte budget
//! (Fig 8's bandwidth-limited regime is driven by the scheduler on top).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Shared byte counters for one direction of one link.
#[derive(Debug, Default)]
pub struct LinkStats {
    pub frames: AtomicU64,
    pub bytes: AtomicU64,
}

impl LinkStats {
    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

/// Sending half of a link.
pub struct TxLink {
    tx: Sender<Vec<u8>>,
    stats: Arc<LinkStats>,
}

impl TxLink {
    /// Serialize a frame onto the link. Returns false if the peer is gone.
    pub fn send(&self, frame: Vec<u8>) -> bool {
        self.stats.frames.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.tx.send(frame).is_ok()
    }
}

/// Receiving half of a link.
pub struct RxLink {
    rx: Receiver<Vec<u8>>,
}

/// Receive outcome distinguishing timeout (possible peer failure) from
/// disconnect.
#[derive(Debug)]
pub enum Recv {
    Frame(Vec<u8>),
    Timeout,
    Disconnected,
}

impl RxLink {
    pub fn recv(&self) -> Recv {
        match self.rx.recv() {
            Ok(f) => Recv::Frame(f),
            Err(_) => Recv::Disconnected,
        }
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Recv {
        match self.rx.recv_timeout(timeout) {
            Ok(f) => Recv::Frame(f),
            Err(RecvTimeoutError::Timeout) => Recv::Timeout,
            Err(RecvTimeoutError::Disconnected) => Recv::Disconnected,
        }
    }
}

/// Create a unidirectional link; stats are shared between both halves and
/// any observer.
pub fn link() -> (TxLink, RxLink, Arc<LinkStats>) {
    let (tx, rx) = channel();
    let stats = Arc::new(LinkStats::default());
    (TxLink { tx, stats: stats.clone() }, RxLink { rx }, stats)
}

/// Full-duplex endpoint pair for one worker: (server side, worker side).
pub struct ServerEnd {
    pub tx: TxLink,
    pub rx: RxLink,
    pub up_stats: Arc<LinkStats>,
    pub down_stats: Arc<LinkStats>,
}

pub struct WorkerEnd {
    pub tx: TxLink,
    pub rx: RxLink,
}

/// Build the two ends of a server↔worker duplex link.
pub fn duplex() -> (ServerEnd, WorkerEnd) {
    let (down_tx, down_rx, down_stats) = link();
    let (up_tx, up_rx, up_stats) = link();
    (
        ServerEnd { tx: down_tx, rx: up_rx, up_stats, down_stats },
        WorkerEnd { tx: up_tx, rx: down_rx },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_bytes_and_frames() {
        let (tx, rx, stats) = link();
        assert!(tx.send(vec![1, 2, 3]));
        assert!(tx.send(vec![4; 10]));
        match rx.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![1, 2, 3]),
            other => panic!("{other:?}"),
        }
        assert_eq!(stats.frames(), 2);
        assert_eq!(stats.bytes(), 13);
    }

    #[test]
    fn timeout_and_disconnect() {
        let (tx, rx, _stats) = link();
        match rx.recv_timeout(Duration::from_millis(5)) {
            Recv::Timeout => {}
            other => panic!("expected timeout, got {other:?}"),
        }
        drop(tx);
        match rx.recv() {
            Recv::Disconnected => {}
            other => panic!("expected disconnect, got {other:?}"),
        }
    }

    #[test]
    fn duplex_cross_talk() {
        let (server, worker) = duplex();
        assert!(server.tx.send(vec![9]));
        match worker.rx.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![9]),
            other => panic!("{other:?}"),
        }
        assert!(worker.tx.send(vec![7, 7]));
        match server.rx.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![7, 7]),
            other => panic!("{other:?}"),
        }
        assert_eq!(server.down_stats.bytes(), 1);
        assert_eq!(server.up_stats.bytes(), 2);
    }

    #[test]
    fn send_to_dropped_peer_fails() {
        let (tx, rx, _) = link();
        drop(rx);
        assert!(!tx.send(vec![1]));
    }

    #[test]
    fn cross_thread() {
        let (server, worker) = duplex();
        let h = std::thread::spawn(move || {
            if let Recv::Frame(f) = worker.rx.recv() {
                worker.tx.send(f);
            }
        });
        server.tx.send(vec![5, 5, 5]);
        match server.rx.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![5, 5, 5]),
            other => panic!("{other:?}"),
        }
        h.join().unwrap();
    }
}
