//! Worker-side loop: receive θ broadcasts, compute the local gradient via
//! a pluggable [`GradProvider`] (native Rust objective or a PJRT-loaded
//! XLA executable), run the GD-SEC censor/EC step, and reply.

use super::protocol::{self, Msg, WireFormat};
use super::transport::{Recv, Transport, WorkerFaults};
use crate::algo::engine::EngineOpts;
use crate::algo::gdsec::{GdSecConfig, WorkerState};
use crate::linalg;
use crate::objectives::BlockedGrad;

/// Source of local loss/gradient computation — the seam between L3 and the
/// compiled L2/L1 artifacts.
///
/// Deliberately NOT `Send`: PJRT wrappers hold raw pointers. Providers are
/// constructed *inside* their worker thread via [`ProviderFactory`].
pub trait GradProvider {
    fn dim(&self) -> usize;
    /// Compute f_m(θ) and ∇f_m(θ) (gradient into `out`); returns the loss.
    fn loss_grad(&mut self, theta: &[f64], out: &mut [f64]) -> f64;
}

/// Native (pure Rust) provider over a [`crate::objectives::LocalObjective`].
///
/// Gradients run through the same fixed nnz-budget block tree as the
/// engine's nested lanes
/// ([`LocalObjective::grad_blocked`](crate::objectives::LocalObjective::grad_blocked),
/// budget from `GDSEC_NNZ_BUDGET`), executed serially on the worker
/// thread — which keeps the distributed trajectory bitwise equal to the
/// single-process engine reference at ANY shard size (pinned by
/// `tests/integration_coordinator.rs`).
pub struct NativeProvider {
    pub local: crate::objectives::LocalObjective,
    plan: BlockedGrad,
}

impl NativeProvider {
    pub fn new(local: crate::objectives::LocalObjective) -> NativeProvider {
        let plan = local.blocked_grad_plan(EngineOpts::from_env().nnz_budget);
        NativeProvider { local, plan }
    }
}

impl GradProvider for NativeProvider {
    fn dim(&self) -> usize {
        self.local.dim()
    }

    fn loss_grad(&mut self, theta: &[f64], out: &mut [f64]) -> f64 {
        self.local.grad_blocked(theta, &mut self.plan, out);
        self.local.value(theta)
    }
}

/// Constructor for a worker's provider, run on the worker thread itself
/// (so non-`Send` PJRT state never crosses threads).
pub type ProviderFactory = Box<dyn FnOnce() -> Box<dyn GradProvider> + Send>;

/// Worker-side liveness phase driven by the scripted
/// [`WorkerFaults`] crash/restart schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    /// Normal operation.
    Live,
    /// Crashed: drains broadcasts (channels stay open, like a straggler
    /// rather than a closed socket) but never replies.
    Crashed,
    /// Restarted and announced via [`Msg::Join`]; waiting for the next
    /// usable θ broadcast to adopt as its fresh snapshot.
    Announced,
}

/// Why the worker loop ended — the multi-process worker binary's
/// reconnect decision: `Shutdown` is a clean protocol exit;
/// `LinkLost` means the transport died under the loop (server crash,
/// dropped TCP connection), and carries the last round the worker saw so
/// a reconnect can announce it in the `Msg::Join` hello (the server's
/// re-admission handshake). In-process callers join the thread and
/// ignore the value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopExit {
    Shutdown,
    LinkLost { last_seen: u32 },
}

/// Run the worker loop until Shutdown (or link loss). `factory` is invoked
/// on this thread to build the provider. `wire` selects the uplink update
/// codec (the paper's sparse format, or the adaptive tagged format).
/// `stale_window` is the staleness bound S: a worker that fell behind
/// replies to every queued broadcast within S−1 rounds of the newest —
/// tagging each reply with its TRUE round id so the server folds it as a
/// stale contribution instead of the worker discarding the backlog —
/// and skips only broadcasts the window has already expired (S = 1
/// reproduces the PR 4 skip-to-newest behavior exactly).
///
/// `faults` scripts crash/restart rounds. From `crash_at` the worker goes
/// dark; from `restart_at` it sends [`Msg::Join`] carrying its last-seen
/// round, then adopts the next usable broadcast as a fresh snapshot:
/// EC/memory state zeroed (the error term re-accumulates from zero —
/// safe for every compress rule) and `theta_prev = θ`, so its first
/// reply is a full transmission exactly like round 1.
#[allow(clippy::too_many_arguments)]
pub fn worker_loop<T: Transport>(
    id: u32,
    m_workers: usize,
    cfg: GdSecConfig,
    factory: ProviderFactory,
    mut end: T,
    faults: WorkerFaults,
    wire: WireFormat,
    stale_window: usize,
) -> LoopExit {
    let stale_window = stale_window.max(1) as u32;
    let mut provider = factory();
    let d = provider.dim();
    let mut state = WorkerState::new(d);
    let mut theta_prev = vec![0.0; d];
    let mut theta_diff = vec![0.0; d];
    let mut phase = Phase::Live;
    let mut last_seen: u32 = 0;
    loop {
        let frame = match end.recv() {
            Recv::Frame(f) => f,
            _ => return LoopExit::LinkLost { last_seen },
        };
        let msg = match protocol::decode(&frame, d as u32) {
            Ok(m) => m,
            Err(_) => continue, // corrupt frame: drop, stay alive
        };
        match msg {
            Msg::Shutdown => return LoopExit::Shutdown,
            Msg::Broadcast { round, theta, active } => {
                // Quorum rounds let the server race ahead of a straggler:
                // collect the queued backlog (in round order — the link
                // is FIFO), then reply to every broadcast still within
                // the staleness window of the newest, oldest first, and
                // merely advance the iterate history past the expired
                // ones. Skipped θs still advance theta_prev — exactly
                // what processing them sequentially would have done — so
                // censoring thresholds stay bitwise identical to the
                // one-at-a-time path. (In the synchronous protocol the
                // inbox never holds two broadcasts, so the drain is a
                // no-op there.)
                let mut pending: Vec<(u32, Vec<f64>, bool)> = vec![(round, theta, active)];
                loop {
                    match end.try_recv() {
                        None => break,
                        Some(Recv::Frame(f)) => match protocol::decode(&f, d as u32) {
                            Ok(Msg::Broadcast { round: r2, theta: t2, active: a2 })
                                if r2 > pending.last().map_or(0, |p| p.0) =>
                            {
                                pending.push((r2, t2, a2));
                            }
                            Ok(Msg::Shutdown) => return LoopExit::Shutdown,
                            _ => {} // corrupt/out-of-order: drop
                        },
                        Some(Recv::Disconnected) => return LoopExit::LinkLost { last_seen },
                        // try_recv never yields Timeout; the arm only
                        // keeps the match exhaustive.
                        Some(Recv::Timeout) => break,
                    }
                }
                let newest = pending.last().map_or(round, |p| p.0);
                for (round, theta, active) in pending {
                    if faults.crashed(round) {
                        // Dark, but keep the iterate history moving so a
                        // permanent crash behaves like the old silent
                        // failure plan.
                        phase = Phase::Crashed;
                        theta_prev.copy_from_slice(&theta);
                        continue;
                    }
                    if phase == Phase::Crashed {
                        // Back up (round ≥ restart_at): announce with the
                        // last round seen before the crash and wait for a
                        // usable snapshot.
                        if !end.send(protocol::encode_wire(
                            &Msg::Join { round: last_seen, worker: id },
                            d as u32,
                            wire,
                        )) {
                            return LoopExit::LinkLost { last_seen };
                        }
                        phase = Phase::Announced;
                        theta_prev.copy_from_slice(&theta);
                        continue;
                    }
                    // `newest - round` broadcasts behind: computable only
                    // while strictly inside the window (its reply would
                    // reach the server at age newest − round + 1 ≤ S).
                    let superseded = newest - round >= stale_window;
                    if superseded || !active {
                        last_seen = round;
                        theta_prev.copy_from_slice(&theta);
                        continue;
                    }
                    if phase == Phase::Announced {
                        // Fresh snapshot: EC/memory state restarts from
                        // zero and θ_prev adopts this θ, so the censor
                        // sees a zero θ-diff and transmits in full —
                        // round-1 semantics for the rejoined worker.
                        state = WorkerState::new(d);
                        theta_prev.copy_from_slice(&theta);
                        phase = Phase::Live;
                    }
                    last_seen = round;
                    linalg::sub(&theta, &theta_prev, &mut theta_diff);
                    let local_f = provider.loss_grad(&theta, state.grad_mut());
                    let update = state.sparsify_step(&cfg, m_workers, &theta_diff);
                    let reply = if update.nnz() > 0 {
                        Msg::Update { round, worker: id, update, local_f }
                    } else {
                        Msg::Silence { round, worker: id, local_f }
                    };
                    theta_prev.copy_from_slice(&theta);
                    if !end.send(protocol::encode_wire(&reply, d as u32, wire)) {
                        return LoopExit::LinkLost { last_seen };
                    }
                }
            }
            // Workers ignore uplink-kind messages.
            Msg::Update { .. } | Msg::Silence { .. } | Msg::Join { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::gdsec::Xi;
    use crate::coordinator::transport::duplex;
    use crate::data::synthetic;
    use crate::objectives::Problem;

    /// How long these tests wait before concluding a worker stayed
    /// silent — previously two hardcoded `50ms` literals, which silently
    /// bounded how slow a worker may be before a probe misreads it as
    /// dark. Override with `GDSEC_SILENCE_PROBE_MS` on a loaded box.
    /// (Runtime straggler handling is NOT this: that is
    /// `CoordConfig::{recv_timeout, dead_after}` plus the quorum cut.)
    fn silence_probe() -> std::time::Duration {
        let ms = std::env::var("GDSEC_SILENCE_PROBE_MS")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(50);
        std::time::Duration::from_millis(ms)
    }

    fn spawn_one(
        cfg: GdSecConfig,
        faults: WorkerFaults,
    ) -> (
        crate::coordinator::transport::VirtualTransport,
        std::thread::JoinHandle<LoopExit>,
        usize,
    ) {
        let prob = Problem::linear(synthetic::dna_like(1, 30), 1, 0.1);
        let d = prob.d;
        let local = prob.locals[0].clone();
        let factory: ProviderFactory =
            Box::new(move || Box::new(NativeProvider::new(local)) as Box<dyn GradProvider>);
        let (mut server, worker) = duplex();
        let h = std::thread::spawn(move || {
            worker_loop(0, 1, cfg, factory, worker, faults, WireFormat::Sparse, 1)
        });
        (server, h, d)
    }

    #[test]
    fn first_broadcast_gets_full_update() {
        let cfg = GdSecConfig { xi: Xi::Uniform(1.0), ..Default::default() };
        let (mut server, h, d) = spawn_one(cfg, WorkerFaults::default());
        let theta = vec![0.0; d];
        server.send(protocol::encode(
            &Msg::Broadcast { round: 1, theta, active: true },
            d as u32,
        ));
        match server.recv() {
            Recv::Frame(f) => match protocol::decode(&f, d as u32).unwrap() {
                Msg::Update { round, worker, update, local_f } => {
                    assert_eq!(round, 1);
                    assert_eq!(worker, 0);
                    assert!(update.nnz() > 0);
                    assert!(local_f.is_finite());
                }
                other => panic!("expected update, got {other:?}"),
            },
            other => panic!("{other:?}"),
        }
        server.send(protocol::encode(&Msg::Shutdown, d as u32));
        h.join().unwrap();
    }

    #[test]
    fn inactive_worker_stays_silent() {
        let cfg = GdSecConfig { xi: Xi::Uniform(1.0), ..Default::default() };
        let (mut server, h, d) = spawn_one(cfg, WorkerFaults::default());
        server.send(protocol::encode(
            &Msg::Broadcast { round: 1, theta: vec![0.0; d], active: false },
            d as u32,
        ));
        match server.recv_timeout(silence_probe()) {
            Recv::Timeout => {}
            other => panic!("expected no reply, got {other:?}"),
        }
        server.send(protocol::encode(&Msg::Shutdown, d as u32));
        h.join().unwrap();
    }

    #[test]
    fn failed_worker_goes_dark_but_drains() {
        let cfg = GdSecConfig { xi: Xi::Uniform(1.0), ..Default::default() };
        let (mut server, h, d) =
            spawn_one(cfg, WorkerFaults { crash_at: Some(2), ..Default::default() });
        server.send(protocol::encode(
            &Msg::Broadcast { round: 1, theta: vec![0.0; d], active: true },
            d as u32,
        ));
        assert!(matches!(server.recv(), Recv::Frame(_)));
        server.send(protocol::encode(
            &Msg::Broadcast { round: 2, theta: vec![0.1; d], active: true },
            d as u32,
        ));
        match server.recv_timeout(silence_probe()) {
            Recv::Timeout => {}
            other => panic!("expected dark worker, got {other:?}"),
        }
        server.send(protocol::encode(&Msg::Shutdown, d as u32));
        h.join().unwrap();
    }

    #[test]
    fn queued_newer_broadcast_supersedes_in_flight_round() {
        // Both broadcasts are queued BEFORE the worker thread starts, so
        // the drain deterministically sees round 2 superseding round 1:
        // exactly one reply comes back, tagged round 2.
        let cfg = GdSecConfig { xi: Xi::Uniform(1.0), ..Default::default() };
        let prob = Problem::linear(synthetic::dna_like(1, 30), 1, 0.1);
        let d = prob.d;
        let local = prob.locals[0].clone();
        let factory: ProviderFactory =
            Box::new(move || Box::new(NativeProvider::new(local)) as Box<dyn GradProvider>);
        let (mut server, worker) = duplex();
        server.send(protocol::encode(
            &Msg::Broadcast { round: 1, theta: vec![0.0; d], active: true },
            d as u32,
        ));
        server.send(protocol::encode(
            &Msg::Broadcast { round: 2, theta: vec![0.01; d], active: true },
            d as u32,
        ));
        let faults = WorkerFaults::default();
        let h = std::thread::spawn(move || {
            worker_loop(0, 1, cfg, factory, worker, faults, WireFormat::Sparse, 1)
        });
        match server.recv() {
            Recv::Frame(f) => match protocol::decode(&f, d as u32).unwrap() {
                Msg::Update { round, .. } => assert_eq!(round, 2, "superseded round replied"),
                other => panic!("expected update, got {other:?}"),
            },
            other => panic!("{other:?}"),
        }
        // No second reply: round 1 was skipped, not queued behind.
        match server.recv_timeout(silence_probe()) {
            Recv::Timeout => {}
            other => panic!("expected exactly one reply, got {other:?}"),
        }
        server.send(protocol::encode(&Msg::Shutdown, d as u32));
        h.join().unwrap();
    }

    #[test]
    fn backlog_within_window_replies_to_each_true_round() {
        // Window 3, three queued broadcasts: the worker replies to ALL of
        // them, oldest first, each tagged with its true round id —
        // instead of discarding the backlog. A fourth broadcast beyond
        // the window would be skipped (covered by the window-1 test
        // above).
        let cfg = GdSecConfig { xi: Xi::Uniform(1.0), ..Default::default() };
        let prob = Problem::linear(synthetic::dna_like(1, 30), 1, 0.1);
        let d = prob.d;
        let local = prob.locals[0].clone();
        let factory: ProviderFactory =
            Box::new(move || Box::new(NativeProvider::new(local)) as Box<dyn GradProvider>);
        let (mut server, worker) = duplex();
        for (round, scale) in [(1u32, 0.0), (2, 0.01), (3, 0.02)] {
            server.send(protocol::encode(
                &Msg::Broadcast { round, theta: vec![scale; d], active: true },
                d as u32,
            ));
        }
        let faults = WorkerFaults::default();
        let h = std::thread::spawn(move || {
            worker_loop(0, 1, cfg, factory, worker, faults, WireFormat::Sparse, 3)
        });
        for expect in 1..=3u32 {
            match server.recv() {
                Recv::Frame(f) => match protocol::decode(&f, d as u32).unwrap() {
                    Msg::Update { round, .. } | Msg::Silence { round, .. } => {
                        assert_eq!(round, expect, "backlog replies out of order")
                    }
                    other => panic!("expected reply, got {other:?}"),
                },
                other => panic!("{other:?}"),
            }
        }
        match server.recv_timeout(silence_probe()) {
            Recv::Timeout => {}
            other => panic!("expected exactly three replies, got {other:?}"),
        }
        server.send(protocol::encode(&Msg::Shutdown, d as u32));
        h.join().unwrap();
    }

    #[test]
    fn crashed_worker_announces_and_rejoins_with_full_update() {
        // Crash at round 2, restart at round 4: rounds 2–3 are dark, the
        // round-4 broadcast triggers a Join tagged with the last round
        // the worker saw (1), and the round-5 broadcast is adopted as the
        // fresh snapshot — answered with a FULL transmission (θ-diff is
        // zero after the state reset, round-1 semantics).
        let cfg = GdSecConfig { xi: Xi::Uniform(1.0), ..Default::default() };
        let (mut server, h, d) = spawn_one(
            cfg,
            WorkerFaults { crash_at: Some(2), restart_at: Some(4), ..Default::default() },
        );
        let bcast = |round: u32, scale: f64| {
            protocol::encode(
                &Msg::Broadcast { round, theta: vec![scale; d], active: true },
                d as u32,
            )
        };
        server.send(bcast(1, 0.0));
        let first = match server.recv() {
            Recv::Frame(f) => protocol::decode(&f, d as u32).unwrap(),
            other => panic!("{other:?}"),
        };
        let full_nnz = match first {
            Msg::Update { round: 1, update, .. } => update.nnz(),
            other => panic!("expected round-1 update, got {other:?}"),
        };
        assert!(full_nnz > 0, "round 1 transmits uncensored");
        // Rounds 2 and 3: crashed, no replies.
        server.send(bcast(2, 0.01));
        server.send(bcast(3, 0.02));
        match server.recv_timeout(silence_probe()) {
            Recv::Timeout => {}
            other => panic!("expected dark worker, got {other:?}"),
        }
        // Round 4: restart → Join announcement with last_seen = 1.
        server.send(bcast(4, 0.03));
        match server.recv() {
            Recv::Frame(f) => match protocol::decode(&f, d as u32).unwrap() {
                Msg::Join { round, worker } => {
                    assert_eq!((round, worker), (1, 0));
                }
                other => panic!("expected join, got {other:?}"),
            },
            other => panic!("{other:?}"),
        }
        // Round 5: fresh snapshot → full update tagged with the true round.
        server.send(bcast(5, 0.04));
        match server.recv() {
            Recv::Frame(f) => match protocol::decode(&f, d as u32).unwrap() {
                Msg::Update { round, update, .. } => {
                    assert_eq!(round, 5);
                    // Zero θ-diff after the snapshot reset ⇒ zero censor
                    // threshold ⇒ every nonzero gradient coordinate goes
                    // on the wire, exactly like round 1.
                    assert!(update.nnz() >= full_nnz, "rejoin reply must be uncensored");
                }
                other => panic!("expected update, got {other:?}"),
            },
            other => panic!("{other:?}"),
        }
        server.send(protocol::encode(&Msg::Shutdown, d as u32));
        h.join().unwrap();
    }

    #[test]
    fn corrupt_frame_survivable() {
        let cfg = GdSecConfig { xi: Xi::Uniform(1.0), ..Default::default() };
        let (mut server, h, d) = spawn_one(cfg, WorkerFaults::default());
        server.send(vec![0xde, 0xad]);
        server.send(protocol::encode(
            &Msg::Broadcast { round: 1, theta: vec![0.0; d], active: true },
            d as u32,
        ));
        assert!(matches!(server.recv(), Recv::Frame(_)));
        server.send(protocol::encode(&Msg::Shutdown, d as u32));
        h.join().unwrap();
    }
}
