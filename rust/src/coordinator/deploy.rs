//! Shared run specification for the multi-process binaries.
//!
//! `gdsec-server` and `gdsec-worker` are separate OS processes that
//! never exchange a config file: both rebuild the *same* seeded
//! problem and GD-SEC hyper-parameters from the same four scalar flags
//! (`--seed --rows --workers --iters`). [`DeploySpec`] is that
//! derivation, factored out so the two binaries — and the server's
//! `--check-inproc` parity run — cannot drift apart. The spec mirrors
//! the integration suite's canonical logistic setup
//! (`tests/integration_coordinator.rs::cfg_for`), so a loopback
//! multi-process run is directly comparable to the pinned in-proc
//! trajectories.

use crate::algo::gdsec::{GdSecConfig, Xi};
use crate::coordinator::CoordConfig;
use crate::data::synthetic;
use crate::objectives::Problem;
use std::sync::Arc;

/// Everything a process needs to reconstruct the run: the dataset seed
/// and size, the worker count (which also shards the dataset), and the
/// round horizon. Two processes with equal specs build bitwise-equal
/// problems and configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeploySpec {
    pub seed: u64,
    pub rows: usize,
    pub workers: usize,
    pub iters: usize,
}

impl DeploySpec {
    /// The seeded logistic problem, sharded across `workers` locals.
    /// Deterministic in the spec: `synthetic::dna_like` is a counter-mode
    /// PRNG draw, and the row→worker shard split is positional.
    pub fn problem(&self) -> Problem {
        Problem::logistic(synthetic::dna_like(self.seed, self.rows), self.workers, 0.05)
    }

    /// The paper-faithful hyper-parameters for [`Self::problem`]:
    /// α = 1/L, β = 0.05, ξ_j ≡ 40 (the integration suite's `cfg_for`).
    pub fn gdsec(&self, prob: &Problem) -> GdSecConfig {
        GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.05,
            xi: Xi::Uniform(40.0),
            ..Default::default()
        }
    }

    /// A server-side [`CoordConfig`] for this spec: exact evaluator,
    /// fstar estimate, and problem label wired in; everything else
    /// (quorum, wire, staleness window, faults, …) keeps the
    /// `CoordConfig::new` env-override defaults so the binaries honor
    /// the same `GDSEC_*` knobs as the in-proc runners.
    pub fn coord_config(&self, prob: &Problem) -> CoordConfig {
        let mut cfg = CoordConfig::new(self.gdsec(prob), self.iters);
        let fstar = prob.estimate_fstar(crate::algo::gdsec::fstar_iters(self.iters));
        let prob2 = prob.clone();
        cfg.problem_name = prob.name.clone();
        cfg.fstar = fstar;
        cfg.evaluator = Some(Arc::new(move |theta: &[f64]| prob2.value(theta)));
        cfg
    }
}

impl Default for DeploySpec {
    fn default() -> DeploySpec {
        DeploySpec { seed: 17, rows: 90, workers: 3, iters: 30 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_specs_build_bitwise_equal_problems() {
        let spec = DeploySpec::default();
        let (a, b) = (spec.problem(), spec.problem());
        assert_eq!(a.d, b.d);
        assert_eq!(a.m(), spec.workers);
        let theta = vec![0.01; a.d];
        assert_eq!(a.value(&theta).to_bits(), b.value(&theta).to_bits());
        let (ga, gb) = (spec.gdsec(&a), spec.gdsec(&b));
        assert_eq!(ga.alpha.to_bits(), gb.alpha.to_bits());
        assert_eq!(ga.beta.to_bits(), gb.beta.to_bits());
    }

    #[test]
    fn coord_config_wires_evaluator_and_fstar() {
        let spec = DeploySpec { seed: 3, rows: 40, workers: 2, iters: 5 };
        let prob = spec.problem();
        let cfg = spec.coord_config(&prob);
        assert_eq!(cfg.iters, 5);
        assert_eq!(cfg.problem_name, prob.name);
        assert!(cfg.fstar.is_finite());
        let theta = vec![0.0; prob.d];
        let ev = cfg.evaluator.as_ref().expect("evaluator wired");
        assert_eq!(ev(&theta).to_bits(), prob.value(&theta).to_bits());
    }
}
