//! Thread-free federated harness: cohort-sampled GD-SEC rounds at
//! M = 10,000 workers in a single process.
//!
//! The threaded [`Coordinator`](super::Coordinator) spawns one OS thread
//! per worker — the right shape for exercising the wire protocol and the
//! liveness machine, and the wrong shape for a 10k-worker fleet (10k
//! stacks, 10k channels, scheduler thrash). This harness keeps the exact
//! GD-SEC round semantics ([`WorkerState::sparsify_into`] on the worker,
//! the sharded Eq. 6 fold on the server) but drives every worker inline
//! on the calling thread over a virtual transport: an update "arrives"
//! by reference, bit accounting uses the real wire encoders
//! ([`compress::wire_bits`]), and nothing is spawned per worker. A
//! 10k-worker round is just a loop — cheap, portable, and deterministic
//! for CI.
//!
//! Two scale features are threaded through, mirroring the coordinator:
//!
//! - **Cohort sampling** ([`CohortPlan`]): each round draws a seeded
//!   subset of the fleet; everyone else keeps h_m/e_m frozen (the
//!   paper's §IV-G1 partial-participation semantics — identical to a
//!   round in which the censoring threshold suppressed every
//!   component). Full participation (`cohort: None`) reproduces
//!   [`gdsec::run_states`] op-for-op.
//! - **O(cohort) server memory** ([`StateStore`]): per-worker h-share
//!   ledgers live in an evictable slab store. Only the workers that
//!   transmitted recently are resident; everyone else is parked in
//!   compact sparse form. The fold books shares through the store's
//!   slot map ([`ShareBook`]), so server resident state is
//!   O(active cohort · touched coords), not O(M·d).
//!
//! `benches/federated_scale.rs` sweeps M × cohort fraction over this
//! harness and pins the evicting store bitwise against an always-resident
//! replica before timing anything.

use crate::algo::engine::EngineOpts;
use crate::algo::gdsec::{GdSecConfig, WorkerState};
use crate::compress::{self, SparseUpdate, WireFormat};
use crate::coordinator::scheduler::CohortPlan;
use crate::objectives::{BlockedGrad, Problem};
use crate::util::pool::Pool;
use crate::util::shard::{ShardApply, ShardPlan, ShareBook};
use crate::util::state_store::{StateStore, DEFAULT_EVICT_ROUNDS};

/// Configuration for one [`run_federated`] experiment.
#[derive(Debug)]
pub struct FederatedConfig {
    /// GD-SEC hyperparameters (α, β, ξ, EC/state-variable toggles).
    pub gdsec: GdSecConfig,
    /// Number of optimization rounds.
    pub iters: usize,
    /// Per-round cohort sampler. `None` = full participation every
    /// round (bitwise the engine's trajectory).
    pub cohort: Option<CohortPlan>,
    /// Ledger eviction horizon in rounds. `None` defers to the policy
    /// of [`effective_horizon`](Self::effective_horizon): evict after
    /// [`DEFAULT_EVICT_ROUNDS`] idle rounds when a cohort is set,
    /// always-resident otherwise.
    pub evict_after: Option<u32>,
    /// Wire encoding used for the uplink bit accounting.
    pub wire: WireFormat,
    /// Record f(θ) every `eval_every` rounds (and always after the
    /// final round). 0 = never.
    pub eval_every: usize,
}

impl FederatedConfig {
    pub fn new(gdsec: GdSecConfig, iters: usize) -> FederatedConfig {
        FederatedConfig {
            gdsec,
            iters,
            cohort: None,
            evict_after: None,
            wire: WireFormat::default(),
            eval_every: 10,
        }
    }

    /// Same policy as [`CoordConfig::effective_horizon`]
    /// (super::CoordConfig::effective_horizon): an explicit
    /// `evict_after` wins; otherwise sampling a cohort implies the
    /// default idle horizon, and full participation keeps the dense
    /// always-resident ledger (bitwise and allocation-wise the
    /// pre-store layout).
    pub fn effective_horizon(&self) -> Option<u32> {
        self.evict_after.or(if self.cohort.is_some() { Some(DEFAULT_EVICT_ROUNDS) } else { None })
    }
}

/// Everything a bench or test needs from a federated run: the recorded
/// objective trace, the uplink/censoring counters, the state-store
/// telemetry, and the final states (for bitwise parity pins).
#[derive(Debug)]
pub struct FederatedOutcome {
    /// (round, f(θ^k)) samples at `eval_every` cadence plus the final round.
    pub fvals: Vec<(usize, f64)>,
    /// Total uplink payload across all rounds (real wire encoders).
    pub uplink_bits: u64,
    /// Number of worker-rounds that transmitted at least one component.
    pub transmissions: u64,
    /// Number of active worker-rounds fully censored (nothing sent).
    pub censored: u64,
    /// Ledger slabs evicted / restored over the run.
    pub evictions: u64,
    pub restores: u64,
    /// Server per-worker-state resident bytes after the final round.
    pub resident_state_bytes: usize,
    /// High-water mark of the same over the whole run.
    pub peak_state_bytes: usize,
    /// Final server model.
    pub theta: Vec<f64>,
    /// Final server state variable h (mirror of Σ_m h_m).
    pub h: Vec<f64>,
    /// The ledger store (query with
    /// [`ledger_dense`](StateStore::ledger_dense) for parity checks).
    pub store: StateStore,
    /// Final worker states (h_m/e_m, for mirror/parity checks).
    pub workers: Vec<WorkerState>,
}

/// Run GD-SEC over the virtual transport: every worker stepped inline,
/// the server fold sharded over `pool`, cohort + ledger eviction as
/// configured. Deterministic for a fixed problem/config at any thread
/// count (worker steps are independent; the sharded fold is bitwise
/// shard- and thread-count invariant; reductions happen in worker-id
/// order on the calling thread).
pub fn run_federated(prob: &Problem, mut fc: FederatedConfig, pool: &Pool) -> FederatedOutcome {
    let d = prob.d;
    let m = prob.m();
    let cfg = fc.gdsec.clone();
    let sv = cfg.state_variable;
    let horizon = fc.effective_horizon();

    let mut workers: Vec<WorkerState> = (0..m).map(|_| WorkerState::new(d)).collect();
    let mut ups: Vec<SparseUpdate> = (0..m).map(|_| SparseUpdate::empty(d)).collect();
    // Same fixed nnz-budget block tree as the engine's nested lanes and
    // the coordinator's NativeProvider — gradients are bitwise identical
    // to both at any block count.
    let nnz_budget = EngineOpts::from_env().nnz_budget;
    let mut plans: Vec<BlockedGrad> =
        prob.locals.iter().map(|l| l.blocked_grad_plan(nnz_budget)).collect();
    let mut store = if sv { StateStore::new(d, m, horizon) } else { StateStore::resident(0, 0) };

    let mut theta = vec![0.0; d];
    let mut theta_prev = vec![0.0; d];
    let mut h = vec![0.0; d];
    let mut agg = vec![0.0; d];
    let mut theta_diff = vec![0.0; d];
    let mut plan = ShardPlan::new();
    plan.ensure(d, pool);

    let mut cohort = fc.cohort.take();
    let mut transmitters: Vec<usize> = Vec::with_capacity(m);
    let mut fvals = Vec::new();
    let mut uplink_bits = 0u64;
    let mut transmissions = 0u64;
    let mut censored = 0u64;

    for k in 1..=fc.iters {
        if let Some(cp) = &mut cohort {
            cp.sample(k, m);
        }

        crate::linalg::sub(&theta, &theta_prev, &mut theta_diff);

        // Worker phase (virtual transport): each active worker computes
        // its local gradient, censors against θ-diff, and "transmits" by
        // leaving the survivors in its reused wire buffer. Inactive
        // workers neither compute nor move h_m/e_m (§IV-G1).
        transmitters.clear();
        for w in 0..m {
            if let Some(cp) = &cohort {
                if !cp.contains(w) {
                    continue;
                }
            }
            let ws = &mut workers[w];
            prob.locals[w].grad_blocked(&theta, &mut plans[w], ws.grad_mut());
            ws.sparsify_into(&cfg, m, &theta_diff, &mut ups[w]);
            if ups[w].nnz() == 0 {
                censored += 1;
            } else {
                uplink_bits += compress::wire_bits(&ups[w], fc.wire) as u64;
                transmissions += 1;
                transmitters.push(w);
            }
        }

        // Server phase: age out ledgers idle past the horizon BEFORE
        // staging this round's transmitters — with the default horizon
        // of 1 only the current cohort's slabs are resident through the
        // fold, which is what makes server memory O(cohort), not O(M).
        if sv {
            store.evict_idle(k as u32);
            for &w in &transmitters {
                store.stage(w, k as u32, &ups[w].idx);
            }
        }
        let (slabs, slot_of) = store.book_view();
        plan.fold(
            pool,
            transmitters.iter().map(|&w| (w, &ups[w])),
            ShardApply {
                theta: &mut theta,
                h: &mut h,
                agg: &mut agg,
                theta_prev: Some(&mut theta_prev),
                alpha: cfg.alpha,
                beta: cfg.beta,
                state_variable: sv,
                fold_scale: 1.0,
                // Engine contract: `agg` is all-zeros between rounds
                // (nothing is ever staged here) and the fold re-zeroes
                // it after the step.
                staged_agg: true,
                shares: sv.then_some(ShareBook { slabs, slot_of, scale: cfg.beta }),
            },
        );

        if fc.eval_every != 0 && (k % fc.eval_every == 0 || k == fc.iters) {
            fvals.push((k, prob.value_pooled(&theta, pool)));
        }
    }

    FederatedOutcome {
        fvals,
        uplink_bits,
        transmissions,
        censored,
        evictions: store.evictions(),
        restores: store.restores(),
        resident_state_bytes: store.resident_bytes(),
        peak_state_bytes: store.peak_resident_bytes(),
        theta,
        h,
        store,
        workers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::gdsec::{self, Xi};
    use crate::coordinator::scheduler::{CohortPlan, DEFAULT_COHORT_SEED};
    use crate::data::synthetic;

    fn small_problem(m: usize) -> Problem {
        let ds = synthetic::rcv1_like(7, m.max(64), 48, 6);
        Problem::logistic(ds, m, 0.0)
    }

    fn small_cfg() -> GdSecConfig {
        GdSecConfig {
            alpha: 0.05,
            beta: 0.5,
            xi: Xi::Uniform(0.3),
            fstar: Some(0.0),
            ..GdSecConfig::default()
        }
    }

    /// Full participation through the federated harness is bitwise the
    /// engine's trajectory: same θ, h, and worker states.
    #[test]
    fn full_participation_matches_engine_bitwise() {
        let prob = small_problem(12);
        let cfg = small_cfg();
        let iters = 40;

        let fed = run_federated(&prob, FederatedConfig::new(cfg.clone(), iters), Pool::global());
        let eng = gdsec::run_states(&prob, &cfg, iters, |_k| None, Pool::global());

        assert_eq!(to_bits(&fed.theta), to_bits(&eng.server.theta));
        assert_eq!(to_bits(&fed.h), to_bits(&eng.server.h));
        for (fw, ew) in fed.workers.iter().zip(eng.workers.iter()) {
            assert_eq!(to_bits(&fw.h), to_bits(&ew.h));
            assert_eq!(to_bits(&fw.e), to_bits(&ew.e));
        }
        assert!(fed.transmissions > 0);
    }

    /// Cohort rounds with the evicting store are bitwise identical to
    /// the same cohort rounds over an always-resident store, and the
    /// eviction machinery actually cycles.
    #[test]
    fn evicting_store_matches_resident_bitwise_under_cohort() {
        let prob = small_problem(24);
        let cfg = small_cfg();
        let iters = 60;
        let mk = |evict_after: Option<u32>| {
            let mut fc = FederatedConfig::new(cfg.clone(), iters);
            fc.cohort = Some(CohortPlan::count(5, DEFAULT_COHORT_SEED));
            fc.evict_after = evict_after;
            run_federated(&prob, fc, Pool::global())
        };
        // u32::MAX horizon: the store never ages anything out — the
        // always-resident baseline with identical cohort schedule.
        let resident = mk(Some(u32::MAX));
        let evicting = mk(None);

        assert_eq!(resident.evictions, 0);
        assert!(evicting.evictions > 0, "horizon-1 store never evicted");
        assert!(evicting.restores > 0, "no worker ever rejoined the cohort");
        assert_eq!(to_bits(&evicting.theta), to_bits(&resident.theta));
        assert_eq!(to_bits(&evicting.h), to_bits(&resident.h));
        let mut a = vec![0.0; prob.d];
        let mut b = vec![0.0; prob.d];
        for w in 0..prob.m() {
            evicting.store.ledger_dense(w, &mut a);
            resident.store.ledger_dense(w, &mut b);
            assert_eq!(to_bits(&a), to_bits(&b), "worker {w} ledger diverged");
        }
        assert!(evicting.peak_state_bytes < resident.peak_state_bytes);
    }

    /// The h mirror holds through cohort sampling and eviction:
    /// h == Σ_m h_m bit-for-bit at the end of the run.
    #[test]
    fn h_mirror_holds_under_cohort_and_eviction() {
        let prob = small_problem(16);
        let mut fc = FederatedConfig::new(small_cfg(), 50);
        fc.cohort = Some(CohortPlan::fraction(0.25, 0xFEED));
        let out = run_federated(&prob, fc, Pool::global());
        let mut sum = vec![0.0; prob.d];
        for ws in &out.workers {
            for (s, v) in sum.iter_mut().zip(ws.h.iter()) {
                *s += *v;
            }
        }
        for (i, (hi, si)) in out.h.iter().zip(sum.iter()).enumerate() {
            assert!(
                (hi - si).abs() <= 1e-9 * si.abs().max(1.0),
                "mirror broke at {i}: {hi} vs {si}"
            );
        }
        // Ledgers mirror the workers' own h_m exactly.
        let mut led = vec![0.0; prob.d];
        for (w, ws) in out.workers.iter().enumerate() {
            out.store.ledger_dense(w, &mut led);
            assert_eq!(to_bits(&led), to_bits(&ws.h), "ledger {w} != worker h");
        }
    }

    /// Two runs of the same config are identical — the harness has no
    /// hidden clock or thread-order dependence.
    #[test]
    fn federated_run_is_deterministic() {
        let prob = small_problem(20);
        let mk = || {
            let mut fc = FederatedConfig::new(small_cfg(), 30);
            fc.cohort = Some(CohortPlan::fraction(0.3, DEFAULT_COHORT_SEED));
            run_federated(&prob, fc, Pool::global())
        };
        let a = mk();
        let b = mk();
        assert_eq!(to_bits(&a.theta), to_bits(&b.theta));
        assert_eq!(a.uplink_bits, b.uplink_bits);
        assert_eq!(a.evictions, b.evictions);
        assert_eq!(a.restores, b.restores);
        assert_eq!(a.fvals.len(), b.fvals.len());
        for ((ka, fa), (kb, fb)) in a.fvals.iter().zip(b.fvals.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(fa.to_bits(), fb.to_bits());
        }
    }

    fn to_bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
