//! Wire protocol between server and workers.
//!
//! Every message is a framed byte buffer; the transport counts frame bytes
//! per link, and the figures use the *uplink payload* bits (the paper's
//! metric) while header/control bytes are reported separately as protocol
//! overhead.
//!
//! Frame layout (little endian):
//! ```text
//! magic  u8   = 0xG5 (0xA5)
//! kind   u8   (MsgKind)
//! round  u32
//! sender u32  (worker id, or u32::MAX for server)
//! len    u32  (payload byte length)
//! payload[len]
//! ```

use crate::compress::{self, SparseUpdate};

pub const MAGIC: u8 = 0xA5;
pub const SERVER_ID: u32 = u32::MAX;
pub const HEADER_LEN: usize = 1 + 1 + 4 + 4 + 4;

/// Message kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgKind {
    /// Server → workers: new iterate θ^k (f64 payload) + active flag.
    Broadcast = 1,
    /// Worker → server: sparsified update Δ̂_m (RLE payload) + local f_m.
    Update = 2,
    /// Worker → server: nothing survived censoring this round
    /// (payload: local f_m only). Payload *bits* for the paper metric: 0.
    Silence = 3,
    /// Server → workers: stop.
    Shutdown = 4,
    /// Worker → server: update in the adaptive wire format
    /// ([`crate::compress::encode_adaptive`] — a 1-byte tag picks the
    /// cheaper of sparse-RLE and dense f32; caps weak-censoring rounds
    /// at `8 + 32·d` payload bits). Decodes to the same [`Msg::Update`].
    UpdateAdaptive = 5,
    /// Worker → server: re-admission announcement from a restarted
    /// worker. `round` carries the last round the worker saw before
    /// crashing (0 if it never completed one); the server answers with
    /// the next θ broadcast and treats it as a fresh snapshot.
    Join = 6,
}

impl MsgKind {
    pub fn from_u8(v: u8) -> Option<MsgKind> {
        match v {
            1 => Some(MsgKind::Broadcast),
            2 => Some(MsgKind::Update),
            3 => Some(MsgKind::Silence),
            4 => Some(MsgKind::Shutdown),
            5 => Some(MsgKind::UpdateAdaptive),
            6 => Some(MsgKind::Join),
            _ => None,
        }
    }
}

/// Uplink payload encoding for worker updates — defined next to the
/// codecs in [`crate::compress`] (the single-process trainers account
/// the same formats without materializing frames); re-exported here for
/// the protocol surface. The crate-wide default is `Adaptive`.
pub use crate::compress::WireFormat;

/// A decoded message.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// θ carried in f64 so the distributed trajectory is bit-identical to
    /// the serial reference (the downlink is not the paper's metric).
    Broadcast { round: u32, theta: Vec<f64>, active: bool },
    Update { round: u32, worker: u32, update: SparseUpdate, local_f: f64 },
    Silence { round: u32, worker: u32, local_f: f64 },
    /// Re-admission handshake opener; `round` is the worker's last-seen
    /// round (0 if none).
    Join { round: u32, worker: u32 },
    Shutdown,
}

/// Encode a frame in the default (paper) wire format.
pub fn encode(msg: &Msg, dim: u32) -> Vec<u8> {
    encode_wire(msg, dim, WireFormat::Sparse)
}

/// Encode a frame; `wire` selects the update payload codec (only
/// [`Msg::Update`] frames differ between formats).
pub fn encode_wire(msg: &Msg, dim: u32, wire: WireFormat) -> Vec<u8> {
    let (kind, round, sender, payload) = match msg {
        Msg::Broadcast { round, theta, active } => {
            let mut p = Vec::with_capacity(1 + theta.len() * 8);
            p.push(u8::from(*active));
            for &v in theta {
                p.extend_from_slice(&v.to_le_bytes());
            }
            (MsgKind::Broadcast, *round, SERVER_ID, p)
        }
        Msg::Update { round, worker, update, local_f } => {
            debug_assert_eq!(update.dim, dim);
            let mut p = Vec::new();
            p.extend_from_slice(&local_f.to_le_bytes());
            let kind = match wire {
                WireFormat::Sparse => {
                    compress::encode_sparse(update, &mut p);
                    MsgKind::Update
                }
                WireFormat::Adaptive => {
                    compress::encode_adaptive(update, &mut p);
                    MsgKind::UpdateAdaptive
                }
            };
            (kind, *round, *worker, p)
        }
        Msg::Silence { round, worker, local_f } => {
            (MsgKind::Silence, *round, *worker, local_f.to_le_bytes().to_vec())
        }
        Msg::Join { round, worker } => (MsgKind::Join, *round, *worker, Vec::new()),
        Msg::Shutdown => (MsgKind::Shutdown, 0, SERVER_ID, Vec::new()),
    };
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.push(MAGIC);
    out.push(kind as u8);
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&sender.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

#[derive(Debug, PartialEq)]
pub enum ProtoError {
    Truncated,
    BadMagic(u8),
    BadKind(u8),
    BadPayload,
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Truncated => write!(f, "frame too short"),
            ProtoError::BadMagic(b) => write!(f, "bad magic byte {b:#x}"),
            ProtoError::BadKind(k) => write!(f, "unknown message kind {k}"),
            ProtoError::BadPayload => write!(f, "payload malformed"),
        }
    }
}

impl std::error::Error for ProtoError {}

/// Decode a frame. `dim` is the model dimension (known to both ends).
pub fn decode(buf: &[u8], dim: u32) -> Result<Msg, ProtoError> {
    if buf.len() < HEADER_LEN {
        return Err(ProtoError::Truncated);
    }
    if buf[0] != MAGIC {
        return Err(ProtoError::BadMagic(buf[0]));
    }
    let kind = MsgKind::from_u8(buf[1]).ok_or(ProtoError::BadKind(buf[1]))?;
    let round = u32::from_le_bytes([buf[2], buf[3], buf[4], buf[5]]);
    let sender = u32::from_le_bytes([buf[6], buf[7], buf[8], buf[9]]);
    let len = u32::from_le_bytes([buf[10], buf[11], buf[12], buf[13]]) as usize;
    if buf.len() != HEADER_LEN + len {
        return Err(ProtoError::Truncated);
    }
    let p = &buf[HEADER_LEN..];
    match kind {
        MsgKind::Broadcast => {
            if p.is_empty() || (p.len() - 1) % 8 != 0 {
                return Err(ProtoError::BadPayload);
            }
            let active = p[0] != 0;
            let n = (p.len() - 1) / 8;
            // A θ of the wrong dimension would decode fine here and only
            // detonate later in the worker's gradient (or silently read
            // out of bounds semantics into the objective) — reject it at
            // the protocol boundary like every other payload mismatch.
            if n != dim as usize {
                return Err(ProtoError::BadPayload);
            }
            let mut theta = Vec::with_capacity(n);
            for k in 0..n {
                let b = &p[1 + 8 * k..1 + 8 * k + 8];
                theta.push(f64::from_le_bytes([
                    b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
                ]));
            }
            Ok(Msg::Broadcast { round, theta, active })
        }
        MsgKind::Update | MsgKind::UpdateAdaptive => {
            if p.len() < 8 {
                return Err(ProtoError::BadPayload);
            }
            let local_f = f64::from_le_bytes([p[0], p[1], p[2], p[3], p[4], p[5], p[6], p[7]]);
            let (update, used) = if kind == MsgKind::Update {
                compress::decode_sparse(&p[8..], dim).ok_or(ProtoError::BadPayload)?
            } else {
                compress::decode_adaptive(&p[8..], dim).ok_or(ProtoError::BadPayload)?
            };
            if 8 + used != p.len() {
                return Err(ProtoError::BadPayload);
            }
            Ok(Msg::Update { round, worker: sender, update, local_f })
        }
        MsgKind::Silence => {
            if p.len() != 8 {
                return Err(ProtoError::BadPayload);
            }
            let local_f = f64::from_le_bytes([p[0], p[1], p[2], p[3], p[4], p[5], p[6], p[7]]);
            Ok(Msg::Silence { round, worker: sender, local_f })
        }
        MsgKind::Join => {
            if !p.is_empty() {
                return Err(ProtoError::BadPayload);
            }
            Ok(Msg::Join { round, worker: sender })
        }
        MsgKind::Shutdown => Ok(Msg::Shutdown),
    }
}

/// The paper-metric payload bits carried by an uplink frame: the encoded
/// sparse update only (silence and headers cost 0 in the paper's model).
/// Always prices the [`WireFormat::Sparse`] codec — the paper's format —
/// regardless of the crate's (Adaptive) default; for frames already in
/// hand use [`update_payload_bits`], which is codec-exact for whichever
/// format actually encoded them.
pub fn uplink_payload_bits(msg: &Msg) -> u64 {
    match msg {
        Msg::Update { update, .. } => compress::sparse_bits(update) as u64,
        _ => 0,
    }
}

/// Exact payload bits of an encoded `Update`/`UpdateAdaptive` frame: the
/// frame bytes minus header and the 8-byte reported loss. For the sparse
/// format this equals [`crate::compress::sparse_bits`] (the codecs are
/// length-exact); for the adaptive format it includes the 1-byte tag.
pub fn update_payload_bits(frame: &[u8]) -> u64 {
    (frame.len().saturating_sub(HEADER_LEN + 8) * 8) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_roundtrip() {
        let m = Msg::Broadcast { round: 7, theta: vec![1.5, -2.25, 1e-300], active: true };
        let buf = encode(&m, 3);
        assert_eq!(decode(&buf, 3).unwrap(), m);
    }

    #[test]
    fn update_roundtrip() {
        let mut v = vec![0.0f64; 50];
        v[3] = 0.5;
        v[49] = -1.0;
        let u = SparseUpdate::from_dense(&v);
        let m = Msg::Update { round: 2, worker: 4, update: u, local_f: 0.125 };
        let buf = encode(&m, 50);
        assert_eq!(decode(&buf, 50).unwrap(), m);
    }

    #[test]
    fn silence_roundtrip_zero_payload_bits() {
        let m = Msg::Silence { round: 9, worker: 1, local_f: 2.5 };
        let buf = encode(&m, 10);
        let back = decode(&buf, 10).unwrap();
        assert_eq!(back, m);
        assert_eq!(uplink_payload_bits(&back), 0);
    }

    #[test]
    fn shutdown_roundtrip() {
        let buf = encode(&Msg::Shutdown, 1);
        assert_eq!(decode(&buf, 1).unwrap(), Msg::Shutdown);
    }

    #[test]
    fn join_roundtrip_and_rejects_payload() {
        let m = Msg::Join { round: 5, worker: 2 };
        let buf = encode(&m, 10);
        assert_eq!(uplink_payload_bits(&m), 0);
        assert_eq!(decode(&buf, 10).unwrap(), m);
        // Never-completed-a-round join.
        let fresh = Msg::Join { round: 0, worker: 0 };
        assert_eq!(decode(&encode(&fresh, 1), 1).unwrap(), fresh);
        // A Join with payload bytes is malformed.
        let mut bad = buf.clone();
        bad[10..14].copy_from_slice(&1u32.to_le_bytes());
        bad.push(0);
        assert_eq!(decode(&bad, 10), Err(ProtoError::BadPayload));
    }

    #[test]
    fn payload_bits_match_codec() {
        let mut v = vec![0.0f64; 100];
        for i in (0..100).step_by(7) {
            v[i] = i as f64;
        }
        let u = SparseUpdate::from_dense(&v);
        let expect = crate::compress::sparse_bits(&u) as u64;
        let m = Msg::Update { round: 1, worker: 0, update: u, local_f: 0.0 };
        assert_eq!(uplink_payload_bits(&m), expect);
    }

    #[test]
    fn adaptive_update_roundtrip_and_tag_accounting() {
        // Sparse-cheaper case: decodes to the same Msg; payload bits are
        // sparse + the 8-bit tag.
        let mut v = vec![0.0f64; 200];
        v[3] = 0.5;
        v[150] = -1.0;
        let u = SparseUpdate::from_dense(&v);
        let sparse_cost = crate::compress::sparse_bits(&u) as u64;
        let m = Msg::Update { round: 4, worker: 1, update: u, local_f: 0.5 };
        let buf = encode_wire(&m, 200, WireFormat::Adaptive);
        assert_eq!(decode(&buf, 200).unwrap(), m);
        assert_eq!(update_payload_bits(&buf), sparse_cost + 8);

        // Dense-cheaper case: a full vector costs 8 + 32·d, less than the
        // RLE stream.
        let dense: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let u = SparseUpdate::from_dense(&dense);
        let m = Msg::Update { round: 5, worker: 2, update: u.clone(), local_f: -2.0 };
        let buf = encode_wire(&m, 100, WireFormat::Adaptive);
        assert_eq!(update_payload_bits(&buf), 8 + 32 * 100);
        assert!(update_payload_bits(&buf) < crate::compress::sparse_bits(&u) as u64);
        match decode(&buf, 100).unwrap() {
            Msg::Update { update, .. } => assert_eq!(update.to_dense(), u.to_dense()),
            other => panic!("expected update, got {other:?}"),
        }

        // The sparse wire's accounting helper agrees with sparse_bits.
        let buf = encode_wire(&m, 100, WireFormat::Sparse);
        assert_eq!(update_payload_bits(&buf), crate::compress::sparse_bits(&u) as u64);
    }

    #[test]
    fn adaptive_rejects_truncation() {
        let mut v = vec![0.0f64; 50];
        v[7] = 1.5;
        let m = Msg::Update {
            round: 1,
            worker: 0,
            update: SparseUpdate::from_dense(&v),
            local_f: 0.0,
        };
        let buf = encode_wire(&m, 50, WireFormat::Adaptive);
        assert!(decode(&buf[..buf.len() - 1], 50).is_err());
    }

    #[test]
    fn rejects_corruption() {
        let m = Msg::Silence { round: 1, worker: 2, local_f: 1.0 };
        let mut buf = encode(&m, 10);
        assert_eq!(decode(&buf[..5], 10), Err(ProtoError::Truncated));
        buf[0] = 0x00;
        assert!(matches!(decode(&buf, 10), Err(ProtoError::BadMagic(0))));
        buf[0] = MAGIC;
        buf[1] = 99;
        assert!(matches!(decode(&buf, 10), Err(ProtoError::BadKind(99))));
        // wrong length
        let m2 = Msg::Broadcast { round: 1, theta: vec![1.0], active: false };
        let mut b2 = encode(&m2, 1);
        b2.push(0);
        assert_eq!(decode(&b2, 1), Err(ProtoError::Truncated));
    }

    #[test]
    fn broadcast_with_wrong_dimension_rejected() {
        // Well-formed frame, wrong model dimension: must fail at decode,
        // not inside the worker's gradient.
        let m = Msg::Broadcast { round: 1, theta: vec![1.0, 2.0, 3.0], active: true };
        let buf = encode(&m, 3);
        assert_eq!(decode(&buf, 4), Err(ProtoError::BadPayload));
        assert_eq!(decode(&buf, 2), Err(ProtoError::BadPayload));
        assert!(decode(&buf, 3).is_ok());
    }

    #[test]
    fn update_with_out_of_range_index_rejected() {
        let mut v = vec![0.0f64; 20];
        v[19] = 1.0;
        let u = SparseUpdate::from_dense(&v);
        let m = Msg::Update { round: 1, worker: 0, update: u, local_f: 0.0 };
        let buf = encode(&m, 20);
        assert!(decode(&buf, 10).is_err());
    }
}
