//! The event-driven round state machine behind the semi-synchronous
//! coordinator.
//!
//! Each round the server broadcasts θ^k and then *admits* worker replies
//! in arrival order. A reply tagged with an older round id is routed to
//! the **stale pool** instead of being misattributed to the current
//! round (the strictly synchronous gather silently did exactly that for
//! a worker that had timed out one round earlier). Once every live
//! active worker has resolved — fresh reply, timeout, or death — the
//! round is **cut**: the first `K` replies in virtual-arrival order
//! (`(DelayPlan::delay(w, k), w)` — deterministic, never wall-clock)
//! are applied immediately, and the rest are parked as stale and folded
//! into the *next* round's aggregation, exactly where GD-SEC's Eq. 6
//! would have put them one round earlier (LAQ-style bounded staleness).
//!
//! With `Quorum::All` the cut keeps every reply and the machine is
//! bit-for-bit identical to the synchronous protocol — pinned by
//! `tests/integration_coordinator.rs` against the serial reference,
//! including under injected delays.

use super::protocol::Msg;
use super::transport::DelayPlan;
use crate::compress::SparseUpdate;

/// How many of a round's live active workers must report before the
/// server steps θ.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Quorum {
    /// Every live active worker — the paper's synchronous protocol.
    #[default]
    All,
    /// A fixed count K (clamped to `[1, active]`).
    Count(usize),
    /// `ceil(ratio · active)`, clamped to `[1, active]`.
    Fraction(f64),
}

impl Quorum {
    /// Default with the `GDSEC_QUORUM` env override: `all`, an absolute
    /// count (`2`), or a participation ratio in (0, 1) (`0.5`).
    ///
    /// Panics on anything else: a malformed value silently degrading to
    /// `All` would turn the CI quorum matrix into a synchronous no-op
    /// while staying green.
    pub fn from_env() -> Quorum {
        match std::env::var("GDSEC_QUORUM").ok().as_deref() {
            None | Some("") | Some("all") => Quorum::All,
            Some(s) => {
                if let Ok(k) = s.parse::<usize>() {
                    Quorum::Count(k)
                } else {
                    match s.parse::<f64>() {
                        Ok(r) if r > 0.0 && r < 1.0 => Quorum::Fraction(r),
                        _ => panic!(
                            "GDSEC_QUORUM must be `all`, a worker count, or a \
                             ratio in (0, 1); got {s:?}"
                        ),
                    }
                }
            }
        }
    }

    /// The quorum size K for a round with `active` live scheduled
    /// workers.
    pub fn k_of(&self, active: usize) -> usize {
        if active == 0 {
            return 0;
        }
        match self {
            Quorum::All => active,
            Quorum::Count(k) => (*k).clamp(1, active),
            Quorum::Fraction(r) => ((r * active as f64).ceil() as usize).clamp(1, active),
        }
    }
}

/// A transmitted update the server holds past its round: parked by a
/// quorum cut, or physically delivered a round late after a timeout.
/// Folded into the next aggregation in `(round, worker)` order.
#[derive(Debug, Clone)]
pub struct StaleUpdate {
    pub round: u32,
    pub worker: usize,
    pub update: SparseUpdate,
}

/// Routing verdict for one admitted reply.
#[derive(Debug)]
pub enum Admit {
    /// A fresh reply for the current round (update or silence) — counts
    /// toward the quorum.
    Fresh,
    /// An older round's update, physically delivered late: the caller
    /// adds it to the stale pool (its bits went on the wire — account
    /// them — but it must not be misread as this round's reply).
    Stale(StaleUpdate),
    /// Nothing actionable: stale silence, duplicate, wrong-direction or
    /// future-round frame.
    Ignored,
}

/// Per-round reply state for one gather.
pub struct RoundState {
    k: u32,
    updates: Vec<Option<SparseUpdate>>,
    local_f: Vec<Option<f64>>,
    replied: Vec<bool>,
}

/// The quorum cut of a completed gather.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Cut {
    /// Repliers beyond the quorum, ascending worker id — their updates
    /// (if any) are parked for the next round.
    pub late: Vec<usize>,
    /// Wall-clock proxy for the round: the largest virtual delay among
    /// the replies the server waited for (the K-th virtual arrival).
    pub units: u64,
}

impl RoundState {
    pub fn new(k: u32, m: usize) -> RoundState {
        RoundState {
            k,
            updates: vec![None; m],
            local_f: vec![None; m],
            replied: vec![false; m],
        }
    }

    /// Admit worker `w`'s decoded reply, routing by round id. The caller
    /// owns liveness (timeouts / strikes) and bit accounting.
    pub fn admit(&mut self, w: usize, msg: Msg) -> Admit {
        match msg {
            Msg::Update { round, update, local_f, .. } => {
                if round == self.k {
                    if self.replied[w] {
                        return Admit::Ignored;
                    }
                    self.replied[w] = true;
                    self.local_f[w] = Some(local_f);
                    self.updates[w] = Some(update);
                    Admit::Fresh
                } else if round < self.k {
                    Admit::Stale(StaleUpdate { round, worker: w, update })
                } else {
                    Admit::Ignored
                }
            }
            Msg::Silence { round, local_f, .. } => {
                if round == self.k && !self.replied[w] {
                    self.replied[w] = true;
                    self.local_f[w] = Some(local_f);
                    Admit::Fresh
                } else {
                    Admit::Ignored
                }
            }
            _ => Admit::Ignored,
        }
    }

    /// Whether worker `w` has reported fresh this round.
    pub fn replied(&self, w: usize) -> bool {
        self.replied[w]
    }

    /// Fresh local objective values, indexed by worker.
    pub fn local_f(&self) -> &[Option<f64>] {
        &self.local_f
    }

    /// Fresh updates, indexed by worker (None = silent / no reply).
    pub fn updates(&self) -> &[Option<SparseUpdate>] {
        &self.updates
    }

    /// Take worker `w`'s fresh update out (for parking late ones).
    pub fn take_update(&mut self, w: usize) -> Option<SparseUpdate> {
        self.updates[w].take()
    }

    /// Cut the round at quorum `k_quorum`: rank this round's repliers by
    /// `(delay(w, k), w)` — virtual arrival order, deterministic for any
    /// thread schedule — keep the first `k_quorum` as on-time, and
    /// return the rest (ascending worker id) as late. `units` is the
    /// largest delay among the on-time replies: the wall-clock proxy the
    /// quorum actually waited for.
    pub fn cut(&self, k_quorum: usize, plan: &DelayPlan) -> Cut {
        let mut arrivals: Vec<(u64, usize)> = (0..self.replied.len())
            .filter(|&w| self.replied[w])
            .map(|w| (plan.delay(w, self.k as usize), w))
            .collect();
        arrivals.sort_unstable();
        let on_time = k_quorum.min(arrivals.len());
        let units = arrivals[..on_time].iter().map(|&(d, _)| d).max().unwrap_or(0);
        let mut late: Vec<usize> = arrivals[on_time..].iter().map(|&(_, w)| w).collect();
        late.sort_unstable();
        Cut { late, units }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(dim: usize, i: u32) -> SparseUpdate {
        let mut u = SparseUpdate::empty(dim);
        u.idx.push(i);
        u.val.push(1.0);
        u
    }

    #[test]
    fn quorum_k_of_clamps() {
        assert_eq!(Quorum::All.k_of(5), 5);
        assert_eq!(Quorum::Count(3).k_of(5), 3);
        assert_eq!(Quorum::Count(0).k_of(5), 1);
        assert_eq!(Quorum::Count(99).k_of(5), 5);
        assert_eq!(Quorum::Fraction(0.5).k_of(5), 3); // ceil(2.5)
        assert_eq!(Quorum::Fraction(0.01).k_of(5), 1);
        assert_eq!(Quorum::Fraction(0.99).k_of(5), 5);
        assert_eq!(Quorum::All.k_of(0), 0);
    }

    #[test]
    fn admit_routes_by_round_id() {
        let mut rs = RoundState::new(5, 3);
        // Fresh update.
        match rs.admit(0, Msg::Update { round: 5, worker: 0, update: upd(4, 1), local_f: 0.5 })
        {
            Admit::Fresh => {}
            other => panic!("{other:?}"),
        }
        assert!(rs.replied(0));
        assert_eq!(rs.local_f()[0], Some(0.5));
        // Stale update routed to the pool, worker still unresolved.
        match rs.admit(1, Msg::Update { round: 4, worker: 1, update: upd(4, 2), local_f: 0.1 })
        {
            Admit::Stale(s) => {
                assert_eq!((s.round, s.worker), (4, 1));
                assert_eq!(s.update.idx, vec![2]);
            }
            other => panic!("{other:?}"),
        }
        assert!(!rs.replied(1));
        // Its fresh reply afterwards still counts.
        assert!(matches!(
            rs.admit(1, Msg::Silence { round: 5, worker: 1, local_f: 0.2 }),
            Admit::Fresh
        ));
        assert!(rs.replied(1));
        // Stale silence / duplicates / future rounds are ignored.
        assert!(matches!(
            rs.admit(2, Msg::Silence { round: 3, worker: 2, local_f: 0.0 }),
            Admit::Ignored
        ));
        assert!(matches!(
            rs.admit(0, Msg::Update { round: 5, worker: 0, update: upd(4, 3), local_f: 0.9 }),
            Admit::Ignored
        ));
        assert!(matches!(
            rs.admit(2, Msg::Update { round: 6, worker: 2, update: upd(4, 3), local_f: 0.9 }),
            Admit::Ignored
        ));
    }

    #[test]
    fn cut_ranks_by_delay_then_worker() {
        let mut rs = RoundState::new(2, 4);
        for w in 0..4 {
            rs.admit(w, Msg::Silence { round: 2, worker: w as u32, local_f: 0.0 });
        }
        // Worker 1 is the straggler; ties (0 units) break by worker id.
        let plan = DelayPlan::PerWorker(vec![0, 500, 0, 7]);
        let cut = rs.cut(3, &plan);
        assert_eq!(cut.late, vec![1]);
        assert_eq!(cut.units, 7); // K-th arrival is worker 3 at 7 units
        // Quorum All keeps everyone and waits for the straggler.
        let cut = rs.cut(4, &plan);
        assert!(cut.late.is_empty());
        assert_eq!(cut.units, 500);
        // No delays: cut falls back to worker-id order.
        let cut = rs.cut(2, &DelayPlan::None);
        assert_eq!(cut.late, vec![2, 3]);
        assert_eq!(cut.units, 0);
    }

    #[test]
    fn cut_with_fewer_repliers_than_quorum() {
        let mut rs = RoundState::new(1, 3);
        rs.admit(2, Msg::Silence { round: 1, worker: 2, local_f: 0.0 });
        let cut = rs.cut(3, &DelayPlan::None);
        assert!(cut.late.is_empty());
    }
}
