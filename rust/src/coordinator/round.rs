//! The event-driven round state machine behind the semi-synchronous
//! coordinator.
//!
//! Each round the server broadcasts θ^k and then *admits* worker replies
//! in arrival order. A reply tagged with an older round id is routed to
//! the **stale pool** instead of being misattributed to the current
//! round (the strictly synchronous gather silently did exactly that for
//! a worker that had timed out one round earlier). Once every live
//! active worker has resolved — fresh reply, timeout, or death — the
//! round is **cut**: the first `K` replies in virtual-arrival order
//! (`(DelayPlan::delay(w, k), w)` — deterministic, never wall-clock;
//! `K` fixed or delay-adaptive via
//! [`QuorumController`](super::scheduler::QuorumController)) are applied
//! immediately, and the rest are parked as stale and folded into a
//! *later* round's aggregation — at the [`delivery_age`] their excess
//! delay spans, hard-bounded by the staleness window S — exactly where
//! GD-SEC's Eq. 6 would have put them rounds earlier (LAQ-style bounded
//! multi-round staleness). Anything older than S never folds
//! ([`Admit::Expired`]).
//!
//! With `Quorum::All` and window 1 the cut keeps every reply and the
//! machine is bit-for-bit identical to the synchronous protocol — pinned
//! by `tests/integration_coordinator.rs` against the serial reference,
//! including under injected delays.

use super::protocol::Msg;
use super::transport::DelayPlan;
use crate::algo::trace::{stale_age_bin, STALE_AGE_BINS};
use crate::compress::SparseUpdate;

/// How many of a round's live active workers must report before the
/// server steps θ.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Quorum {
    /// Every live active worker — the paper's synchronous protocol.
    #[default]
    All,
    /// A fixed count K (clamped to `[1, active]`).
    Count(usize),
    /// `ceil(ratio · active)`, clamped to `[1, active]`.
    Fraction(f64),
    /// Delay-adaptive K, chosen online by a
    /// [`QuorumController`](super::scheduler::QuorumController) from the
    /// per-worker EMA of observed virtual arrival delays: the cut waits
    /// for every worker predicted within (a slack factor of) the
    /// `target_quantile`-th delay order statistic, never fewer than
    /// `ceil(min_frac · active)`. With no observations yet (or through
    /// the stateless [`k_of`](Quorum::k_of)) it falls back to that
    /// `min_frac` floor.
    Adaptive { target_quantile: f64, min_frac: f64 },
}

impl Quorum {
    /// Default with the `GDSEC_QUORUM` env override (see
    /// [`parse`](Quorum::parse) for the accepted forms).
    ///
    /// Panics on anything else — including an explicit `0`: a malformed
    /// value silently degrading to `All` (or a zero quorum silently
    /// clamping to 1) would turn the CI quorum matrix into a synchronous
    /// no-op while staying green.
    pub fn from_env() -> Quorum {
        match std::env::var("GDSEC_QUORUM").ok().as_deref() {
            None | Some("") => Quorum::All,
            Some(s) => Quorum::parse(s).unwrap_or_else(|e| {
                panic!(
                    "GDSEC_QUORUM must be `all`, a positive worker count, a \
                     ratio in (0, 1], or `adaptive[:quantile[:min_frac]]`: {e}"
                )
            }),
        }
    }

    /// Parse a quorum spec: `all`, a positive worker count (`2`), a
    /// participation ratio in (0, 1] (`0.5`; `1.0` ≡ `all` — a full
    /// ratio is well-defined, not malformed), or
    /// `adaptive[:quantile[:min_frac]]` (defaults 0.75 / 0.25).
    /// `0` and `0.0` are rejected explicitly: a zero quorum would
    /// otherwise clamp to 1 in [`k_of`](Quorum::k_of) and silently mean
    /// "first reply wins".
    pub fn parse(s: &str) -> Result<Quorum, String> {
        if s == "all" {
            return Ok(Quorum::All);
        }
        if let Some(rest) = s.strip_prefix("adaptive") {
            let mut target_quantile = 0.75;
            let mut min_frac = 0.25;
            if let Some(args) = rest.strip_prefix(':') {
                let mut it = args.split(':');
                if let Some(q) = it.next() {
                    target_quantile = q.parse::<f64>().map_err(|_| format!("bad quantile {q:?}"))?;
                }
                if let Some(f) = it.next() {
                    min_frac = f.parse::<f64>().map_err(|_| format!("bad min_frac {f:?}"))?;
                }
                if it.next().is_some() {
                    return Err(format!("too many `:` fields in {s:?}"));
                }
            } else if !rest.is_empty() {
                return Err(format!("got {s:?}"));
            }
            if !(target_quantile > 0.0 && target_quantile <= 1.0) {
                return Err(format!("quantile {target_quantile} outside (0, 1]"));
            }
            if !(min_frac > 0.0 && min_frac <= 1.0) {
                return Err(format!("min_frac {min_frac} outside (0, 1]"));
            }
            return Ok(Quorum::Adaptive { target_quantile, min_frac });
        }
        if let Ok(k) = s.parse::<usize>() {
            return if k == 0 {
                Err("quorum count 0 rejected".into())
            } else {
                Ok(Quorum::Count(k))
            };
        }
        match s.parse::<f64>() {
            Ok(r) if r > 0.0 && r < 1.0 => Ok(Quorum::Fraction(r)),
            Ok(r) if r == 1.0 => Ok(Quorum::All),
            Ok(r) => Err(format!("ratio {r} outside (0, 1]")),
            Err(_) => Err(format!("got {s:?}")),
        }
    }

    /// The quorum size K for a round with `active` live scheduled
    /// workers. Stateless: `Adaptive` answers with its `min_frac` floor
    /// — the cold-start value; the online EMA decision lives in
    /// [`QuorumController::k_for`](super::scheduler::QuorumController::k_for).
    pub fn k_of(&self, active: usize) -> usize {
        if active == 0 {
            return 0;
        }
        match self {
            Quorum::All => active,
            Quorum::Count(k) => (*k).clamp(1, active),
            Quorum::Fraction(r) => ((r * active as f64).ceil() as usize).clamp(1, active),
            Quorum::Adaptive { min_frac, .. } => {
                ((min_frac * active as f64).ceil() as usize).clamp(1, active)
            }
        }
    }
}

/// The delivery age of a reply that missed a cut: how many rounds after
/// its transmission round it folds. The cut closed at `units` virtual
/// time; the reply lands `delay − units` units later, and each
/// subsequent round is modeled as lasting this round's `units` (at least
/// 1, so ties and zero-delay cuts still progress) — clamped into `[1,
/// window]`, the staleness window's hard bound. Shared by the
/// coordinator round loop and the engine-side
/// [`QuorumSim`](super::scheduler::QuorumSim), so both model the same
/// in-flight times.
pub fn delivery_age(delay: u64, units: u64, window: usize) -> u32 {
    let per_round = units.max(1);
    let excess = delay.saturating_sub(units);
    let age = excess.div_ceil(per_round).max(1);
    age.min(window.max(1) as u64) as u32
}

/// A transmitted update the server holds past its round: parked by a
/// quorum cut, or physically delivered late after a timeout. `age` is
/// the number of rounds after `round` at which it folds (`due = round +
/// age`), hard-bounded by the staleness window S — the pool folds its
/// due entries each round in `(round, worker)` order and an update older
/// than S is dropped at admission ([`Admit::Expired`]), never folded.
#[derive(Debug, Clone)]
pub struct StaleUpdate {
    pub round: u32,
    pub worker: usize,
    /// Fold age in rounds (1 ≤ age ≤ S): the entry folds into round
    /// `round + age`'s aggregation.
    pub age: u32,
    pub update: SparseUpdate,
}

/// O(log n) membership in a sorted ascending worker-id set — the
/// scheduler's active sets and the cohort sampler's draws are always
/// sorted, so broadcast fan-outs test membership without an O(M) scan
/// per worker (O(M²) per round at M = 10k).
pub fn in_sorted(set: &[usize], w: usize) -> bool {
    set.binary_search(&w).is_ok()
}

/// Evict every parked entry originating from `worker`, returning how
/// many were removed. Re-admission calls this so a transmission computed
/// BEFORE a worker's crash can never fold after its EC state restarted
/// from zero (the parked wire image belongs to an h_m/e_m history that no
/// longer exists); permanent-death renormalization uses it for the same
/// reason in reverse — the booked share is being withdrawn.
pub fn evict_worker(stale: &mut Vec<StaleUpdate>, worker: usize) -> usize {
    let before = stale.len();
    stale.retain(|s| s.worker != worker);
    before - stale.len()
}

/// Split the stale pool for round `k`: move every entry whose fold round
/// `round + age` has arrived into `due` (cleared first), keeping the
/// rest pooled. The pool is sorted by `(round, worker)` beforehand so
/// `due` carries the canonical fold order — the per-element accumulation
/// order the bitwise pin is defined over. The sort is unstable (keys are
/// unique: a worker parks at most one update per round) and both moves
/// are in-place swaps, so a warm caller-owned `due` makes the whole
/// split allocation-free — unlike the `drain(..).partition()` it
/// replaces, which built two fresh `Vec`s every round.
pub fn split_due(pool: &mut Vec<StaleUpdate>, k: usize, due: &mut Vec<StaleUpdate>) {
    pool.sort_unstable_by_key(|s| (s.round, s.worker));
    due.clear();
    let mut keep = 0;
    for i in 0..pool.len() {
        if (pool[i].round + pool[i].age) as usize <= k {
            // An empty SparseUpdate holds no heap storage, so the
            // placeholder costs nothing.
            due.push(std::mem::replace(
                &mut pool[i],
                StaleUpdate { round: 0, worker: 0, age: 0, update: SparseUpdate::empty(0) },
            ));
        } else {
            pool.swap(keep, i);
            keep += 1;
        }
    }
    pool.truncate(keep);
}

/// Routing verdict for one admitted reply.
#[derive(Debug)]
pub enum Admit {
    /// A fresh reply for the current round (update or silence) — counts
    /// toward the quorum.
    Fresh,
    /// An older round's update, physically delivered late within the
    /// staleness window: the caller adds it to the stale pool (its bits
    /// went on the wire — account them — but it must not be misread as
    /// this round's reply).
    Stale(StaleUpdate),
    /// An update older than the staleness window S: its bits went on the
    /// wire (account them) but it must NOT fold — the window is a hard
    /// bound on how old a folded contribution may be.
    Expired(StaleUpdate),
    /// Nothing actionable: stale silence, duplicate, wrong-direction or
    /// future-round frame.
    Ignored,
}

/// Per-round reply state for one gather.
pub struct RoundState {
    k: u32,
    /// Staleness window S: updates older than this are expired, not
    /// pooled.
    window: u32,
    updates: Vec<Option<SparseUpdate>>,
    local_f: Vec<Option<f64>>,
    replied: Vec<bool>,
    /// Ages of the stale updates admitted (not expired) this round.
    stale_age_hist: [u64; STALE_AGE_BINS],
}

/// The quorum cut of a completed gather.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Cut {
    /// Repliers beyond the quorum, ascending worker id — their updates
    /// (if any) are parked for the next round.
    pub late: Vec<usize>,
    /// Wall-clock proxy for the round: the largest virtual delay among
    /// the replies the server waited for (the K-th virtual arrival).
    pub units: u64,
}

impl RoundState {
    pub fn new(k: u32, m: usize, window: u32) -> RoundState {
        RoundState {
            k,
            window: window.max(1),
            updates: vec![None; m],
            local_f: vec![None; m],
            replied: vec![false; m],
            stale_age_hist: [0; STALE_AGE_BINS],
        }
    }

    /// Admit worker `w`'s decoded reply, routing by round id. The caller
    /// owns liveness (timeouts / strikes) and bit accounting.
    pub fn admit(&mut self, w: usize, msg: Msg) -> Admit {
        match msg {
            Msg::Update { round, update, local_f, .. } => {
                if round == self.k {
                    if self.replied[w] {
                        return Admit::Ignored;
                    }
                    self.replied[w] = true;
                    self.local_f[w] = Some(local_f);
                    self.updates[w] = Some(update);
                    Admit::Fresh
                } else if round < self.k {
                    // Fold age when this joins round k's aggregation.
                    let age = self.k - round;
                    let su = StaleUpdate { round, worker: w, age, update };
                    if age > self.window {
                        Admit::Expired(su)
                    } else {
                        self.stale_age_hist[stale_age_bin(age)] += 1;
                        Admit::Stale(su)
                    }
                } else {
                    Admit::Ignored
                }
            }
            Msg::Silence { round, local_f, .. } => {
                if round == self.k && !self.replied[w] {
                    self.replied[w] = true;
                    self.local_f[w] = Some(local_f);
                    Admit::Fresh
                } else {
                    Admit::Ignored
                }
            }
            _ => Admit::Ignored,
        }
    }

    /// Whether worker `w` has reported fresh this round.
    pub fn replied(&self, w: usize) -> bool {
        self.replied[w]
    }

    /// Staleness-age histogram of this gather's admitted (non-expired)
    /// stale updates ([`crate::algo::trace::stale_age_bin`] bins).
    ///
    /// This counts at ADMISSION time and only covers physically-late
    /// deliveries routed through [`admit`](Self::admit) — deliberately
    /// not the same thing as
    /// [`RoundMetrics::stale_age_hist`](crate::coordinator::RoundMetrics::stale_age_hist),
    /// which counts at FOLD time and also covers updates the quorum cut
    /// parked (those never pass through `admit` as stale).
    pub fn stale_age_hist(&self) -> [u64; STALE_AGE_BINS] {
        self.stale_age_hist
    }

    /// Fresh local objective values, indexed by worker.
    pub fn local_f(&self) -> &[Option<f64>] {
        &self.local_f
    }

    /// Fresh updates, indexed by worker (None = silent / no reply).
    pub fn updates(&self) -> &[Option<SparseUpdate>] {
        &self.updates
    }

    /// Take worker `w`'s fresh update out (for parking late ones).
    pub fn take_update(&mut self, w: usize) -> Option<SparseUpdate> {
        self.updates[w].take()
    }

    /// Cut the round at quorum `k_quorum`: rank this round's repliers by
    /// `(delay(w, k), w)` — virtual arrival order, deterministic for any
    /// thread schedule — keep the first `k_quorum` as on-time, and
    /// return the rest (ascending worker id) as late. `units` is the
    /// largest delay among the on-time replies: the wall-clock proxy the
    /// quorum actually waited for.
    pub fn cut(&self, k_quorum: usize, plan: &DelayPlan) -> Cut {
        self.cut_by(k_quorum, |w| plan.delay(w, self.k as usize))
    }

    /// [`Self::cut`] over an arbitrary per-worker delay source — the
    /// real-transport path ranks *measured wall-clock* reply delays
    /// (µs since broadcast) with the identical `(delay, w)` tie-break,
    /// so the cut logic is one implementation for both modes.
    pub fn cut_by(&self, k_quorum: usize, delay_of: impl Fn(usize) -> u64) -> Cut {
        let mut arrivals: Vec<(u64, usize)> = (0..self.replied.len())
            .filter(|&w| self.replied[w])
            .map(|w| (delay_of(w), w))
            .collect();
        arrivals.sort_unstable();
        let on_time = k_quorum.min(arrivals.len());
        let units = arrivals[..on_time].iter().map(|&(d, _)| d).max().unwrap_or(0);
        let mut late: Vec<usize> = arrivals[on_time..].iter().map(|&(_, w)| w).collect();
        late.sort_unstable();
        Cut { late, units }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(dim: usize, i: u32) -> SparseUpdate {
        let mut u = SparseUpdate::empty(dim);
        u.idx.push(i);
        u.val.push(1.0);
        u
    }

    #[test]
    fn quorum_k_of_clamps() {
        assert_eq!(Quorum::All.k_of(5), 5);
        assert_eq!(Quorum::Count(3).k_of(5), 3);
        assert_eq!(Quorum::Count(0).k_of(5), 1);
        assert_eq!(Quorum::Count(99).k_of(5), 5);
        assert_eq!(Quorum::Fraction(0.5).k_of(5), 3); // ceil(2.5)
        assert_eq!(Quorum::Fraction(0.01).k_of(5), 1);
        assert_eq!(Quorum::Fraction(0.99).k_of(5), 5);
        assert_eq!(Quorum::All.k_of(0), 0);
        // Adaptive without observation state falls back to its floor.
        let a = Quorum::Adaptive { target_quantile: 0.75, min_frac: 0.5 };
        assert_eq!(a.k_of(5), 3); // ceil(2.5)
        assert_eq!(a.k_of(0), 0);
    }

    #[test]
    fn in_sorted_matches_linear_scan() {
        let set = [0usize, 3, 4, 9, 17];
        for w in 0..20 {
            assert_eq!(in_sorted(&set, w), set.contains(&w), "w={w}");
        }
        assert!(!in_sorted(&[], 0));
    }

    #[test]
    fn evict_worker_removes_only_that_workers_entries() {
        let mut pool = vec![
            StaleUpdate { round: 3, worker: 1, age: 1, update: upd(4, 0) },
            StaleUpdate { round: 3, worker: 2, age: 2, update: upd(4, 1) },
            StaleUpdate { round: 4, worker: 1, age: 2, update: upd(4, 2) },
        ];
        assert_eq!(evict_worker(&mut pool, 1), 2);
        assert_eq!(pool.len(), 1);
        assert_eq!(pool[0].worker, 2);
        assert_eq!(evict_worker(&mut pool, 1), 0);
    }

    #[test]
    fn split_due_orders_and_keeps_pending() {
        let mut pool = vec![
            StaleUpdate { round: 5, worker: 2, age: 1, update: upd(4, 0) }, // due at 6
            StaleUpdate { round: 4, worker: 0, age: 2, update: upd(4, 1) }, // due at 6
            StaleUpdate { round: 5, worker: 1, age: 2, update: upd(4, 2) }, // due at 7
            StaleUpdate { round: 4, worker: 3, age: 1, update: upd(4, 3) }, // due at 5 (overdue)
        ];
        let mut due = vec![StaleUpdate { round: 9, worker: 9, age: 9, update: upd(4, 0) }];
        split_due(&mut pool, 6, &mut due);
        // Due entries in (round, worker) order; the stale `due` content
        // was cleared.
        let order: Vec<(u32, usize)> = due.iter().map(|s| (s.round, s.worker)).collect();
        assert_eq!(order, vec![(4, 0), (4, 3), (5, 2)]);
        assert_eq!(due[1].update.idx, vec![3]);
        // Pending entry survives with its payload intact.
        assert_eq!(pool.len(), 1);
        assert_eq!((pool[0].round, pool[0].worker), (5, 1));
        assert_eq!(pool[0].update.idx, vec![2]);
        // Nothing due: pool unchanged, due empty.
        split_due(&mut pool, 6, &mut due);
        assert!(due.is_empty());
        assert_eq!(pool.len(), 1);
    }

    #[test]
    fn rejoined_worker_first_reply_is_fresh_not_stale() {
        // Re-admission contract: the rejoined worker replies to the
        // CURRENT round (it adopted the fresh θ snapshot), so admission
        // must classify it Fresh — counting toward the quorum and
        // resetting strikes — never as a stale/expired delivery.
        let mut rs = RoundState::new(7, 3, 2);
        let verdict = rs.admit(
            1,
            Msg::Update { round: 7, worker: 1, update: upd(4, 0), local_f: 0.5 },
        );
        assert!(matches!(verdict, Admit::Fresh));
        assert!(rs.replied(1));
    }

    #[test]
    fn quorum_parse_contract() {
        assert_eq!(Quorum::parse("all"), Ok(Quorum::All));
        assert_eq!(Quorum::parse("3"), Ok(Quorum::Count(3)));
        assert_eq!(Quorum::parse("0.5"), Ok(Quorum::Fraction(0.5)));
        // A full ratio is well-defined synchronous participation, not an
        // error.
        assert_eq!(Quorum::parse("1.0"), Ok(Quorum::All));
        // A zero quorum must be rejected, not clamped to 1.
        assert!(Quorum::parse("0").is_err());
        assert!(Quorum::parse("0.0").is_err());
        assert!(Quorum::parse("1.5").is_err());
        assert!(Quorum::parse("-0.3").is_err());
        assert!(Quorum::parse("bogus").is_err());
        assert_eq!(
            Quorum::parse("adaptive"),
            Ok(Quorum::Adaptive { target_quantile: 0.75, min_frac: 0.25 })
        );
        assert_eq!(
            Quorum::parse("adaptive:0.6"),
            Ok(Quorum::Adaptive { target_quantile: 0.6, min_frac: 0.25 })
        );
        assert_eq!(
            Quorum::parse("adaptive:0.6:0.34"),
            Ok(Quorum::Adaptive { target_quantile: 0.6, min_frac: 0.34 })
        );
        assert!(Quorum::parse("adaptive:0.6:0.3:9").is_err());
        assert!(Quorum::parse("adaptive:2.0").is_err());
        assert!(Quorum::parse("adaptive:0.5:0.0").is_err());
        assert!(Quorum::parse("adaptivex").is_err());
    }

    #[test]
    fn delivery_age_models_excess_over_cut() {
        // Tie with the cut (excess 0): next round.
        assert_eq!(delivery_age(5, 5, 3), 1);
        // Excess within one round-duration: next round.
        assert_eq!(delivery_age(8, 5, 3), 1);
        // Excess spanning rounds: ceil(excess / units).
        assert_eq!(delivery_age(15, 5, 3), 2);
        assert_eq!(delivery_age(16, 5, 3), 3);
        // Hard-bounded by the window.
        assert_eq!(delivery_age(900, 5, 3), 3);
        assert_eq!(delivery_age(900, 5, 1), 1);
        // Zero-unit cut (all ties) still progresses one round per unit.
        assert_eq!(delivery_age(0, 0, 4), 1);
        assert_eq!(delivery_age(2, 0, 4), 2);
    }

    #[test]
    fn admit_routes_by_round_id() {
        let mut rs = RoundState::new(5, 3, 4);
        // Fresh update.
        match rs.admit(0, Msg::Update { round: 5, worker: 0, update: upd(4, 1), local_f: 0.5 })
        {
            Admit::Fresh => {}
            other => panic!("{other:?}"),
        }
        assert!(rs.replied(0));
        assert_eq!(rs.local_f()[0], Some(0.5));
        // Stale update routed to the pool, worker still unresolved.
        match rs.admit(1, Msg::Update { round: 4, worker: 1, update: upd(4, 2), local_f: 0.1 })
        {
            Admit::Stale(s) => {
                assert_eq!((s.round, s.worker, s.age), (4, 1, 1));
                assert_eq!(s.update.idx, vec![2]);
            }
            other => panic!("{other:?}"),
        }
        assert!(!rs.replied(1));
        assert_eq!(rs.stale_age_hist(), [1, 0, 0, 0]);
        // Its fresh reply afterwards still counts.
        assert!(matches!(
            rs.admit(1, Msg::Silence { round: 5, worker: 1, local_f: 0.2 }),
            Admit::Fresh
        ));
        assert!(rs.replied(1));
        // Stale silence / duplicates / future rounds are ignored.
        assert!(matches!(
            rs.admit(2, Msg::Silence { round: 3, worker: 2, local_f: 0.0 }),
            Admit::Ignored
        ));
        assert!(matches!(
            rs.admit(0, Msg::Update { round: 5, worker: 0, update: upd(4, 3), local_f: 0.9 }),
            Admit::Ignored
        ));
        assert!(matches!(
            rs.admit(2, Msg::Update { round: 6, worker: 2, update: upd(4, 3), local_f: 0.9 }),
            Admit::Ignored
        ));
    }

    #[test]
    fn stale_beyond_window_expires() {
        // Window 2, round 9: an update from round 7 (age 2) pools, one
        // from round 6 (age 3) expires — the hard staleness bound.
        let mut rs = RoundState::new(9, 3, 2);
        match rs.admit(0, Msg::Update { round: 7, worker: 0, update: upd(4, 1), local_f: 0.0 })
        {
            Admit::Stale(s) => assert_eq!(s.age, 2),
            other => panic!("{other:?}"),
        }
        match rs.admit(1, Msg::Update { round: 6, worker: 1, update: upd(4, 2), local_f: 0.0 })
        {
            Admit::Expired(s) => assert_eq!((s.age, s.update.nnz()), (3, 1)),
            other => panic!("{other:?}"),
        }
        // Only the admitted one is in the histogram (age-2 bin).
        assert_eq!(rs.stale_age_hist(), [0, 1, 0, 0]);
        assert!(!rs.replied(0) && !rs.replied(1));
    }

    #[test]
    fn cut_ranks_by_delay_then_worker() {
        let mut rs = RoundState::new(2, 4, 1);
        for w in 0..4 {
            rs.admit(w, Msg::Silence { round: 2, worker: w as u32, local_f: 0.0 });
        }
        // Worker 1 is the straggler; ties (0 units) break by worker id.
        let plan = DelayPlan::PerWorker(vec![0, 500, 0, 7]);
        let cut = rs.cut(3, &plan);
        assert_eq!(cut.late, vec![1]);
        assert_eq!(cut.units, 7); // K-th arrival is worker 3 at 7 units
        // Quorum All keeps everyone and waits for the straggler.
        let cut = rs.cut(4, &plan);
        assert!(cut.late.is_empty());
        assert_eq!(cut.units, 500);
        // No delays: cut falls back to worker-id order.
        let cut = rs.cut(2, &DelayPlan::None);
        assert_eq!(cut.late, vec![2, 3]);
        assert_eq!(cut.units, 0);
    }

    #[test]
    fn cut_with_fewer_repliers_than_quorum() {
        let mut rs = RoundState::new(1, 3, 1);
        rs.admit(2, Msg::Silence { round: 1, worker: 2, local_f: 0.0 });
        let cut = rs.cut(3, &DelayPlan::None);
        assert!(cut.late.is_empty());
    }
}
