//! Real-socket [`Transport`] backend: length-framed protocol frames over
//! `std::net::TcpStream`.
//!
//! Wire layout: each frame from `protocol::encode` is prefixed with its
//! length as a u32-LE and written verbatim — the frame bytes themselves
//! are byte-for-byte the channel path's, so `protocol::decode` (and the
//! payload-bit accounting derived from it) is untouched by the backend
//! swap. The 4-byte prefix is *framing overhead*, deliberately excluded
//! from [`LinkStats`] so uplink byte totals match the virtual transport
//! exactly for identical trajectories.
//!
//! Loss model: a dead peer surfaces as [`Recv::Disconnected`] (sticky),
//! which the coordinator maps onto the existing liveness-strike path; a
//! restarted worker reconnects and re-enters through the `Msg::Join`
//! re-admission handshake (the hello frame doubles as the join).

use super::protocol::{self, Msg};
use super::transport::{LinkStats, Recv, RecvStatus, Transport};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Hard upper bound on a framed message (256 MiB). A length prefix above
/// this is unconditionally a protocol error (corrupt stream or a
/// non-GD-SEC peer), never a legitimate frame — decode dimensions are
/// checked later, this guards the allocator first.
pub const MAX_FRAME_LEN: u32 = 1 << 28;

/// Read chunk size for the stream pump. Larger than most frames, so a
/// frame usually arrives in one or two reads; torn reads at arbitrary
/// boundaries are reassembled regardless.
const READ_CHUNK: usize = 64 * 1024;

/// Stream-level framing errors — loud, with the offending sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// Length prefix exceeds [`MAX_FRAME_LEN`].
    Oversized { len: u32 },
    /// Stream ended mid-frame: `have` buffered bytes of a `need`-byte
    /// prefix+frame.
    TruncatedTail { have: usize, need: usize },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversized { len } => write!(
                f,
                "frame length prefix {len} exceeds MAX_FRAME_LEN {MAX_FRAME_LEN}"
            ),
            FrameError::TruncatedTail { have, need } => {
                write!(f, "stream ended mid-frame: have {have} of {need} bytes")
            }
        }
    }
}

/// Incremental reassembler for u32-LE length-framed streams. Feed it
/// arbitrary byte chunks (torn at any boundary); it yields whole frames
/// in order. Consumed bytes are compacted lazily so the buffer doesn't
/// grow without bound across frames.
#[derive(Default)]
pub struct FrameAssembler {
    buf: Vec<u8>,
    start: usize,
}

impl FrameAssembler {
    pub fn new() -> FrameAssembler {
        FrameAssembler::default()
    }

    /// Buffer a chunk read off the stream.
    pub fn push(&mut self, chunk: &[u8]) {
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(chunk);
    }

    /// Pop the next complete frame into `out` (contents replaced).
    /// `Ok(true)` on a frame, `Ok(false)` when more bytes are needed.
    pub fn next_into(&mut self, out: &mut Vec<u8>) -> Result<bool, FrameError> {
        let avail = &self.buf[self.start..];
        if avail.len() < 4 {
            return Ok(false);
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]);
        if len > MAX_FRAME_LEN {
            return Err(FrameError::Oversized { len });
        }
        let need = 4 + len as usize;
        if avail.len() < need {
            return Ok(false);
        }
        out.clear();
        out.extend_from_slice(&avail[4..need]);
        self.start += need;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        }
        Ok(true)
    }

    /// Allocating convenience wrapper around [`Self::next_into`].
    pub fn next(&mut self) -> Result<Option<Vec<u8>>, FrameError> {
        let mut out = Vec::new();
        Ok(if self.next_into(&mut out)? { Some(out) } else { None })
    }

    /// Called at clean stream end (EOF): leftover bytes mean the peer
    /// died mid-frame — reject loudly rather than dropping them.
    pub fn finish(&self) -> Result<(), FrameError> {
        let avail = &self.buf[self.start..];
        if avail.is_empty() {
            return Ok(());
        }
        let need = if avail.len() >= 4 {
            4 + u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize
        } else {
            4
        };
        Err(FrameError::TruncatedTail { have: avail.len(), need })
    }
}

/// Prefix a frame with its u32-LE length — the exact bytes `send` puts
/// on the wire (exposed for the framing property tests).
pub fn frame_to_wire(frame: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + frame.len());
    out.extend_from_slice(&(frame.len() as u32).to_le_bytes());
    out.extend_from_slice(frame);
    out
}

/// [`Transport`] over one connected `TcpStream`, Nagle off. Mirrors the
/// virtual transport's semantics: `send` counts stats before attempting
/// delivery; peer loss is sticky [`Recv::Disconnected`].
pub struct TcpTransport {
    stream: TcpStream,
    asm: FrameAssembler,
    chunk: Vec<u8>,
    sent: Arc<LinkStats>,
    rcvd: Arc<LinkStats>,
    /// Cached setsockopt state so the hot receive path doesn't issue a
    /// syscall per call when the deadline policy is unchanged.
    read_timeout: Option<Duration>,
    peer_lost: bool,
}

impl TcpTransport {
    pub fn from_stream(stream: TcpStream) -> TcpTransport {
        stream.set_nodelay(true).expect("set_nodelay");
        TcpTransport {
            stream,
            asm: FrameAssembler::new(),
            chunk: vec![0u8; READ_CHUNK],
            sent: Arc::new(LinkStats::default()),
            rcvd: Arc::new(LinkStats::default()),
            read_timeout: None,
            peer_lost: false,
        }
    }

    /// Connect with capped exponential backoff (workers usually start
    /// before the server finishes binding; a fixed small retry budget
    /// keeps misconfigured addresses loud rather than hanging forever).
    pub fn connect(addr: SocketAddr) -> std::io::Result<TcpTransport> {
        TcpTransport::connect_with(addr, 24, Duration::from_millis(25))
    }

    pub fn connect_with(
        addr: SocketAddr,
        attempts: u32,
        first_backoff: Duration,
    ) -> std::io::Result<TcpTransport> {
        let mut backoff = first_backoff;
        let mut last_err = None;
        for attempt in 0..attempts.max(1) {
            match TcpStream::connect(addr) {
                Ok(s) => return Ok(TcpTransport::from_stream(s)),
                Err(e) => {
                    last_err = Some(e);
                    if attempt + 1 < attempts.max(1) {
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(Duration::from_secs(2));
                    }
                }
            }
        }
        Err(last_err.unwrap())
    }

    fn set_read_timeout(&mut self, t: Option<Duration>) {
        if self.read_timeout != t {
            // Failure here degrades a timeout into a hang — loud instead.
            self.stream.set_read_timeout(t).expect("set_read_timeout");
            self.read_timeout = t;
        }
    }

    /// Core receive loop: drain reassembled frames first, then pump the
    /// socket until a frame completes, `deadline` passes (`None` blocks
    /// indefinitely), or the peer is lost.
    fn pump(&mut self, buf: &mut Vec<u8>, deadline: Option<Instant>) -> RecvStatus {
        loop {
            match self.asm.next_into(buf) {
                Ok(true) => {
                    self.rcvd.frames.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    self.rcvd
                        .bytes
                        .fetch_add(buf.len() as u64, std::sync::atomic::Ordering::Relaxed);
                    return RecvStatus::Frame;
                }
                Ok(false) => {}
                Err(e) => {
                    eprintln!("tcp transport: {e}; dropping peer");
                    self.peer_lost = true;
                    return RecvStatus::Disconnected;
                }
            }
            if self.peer_lost {
                return RecvStatus::Disconnected;
            }
            match deadline {
                None => self.set_read_timeout(None),
                Some(d) => {
                    let remaining = d.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        return RecvStatus::Timeout;
                    }
                    // A zero socket timeout means "block forever" — clamp.
                    self.set_read_timeout(Some(remaining.max(Duration::from_millis(1))));
                }
            }
            match self.stream.read(&mut self.chunk) {
                Ok(0) => {
                    if let Err(e) = self.asm.finish() {
                        eprintln!("tcp transport: peer closed mid-frame: {e}");
                    }
                    self.peer_lost = true;
                    return RecvStatus::Disconnected;
                }
                Ok(n) => {
                    let (chunk, asm) = (&self.chunk[..n], &mut self.asm);
                    asm.push(chunk);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    if deadline.is_some() {
                        return RecvStatus::Timeout;
                    }
                    // Blocking recv with no deadline: spurious timeout
                    // from a stale socket option — keep waiting.
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.peer_lost = true;
                    return RecvStatus::Disconnected;
                }
            }
        }
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: Vec<u8>) -> bool {
        // Stats first, mirroring the virtual transport: the sender paid
        // for the frame whether or not the peer still listens. (Rust's
        // std ignores SIGPIPE, so a dead peer is an io::Error here.)
        self.sent.frames.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.sent.bytes.fetch_add(frame.len() as u64, std::sync::atomic::Ordering::Relaxed);
        if self.peer_lost {
            return false;
        }
        let len = (frame.len() as u32).to_le_bytes();
        let ok = self
            .stream
            .write_all(&len)
            .and_then(|()| self.stream.write_all(&frame))
            .is_ok();
        if !ok {
            self.peer_lost = true;
        }
        ok
    }

    fn recv(&mut self) -> Recv {
        let mut buf = Vec::new();
        match self.pump(&mut buf, None) {
            RecvStatus::Frame => Recv::Frame(buf),
            RecvStatus::Timeout => Recv::Timeout,
            RecvStatus::Disconnected => Recv::Disconnected,
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Recv {
        let mut buf = Vec::new();
        match self.pump(&mut buf, Some(Instant::now() + timeout)) {
            RecvStatus::Frame => Recv::Frame(buf),
            RecvStatus::Timeout => Recv::Timeout,
            RecvStatus::Disconnected => Recv::Disconnected,
        }
    }

    fn try_recv(&mut self) -> Option<Recv> {
        // Already-reassembled frame: no syscall needed.
        let mut buf = Vec::new();
        match self.asm.next_into(&mut buf) {
            Ok(true) => {
                self.rcvd.frames.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.rcvd
                    .bytes
                    .fetch_add(buf.len() as u64, std::sync::atomic::Ordering::Relaxed);
                return Some(Recv::Frame(buf));
            }
            Ok(false) => {}
            Err(e) => {
                eprintln!("tcp transport: {e}; dropping peer");
                self.peer_lost = true;
                return Some(Recv::Disconnected);
            }
        }
        if self.peer_lost {
            return Some(Recv::Disconnected);
        }
        // Slurp whatever the socket has without blocking, then retry.
        self.stream.set_nonblocking(true).expect("set_nonblocking");
        let mut result = None;
        loop {
            match self.stream.read(&mut self.chunk) {
                Ok(0) => {
                    self.peer_lost = true;
                    result = Some(Recv::Disconnected);
                    break;
                }
                Ok(n) => {
                    let (chunk, asm) = (&self.chunk[..n], &mut self.asm);
                    asm.push(chunk);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.peer_lost = true;
                    result = Some(Recv::Disconnected);
                    break;
                }
            }
        }
        self.stream.set_nonblocking(false).expect("set_nonblocking");
        // set_nonblocking clears any read timeout on some platforms;
        // invalidate the cache so the next deadline re-arms it.
        self.read_timeout = None;
        match self.asm.next_into(&mut buf) {
            Ok(true) => {
                self.rcvd.frames.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.rcvd
                    .bytes
                    .fetch_add(buf.len() as u64, std::sync::atomic::Ordering::Relaxed);
                Some(Recv::Frame(buf))
            }
            Ok(false) => result,
            Err(e) => {
                eprintln!("tcp transport: {e}; dropping peer");
                self.peer_lost = true;
                Some(Recv::Disconnected)
            }
        }
    }

    fn recv_into(&mut self, buf: &mut Vec<u8>, timeout: Duration) -> RecvStatus {
        self.pump(buf, Some(Instant::now() + timeout))
    }

    fn sent_stats(&self) -> &Arc<LinkStats> {
        &self.sent
    }

    fn rcvd_stats(&self) -> &Arc<LinkStats> {
        &self.rcvd
    }
}

/// Parse a socket address from an env-style spec — a literal
/// `host:port` or anything `ToSocketAddrs` resolves. Panics loudly with
/// the variable name and offending value; a deployment with a garbled
/// address must never silently fall back.
pub fn parse_addr(var: &str, spec: &str) -> SocketAddr {
    let s = spec.trim();
    if let Ok(a) = s.parse::<SocketAddr>() {
        return a;
    }
    match s.to_socket_addrs() {
        Ok(mut iter) => iter
            .next()
            .unwrap_or_else(|| panic!("{var}: {spec:?} resolved to no addresses")),
        Err(e) => panic!("{var}: invalid socket address {spec:?} ({e})"),
    }
}

/// `GDSEC_LISTEN` — the server bind address (e.g. `127.0.0.1:7700`).
pub fn listen_from_env() -> Option<SocketAddr> {
    std::env::var("GDSEC_LISTEN").ok().map(|s| parse_addr("GDSEC_LISTEN", &s))
}

/// `GDSEC_CONNECT` — the worker's server address.
pub fn connect_from_env() -> Option<SocketAddr> {
    std::env::var("GDSEC_CONNECT").ok().map(|s| parse_addr("GDSEC_CONNECT", &s))
}

/// Worker-side hello: a `Msg::Join` carrying the worker id and its
/// last-seen round. This is both the slot-assignment handshake (TCP
/// accept order is racy; ids are not) and, on reconnect, the liveness
/// machine's re-admission opener.
pub fn send_hello(t: &mut TcpTransport, worker: u32, last_seen: u32) -> bool {
    let frame = protocol::encode(&Msg::Join { round: last_seen, worker }, 0);
    let len = frame.len() as u64;
    let ok = t.send(frame);
    // Plumbing, not protocol traffic — keep both sides' stats hello-free
    // (see `read_hello`).
    t.sent.frames.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
    t.sent.bytes.fetch_sub(len, std::sync::atomic::Ordering::Relaxed);
    ok
}

/// Server-side hello read: `(worker_id, last_seen_round)`.
/// `Msg::Join` decodes dimension-independently (empty payload), so
/// `dim = 0` here is exact, not a guess.
///
/// The hello is connection plumbing, not protocol traffic — it exists
/// only because TCP accept order is racy and the virtual transport
/// needs no such handshake. Its bytes are retracted from the link's
/// receive stats so a clean TCP run's uplink accounting is equal to the
/// in-proc virtual run's, byte for byte.
pub fn read_hello(t: &mut TcpTransport, timeout: Duration) -> Option<(u32, u32)> {
    match t.recv_timeout(timeout) {
        Recv::Frame(frame) => match protocol::decode(&frame, 0) {
            Ok(Msg::Join { round, worker }) => {
                t.rcvd.frames.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
                t.rcvd.bytes.fetch_sub(frame.len() as u64, std::sync::atomic::Ordering::Relaxed);
                Some((worker, round))
            }
            other => {
                eprintln!("tcp transport: expected Join hello, got {other:?}");
                None
            }
        },
        other => {
            eprintln!("tcp transport: no hello ({other:?})");
            None
        }
    }
}

/// Accept exactly `m` workers off the listener, slotting each by the id
/// in its hello frame. Panics on duplicate/out-of-range ids or a missing
/// hello — a malformed fleet must fail the run loudly at startup.
pub fn accept_fleet(listener: &TcpListener, m: usize) -> Vec<TcpTransport> {
    let mut slots: Vec<Option<TcpTransport>> = (0..m).map(|_| None).collect();
    let mut seated = 0usize;
    while seated < m {
        let (stream, peer) = listener.accept().expect("accept worker connection");
        let mut t = TcpTransport::from_stream(stream);
        let Some((worker, _last_seen)) = read_hello(&mut t, Duration::from_secs(10)) else {
            panic!("worker at {peer} sent no valid hello");
        };
        let w = worker as usize;
        assert!(w < m, "hello from worker {worker} but fleet size is {m}");
        assert!(slots[w].is_none(), "duplicate hello for worker {worker}");
        slots[w] = Some(t);
        seated += 1;
    }
    slots.into_iter().map(|s| s.unwrap()).collect()
}

/// Detached acceptor for mid-run reconnects: every post-startup
/// connection's hello is forwarded as `(worker_id, transport)` for the
/// coordinator to swap in and re-admit via the existing Join path. The
/// thread exits when the receiver is dropped and the next accept's
/// send fails (or the process ends).
pub fn spawn_acceptor(
    listener: TcpListener,
    m: usize,
) -> Receiver<(usize, Box<dyn Transport>)> {
    let (tx, rx) = channel::<(usize, Box<dyn Transport>)>();
    std::thread::spawn(move || {
        loop {
            let Ok((stream, peer)) = listener.accept() else { return };
            let mut t = TcpTransport::from_stream(stream);
            match read_hello(&mut t, Duration::from_secs(10)) {
                Some((worker, _)) if (worker as usize) < m => {
                    if tx.send((worker as usize, Box::new(t))).is_err() {
                        return;
                    }
                }
                Some((worker, _)) => {
                    eprintln!("tcp transport: rejoin hello from out-of-range worker {worker}");
                }
                None => {
                    eprintln!("tcp transport: dropping helloless connection from {peer}");
                }
            }
        }
    });
    rx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (TcpTransport, TcpTransport) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || TcpTransport::connect(addr).unwrap());
        let (server_stream, _) = listener.accept().unwrap();
        (TcpTransport::from_stream(server_stream), h.join().unwrap())
    }

    #[test]
    fn assembler_yields_frames_across_arbitrary_splits() {
        let frames: Vec<Vec<u8>> = vec![vec![1, 2, 3], vec![], vec![9; 300]];
        let mut wire = Vec::new();
        for f in &frames {
            wire.extend_from_slice(&frame_to_wire(f));
        }
        for split in 1..wire.len() {
            let mut asm = FrameAssembler::new();
            let mut got = Vec::new();
            for chunk in wire.chunks(split) {
                asm.push(chunk);
                while let Some(f) = asm.next().unwrap() {
                    got.push(f);
                }
            }
            assert_eq!(got, frames, "split={split}");
            asm.finish().unwrap();
        }
    }

    #[test]
    fn assembler_rejects_oversized_prefix() {
        let mut asm = FrameAssembler::new();
        asm.push(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert_eq!(asm.next().unwrap_err(), FrameError::Oversized { len: MAX_FRAME_LEN + 1 });
    }

    #[test]
    fn assembler_flags_truncated_tail() {
        let mut asm = FrameAssembler::new();
        asm.push(&frame_to_wire(&[5, 5, 5])[..5]); // 4-byte prefix + 1 of 3
        assert!(asm.next().unwrap().is_none());
        assert_eq!(asm.finish().unwrap_err(), FrameError::TruncatedTail { have: 5, need: 7 });
        // Partial prefix alone is also a truncation.
        let mut asm2 = FrameAssembler::new();
        asm2.push(&[1, 0]);
        assert_eq!(asm2.finish().unwrap_err(), FrameError::TruncatedTail { have: 2, need: 4 });
    }

    #[test]
    fn loopback_roundtrip_and_stats_exclude_prefix() {
        let (mut server, mut worker) = pair();
        assert!(server.send(vec![7; 10]));
        match worker.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![7; 10]),
            other => panic!("{other:?}"),
        }
        assert_eq!(server.sent_stats().bytes(), 10); // not 14
        assert_eq!(worker.rcvd_stats().bytes(), 10);
        assert_eq!(worker.rcvd_stats().frames(), 1);
    }

    #[test]
    fn loopback_torn_reads_on_large_frame() {
        // Frame bigger than the 64 KiB read chunk forces reassembly
        // across multiple reads.
        let (mut server, mut worker) = pair();
        let big: Vec<u8> = (0..200_000u32).map(|i| i as u8).collect();
        let big2 = big.clone();
        let h = std::thread::spawn(move || {
            let mut s = server;
            assert!(s.send(big2));
            assert!(s.send(vec![1, 2, 3]));
            s
        });
        match worker.recv() {
            Recv::Frame(f) => assert_eq!(f, big),
            other => panic!("{other:?}"),
        }
        match worker.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![1, 2, 3]),
            other => panic!("{other:?}"),
        }
        h.join().unwrap();
    }

    #[test]
    fn loopback_timeout_disconnect_and_sticky_loss() {
        let (server, mut worker) = pair();
        match worker.recv_timeout(Duration::from_millis(20)) {
            Recv::Timeout => {}
            other => panic!("expected timeout, got {other:?}"),
        }
        drop(server);
        match worker.recv() {
            Recv::Disconnected => {}
            other => panic!("expected disconnect, got {other:?}"),
        }
        // Sticky: every subsequent call keeps reporting the loss.
        assert!(matches!(worker.recv_timeout(Duration::from_millis(5)), Recv::Disconnected));
        assert!(matches!(worker.try_recv(), Some(Recv::Disconnected)));
        assert!(!worker.send(vec![1]));
    }

    #[test]
    fn loopback_try_recv_nonblocking() {
        let (mut server, mut worker) = pair();
        assert!(worker.try_recv().is_none());
        assert!(server.send(vec![4, 2]));
        // Loopback delivery is fast but not instant; poll briefly.
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            match worker.try_recv() {
                Some(Recv::Frame(f)) => {
                    assert_eq!(f, vec![4, 2]);
                    break;
                }
                None if Instant::now() < deadline => std::thread::sleep(Duration::from_millis(1)),
                other => panic!("{other:?}"),
            }
        }
        // Timeout path still works after the nonblocking excursion.
        assert!(matches!(
            worker.recv_timeout(Duration::from_millis(10)),
            Recv::Timeout
        ));
    }

    #[test]
    fn loopback_recv_into_reuses_buffer() {
        let (mut server, mut worker) = pair();
        assert!(server.send(vec![8; 32]));
        assert!(server.send(vec![6; 16]));
        let mut buf = Vec::with_capacity(64);
        assert_eq!(worker.recv_into(&mut buf, Duration::from_secs(2)), RecvStatus::Frame);
        assert_eq!(buf, vec![8; 32]);
        let cap = buf.capacity();
        assert_eq!(worker.recv_into(&mut buf, Duration::from_secs(2)), RecvStatus::Frame);
        assert_eq!(buf, vec![6; 16]);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn hello_handshake_and_fleet_seating() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // Connect out of order: worker 2, then 0, then 1.
        let hs: Vec<_> = [2u32, 0, 1]
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || {
                    let mut t = TcpTransport::connect(addr).unwrap();
                    assert!(send_hello(&mut t, w, 7 * w));
                    t
                })
            })
            .collect();
        let mut fleet = accept_fleet(&listener, 3);
        assert_eq!(fleet.len(), 3);
        let mut workers: Vec<_> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        // Seat w is wired to the transport that sent hello id w.
        for (w, end) in fleet.iter_mut().enumerate() {
            assert!(end.send(vec![w as u8]));
        }
        for (w, t) in workers.iter_mut().enumerate() {
            match t.recv() {
                Recv::Frame(f) => assert_eq!(f, vec![w as u8]),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn hello_handshake_is_stats_neutral() {
        // The hello exists because TCP accept order is racy; the virtual
        // transport has no such frame. Byte-accounting parity between
        // the two backends requires it to stay invisible to LinkStats.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(addr).unwrap();
            assert!(send_hello(&mut t, 0, 0));
            t
        });
        let fleet = accept_fleet(&listener, 1);
        let worker = h.join().unwrap();
        assert_eq!(fleet[0].rcvd_stats().frames(), 0);
        assert_eq!(fleet[0].rcvd_stats().bytes(), 0);
        assert_eq!(worker.sent_stats().frames(), 0);
        assert_eq!(worker.sent_stats().bytes(), 0);
    }

    #[test]
    fn acceptor_forwards_rejoin_hellos() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let rx = spawn_acceptor(listener, 4);
        let h = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(addr).unwrap();
            assert!(send_hello(&mut t, 3, 12));
            t
        });
        let (w, mut end) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(w, 3);
        assert!(end.send(vec![0xAB]));
        let mut t = h.join().unwrap();
        match t.recv() {
            Recv::Frame(f) => assert_eq!(f, vec![0xAB]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_addr_accepts_literal_and_resolvable() {
        assert_eq!(
            parse_addr("X", " 127.0.0.1:7700 "),
            "127.0.0.1:7700".parse::<SocketAddr>().unwrap()
        );
        let resolved = parse_addr("X", "localhost:7701");
        assert_eq!(resolved.port(), 7701);
    }

    #[test]
    #[should_panic(expected = "GDSEC_LISTEN")]
    fn parse_addr_panics_with_var_and_value() {
        parse_addr("GDSEC_LISTEN", "not-an-address");
    }

    #[test]
    #[should_panic(expected = "GDSEC_CONNECT")]
    fn parse_addr_panics_on_missing_port() {
        // ToSocketAddrs requires host:port; a bare host must be loud.
        parse_addr("GDSEC_CONNECT", "127.0.0.1");
    }
}
