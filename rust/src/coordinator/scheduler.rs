//! Worker participation schedulers (paper §IV-G1: bandwidth-limited
//! operation where the server schedules only a fraction of workers each
//! round) and the **delay-adaptive quorum controller**: the logic that
//! picks each round's quorum size K online from the observed virtual
//! arrival distribution ([`Quorum::Adaptive`]), plus the
//! [`QuorumSim`] harness that drives the same cut/park/fold decisions
//! through [`Engine::step_quorum_aged`](crate::algo::engine::Engine)
//! single-process that the coordinator round loop makes distributed.

use super::round::{delivery_age, Quorum};
use super::transport::DelayPlan;
use crate::util::rng::Pcg64;

/// Scheduling policy.
#[derive(Debug, Clone)]
pub enum Scheduler {
    /// Every worker, every round.
    All,
    /// Round-robin over a rotating window of ⌈fraction·M⌉ workers — the
    /// paper's RR policy ([62]).
    RoundRobin { fraction: f64 },
    /// Uniformly random ⌈fraction·M⌉ workers per round.
    Random { fraction: f64, rng: Pcg64 },
}

impl Scheduler {
    pub fn parse(name: &str, fraction: f64, seed: u64) -> Option<Scheduler> {
        match name {
            "all" => Some(Scheduler::All),
            "rr" | "round-robin" => Some(Scheduler::RoundRobin { fraction }),
            "random" => Some(Scheduler::Random { fraction, rng: Pcg64::seeded(seed) }),
            _ => None,
        }
    }

    /// Number of workers active per round for M total.
    pub fn active_count(&self, m: usize) -> usize {
        match self {
            Scheduler::All => m,
            Scheduler::RoundRobin { fraction } | Scheduler::Random { fraction, .. } => {
                ((fraction * m as f64).ceil() as usize).clamp(1, m)
            }
        }
    }

    /// Active worker set for round `k` (1-based), sorted ascending.
    pub fn active(&mut self, k: usize, m: usize) -> Vec<usize> {
        let c = self.active_count(m);
        match self {
            Scheduler::All => (0..m).collect(),
            Scheduler::RoundRobin { .. } => {
                let start = ((k - 1) * c) % m;
                let mut set: Vec<usize> = (0..c).map(|i| (start + i) % m).collect();
                set.sort_unstable();
                set
            }
            Scheduler::Random { rng, .. } => {
                let mut set = rng.sample_indices(m, c);
                set.sort_unstable();
                set
            }
        }
    }
}

/// Default seed for [`CohortPlan`] sampling when `GDSEC_COHORT` picks
/// the cohort (reproduction runs pin it; see EXPERIMENTS.md §Federated
/// scale).
pub const DEFAULT_COHORT_SEED: u64 = 0xC0B0;

/// How a [`CohortPlan`] sizes each round's cohort.
#[derive(Debug, Clone, Copy, PartialEq)]
enum CohortSize {
    /// Exactly `k` workers (clamped to `[1, M]`).
    Count(usize),
    /// `ceil(frac·M)`, clamped to `[1, M]` — the same formula as
    /// [`Scheduler::active_count`].
    Fraction(f64),
}

/// Seeded per-round cohort sampling for cross-device scale (`M` in the
/// thousands, only a sampled cohort transmits per round). Each round
/// draws a uniform without-replacement cohort from a fresh
/// [`Pcg64`] stream keyed by `(seed, round)` — the sample is a pure
/// function of (seed, round, M), independent of call history, so
/// trajectories replay exactly across runs, restarts, and drivers.
///
/// The cohort *composes* with the existing machinery rather than
/// replacing it: the coordinator intersects it with the
/// [`Scheduler`]'s active set, the liveness machine then drops dead
/// members, and the [`Quorum`] clamps to the surviving live cohort.
/// A full cohort (fraction 1.0 / count ≥ M) selects everyone and the
/// round is bit-for-bit today's behavior.
///
/// Steady-state sampling is allocation-free: the permutation, id, and
/// membership buffers persist and the partial Fisher–Yates touches
/// only O(cohort) entries.
#[derive(Debug, Clone)]
pub struct CohortPlan {
    size: CohortSize,
    seed: u64,
    /// Identity-reset permutation scratch for the partial shuffle.
    perm: Vec<u32>,
    /// The current round's cohort, ascending worker id.
    ids: Vec<usize>,
    /// Membership flags for O(1) `contains` (cleared via `ids`).
    member: Vec<bool>,
}

impl CohortPlan {
    /// Cohort of exactly `k` workers per round.
    pub fn count(k: usize, seed: u64) -> CohortPlan {
        assert!(k >= 1, "cohort count must be positive");
        CohortPlan::with_size(CohortSize::Count(k), seed)
    }

    /// Cohort of `ceil(frac·M)` workers per round, `frac` ∈ (0, 1].
    pub fn fraction(frac: f64, seed: u64) -> CohortPlan {
        assert!(frac > 0.0 && frac <= 1.0, "cohort fraction must be in (0, 1]");
        CohortPlan::with_size(CohortSize::Fraction(frac), seed)
    }

    fn with_size(size: CohortSize, seed: u64) -> CohortPlan {
        CohortPlan { size, seed, perm: Vec::new(), ids: Vec::new(), member: Vec::new() }
    }

    /// Parse a `GDSEC_COHORT` spec: a positive worker count (`500`) or
    /// a fraction in (0, 1] (`0.1`; `1.0` = full participation — well-
    /// defined, not malformed). `0` and `0.0` are rejected explicitly:
    /// a zero cohort would otherwise clamp to 1 and silently mean "one
    /// worker trains the fleet".
    pub fn parse(spec: &str, seed: u64) -> Result<CohortPlan, String> {
        if let Ok(k) = spec.parse::<usize>() {
            return if k == 0 {
                Err("cohort count 0 rejected".into())
            } else {
                Ok(CohortPlan::count(k, seed))
            };
        }
        match spec.parse::<f64>() {
            Ok(f) if f > 0.0 && f <= 1.0 => Ok(CohortPlan::fraction(f, seed)),
            Ok(f) => Err(format!("fraction {f} outside (0, 1]")),
            Err(_) => Err(format!("got {spec:?}")),
        }
    }

    /// The `GDSEC_COHORT` env override (`None`/empty = full
    /// participation, i.e. no cohort sampling at all). Panics loudly on
    /// zero or garbage, matching the strict `GDSEC_QUORUM` style.
    pub fn from_env() -> Option<CohortPlan> {
        match std::env::var("GDSEC_COHORT").ok().as_deref() {
            None | Some("") => None,
            Some(s) => Some(CohortPlan::parse(s, DEFAULT_COHORT_SEED).unwrap_or_else(|e| {
                panic!(
                    "GDSEC_COHORT must be a positive worker count or a \
                     fraction in (0, 1]: {e}"
                )
            })),
        }
    }

    /// This round's cohort size for M workers.
    pub fn cohort_count(&self, m: usize) -> usize {
        match self.size {
            CohortSize::Count(k) => k.clamp(1, m),
            CohortSize::Fraction(f) => ((f * m as f64).ceil() as usize).clamp(1, m),
        }
    }

    /// Draw round `k`'s cohort over M workers. Read it back via
    /// [`ids`](Self::ids) / [`contains`](Self::contains).
    pub fn sample(&mut self, k: usize, m: usize) {
        // Clear the previous round's membership via its id list.
        for &w in &self.ids {
            if let Some(f) = self.member.get_mut(w) {
                *f = false;
            }
        }
        if self.member.len() != m {
            self.member.clear();
            self.member.resize(m, false);
        }
        self.ids.clear();
        let c = self.cohort_count(m);
        if c == m {
            self.ids.extend(0..m);
        } else {
            // Identity-reset permutation + partial Fisher–Yates: c
            // swaps from a fresh per-round stream.
            if self.perm.len() != m {
                self.perm.clear();
                self.perm.extend(0..m as u32);
            } else {
                for (i, p) in self.perm.iter_mut().enumerate() {
                    *p = i as u32;
                }
            }
            let mut rng = Pcg64::new(self.seed, k as u64);
            for i in 0..c {
                let j = i + rng.index(m - i);
                self.perm.swap(i, j);
            }
            self.ids.extend(self.perm[..c].iter().map(|&w| w as usize));
            self.ids.sort_unstable();
        }
        for &w in &self.ids {
            self.member[w] = true;
        }
    }

    /// The most recent sample, ascending worker id.
    pub fn ids(&self) -> &[usize] {
        &self.ids
    }

    /// O(1) membership in the most recent sample.
    pub fn contains(&self, w: usize) -> bool {
        self.member.get(w).copied().unwrap_or(false)
    }
}

/// EMA coefficient for the per-worker delay estimate: one observation
/// moves the estimate a quarter of the way — slow enough to ignore
/// one-round jitter, fast enough to track a phase shift in a handful of
/// rounds.
pub const ADAPT_EMA: f64 = 0.25;

/// Multiplicative slack on the quantile threshold: a worker predicted
/// within `ADAPT_SLACK ×` the target order statistic still makes the
/// quorum, so jitter around a tight fast cluster does not randomly
/// evict cluster members — only genuine stragglers (far beyond the
/// cluster) are cut.
pub const ADAPT_SLACK: f64 = 2.0;

/// Online quorum-size decisions for a [`Quorum`] policy. Fixed policies
/// (`All`/`Count`/`Fraction`) pass through [`Quorum::k_of`];
/// [`Quorum::Adaptive`] keeps a per-worker EMA of observed virtual
/// arrival delays and cuts each round at the workers predicted within
/// [`ADAPT_SLACK`] of the `target_quantile`-th delay order statistic,
/// floored at `ceil(min_frac · expected)`.
///
/// Both drivers use it identically: decide K from the PRE-round
/// estimates ([`k_for`](QuorumController::k_for)), gather, then
/// [`observe`](QuorumController::observe) every replier's delay. The
/// decide/observe split is transport-agnostic — the unit fed to
/// `observe` is seeded virtual [`DelayPlan`] units on the in-memory
/// transport (state then depends only on the deterministic plan, so
/// adaptive trajectories stay reproducible and thread-count
/// independent) and **measured wall-clock microseconds** since the
/// round's broadcast on a real transport (the controller genuinely
/// adapts to the machine; only relative magnitudes matter, so the unit
/// swap needs no retuning of [`ADAPT_EMA`]/[`ADAPT_SLACK`]).
pub struct QuorumController {
    policy: Quorum,
    ema: Vec<f64>,
    seen: Vec<bool>,
    scratch: Vec<f64>,
}

impl QuorumController {
    pub fn new(policy: Quorum, m: usize) -> QuorumController {
        QuorumController {
            policy,
            ema: vec![0.0; m],
            seen: vec![false; m],
            scratch: Vec::with_capacity(m),
        }
    }

    pub fn policy(&self) -> Quorum {
        self.policy
    }

    /// The quorum size for a round whose live scheduled workers are
    /// `expected`. Until every expected worker has at least one
    /// observation, `Adaptive` answers with its `min_frac` floor — a
    /// cheap cold start: the cut's late replies fold as stale, so
    /// starting aggressive costs bounded staleness, never waiting on an
    /// unknown straggler.
    pub fn k_for(&mut self, expected: &[usize]) -> usize {
        let n = expected.len();
        let Quorum::Adaptive { target_quantile, min_frac } = self.policy else {
            return self.policy.k_of(n);
        };
        if n == 0 {
            return 0;
        }
        let floor = ((min_frac * n as f64).ceil() as usize).clamp(1, n);
        if expected.iter().any(|&w| !self.seen[w]) {
            return floor;
        }
        self.scratch.clear();
        self.scratch.extend(expected.iter().map(|&w| self.ema[w]));
        self.scratch.sort_by(f64::total_cmp);
        let rank = ((target_quantile * n as f64).ceil() as usize).clamp(1, n);
        let tau = self.scratch[rank - 1] * ADAPT_SLACK;
        let k = self.scratch.iter().filter(|&&e| e <= tau).count();
        k.clamp(floor, n)
    }

    /// Feed one observed arrival delay for worker `w` (called for every
    /// replier after the gather, cut-late repliers included — their
    /// delay is exactly the signal the next round's K needs). `units`
    /// is virtual [`DelayPlan`] units on the in-memory transport,
    /// measured µs since broadcast on a real one.
    pub fn observe(&mut self, w: usize, units: u64) {
        let x = units as f64;
        if self.seen[w] {
            self.ema[w] += ADAPT_EMA * (x - self.ema[w]);
        } else {
            self.ema[w] = x;
            self.seen[w] = true;
        }
    }
}

/// Deterministic single-process driver for semi-synchronous engine runs:
/// per round it ranks the available workers' virtual arrivals under a
/// [`DelayPlan`], asks the [`QuorumController`] for K, cuts, assigns
/// each late reply its [`delivery_age`] (the rounds its excess delay
/// spans, clamped to the staleness window), and tracks in-flight workers
/// so they sit out the rounds their update spends in transit. The
/// decide-K → cut → observe logic is the coordinator round loop's; the
/// in-flight model is stricter — a slow worker here computes nothing
/// while its update is in transit (and is only observed when it
/// arrives), whereas the coordinator's links pipeline, so a cut-late
/// worker keeps replying every round. The two drivers are therefore NOT
/// bit-pinned to each other under cuts, only under `Quorum::All`. Feed
/// the returned late set straight into
/// [`Engine::step_quorum_aged`](crate::algo::engine::Engine::step_quorum_aged).
pub struct QuorumSim {
    plan: DelayPlan,
    ctrl: QuorumController,
    window: usize,
    /// Per worker: the first round it is available again (an in-flight
    /// update from round k with age a occupies it through round k+a−1).
    busy_until: Vec<usize>,
    expected: Vec<usize>,
    arrivals: Vec<(u64, usize)>,
    late: Vec<(usize, u32)>,
}

impl QuorumSim {
    pub fn new(m: usize, policy: Quorum, plan: DelayPlan, window: usize) -> QuorumSim {
        QuorumSim {
            plan,
            ctrl: QuorumController::new(policy, m),
            window: window.max(1),
            busy_until: vec![0; m],
            expected: Vec::with_capacity(m),
            arrivals: Vec::with_capacity(m),
            late: Vec::with_capacity(m),
        }
    }

    /// Cut round `k` (1-based) over the workers in `act` (`None` = all)
    /// that are not mid-flight. Returns the `(worker, delivery age)`
    /// late set (ascending worker id — pass to `step_quorum_aged`) and
    /// the round's virtual units (the K-th arrival's delay: what the
    /// quorum waited for).
    pub fn round(&mut self, k: usize, act: Option<&[usize]>) -> (&[(usize, u32)], u64) {
        self.expected.clear();
        self.arrivals.clear();
        for w in 0..self.busy_until.len() {
            if self.busy_until[w] <= k && act.map_or(true, |set| set.contains(&w)) {
                self.expected.push(w);
                self.arrivals.push((self.plan.delay(w, k), w));
            }
        }
        // K from the PRE-round estimates (predictive, like the
        // coordinator), then observe this round's arrivals.
        let kq = self.ctrl.k_for(&self.expected);
        self.arrivals.sort_unstable();
        for &(d, w) in &self.arrivals {
            self.ctrl.observe(w, d);
        }
        let on_time = kq.min(self.arrivals.len());
        let units = self.arrivals[..on_time].iter().map(|&(d, _)| d).max().unwrap_or(0);
        self.late.clear();
        for &(d, w) in &self.arrivals[on_time..] {
            let age = delivery_age(d, units, self.window);
            self.busy_until[w] = k + age as usize;
            self.late.push((w, age));
        }
        self.late.sort_unstable();
        (&self.late, units)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_selects_everyone() {
        let mut s = Scheduler::All;
        assert_eq!(s.active(1, 4), vec![0, 1, 2, 3]);
        assert_eq!(s.active(9, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn rr_half_covers_all_in_two_rounds() {
        let mut s = Scheduler::RoundRobin { fraction: 0.5 };
        let m = 10;
        let r1 = s.active(1, m);
        let r2 = s.active(2, m);
        assert_eq!(r1.len(), 5);
        assert_eq!(r2.len(), 5);
        let mut all: Vec<usize> = r1.iter().chain(r2.iter()).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn rr_fairness_over_cycle() {
        // Every worker appears exactly fraction·rounds times over a full
        // cycle, for any m / fraction combination.
        let mut s = Scheduler::RoundRobin { fraction: 0.3 };
        let m = 7;
        let c = s.active_count(m); // ceil(2.1) = 3
        assert_eq!(c, 3);
        let mut counts = vec![0usize; m];
        // lcm-ish long horizon
        for k in 1..=7 * 3 * 4 {
            for w in s.active(k, m) {
                counts[w] += 1;
            }
        }
        let min = counts.iter().min().unwrap();
        let max = counts.iter().max().unwrap();
        assert!(max - min <= 1, "unfair RR: {counts:?}");
    }

    #[test]
    fn random_selects_distinct_fraction() {
        let mut s = Scheduler::Random { fraction: 0.25, rng: Pcg64::seeded(1) };
        for k in 1..20 {
            let set = s.active(k, 16);
            assert_eq!(set.len(), 4);
            let mut d = set.clone();
            d.dedup();
            assert_eq!(d.len(), 4);
            assert!(set.iter().all(|&w| w < 16));
        }
    }

    #[test]
    fn active_count_clamps() {
        let s = Scheduler::RoundRobin { fraction: 0.01 };
        assert_eq!(s.active_count(5), 1);
        let s = Scheduler::RoundRobin { fraction: 2.0 };
        assert_eq!(s.active_count(5), 5);
    }

    #[test]
    fn adaptive_controller_tracks_straggler_sets() {
        let policy = Quorum::Adaptive { target_quantile: 0.3, min_frac: 0.25 };
        let mut ctrl = QuorumController::new(policy, 8);
        let all: Vec<usize> = (0..8).collect();
        // Cold start: the min_frac floor (ceil(0.25·8) = 2).
        assert_eq!(ctrl.k_for(&all), 2);
        // One observed round: 7 fast workers at 2 units, one at 40.
        for w in 0..7 {
            ctrl.observe(w, 2);
        }
        ctrl.observe(7, 40);
        // rank = ceil(0.3·8) = 3 ⇒ τ = 2·SLACK = 4 ⇒ the fast 7 make it.
        assert_eq!(ctrl.k_for(&all), 7);
        // Workers 3..7 turn into stragglers; the EMA needs a few
        // observations to cross τ, then K settles on the fast 3.
        for _ in 0..12 {
            for w in 0..3 {
                ctrl.observe(w, 2);
            }
            for w in 3..8 {
                ctrl.observe(w, 40);
            }
        }
        assert_eq!(ctrl.k_for(&all), 3);
        // The floor always binds.
        let tight = Quorum::Adaptive { target_quantile: 0.3, min_frac: 0.9 };
        let mut ctrl = QuorumController::new(tight, 4);
        for w in 0..4 {
            ctrl.observe(w, if w == 0 { 1 } else { 500 });
        }
        assert_eq!(ctrl.k_for(&[0, 1, 2, 3]), 4); // ceil(0.9·4)
        // Fixed policies pass through k_of.
        let mut fixed = QuorumController::new(Quorum::Count(2), 5);
        assert_eq!(fixed.k_for(&[0, 1, 2, 3, 4]), 2);
        assert_eq!(fixed.k_for(&[]), 0);
    }

    #[test]
    fn adaptive_no_delays_stays_synchronous() {
        // With every arrival tied at 0 the quantile threshold is 0 and
        // everyone is within it: adaptive must not cut a homogeneous
        // fleet (after the one cold-start round at the floor).
        let policy = Quorum::Adaptive { target_quantile: 0.5, min_frac: 0.25 };
        let mut sim = QuorumSim::new(4, policy, DelayPlan::None, 1);
        let (late, units) = sim.round(1, None);
        assert_eq!((late.len(), units), (3, 0)); // cold-start floor K=1
        for k in 2..10 {
            let (late, units) = sim.round(k, None);
            assert!(late.is_empty(), "round {k} cut a homogeneous fleet: {late:?}");
            assert_eq!(units, 0);
        }
    }

    #[test]
    fn quorum_sim_parks_straggler_and_tracks_flight_time() {
        // One hard straggler under Count(2): cut at the fast pair, the
        // straggler's excess spans the window and it sits out its
        // in-flight rounds.
        let plan = DelayPlan::PerWorker(vec![1, 1, 900]);
        let mut sim = QuorumSim::new(3, Quorum::Count(2), plan, 3);
        let (late, units) = sim.round(1, None);
        assert_eq!(units, 1);
        assert_eq!(late, &[(2, 3)]); // ceil(899/1) clamped to the window
        // Rounds 2 and 3: the straggler is mid-flight — only the fast
        // pair arrives, nobody is late.
        for k in 2..=3 {
            let (late, units) = sim.round(k, None);
            assert!(late.is_empty(), "round {k}");
            assert_eq!(units, 1);
        }
        // Round 4: it is back, and gets cut again.
        let (late, _) = sim.round(4, None);
        assert_eq!(late, &[(2, 3)]);
    }

    #[test]
    fn cohort_sample_is_deterministic_and_history_free() {
        let (m, k) = (1000usize, 17usize);
        let mut a = CohortPlan::fraction(0.1, 42);
        let mut b = CohortPlan::fraction(0.1, 42);
        // b burns earlier rounds first — history must not matter.
        for r in 1..k {
            b.sample(r, m);
        }
        a.sample(k, m);
        b.sample(k, m);
        assert_eq!(a.ids(), b.ids());
        assert_eq!(a.ids().len(), 100);
        assert!(a.ids().windows(2).all(|w| w[0] < w[1]), "sorted unique");
        assert!(a.ids().iter().all(|&w| w < m));
        for w in 0..m {
            assert_eq!(a.contains(w), a.ids().binary_search(&w).is_ok());
        }
        // Different rounds and different seeds draw different cohorts.
        let prev: Vec<usize> = a.ids().to_vec();
        a.sample(k + 1, m);
        assert_ne!(a.ids(), prev.as_slice());
        let mut c = CohortPlan::fraction(0.1, 43);
        c.sample(k, m);
        assert_ne!(c.ids(), prev.as_slice());
    }

    #[test]
    fn cohort_covers_fleet_over_rounds() {
        // Uniform sampling must not starve anyone over a long horizon.
        let m = 60usize;
        let mut plan = CohortPlan::count(6, 7);
        let mut seen = vec![false; m];
        for k in 1..=400 {
            plan.sample(k, m);
            for &w in plan.ids() {
                seen[w] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "starved workers: {seen:?}");
    }

    #[test]
    fn cohort_full_fraction_is_everyone() {
        let mut plan = CohortPlan::fraction(1.0, 1);
        plan.sample(5, 7);
        assert_eq!(plan.ids(), (0..7).collect::<Vec<_>>().as_slice());
        let mut plan = CohortPlan::count(99, 1);
        plan.sample(5, 7);
        assert_eq!(plan.ids().len(), 7);
        // Count clamps to [1, m]; fraction uses the active_count
        // formula.
        assert_eq!(CohortPlan::count(3, 0).cohort_count(10), 3);
        assert_eq!(CohortPlan::fraction(0.25, 0).cohort_count(10), 3); // ceil(2.5)
        assert_eq!(CohortPlan::fraction(0.001, 0).cohort_count(10), 1);
    }

    #[test]
    fn cohort_parse_contract() {
        assert!(CohortPlan::parse("500", 0).is_ok());
        assert!(CohortPlan::parse("0.1", 0).is_ok());
        assert!(CohortPlan::parse("1.0", 0).is_ok());
        assert!(CohortPlan::parse("0", 0).is_err());
        assert!(CohortPlan::parse("0.0", 0).is_err());
        assert!(CohortPlan::parse("1.5", 0).is_err());
        assert!(CohortPlan::parse("-2", 0).is_err());
        assert!(CohortPlan::parse("bogus", 0).is_err());
    }

    #[test]
    fn parse_names() {
        assert!(matches!(Scheduler::parse("all", 1.0, 0), Some(Scheduler::All)));
        assert!(matches!(
            Scheduler::parse("rr", 0.5, 0),
            Some(Scheduler::RoundRobin { .. })
        ));
        assert!(matches!(
            Scheduler::parse("random", 0.5, 0),
            Some(Scheduler::Random { .. })
        ));
        assert!(Scheduler::parse("bogus", 0.5, 0).is_none());
    }
}
