//! Worker participation schedulers (paper §IV-G1: bandwidth-limited
//! operation where the server schedules only a fraction of workers each
//! round).

use crate::util::rng::Pcg64;

/// Scheduling policy.
#[derive(Debug, Clone)]
pub enum Scheduler {
    /// Every worker, every round.
    All,
    /// Round-robin over a rotating window of ⌈fraction·M⌉ workers — the
    /// paper's RR policy ([62]).
    RoundRobin { fraction: f64 },
    /// Uniformly random ⌈fraction·M⌉ workers per round.
    Random { fraction: f64, rng: Pcg64 },
}

impl Scheduler {
    pub fn parse(name: &str, fraction: f64, seed: u64) -> Option<Scheduler> {
        match name {
            "all" => Some(Scheduler::All),
            "rr" | "round-robin" => Some(Scheduler::RoundRobin { fraction }),
            "random" => Some(Scheduler::Random { fraction, rng: Pcg64::seeded(seed) }),
            _ => None,
        }
    }

    /// Number of workers active per round for M total.
    pub fn active_count(&self, m: usize) -> usize {
        match self {
            Scheduler::All => m,
            Scheduler::RoundRobin { fraction } | Scheduler::Random { fraction, .. } => {
                ((fraction * m as f64).ceil() as usize).clamp(1, m)
            }
        }
    }

    /// Active worker set for round `k` (1-based), sorted ascending.
    pub fn active(&mut self, k: usize, m: usize) -> Vec<usize> {
        let c = self.active_count(m);
        match self {
            Scheduler::All => (0..m).collect(),
            Scheduler::RoundRobin { .. } => {
                let start = ((k - 1) * c) % m;
                let mut set: Vec<usize> = (0..c).map(|i| (start + i) % m).collect();
                set.sort_unstable();
                set
            }
            Scheduler::Random { rng, .. } => {
                let mut set = rng.sample_indices(m, c);
                set.sort_unstable();
                set
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_selects_everyone() {
        let mut s = Scheduler::All;
        assert_eq!(s.active(1, 4), vec![0, 1, 2, 3]);
        assert_eq!(s.active(9, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn rr_half_covers_all_in_two_rounds() {
        let mut s = Scheduler::RoundRobin { fraction: 0.5 };
        let m = 10;
        let r1 = s.active(1, m);
        let r2 = s.active(2, m);
        assert_eq!(r1.len(), 5);
        assert_eq!(r2.len(), 5);
        let mut all: Vec<usize> = r1.iter().chain(r2.iter()).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn rr_fairness_over_cycle() {
        // Every worker appears exactly fraction·rounds times over a full
        // cycle, for any m / fraction combination.
        let mut s = Scheduler::RoundRobin { fraction: 0.3 };
        let m = 7;
        let c = s.active_count(m); // ceil(2.1) = 3
        assert_eq!(c, 3);
        let mut counts = vec![0usize; m];
        // lcm-ish long horizon
        for k in 1..=7 * 3 * 4 {
            for w in s.active(k, m) {
                counts[w] += 1;
            }
        }
        let min = counts.iter().min().unwrap();
        let max = counts.iter().max().unwrap();
        assert!(max - min <= 1, "unfair RR: {counts:?}");
    }

    #[test]
    fn random_selects_distinct_fraction() {
        let mut s = Scheduler::Random { fraction: 0.25, rng: Pcg64::seeded(1) };
        for k in 1..20 {
            let set = s.active(k, 16);
            assert_eq!(set.len(), 4);
            let mut d = set.clone();
            d.dedup();
            assert_eq!(d.len(), 4);
            assert!(set.iter().all(|&w| w < 16));
        }
    }

    #[test]
    fn active_count_clamps() {
        let s = Scheduler::RoundRobin { fraction: 0.01 };
        assert_eq!(s.active_count(5), 1);
        let s = Scheduler::RoundRobin { fraction: 2.0 };
        assert_eq!(s.active_count(5), 5);
    }

    #[test]
    fn parse_names() {
        assert!(matches!(Scheduler::parse("all", 1.0, 0), Some(Scheduler::All)));
        assert!(matches!(
            Scheduler::parse("rr", 0.5, 0),
            Some(Scheduler::RoundRobin { .. })
        ));
        assert!(matches!(
            Scheduler::parse("random", 0.5, 0),
            Some(Scheduler::Random { .. })
        ));
        assert!(Scheduler::parse("bogus", 0.5, 0).is_none());
    }
}
