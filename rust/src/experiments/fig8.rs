//! Fig 8 — bandwidth-limited operation: linear regression on CIFAR-like
//! data (2000 samples, d = 3072, M = 100 workers), round-robin scheduling
//! of half the workers per round. GD(all) vs GD(half) vs GD-SEC(all,
//! ξ/M = 100) vs GD-SEC(half, ξ/M = 10). Paper finding: GD-SEC with RR
//! half-participation is only slightly slower than full participation,
//! while GD(half) degrades clearly.

use super::{common_eps, compare_table, write_traces, ExpContext, FigReport};
use crate::algo::gdsec::{GdSecConfig, Xi};
use crate::algo::{gd, gdsec};
use crate::coordinator::scheduler::Scheduler;
use crate::data::synthetic;
use crate::objectives::Problem;
use crate::util::error::Result;

pub fn run(ctx: &ExpContext) -> Result<FigReport> {
    let n = ctx.samples(2000);
    let m = if ctx.quick { 20 } else { 100 };
    let data = synthetic::cifar_like(ctx.seed, n);
    let lambda = 1.0 / n as f64;
    let prob = Problem::linear(data, m, lambda);
    let iters = ctx.iters(600);
    // Paper tunes α = 2/L for CIFAR-10; the synthetic substitute is
    // closer to the stability edge, so 1/L.
    let alpha = 1.0 / prob.lipschitz();
    let fstar = prob.estimate_fstar(gdsec::fstar_iters(iters));

    let gd_cfg = gd::GdConfig { alpha, eval_every: 1, fstar: Some(fstar) };
    let t_gd_all = gd::run(&prob, &gd_cfg, iters);
    let mut rr1 = Scheduler::RoundRobin { fraction: 0.5 };
    let mut t_gd_half =
        gd::run_scheduled(&prob, &gd_cfg, iters, |k| Some(rr1.active(k, m)));
    t_gd_half.algo = "GD(RR half)".into();

    let t_sec_all = gdsec::run(
        &prob,
        &GdSecConfig {
            alpha,
            beta: 0.01,
            // paper: ξ/M = 100 on real CIFAR; retuned 4000 for the substitute
            // (largest value matching GD's convergence curve).
            xi: Xi::Uniform(4000.0 * m as f64),
            fstar: Some(fstar),
            ..Default::default()
        },
        iters,
    );
    let mut rr2 = Scheduler::RoundRobin { fraction: 0.5 };
    let mut t_sec_half = gdsec::run_scheduled(
        &prob,
        &GdSecConfig {
            alpha,
            beta: 0.01,
            // half participation needs a 10x smaller threshold (paper: 10).
            xi: Xi::Uniform(400.0 * m as f64),
            fstar: Some(fstar),
            ..Default::default()
        },
        iters,
        |k| Some(rr2.active(k, m)),
    );
    t_sec_half.algo = "GD-SEC(RR half)".into();

    let traces = [&t_gd_all, &t_gd_half, &t_sec_all, &t_sec_half];
    let eps = common_eps(&[&t_gd_all, &t_sec_all, &t_sec_half], 2.0);
    let (rendered, mut headline) = compare_table(&traces, eps);
    headline.push((
        "sec_half_vs_sec_all_final_err_ratio".into(),
        t_sec_half.final_error() / t_sec_all.final_error().max(1e-300),
    ));
    headline.push((
        "gd_half_vs_gd_all_final_err_ratio".into(),
        t_gd_half.final_error() / t_gd_all.final_error().max(1e-300),
    ));
    let csv_files = write_traces(ctx, "fig8", &traces)?;
    Ok(FigReport {
        fig: "fig8".into(),
        title: format!("linreg / cifar-like (n={n}, d=3072, M={m}), eps={eps:.2e}"),
        rendered,
        csv_files,
        headline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_gdsec_half_tracks_full() {
        let dir = std::env::temp_dir().join(format!("gdsec_fig8_{}", std::process::id()));
        let ctx = ExpContext::quick(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = run(&ctx).unwrap();
        let sec_ratio = r
            .headline
            .iter()
            .find(|(k, _)| k == "sec_half_vs_sec_all_final_err_ratio")
            .unwrap()
            .1;
        assert!(sec_ratio.is_finite() && sec_ratio > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
