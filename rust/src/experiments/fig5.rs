//! Fig 5 — impact of ξ: nonconvex nonlinear least squares on W2A-like
//! data (d = 300). GD vs GD-SEC with ξ/M ∈ {500, 2000, 5000}. Paper
//! headline: ξ/M = 5000 reaches objective error 0.0112 with ≈0.38% of
//! GD's bits; larger ξ trades a few extra iterations for fewer bits.

use super::{common_eps, compare_table, write_traces, ExpContext, FigReport};
use crate::algo::gdsec::{GdSecConfig, Xi};
use crate::algo::{gd, gdsec};
use crate::data::synthetic;
use crate::objectives::Problem;
use crate::util::error::Result;

pub fn run(ctx: &ExpContext) -> Result<FigReport> {
    let n = ctx.samples(3470);
    let m = 5;
    let data = synthetic::w2a_like(ctx.seed, n);
    let lambda = 1.0 / n as f64;
    let prob = Problem::nlls(data, m, lambda);
    let iters = ctx.iters(1500);
    let alpha = 1.0 / prob.lipschitz();
    let fstar = prob.estimate_fstar(gdsec::fstar_iters(iters));

    let t_gd = gd::run(&prob, &gd::GdConfig { alpha, eval_every: 1, fstar: Some(fstar) }, iters);
    let mut variants = Vec::new();
    for xi_over_m in [500.0, 2000.0, 5000.0] {
        let mut t = gdsec::run(
            &prob,
            &GdSecConfig {
                alpha,
                beta: 0.01,
                xi: Xi::Uniform(xi_over_m * m as f64),
                fstar: Some(fstar),
                ..Default::default()
            },
            iters,
        );
        t.algo = format!("GD-SEC(ξ/M={xi_over_m})");
        variants.push(t);
    }
    let mut traces: Vec<&crate::algo::trace::Trace> = vec![&t_gd];
    traces.extend(variants.iter());
    let eps = common_eps(&traces, 2.0);
    let (rendered, mut headline) = compare_table(&traces, eps);
    // Bits monotonically decrease with xi.
    headline.push((
        "bits_ratio_xi5000_vs_gd".into(),
        variants[2].total_bits() as f64 / t_gd.total_bits() as f64,
    ));
    let csv_files = write_traces(ctx, "fig5", &traces)?;
    Ok(FigReport {
        fig: "fig5".into(),
        title: format!("nlls / w2a-like (n={n}, d=300, M={m}), eps={eps:.2e}"),
        rendered,
        csv_files,
        headline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_bits_decrease_with_xi() {
        let dir = std::env::temp_dir().join(format!("gdsec_fig5_{}", std::process::id()));
        let ctx = ExpContext::quick(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = run(&ctx).unwrap();
        let ratio =
            r.headline.iter().find(|(k, _)| k == "bits_ratio_xi5000_vs_gd").unwrap().1;
        assert!(ratio < 0.5, "xi=5000 should spend far fewer bits than GD: {ratio}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
