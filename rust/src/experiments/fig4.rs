//! Fig 4 — impact of the state variable: linear regression on
//! COLON-CANCER-like data (62×2000, n ≪ d). GD-SEC with β ∈ {0.01, 0.1,
//! 0.5} at matched thresholds vs GD-SEC *without* state variables vs GD.
//! Paper findings: (a) state variables allow a much larger ξ (more
//! savings) at small β; (b) raising β without lowering ξ destabilizes.

use super::{common_eps, compare_table, write_traces, ExpContext, FigReport};
use crate::algo::gdsec::{GdSecConfig, Xi};
use crate::algo::{gd, gdsec};
use crate::data::synthetic;
use crate::objectives::Problem;
use crate::util::error::Result;

pub fn run(ctx: &ExpContext) -> Result<FigReport> {
    let m = 5;
    let data = synthetic::colon_like(ctx.seed);
    let n = data.n();
    let lambda = 1.0 / n as f64;
    let prob = Problem::linear(data, m, lambda);
    let iters = ctx.iters(1000);
    let alpha = 1.0 / prob.lipschitz();
    let fstar = prob.estimate_fstar(gdsec::fstar_iters(iters));
    let xi_big = 2000.0 * m as f64;

    let t_gd = gd::run(&prob, &gd::GdConfig { alpha, eval_every: 1, fstar: Some(fstar) }, iters);
    let mut variants = Vec::new();
    for beta in [0.01, 0.1, 0.5] {
        let mut t = gdsec::run(
            &prob,
            &GdSecConfig {
                alpha,
                beta,
                xi: Xi::Uniform(xi_big),
                fstar: Some(fstar),
                ..Default::default()
            },
            iters,
        );
        t.algo = format!("GD-SEC(β={beta})");
        variants.push(t);
    }
    // No state variable: h ≡ 0 everywhere; matched smaller threshold (the
    // largest at which it remains stable here).
    let mut t_nosv = gdsec::run(
        &prob,
        &GdSecConfig {
            alpha,
            beta: 0.0,
            xi: Xi::Uniform(250.0 * m as f64),
            state_variable: false,
            fstar: Some(fstar),
            ..Default::default()
        },
        iters,
    );
    t_nosv.algo = "GD-SEC(no-state)".into();

    let mut traces: Vec<&crate::algo::trace::Trace> = vec![&t_gd];
    traces.extend(variants.iter());
    traces.push(&t_nosv);
    let eps = common_eps(&[&t_gd, &variants[0]], 2.0);
    let (rendered, mut headline) = compare_table(&traces, eps);
    // state-variable effect: bits of β=0.01 variant vs no-state variant
    headline.push((
        "state_var_bits_ratio".into(),
        variants[0].total_bits() as f64 / t_nosv.total_bits().max(1) as f64,
    ));
    headline.push(("beta_0.5_final_err".into(), variants[2].final_error()));
    headline.push(("beta_0.01_final_err".into(), variants[0].final_error()));
    let csv_files = write_traces(ctx, "fig4", &traces)?;
    Ok(FigReport {
        fig: "fig4".into(),
        title: format!("linreg / colon-like (n={n}, d=2000, M={m}), eps={eps:.2e}"),
        rendered,
        csv_files,
        headline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_small_beta_stable() {
        let dir = std::env::temp_dir().join(format!("gdsec_fig4_{}", std::process::id()));
        let ctx = ExpContext::quick(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = run(&ctx).unwrap();
        let b001 = r.headline.iter().find(|(k, _)| k == "beta_0.01_final_err").unwrap().1;
        assert!(b001.is_finite());
        std::fs::remove_dir_all(&dir).ok();
    }
}
