//! Fig 9 — stochastic extension: linear regression on MNIST-like data
//! (6000 samples, M = 100, minibatch 1, step schedule
//! α_k = γ₀(1+γ₀λk)^{-1} with γ₀ = 0.01): SGD vs SGD-SEC vs QSGD-SEC,
//! ξ/M = 100. SGD-SEC tracks SGD's convergence with far fewer bits, and
//! quantizing the survivors (QSGD-SEC) compounds the savings.

use super::{compare_table, write_traces, ExpContext, FigReport};
use crate::algo::gdsec::Xi;
use crate::algo::sgdsec::{self, SgdSecConfig};
use crate::data::synthetic;
use crate::objectives::Problem;
use crate::util::error::Result;

pub fn run(ctx: &ExpContext) -> Result<FigReport> {
    let n = ctx.samples(6000);
    let m = if ctx.quick { 20 } else { 100 };
    let data = synthetic::mnist_like(ctx.seed, n);
    let lambda = 1.0 / n as f64;
    let prob = Problem::linear(data, m, lambda);
    let iters = ctx.iters(1000);
    let fstar = prob.estimate_fstar(ctx.iters(3000));

    let base = SgdSecConfig {
        gamma0: 0.01,
        lambda,
        beta: 0.01,
        xi: Xi::Uniform(100.0 * m as f64),
        batch: 1,
        seed: ctx.seed,
        quantize_s: None,
        eval_every: 5,
        fstar: Some(fstar),
    };
    let t_sgd = sgdsec::run_sgd(&prob, &base, iters);
    let t_sec = sgdsec::run_sgdsec(&prob, &base, iters);
    let mut qcfg = base.clone();
    qcfg.quantize_s = Some(255);
    let t_qsec = sgdsec::run_sgdsec(&prob, &qcfg, iters);

    let traces = [&t_sgd, &t_sec, &t_qsec];
    // Stochastic noise floor: target = 2x the best final error.
    let eps = traces
        .iter()
        .map(|t| t.final_error())
        .fold(f64::INFINITY, f64::min)
        .max(1e-12)
        * 2.0;
    let (rendered, mut headline) = compare_table(&traces, eps);
    headline.push((
        "sgdsec_bits_over_sgd".into(),
        t_sec.total_bits() as f64 / t_sgd.total_bits().max(1) as f64,
    ));
    headline.push((
        "qsgdsec_bits_over_sgdsec".into(),
        t_qsec.total_bits() as f64 / t_sec.total_bits().max(1) as f64,
    ));
    let csv_files = write_traces(ctx, "fig9", &traces)?;
    Ok(FigReport {
        fig: "fig9".into(),
        title: format!("SGD variants / mnist-like (n={n}, d=784, M={m}, batch=1)"),
        rendered,
        csv_files,
        headline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_savings_compound() {
        let dir = std::env::temp_dir().join(format!("gdsec_fig9_{}", std::process::id()));
        let ctx = ExpContext::quick(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = run(&ctx).unwrap();
        let sec = r.headline.iter().find(|(k, _)| k == "sgdsec_bits_over_sgd").unwrap().1;
        let q = r.headline.iter().find(|(k, _)| k == "qsgdsec_bits_over_sgdsec").unwrap().1;
        assert!(sec < 1.0, "SGD-SEC should beat SGD on bits: {sec}");
        assert!(q < 1.0, "QSGD-SEC should beat SGD-SEC on bits: {q}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
