//! Fig 7 — coordinate-scaled thresholds on RCV1-like sparse data
//! (logistic regression, d = 47236): ξ_i = ξ/L^i vs uniform ξ_i = ξ,
//! objective value vs total transmitted entries. Scaling by the
//! coordinate-wise smoothness lets slow coordinates carry much larger
//! thresholds → fewer transmitted entries at equal objective.

use super::{write_traces, ExpContext, FigReport};
use crate::algo::gdsec::{GdSecConfig, Xi};
use crate::algo::gdsec;
use crate::data::synthetic;
use crate::objectives::Problem;
use crate::util::tablefmt::{sci, Table};
use crate::util::error::Result;

pub fn run(ctx: &ExpContext) -> Result<FigReport> {
    // Full RCV1-train is 15181×47236; quick mode shrinks n and d.
    let (n, d) = if ctx.quick { (800, 4000) } else { (6000, 47236) };
    let m = 5;
    let data = synthetic::rcv1_like(ctx.seed, n, d, 50);
    let lambda = 1.0 / n as f64;
    let prob = Problem::logistic(data, m, lambda);
    let iters = ctx.iters(1000);
    // 0.5/L: the power-iteration L estimate is slightly loose at d=47k
    // and GD-SEC's state dynamics sit near the stability edge at 1/L.
    let alpha = 0.5 / prob.lipschitz();
    let fstar = prob.estimate_fstar(ctx.iters(2000));
    // Grid-searched scale (paper does a full 2^a grid; the shape of the
    // result — scaled beats uniform — is what we reproduce).
    let xi = 1.0 * m as f64;

    let mut t_uniform = gdsec::run(
        &prob,
        &GdSecConfig {
            alpha,
            beta: 0.01,
            xi: Xi::Uniform(xi),
            eval_every: 5,
            fstar: Some(fstar),
            ..Default::default()
        },
        iters,
    );
    t_uniform.algo = "GD-SEC(ξ_i=ξ)".into();
    let coord_l = prob.coord_lipschitz();
    // Normalize by the geometric mean of L^i so the typical threshold
    // matches the uniform run (the arithmetic mean is dominated by the
    // few very popular features under the power-law profile).
    let l_mean = (coord_l.iter().map(|l| l.max(1e-300).ln()).sum::<f64>()
        / coord_l.len() as f64)
        .exp();
    let mut t_scaled = gdsec::run(
        &prob,
        &GdSecConfig {
            alpha,
            beta: 0.01,
            xi: Xi::scaled_by_lipschitz(xi * l_mean, &coord_l),
            eval_every: 5,
            fstar: Some(fstar),
            ..Default::default()
        },
        iters,
    );
    t_scaled.algo = "GD-SEC(ξ_i=ξ/L^i)".into();

    let traces = [&t_uniform, &t_scaled];
    let mut table = Table::new(&["variant", "final err", "entries sent", "bits"]);
    for t in &traces {
        let last = t.rows.last().unwrap();
        table.row(vec![
            t.algo.clone(),
            sci(t.final_error()),
            last.entries.to_string(),
            last.bits.to_string(),
        ]);
    }
    let e_uniform = t_uniform.rows.last().unwrap().entries;
    let e_scaled = t_scaled.rows.last().unwrap().entries;
    let csv_files = write_traces(ctx, "fig7", &traces)?;
    Ok(FigReport {
        fig: "fig7".into(),
        title: format!("logreg / rcv1-like (n={n}, d={d}, M={m})"),
        rendered: table.render(),
        csv_files,
        headline: vec![
            ("entries_scaled_over_uniform".into(), e_scaled as f64 / e_uniform.max(1) as f64),
            ("uniform_final_err".into(), t_uniform.final_error()),
            ("scaled_final_err".into(), t_scaled.final_error()),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_scaled_sends_fewer_entries_at_similar_error() {
        let dir = std::env::temp_dir().join(format!("gdsec_fig7_{}", std::process::id()));
        let ctx = ExpContext::quick(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = run(&ctx).unwrap();
        let ratio =
            r.headline.iter().find(|(k, _)| k == "entries_scaled_over_uniform").unwrap().1;
        let e_u = r.headline.iter().find(|(k, _)| k == "uniform_final_err").unwrap().1;
        let e_s = r.headline.iter().find(|(k, _)| k == "scaled_final_err").unwrap().1;
        // Pareto criterion (paper Fig 7): scaled must be at least as good
        // on one axis without losing on the other.
        assert!(
            (ratio <= 1.05 && e_s <= e_u * 1.05) || (ratio < 0.9) || (e_s < e_u * 0.9),
            "scaled not Pareto-comparable: entries ratio {ratio}, err {e_s} vs {e_u}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
