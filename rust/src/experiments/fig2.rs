//! Fig 2 — regularized logistic regression on the paper's own synthetic
//! recipe (M = 5, 50 samples/worker, d = 300, block-structured U(a,b)
//! features): error vs iterations and vs bits. Paper headline: ≈91.22%
//! bit savings at objective error 1e-10 (linear convergence regime).
//!
//! Paper parameters: ξ/M = 80 (GD-SEC), ξ̃/M = 40 (CGD), top-10 with
//! γ₀ = 0.01, α tuned for GD and shared.

use super::{common_eps, compare_table, write_traces, ExpContext, FigReport};
use crate::algo::gdsec::{GdSecConfig, Xi};
use crate::algo::{cgd, gd, gdsec, iag, qgd, topj};
use crate::data::synthetic;
use crate::objectives::Problem;
use crate::util::error::Result;

pub fn run(ctx: &ExpContext) -> Result<FigReport> {
    let m = 5;
    let n_per = 50;
    let data = synthetic::paper_logreg(ctx.seed, m, n_per, 300);
    let n = data.n();
    let lambda = 1.0 / n as f64;
    let prob = Problem::logistic(data, m, lambda);
    let iters = ctx.iters(3000);
    let alpha = 1.0 / prob.lipschitz();
    let fstar = prob.estimate_fstar(gdsec::fstar_iters(iters));

    let t_gd = gd::run(&prob, &gd::GdConfig { alpha, eval_every: 1, fstar: Some(fstar) }, iters);
    let t_sec = gdsec::run(
        &prob,
        &GdSecConfig {
            alpha,
            beta: 0.01,
            xi: Xi::Uniform(80.0 * m as f64),
            fstar: Some(fstar),
            ..Default::default()
        },
        iters,
    );
    let t_topj = topj::run(
        &prob,
        &topj::TopJConfig { j: 10, gamma0: 0.01, lambda, eval_every: 1, fstar: Some(fstar) },
        iters,
    );
    let t_cgd = cgd::run(
        &prob,
        &cgd::CgdConfig { alpha, xi: 40.0 * m as f64, eval_every: 1, fstar: Some(fstar) },
        iters,
    );
    let t_qgd = qgd::run(
        &prob,
        &qgd::QgdConfig { alpha, s: 255, seed: ctx.seed, eval_every: 1, fstar: Some(fstar) },
        iters,
    );
    let t_iag = iag::run(
        &prob,
        &iag::IagConfig {
            alpha: alpha / m as f64,
            seed: ctx.seed,
            eval_every: 1,
            fstar: Some(fstar),
        },
        iters,
    );

    let traces = [&t_gd, &t_sec, &t_topj, &t_cgd, &t_qgd, &t_iag];
    let eps = if t_gd.iters_to_reach(1e-10).is_some() && t_sec.iters_to_reach(1e-10).is_some() {
        1e-10
    } else {
        common_eps(&[&t_gd, &t_sec], 2.0)
    };
    let (rendered, headline) = compare_table(&traces, eps);
    let csv_files = write_traces(ctx, "fig2", &traces)?;
    Ok(FigReport {
        fig: "fig2".into(),
        title: format!("logreg / paper synthetic (n={n}, d=300, M={m}), eps={eps:.2e}"),
        rendered,
        csv_files,
        headline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_savings() {
        let dir = std::env::temp_dir().join(format!("gdsec_fig2_{}", std::process::id()));
        let ctx = ExpContext::quick(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = run(&ctx).unwrap();
        let sec = r.headline.iter().find(|(k, _)| k.starts_with("GD-SEC"));
        if let Some((_, s)) = sec {
            assert!(*s > 0.3, "GD-SEC savings too small: {s}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
