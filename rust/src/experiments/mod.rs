//! Figure-regeneration harness: one runner per figure of the paper's
//! evaluation (§IV). Each runner builds the workload (synthetic substitute
//! per DESIGN.md §6), runs GD-SEC and the figure's baselines, writes the
//! plotted series to `results/figN_*.csv`, and prints a paper-style
//! summary table (who wins, by what factor).
//!
//! `quick` mode shrinks iteration counts ~10× so the whole suite runs in
//! CI / `cargo test`; the bench targets (`cargo bench`) run full size.

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;

use crate::algo::trace::Trace;
use crate::util::error::Result;
use crate::util::tablefmt::{bits, pct, sci, Table};
use std::path::{Path, PathBuf};

/// Shared experiment context.
#[derive(Debug, Clone)]
pub struct ExpContext {
    pub out_dir: PathBuf,
    pub quick: bool,
    pub seed: u64,
}

impl ExpContext {
    pub fn new<P: AsRef<Path>>(out_dir: P) -> ExpContext {
        ExpContext { out_dir: out_dir.as_ref().to_path_buf(), quick: false, seed: 42 }
    }

    pub fn quick<P: AsRef<Path>>(out_dir: P) -> ExpContext {
        ExpContext { quick: true, ..ExpContext::new(out_dir) }
    }

    /// Scale an iteration budget for quick mode.
    pub fn iters(&self, full: usize) -> usize {
        if self.quick {
            (full / 10).clamp(20, 200)
        } else {
            full
        }
    }

    /// Scale a sample count for quick mode.
    pub fn samples(&self, full: usize) -> usize {
        if self.quick {
            (full / 5).max(50)
        } else {
            full
        }
    }

    pub fn csv_path(&self, name: &str) -> PathBuf {
        self.out_dir.join(name)
    }
}

/// The output of one figure runner.
#[derive(Debug, Clone)]
pub struct FigReport {
    pub fig: String,
    pub title: String,
    /// Rendered summary table (printed by the CLI / benches).
    pub rendered: String,
    pub csv_files: Vec<String>,
    /// Headline numbers for EXPERIMENTS.md (name, value).
    pub headline: Vec<(String, f64)>,
}

impl FigReport {
    pub fn print(&self) {
        println!("== {}: {} ==", self.fig, self.title);
        println!("{}", self.rendered);
        for (k, v) in &self.headline {
            println!("  {k}: {v:.4}");
        }
        if !self.csv_files.is_empty() {
            println!("  csv: {}", self.csv_files.join(", "));
        }
    }
}

/// Run a figure by id ("fig1".."fig9" or "all").
pub fn run_figure(fig: &str, ctx: &ExpContext) -> Result<Vec<FigReport>> {
    std::fs::create_dir_all(&ctx.out_dir)?;
    let one = |r: FigReport| Ok(vec![r]);
    match fig {
        "fig1" | "1" => one(fig1::run(ctx)?),
        "fig2" | "2" => one(fig2::run(ctx)?),
        "fig3" | "3" => one(fig3::run(ctx)?),
        "fig4" | "4" => one(fig4::run(ctx)?),
        "fig5" | "5" => one(fig5::run(ctx)?),
        "fig6" | "6" => one(fig6::run(ctx)?),
        "fig7" | "7" => one(fig7::run(ctx)?),
        "fig8" | "8" => one(fig8::run(ctx)?),
        "fig9" | "9" => one(fig9::run(ctx)?),
        "all" => {
            let mut out = Vec::new();
            for f in ["fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9"] {
                out.extend(run_figure(f, ctx)?);
            }
            Ok(out)
        }
        other => crate::bail!("unknown figure '{other}' (fig1..fig9 or all)"),
    }
}

/// Standard comparison table: per algorithm, iterations and bits to reach
/// the target error, total bits, and savings vs the first (reference,
/// usually GD) trace.
pub fn compare_table(traces: &[&Trace], eps: f64) -> (String, Vec<(String, f64)>) {
    let mut table = Table::new(&[
        "algorithm",
        "final err",
        &format!("iters→{eps:.0e}"),
        &format!("bits→{eps:.0e}"),
        "total bits",
        "tx",
        "savings vs ref",
    ]);
    let reference = traces[0];
    let mut headline = Vec::new();
    for t in traces {
        let iters = t.iters_to_reach(eps).map(|v| v.to_string()).unwrap_or("-".into());
        let b = t.bits_to_reach(eps);
        let savings = t.savings_vs(reference, eps);
        table.row(vec![
            t.algo.clone(),
            sci(t.final_error()),
            iters,
            b.map(|v| bits(v as f64)).unwrap_or("-".into()),
            bits(t.total_bits() as f64),
            t.total_transmissions().to_string(),
            if savings.is_nan() { "-".into() } else { pct(savings) },
        ]);
        if !savings.is_nan() {
            headline.push((format!("{} savings@{eps:.0e}", t.algo), savings));
        }
    }
    (table.render(), headline)
}

/// Write every trace's CSV under the context dir with a figure prefix.
pub fn write_traces(ctx: &ExpContext, prefix: &str, traces: &[&Trace]) -> Result<Vec<String>> {
    let mut files = Vec::new();
    for t in traces {
        let slug: String = t
            .algo
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let name = format!("{prefix}_{slug}.csv");
        t.write_csv(ctx.csv_path(&name))?;
        files.push(name);
    }
    Ok(files)
}

/// Pick a target error that every converging trace reaches: a small
/// multiple of the worst final error among `traces` (robust to quick mode
/// where absolute targets like 1e-10 are unreachable).
pub fn common_eps(traces: &[&Trace], slack: f64) -> f64 {
    traces
        .iter()
        .map(|t| t.final_error())
        .filter(|e| e.is_finite() && *e > 0.0)
        .fold(0.0f64, f64::max)
        * slack
}
