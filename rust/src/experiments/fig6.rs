//! Fig 6 — per-(worker, coordinate) transmission heatmap on the
//! engineered coordinate-Lipschitz dataset (10 workers, d = 50,
//! L_m^i = m·1.1^i): workers/coordinates with smaller smoothness
//! constants transmit less often.

use super::{ExpContext, FigReport};
use crate::algo::gdsec::{transmission_heatmap, GdSecConfig, Xi};
use crate::data::synthetic;
use crate::objectives::Problem;
use crate::util::csv::CsvWriter;
use crate::util::error::Result;

pub fn run(ctx: &ExpContext) -> Result<FigReport> {
    let data = synthetic::coord_lipschitz(ctx.seed);
    let prob = Problem::linear(data, 10, 0.0);
    let iters = ctx.iters(1000);
    let alpha = 1.0 / prob.lipschitz();
    let cfg = GdSecConfig {
        alpha,
        beta: 0.01,
        xi: Xi::Uniform(50_000.0 * 10.0),
        ..Default::default()
    };
    let hm = transmission_heatmap(&prob, &cfg, iters);

    // CSV: one row per worker, one column per coordinate.
    let header: Vec<String> = (0..50).map(|i| format!("c{i}")).collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let path = ctx.csv_path("fig6_heatmap.csv");
    let mut w = CsvWriter::create(&path, &header_refs)?;
    for row in &hm {
        w.row_f64(&row.iter().map(|&c| c as f64).collect::<Vec<_>>())?;
    }
    w.flush()?;

    // Monotonicity diagnostics (the paper's qualitative claims):
    // 1) total transmissions per worker increase with worker index m,
    // 2) for a fixed worker, transmissions increase along coordinates.
    let per_worker: Vec<u64> = hm.iter().map(|r| r.iter().sum()).collect();
    let worker_rank_corr = spearman(&per_worker);
    let mid_worker = &hm[4];
    let coord_rank_corr = spearman(mid_worker);

    let mut rendered = String::from("worker totals (m=1..10): ");
    for t in &per_worker {
        rendered.push_str(&format!("{t} "));
    }
    rendered.push_str(&format!(
        "\nSpearman(worker idx, transmissions) = {worker_rank_corr:.3}\n\
         Spearman(coord idx, transmissions | worker 5) = {coord_rank_corr:.3}\n"
    ));
    Ok(FigReport {
        fig: "fig6".into(),
        title: format!("transmissions heatmap (M=10, d=50, {iters} iters)"),
        rendered,
        csv_files: vec!["fig6_heatmap.csv".into()],
        headline: vec![
            ("worker_rank_corr".into(), worker_rank_corr),
            ("coord_rank_corr".into(), coord_rank_corr),
        ],
    })
}

/// Spearman rank correlation of a series against its index order.
fn spearman(series: &[u64]) -> f64 {
    let n = series.len();
    if n < 2 {
        return 0.0;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| series[i]);
    let mut rank = vec![0.0f64; n];
    for (r, &i) in order.iter().enumerate() {
        rank[i] = r as f64;
    }
    let mean = (n as f64 - 1.0) / 2.0;
    let mut num = 0.0;
    let mut den_a = 0.0;
    let mut den_b = 0.0;
    for (i, &ri) in rank.iter().enumerate() {
        let a = i as f64 - mean;
        let b = ri - mean;
        num += a * b;
        den_a += a * a;
        den_b += b * b;
    }
    num / (den_a.sqrt() * den_b.sqrt()).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spearman_sanity() {
        assert!((spearman(&[1, 2, 3, 4]) - 1.0).abs() < 1e-12);
        assert!((spearman(&[4, 3, 2, 1]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn quick_monotone_structure() {
        let dir = std::env::temp_dir().join(format!("gdsec_fig6_{}", std::process::id()));
        let ctx = ExpContext::quick(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = run(&ctx).unwrap();
        let wc = r.headline.iter().find(|(k, _)| k == "worker_rank_corr").unwrap().1;
        assert!(wc > 0.5, "worker transmissions should increase with L_m: {wc}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
