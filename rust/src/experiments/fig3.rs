//! Fig 3 — impact of error correction: lasso regression on DNA-like data
//! (d = 180). GD vs GD-SEC vs GD-SOEC (sparsification but NO error
//! correction). Thresholds are re-tuned for the synthetic substitute
//! (paper: 2000 vs 250 on real DNA; here 500 vs 20): in both cases GD-SEC
//! tolerates a far larger threshold because error correction replays
//! suppressed mass later — the paper's qualitative claim.

use super::{common_eps, compare_table, write_traces, ExpContext, FigReport};
use crate::algo::gdsec::{GdSecConfig, Xi};
use crate::algo::{gd, gdsec};
use crate::data::synthetic;
use crate::objectives::Problem;
use crate::util::error::Result;

pub fn run(ctx: &ExpContext) -> Result<FigReport> {
    let n = ctx.samples(2000);
    let m = 5;
    let data = synthetic::dna_like(ctx.seed, n);
    let lambda = 1.0 / n as f64;
    let prob = Problem::lasso(data, m, lambda);
    let iters = ctx.iters(2000);
    // Paper tunes α = 0.001 for DNA; scale-free equivalent: 1/L of the
    // smooth part.
    let alpha = 1.0 / prob.lipschitz();
    let fstar = prob.estimate_fstar(gdsec::fstar_iters(iters));

    let t_gd = gd::run(&prob, &gd::GdConfig { alpha, eval_every: 1, fstar: Some(fstar) }, iters);
    let t_sec = gdsec::run(
        &prob,
        &GdSecConfig {
            alpha,
            beta: 0.01,
            xi: Xi::Uniform(500.0 * m as f64),
            fstar: Some(fstar),
            ..Default::default()
        },
        iters,
    );
    let mut soec_cfg = GdSecConfig {
        alpha,
        beta: 0.01,
        xi: Xi::Uniform(20.0 * m as f64),
        error_correction: false,
        fstar: Some(fstar),
        ..Default::default()
    };
    let t_soec = gdsec::run(&prob, &soec_cfg, iters);
    // Also show SOEC at GD-SEC's aggressive threshold: it stalls.
    soec_cfg.xi = Xi::Uniform(500.0 * m as f64);
    let mut t_soec_big = gdsec::run(&prob, &soec_cfg, iters);
    t_soec_big.algo = "GD-SOEC(ξ=SEC)".into();

    let mut t_soec_named = t_soec;
    t_soec_named.algo = "GD-SOEC".into();

    let traces = [&t_gd, &t_sec, &t_soec_named, &t_soec_big];
    let eps = common_eps(&[&t_gd, &t_sec, &t_soec_named], 2.0);
    let (rendered, mut headline) = compare_table(&traces, eps);
    // EC ablation headline: final error ratio SOEC(ξ=SEC)/SEC — error
    // correction is what makes the aggressive threshold usable.
    headline.push((
        "soec_at_sec_threshold_err_ratio".into(),
        t_soec_big.final_error().abs() / t_sec.final_error().abs().max(1e-12),
    ));
    let csv_files = write_traces(ctx, "fig3", &traces)?;
    Ok(FigReport {
        fig: "fig3".into(),
        title: format!("lasso / dna-like (n={n}, d=180, M={m}), eps={eps:.2e}"),
        rendered,
        csv_files,
        headline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_ec_beats_no_ec() {
        let dir = std::env::temp_dir().join(format!("gdsec_fig3_{}", std::process::id()));
        let ctx = ExpContext::quick(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = run(&ctx).unwrap();
        let ratio = r
            .headline
            .iter()
            .find(|(k, _)| k == "soec_at_sec_threshold_err_ratio")
            .map(|(_, v)| *v)
            .unwrap();
        assert!(ratio > 1.0, "EC should beat no-EC at the aggressive threshold: {ratio}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
