//! Fig 1 — regularized linear regression on MNIST (2000 samples, M = 5):
//! objective error vs iterations and vs transmitted bits for GD, GD-SEC,
//! top-j, CGD, QGD and NoUnif-IAG.
//!
//! Paper setup: λ = 1/N, α = 1/L tuned for GD and shared (except top-j's
//! decreasing schedule and IAG's α/(2ML)), ξ/M = 800 for GD-SEC, ξ̃/M = 1
//! for CGD, top-100 with γ₀ = 0.01. Headline: GD-SEC saves ≈99.34% of the
//! bits at objective error 5.4e-3.

use super::{common_eps, compare_table, write_traces, ExpContext, FigReport};
use crate::algo::gdsec::{GdSecConfig, Xi};
use crate::algo::{cgd, gd, gdsec, iag, qgd, topj};
use crate::data::synthetic;
use crate::objectives::Problem;
use crate::util::error::Result;

pub fn run(ctx: &ExpContext) -> Result<FigReport> {
    let n = ctx.samples(2000);
    let m = 5;
    let data = synthetic::mnist_like(ctx.seed, n);
    let lambda = 1.0 / n as f64;
    let prob = Problem::linear(data, m, lambda);
    let iters = ctx.iters(500);
    let l = prob.lipschitz();
    let alpha = 1.0 / l;
    let fstar = prob.estimate_fstar(gdsec::fstar_iters(iters));

    let t_gd = gd::run(&prob, &gd::GdConfig { alpha, eval_every: 1, fstar: Some(fstar) }, iters);
    let t_sec = gdsec::run(
        &prob,
        &GdSecConfig {
            alpha,
            beta: 0.01,
            // Paper uses ξ/M = 800 on real MNIST; the synthetic substitute
            // has hotter gradient coordinates, ξ/M = 200 is the largest
            // threshold that keeps GD-SEC on GD's convergence curve.
            xi: Xi::Uniform(200.0 * m as f64),
            fstar: Some(fstar),
            ..Default::default()
        },
        iters,
    );
    let t_topj = topj::run(
        &prob,
        &topj::TopJConfig {
            j: 100,
            gamma0: 0.01,
            lambda,
            eval_every: 1,
            fstar: Some(fstar),
        },
        iters,
    );
    let t_cgd = cgd::run(
        &prob,
        &cgd::CgdConfig { alpha, xi: m as f64, eval_every: 1, fstar: Some(fstar) },
        iters,
    );
    let t_qgd = qgd::run(
        &prob,
        &qgd::QgdConfig { alpha, s: 255, seed: ctx.seed, eval_every: 1, fstar: Some(fstar) },
        iters,
    );
    let t_iag = iag::run(
        &prob,
        &iag::IagConfig {
            alpha: alpha / (2.0 * m as f64),
            seed: ctx.seed,
            eval_every: 1,
            fstar: Some(fstar),
        },
        iters,
    );

    let traces = [&t_gd, &t_sec, &t_topj, &t_cgd, &t_qgd, &t_iag];
    // Paper target 5.4e-3 is specific to real MNIST scaling; use it when
    // reachable, else a common reachable target.
    let eps = if t_gd.iters_to_reach(5.4e-3).is_some() && t_sec.iters_to_reach(5.4e-3).is_some() {
        5.4e-3
    } else {
        common_eps(&[&t_gd, &t_sec], 2.0)
    };
    let (rendered, headline) = compare_table(&traces, eps);
    let csv_files = write_traces(ctx, "fig1", &traces)?;
    Ok(FigReport {
        fig: "fig1".into(),
        title: format!("linreg / mnist-like (n={n}, d=784, M={m}), eps={eps:.2e}"),
        rendered,
        csv_files,
        headline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_shape() {
        let dir = std::env::temp_dir().join(format!("gdsec_fig1_{}", std::process::id()));
        let ctx = ExpContext::quick(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = run(&ctx).unwrap();
        assert_eq!(r.csv_files.len(), 6);
        assert!(r.rendered.contains("GD-SEC"));
        // GD-SEC must save bits vs GD at the common target.
        let sec = r.headline.iter().find(|(k, _)| k.starts_with("GD-SEC"));
        if let Some((_, s)) = sec {
            assert!(*s > 0.5, "GD-SEC savings too small: {s}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
