//! The paper's four objective families (Eqs. 19–23) and the distributed
//! `Problem` abstraction: `f(θ) = Σ_m f_m(θ)` with worker-local shards.
//!
//! Scaling follows the paper exactly: data terms carry the *global* `1/N`,
//! regularizers carry `λ/(2M)` (or `λ/M` for lasso's ℓ1), so summing the M
//! local functions reproduces the centralized objective.

use crate::data::{Dataset, Shard};
use crate::linalg;
use std::sync::Arc;

/// Which loss (paper equation in parens).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObjectiveKind {
    /// Regularized linear regression (19): 1/(2N)·Σ(y−xᵀθ)² + λ/(2M)‖θ‖².
    LinReg,
    /// Regularized logistic regression (20): 1/N·Σ log(1+e^{−y xᵀθ}) + λ/(2M)‖θ‖².
    LogReg,
    /// Lasso (21): 1/(2N)·Σ(y−xᵀθ)² + λ/M·‖θ‖₁ (subgradient (22)).
    Lasso,
    /// Nonlinear least squares (23), nonconvex: 1/(2N)·Σ(y−σ(xᵀθ))² + λ/(2M)‖θ‖².
    Nlls,
}

impl ObjectiveKind {
    pub fn name(&self) -> &'static str {
        match self {
            ObjectiveKind::LinReg => "linreg",
            ObjectiveKind::LogReg => "logreg",
            ObjectiveKind::Lasso => "lasso",
            ObjectiveKind::Nlls => "nlls",
        }
    }

    pub fn parse(s: &str) -> Option<ObjectiveKind> {
        match s {
            "linreg" => Some(ObjectiveKind::LinReg),
            "logreg" => Some(ObjectiveKind::LogReg),
            "lasso" => Some(ObjectiveKind::Lasso),
            "nlls" => Some(ObjectiveKind::Nlls),
            _ => None,
        }
    }
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Numerically-stable log(1 + e^z).
#[inline]
fn log1pexp(z: f64) -> f64 {
    if z > 30.0 {
        z
    } else if z < -30.0 {
        z.exp()
    } else {
        z.exp().ln_1p()
    }
}

/// dℓ/dz of the scalar loss at linear predictor `z` with label `yi` —
/// the per-row weight of the fused gradient pass (shared by the full,
/// minibatch, and row-split gradient kernels so all three apply the SAME
/// floating-point operations per row).
#[inline]
fn residual_weight(kind: ObjectiveKind, yi: f64, z: f64) -> f64 {
    match kind {
        ObjectiveKind::LinReg | ObjectiveKind::Lasso => z - yi,
        ObjectiveKind::LogReg => -yi * sigmoid(-yi * z),
        ObjectiveKind::Nlls => {
            let p = sigmoid(z);
            -(yi - p) * p * (1.0 - p)
        }
    }
}

/// One worker's local objective `f_m`.
#[derive(Debug, Clone)]
pub struct LocalObjective {
    pub shard: Shard,
    pub kind: ObjectiveKind,
    /// Regularization weight λ (shared across workers).
    pub lambda: f64,
    /// Global sample count N (data terms are 1/N-scaled).
    pub n_total: usize,
    /// Worker count M (regularizer is 1/M-scaled).
    pub m_workers: usize,
}

impl LocalObjective {
    pub fn dim(&self) -> usize {
        self.shard.d()
    }

    /// f_m(θ).
    pub fn value(&self, theta: &[f64]) -> f64 {
        let nm = self.shard.n();
        let n = self.n_total as f64;
        let m = self.m_workers as f64;
        let mut z = vec![0.0; nm];
        self.shard.x.matvec(theta, &mut z);
        let data_term = match self.kind {
            ObjectiveKind::LinReg | ObjectiveKind::Lasso => {
                let mut s = 0.0;
                for i in 0..nm {
                    let r = self.shard.y[i] - z[i];
                    s += r * r;
                }
                s / (2.0 * n)
            }
            ObjectiveKind::LogReg => {
                let mut s = 0.0;
                for i in 0..nm {
                    s += log1pexp(-self.shard.y[i] * z[i]);
                }
                s / n
            }
            ObjectiveKind::Nlls => {
                let mut s = 0.0;
                for i in 0..nm {
                    let r = self.shard.y[i] - sigmoid(z[i]);
                    s += r * r;
                }
                s / (2.0 * n)
            }
        };
        let reg = match self.kind {
            ObjectiveKind::Lasso => self.lambda / m * linalg::nrm1(theta),
            _ => self.lambda / (2.0 * m) * linalg::nrm2_sq(theta),
        };
        data_term + reg
    }

    /// ∇f_m(θ) (subgradient for lasso), overwriting `out`.
    ///
    /// Full-batch fast path: one fused streaming pass over the shard
    /// (z = x·θ and the X^T accumulation in the same row visit) instead of
    /// the two-pass matvec/matvec^T of `grad_indices` — ~2× less memory
    /// traffic on the worker hot loop (EXPERIMENTS.md §Perf).
    pub fn grad(&self, theta: &[f64], out: &mut [f64]) {
        linalg::zero(out);
        self.grad_data_range(theta, 0, self.shard.n(), out);
        self.add_regularizer(theta, out);
    }

    /// Accumulate this worker's regularizer (sub)gradient — λ/M-scaled ℓ2
    /// or ℓ1 term — into `out`. Shared by every gradient kernel (full,
    /// minibatch, blocked) so the regularizer arithmetic is identical
    /// across all of them.
    pub fn add_regularizer(&self, theta: &[f64], out: &mut [f64]) {
        let lm = self.lambda / self.m_workers as f64;
        match self.kind {
            ObjectiveKind::Lasso => {
                for j in 0..theta.len() {
                    out[j] += lm * sign(theta[j]);
                }
            }
            _ => linalg::axpy(lm, theta, out),
        }
    }

    /// Fold pre-computed row-block partial gradients (ascending row
    /// order, each `zero + grad_data_range` over its block) plus the
    /// regularizer into `out` — THE reduction tree of the engine's
    /// nested (worker, row-block) lanes. `grad_blocked` executes the
    /// same tree serially, so the coordinator's native workers and the
    /// engine produce bitwise identical gradients for any thread count.
    /// With a single block this is `copy + regularizer`, bitwise equal
    /// to [`grad`](Self::grad).
    pub fn fold_block_grads<'b, I>(&self, theta: &[f64], mut bufs: I, out: &mut [f64])
    where
        I: Iterator<Item = &'b [f64]>,
    {
        match bufs.next() {
            None => linalg::zero(out),
            Some(first) => {
                out.copy_from_slice(first);
                for b in bufs {
                    linalg::axpy(1.0, b, out);
                }
            }
        }
        self.add_regularizer(theta, out);
    }

    /// Build the fixed row-block plan `grad_blocked` folds — the same
    /// nnz-budget cut the engine's nested lanes use for this shard.
    pub fn blocked_grad_plan(&self, nnz_budget: usize) -> BlockedGrad {
        let ranges = self.shard.x.split_rows_by_nnz(nnz_budget);
        let bufs = ranges.iter().map(|_| vec![0.0; self.dim()]).collect();
        BlockedGrad { ranges, bufs }
    }

    /// ∇f_m(θ) through the fixed block tree of `plan`, serially: each
    /// block accumulates into its private buffer, buffers fold in
    /// ascending row order ([`fold_block_grads`]), then the regularizer.
    /// Bitwise identical to the engine's nested lanes at any thread
    /// count, and to [`grad`](Self::grad) when the plan has ≤ 1 block.
    pub fn grad_blocked(&self, theta: &[f64], plan: &mut BlockedGrad, out: &mut [f64]) {
        for (&(start, end), buf) in plan.ranges.iter().zip(plan.bufs.iter_mut()) {
            linalg::zero(buf);
            self.grad_data_range(theta, start, end, buf);
        }
        self.fold_block_grads(theta, plan.bufs.iter().map(|b| b.as_slice()), out);
    }

    /// Data-term gradient contribution of local rows `[start, end)`
    /// accumulated into `out` (no zeroing, no regularizer):
    /// `out += Σ_{i ∈ range} ℓ'(z_i)/N · x_i`. This is the unit of the
    /// intra-worker row-split ([`GradSplit`]); `grad` is exactly
    /// "zero + full-range + regularizer", so the split kernels reuse the
    /// same per-row arithmetic.
    pub fn grad_data_range(&self, theta: &[f64], start: usize, end: usize, out: &mut [f64]) {
        let n = self.n_total as f64;
        let kind = self.kind;
        let y = &self.shard.y;
        self.shard.x.fused_grad_pass_range(theta, out, start, end, |i, z| {
            residual_weight(kind, y[i], z) / n
        });
    }

    /// Gradient over a subset of local samples, with the data term scaled
    /// by `scale` (for minibatch SGD the caller passes N_m/|B| so the
    /// estimate is unbiased for the full local data term). Regularizer is
    /// always exact. Overwrites `out`.
    pub fn grad_indices(&self, theta: &[f64], idx: &[usize], scale: f64, out: &mut [f64]) {
        let n = self.n_total as f64;
        linalg::zero(out);
        // Residual weights per selected sample, then one X^T pass.
        // For dense shards a row-gather keeps the pass cache-friendly;
        // CSR rows are gathered the same way.
        let mut z = vec![0.0; self.shard.n()];
        self.shard.x.matvec(theta, &mut z);
        let mut w = vec![0.0; self.shard.n()];
        for &i in idx {
            w[i] = residual_weight(self.kind, self.shard.y[i], z[i]) * scale / n;
        }
        self.shard.x.matvec_t_acc(1.0, &w, out);
        self.add_regularizer(theta, out);
    }

    /// Smoothness constant L_m of the *smooth part* of f_m (used for
    /// NoUnif-IAG sampling probabilities and step-size heuristics). The
    /// power iteration's transposed accumulation runs on the shared
    /// [`Pool::global`](crate::util::pool::Pool::global) (bitwise equal
    /// to the serial walk, so L_m never depends on the thread count);
    /// must not be called from inside a scatter job of that pool.
    pub fn lipschitz(&self) -> f64 {
        let n = self.n_total as f64;
        let m = self.m_workers as f64;
        let sigma_sq = self.shard.x.spectral_sq_pooled(60, crate::util::pool::Pool::global());
        let curv = loss_curvature_bound(self.kind);
        let reg = match self.kind {
            ObjectiveKind::Lasso => 0.0, // ℓ1 is not smooth; only data term
            _ => self.lambda / m,
        };
        curv * sigma_sq / n + reg
    }
}

#[inline]
fn sign(v: f64) -> f64 {
    if v > 0.0 {
        1.0
    } else if v < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Upper bound on the second derivative of the scalar loss wrt the linear
/// predictor z (the `c` in L ≤ c·σ²_max/N):
/// linreg/lasso: ℓ(z)=½(y−z)² → ℓ''=1. logreg: ℓ''=σ(1−σ) ≤ 1/4.
/// nlls: |d²/dz² ½(y−σ(z))²| ≤ 0.25 over y∈[−1,1] (loose but safe bound
/// covering the |σ'|²+|r·σ''| terms).
fn loss_curvature_bound(kind: ObjectiveKind) -> f64 {
    match kind {
        ObjectiveKind::LinReg | ObjectiveKind::Lasso => 1.0,
        ObjectiveKind::LogReg => 0.25,
        ObjectiveKind::Nlls => 0.25,
    }
}

/// A reusable per-worker row-block gradient plan + buffers: the engine's
/// nested lane tree for ONE shard, executed serially by
/// [`LocalObjective::grad_blocked`] (the coordinator's native workers use
/// it so the distributed trajectory stays bitwise equal to the engine's).
pub struct BlockedGrad {
    ranges: Vec<(usize, usize)>,
    bufs: Vec<Vec<f64>>,
}

impl BlockedGrad {
    pub fn blocks(&self) -> usize {
        self.ranges.len()
    }
}

/// Reusable scratch for [`Problem::grad_pooled`] and the engine's nested
/// fan-out: one lane per (worker, row-block) with a private d-length
/// accumulator.
///
/// The lane structure — which worker, which row range — is FIXED at
/// construction and independent of the pool's thread count, and the
/// caller folds lanes in (worker asc, block asc) order, so the reduced
/// gradient is bit-for-bit identical for any thread count (pinned by
/// `tests/prop_parallel_parity.rs`). Splitting *within* a shard is what
/// keeps all cores busy when M < cores or shards are imbalanced — the
/// regime of `estimate_fstar`, whose problem-wide gradient was previously
/// a serial loop over workers.
pub struct GradSplit {
    d: usize,
    pub(crate) lanes: Vec<GradSplitLane>,
}

pub(crate) struct GradSplitLane {
    pub(crate) worker: usize,
    pub(crate) start: usize,
    pub(crate) end: usize,
    pub(crate) buf: Vec<f64>,
}

impl GradSplit {
    /// Default rows per lane: small enough that even one RCV1-sized
    /// shard splits across every core, large enough that a lane amortizes
    /// its d-length reduce.
    pub const DEFAULT_ROW_BLOCK: usize = 512;

    /// Fallback nnz budget per lane for [`new_by_nnz`](Self::new_by_nnz)
    /// — what [`crate::util::cache::auto_nnz_budget`] derives on the
    /// 1 MiB-L2 reference machine (the engine defaults now come from
    /// the probed cache model, not this constant): comparable work to
    /// [`DEFAULT_ROW_BLOCK`](Self::DEFAULT_ROW_BLOCK) rows of a dense
    /// ~128-wide shard, small enough that one RCV1-scale shard still
    /// splits across every core. Deliberately large relative to the
    /// test-suite problems so tiny shards stay single-lane (a one-block
    /// fold is bitwise equal to the serial fused pass).
    pub const DEFAULT_NNZ_BUDGET: usize = 65_536;

    /// Split every worker's shard into `row_block`-row lanes (the last
    /// lane of a shard may be short; empty shards contribute none).
    pub fn new(prob: &Problem, row_block: usize) -> GradSplit {
        let rb = row_block.max(1);
        let mut lanes = Vec::new();
        for (w, l) in prob.locals.iter().enumerate() {
            let nm = l.shard.n();
            let mut s = 0;
            while s < nm {
                let e = (s + rb).min(nm);
                lanes.push(GradSplitLane { worker: w, start: s, end: e, buf: vec![0.0; prob.d] });
                s = e;
            }
        }
        GradSplit { d: prob.d, lanes }
    }

    /// Split every worker's shard into lanes greedily filled to an `nnz`
    /// budget ([`Features::split_rows_by_nnz`]) instead of equal row
    /// counts — sparse shards pack wildly unequal nnz into equal row
    /// blocks, so budget-cut lanes balance *work* across the pool.
    pub fn new_by_nnz(prob: &Problem, nnz_budget: usize) -> GradSplit {
        let mut lanes = Vec::new();
        for (w, l) in prob.locals.iter().enumerate() {
            for (start, end) in l.shard.x.split_rows_by_nnz(nnz_budget) {
                lanes.push(GradSplitLane { worker: w, start, end, buf: vec![0.0; prob.d] });
            }
        }
        GradSplit { d: prob.d, lanes }
    }

    /// [`new_by_nnz`](Self::new_by_nnz) with the cache model's
    /// L2-resident budget ([`crate::util::cache::auto_nnz_budget`]) —
    /// the same tree the engine builds with default
    /// [`EngineOpts`](crate::algo::engine::EngineOpts).
    pub fn for_problem(prob: &Problem) -> GradSplit {
        GradSplit::new_by_nnz(prob, crate::util::cache::auto_nnz_budget())
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Per-worker spans into the (worker asc, block asc)-ordered lane
    /// list: lane indices `[spans[w].0, spans[w].1)` belong to worker `w`.
    pub(crate) fn worker_spans(&self, m: usize) -> Vec<(usize, usize)> {
        let mut spans = vec![(0usize, 0usize); m];
        let mut i = 0;
        for w in 0..m {
            let b0 = i;
            while i < self.lanes.len() && self.lanes[i].worker == w {
                i += 1;
            }
            spans[w] = (b0, i);
        }
        debug_assert_eq!(i, self.lanes.len());
        spans
    }
}

/// A distributed optimization problem: M workers, each holding `f_m`.
#[derive(Clone)]
pub struct Problem {
    pub name: String,
    pub kind: ObjectiveKind,
    pub locals: Arc<Vec<LocalObjective>>,
    pub lambda: f64,
    pub d: usize,
    pub n_total: usize,
}

impl Problem {
    /// Build from a dataset sharded over `m` workers.
    pub fn new(kind: ObjectiveKind, data: Dataset, m: usize, lambda: f64) -> Problem {
        let n_total = data.n();
        let d = data.d();
        let name = format!("{}/{}", kind.name(), data.name);
        let locals: Vec<LocalObjective> = data
            .shard(m)
            .into_iter()
            .map(|shard| LocalObjective { shard, kind, lambda, n_total, m_workers: m })
            .collect();
        Problem { name, kind, locals: Arc::new(locals), lambda, d, n_total }
    }

    pub fn linear(data: Dataset, m: usize, lambda: f64) -> Problem {
        Problem::new(ObjectiveKind::LinReg, data, m, lambda)
    }

    pub fn logistic(data: Dataset, m: usize, lambda: f64) -> Problem {
        Problem::new(ObjectiveKind::LogReg, data, m, lambda)
    }

    pub fn lasso(data: Dataset, m: usize, lambda: f64) -> Problem {
        Problem::new(ObjectiveKind::Lasso, data, m, lambda)
    }

    pub fn nlls(data: Dataset, m: usize, lambda: f64) -> Problem {
        Problem::new(ObjectiveKind::Nlls, data, m, lambda)
    }

    pub fn m(&self) -> usize {
        self.locals.len()
    }

    /// Global objective f(θ) = Σ_m f_m(θ).
    pub fn value(&self, theta: &[f64]) -> f64 {
        self.locals.iter().map(|l| l.value(theta)).sum()
    }

    /// [`value`](Self::value) with the per-worker local evaluations fanned
    /// out over `pool`. The partial values land in per-worker slots and
    /// are summed in worker order, so the result is bitwise equal to the
    /// serial evaluation for any thread count.
    pub fn value_pooled(&self, theta: &[f64], pool: &crate::util::pool::Pool) -> f64 {
        if pool.threads() == 1 || self.m() <= 1 {
            return self.value(theta);
        }
        let mut vals = vec![0.0f64; self.m()];
        pool.scatter(&mut vals, |w, v| *v = self.locals[w].value(theta));
        vals.iter().sum()
    }

    /// Global gradient into `out`.
    pub fn grad(&self, theta: &[f64], out: &mut [f64]) {
        linalg::zero(out);
        let mut g = vec![0.0; self.d];
        for l in self.locals.iter() {
            l.grad(theta, &mut g);
            linalg::axpy(1.0, &g, out);
        }
    }

    /// Global gradient with the (worker, row-block) lanes of `split`
    /// fanned out over `pool` and reduced in lane order on the calling
    /// thread, plus ONE closed-form regularizer term (λ instead of M
    /// copies of λ/M). Deterministic for any thread count — the summation
    /// tree is fixed by `split`, never by scheduling. Not bitwise equal
    /// to [`grad`] (different reduction tree), which is why callers pick
    /// one kernel and use it for every thread count.
    pub fn grad_pooled(
        &self,
        theta: &[f64],
        out: &mut [f64],
        split: &mut GradSplit,
        pool: &crate::util::pool::Pool,
    ) {
        assert_eq!(split.d, self.d, "GradSplit built for a different problem");
        assert_eq!(theta.len(), self.d);
        assert_eq!(out.len(), self.d);
        pool.scatter(&mut split.lanes, |_, lane| {
            linalg::zero(&mut lane.buf);
            self.locals[lane.worker].grad_data_range(theta, lane.start, lane.end, &mut lane.buf);
        });
        linalg::zero(out);
        for lane in &split.lanes {
            linalg::axpy(1.0, &lane.buf, out);
        }
        match self.kind {
            ObjectiveKind::Lasso => {
                for j in 0..self.d {
                    out[j] += self.lambda * sign(theta[j]);
                }
            }
            _ => linalg::axpy(self.lambda, theta, out),
        }
    }

    /// Global smoothness constant L of f (smooth part).
    /// Computed from the *pooled* data matrix spectral norm: since all data
    /// terms share the 1/N scale, L = c·σ_max(X)²/N + λ. We bound
    /// σ_max(X)² ≤ Σ_m σ_max(X_m)², and tighten with a short power
    /// iteration over the stacked operator implemented shard-wise.
    pub fn lipschitz(&self) -> f64 {
        let n = self.n_total as f64;
        let curv = loss_curvature_bound(self.kind);
        let reg = match self.kind {
            ObjectiveKind::Lasso => 0.0,
            _ => self.lambda,
        };
        curv * self.pooled_spectral_sq(80) / n + reg
    }

    /// Power iteration for σ_max(X)² where X is the row-stacked shard
    /// data. The transposed accumulation — the expensive half at RCV1
    /// scale — runs the column-blocked pooled kernel on the shared pool
    /// (bitwise identical to the serial walk, so L never depends on the
    /// thread count). Called from setup paths only, never from inside a
    /// scatter job.
    fn pooled_spectral_sq(&self, iters: usize) -> f64 {
        let d = self.d;
        let pool = crate::util::pool::Pool::global();
        let mut v = vec![1.0 / (d as f64).sqrt(); d];
        let mut atav = vec![0.0; d];
        let mut lambda = 0.0;
        for _ in 0..iters {
            linalg::zero(&mut atav);
            for l in self.locals.iter() {
                let nm = l.shard.n();
                if nm == 0 {
                    continue;
                }
                let mut av = vec![0.0; nm];
                l.shard.x.matvec(&v, &mut av);
                l.shard.x.matvec_t_acc_pooled(1.0, &av, &mut atav, pool);
            }
            lambda = linalg::nrm2(&atav);
            if lambda <= 1e-300 {
                return 0.0;
            }
            for i in 0..d {
                v[i] = atav[i] / lambda;
            }
        }
        lambda
    }

    /// Coordinate-wise smoothness constants L^i of the global smooth part:
    /// L^i = c·(Σ_n x_{n,i}²)/N + λ (exact for quadratic, bound for
    /// logistic/nlls). Used for the Fig 7 scaling ξ_i = ξ/L^i.
    pub fn coord_lipschitz(&self) -> Vec<f64> {
        let n = self.n_total as f64;
        let curv = loss_curvature_bound(self.kind);
        let reg = match self.kind {
            ObjectiveKind::Lasso => 0.0,
            _ => self.lambda,
        };
        let mut acc = vec![0.0; self.d];
        for l in self.locals.iter() {
            let cs = l.shard.x.col_sq_sums();
            for j in 0..self.d {
                acc[j] += cs[j];
            }
        }
        acc.iter().map(|&s| curv * s / n + reg).collect()
    }

    /// Per-worker smoothness constants (NoUnif-IAG sampling weights).
    pub fn worker_lipschitz(&self) -> Vec<f64> {
        self.locals.iter().map(|l| l.lipschitz()).collect()
    }

    /// Strong-convexity modulus μ when known (≥ λ for ℓ2-regularized
    /// convex losses; 0 otherwise).
    pub fn strong_convexity(&self) -> f64 {
        match self.kind {
            ObjectiveKind::LinReg | ObjectiveKind::LogReg => self.lambda,
            _ => 0.0,
        }
    }

    /// Estimate f* := min f(θ) by running (sub)gradient descent far past
    /// the horizon the experiments use, on the process-wide
    /// [`Pool::global`](crate::util::pool::Pool::global) — see
    /// [`estimate_fstar_pooled`](Self::estimate_fstar_pooled).
    pub fn estimate_fstar(&self, iters: usize) -> f64 {
        self.estimate_fstar_pooled(iters, crate::util::pool::Pool::global())
    }

    /// The f* estimator's GD loop with every gradient fanned out over
    /// `pool` via [`grad_pooled`](Self::grad_pooled) (row-split lanes, so
    /// it scales even when M < cores) and every objective evaluation via
    /// [`value_pooled`](Self::value_pooled). For smooth objectives uses
    /// α=1/L fixed; for lasso a decreasing step with best-value tracking.
    /// The estimate is bit-for-bit identical for any thread count
    /// (pinned by `tests/prop_parallel_parity.rs`).
    pub fn estimate_fstar_pooled(&self, iters: usize, pool: &crate::util::pool::Pool) -> f64 {
        let d = self.d;
        let l = self.lipschitz().max(1e-12);
        let mut split = GradSplit::for_problem(self);
        let mut theta = vec![0.0; d];
        let mut g = vec![0.0; d];
        let mut best = self.value_pooled(&theta, pool);
        match self.kind {
            ObjectiveKind::Lasso => {
                let gamma0 = 1.0 / l;
                for k in 0..iters {
                    self.grad_pooled(&theta, &mut g, &mut split, pool);
                    let alpha = gamma0 / (1.0 + 0.05 * k as f64).sqrt();
                    linalg::axpy(-alpha, &g, &mut theta);
                    let v = self.value_pooled(&theta, pool);
                    if v < best {
                        best = v;
                    }
                }
            }
            _ => {
                let alpha = 1.0 / l;
                for _ in 0..iters {
                    self.grad_pooled(&theta, &mut g, &mut split, pool);
                    linalg::axpy(-alpha, &g, &mut theta);
                }
                best = best.min(self.value_pooled(&theta, pool));
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Pcg64;

    fn fd_grad(l: &LocalObjective, theta: &[f64]) -> Vec<f64> {
        let eps = 1e-6;
        let mut out = vec![0.0; theta.len()];
        let mut tp = theta.to_vec();
        for j in 0..theta.len() {
            let orig = tp[j];
            tp[j] = orig + eps;
            let fp = l.value(&tp);
            tp[j] = orig - eps;
            let fm = l.value(&tp);
            tp[j] = orig;
            out[j] = (fp - fm) / (2.0 * eps);
        }
        out
    }

    fn check_grad(kind: ObjectiveKind) {
        let data = synthetic::paper_logreg(11, 2, 10, 300);
        let prob = Problem::new(kind, data, 2, 0.05);
        let mut rng = Pcg64::seeded(3);
        // Keep theta away from lasso's kink at 0.
        let theta: Vec<f64> =
            (0..prob.d).map(|_| rng.normal() * 0.05 + 0.2 * rng.sign()).collect();
        for l in prob.locals.iter() {
            let mut g = vec![0.0; prob.d];
            l.grad(&theta, &mut g);
            let fd = fd_grad(l, &theta);
            for j in (0..prob.d).step_by(37) {
                let denom = fd[j].abs().max(1e-6);
                assert!(
                    (g[j] - fd[j]).abs() / denom < 1e-3,
                    "{:?} coord {j}: analytic {} vs fd {}",
                    kind,
                    g[j],
                    fd[j]
                );
            }
        }
    }

    #[test]
    fn grad_matches_fd_linreg() {
        check_grad(ObjectiveKind::LinReg);
    }

    #[test]
    fn grad_matches_fd_logreg() {
        check_grad(ObjectiveKind::LogReg);
    }

    #[test]
    fn grad_matches_fd_lasso() {
        check_grad(ObjectiveKind::Lasso);
    }

    #[test]
    fn grad_matches_fd_nlls() {
        check_grad(ObjectiveKind::Nlls);
    }

    #[test]
    fn locals_sum_to_global() {
        let data = synthetic::dna_like(5, 60);
        let prob = Problem::linear(data, 4, 0.1);
        let mut rng = Pcg64::seeded(7);
        let theta: Vec<f64> = (0..prob.d).map(|_| rng.normal()).collect();
        let total: f64 = prob.locals.iter().map(|l| l.value(&theta)).sum();
        assert!((total - prob.value(&theta)).abs() < 1e-10);
        // Centralized objective computed directly:
        let one = Problem::linear(synthetic::dna_like(5, 60), 1, 0.1);
        assert!((one.value(&theta) - prob.value(&theta)).abs() < 1e-8);
    }

    #[test]
    fn descent_reduces_value() {
        for kind in [
            ObjectiveKind::LinReg,
            ObjectiveKind::LogReg,
            ObjectiveKind::Lasso,
            ObjectiveKind::Nlls,
        ] {
            let data = synthetic::dna_like(9, 100);
            let prob = Problem::new(kind, data, 3, 0.01);
            let alpha = 1.0 / prob.lipschitz().max(1e-9);
            let mut theta = vec![0.0; prob.d];
            let mut g = vec![0.0; prob.d];
            let f0 = prob.value(&theta);
            for _ in 0..20 {
                prob.grad(&theta, &mut g);
                linalg::axpy(-alpha, &g, &mut theta);
            }
            let f1 = prob.value(&theta);
            assert!(f1 < f0, "{kind:?}: {f1} !< {f0}");
        }
    }

    #[test]
    fn lipschitz_bounds_hessian_action() {
        // For linreg, ‖∇f(a)−∇f(b)‖ ≤ L‖a−b‖ exactly testable.
        let data = synthetic::dna_like(13, 80);
        let prob = Problem::linear(data, 2, 0.05);
        let l = prob.lipschitz();
        let mut rng = Pcg64::seeded(5);
        for _ in 0..10 {
            let a: Vec<f64> = (0..prob.d).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..prob.d).map(|_| rng.normal()).collect();
            let mut ga = vec![0.0; prob.d];
            let mut gb = vec![0.0; prob.d];
            prob.grad(&a, &mut ga);
            prob.grad(&b, &mut gb);
            let mut diff_g = vec![0.0; prob.d];
            linalg::sub(&ga, &gb, &mut diff_g);
            let mut diff_x = vec![0.0; prob.d];
            linalg::sub(&a, &b, &mut diff_x);
            assert!(
                linalg::nrm2(&diff_g) <= l * linalg::nrm2(&diff_x) * (1.0 + 1e-6),
                "L violated"
            );
        }
    }

    #[test]
    fn coord_lipschitz_exact_for_linreg() {
        let data = synthetic::coord_lipschitz(3);
        let prob = Problem::linear(data, 10, 0.0);
        let li = prob.coord_lipschitz();
        // Monotone increasing per construction.
        assert!(li[49] > li[25] && li[25] > li[0]);
        // For linreg with λ=0: L^i = (Σ x_i²)/N exactly.
        let data2 = synthetic::coord_lipschitz(3);
        let cs = data2.x.col_sq_sums();
        for j in (0..50).step_by(9) {
            let expect = cs[j] / 500.0;
            assert!((li[j] - expect).abs() < 1e-9 * expect.max(1.0));
        }
    }

    #[test]
    fn minibatch_unbiased_full_batch_identity() {
        // grad_indices over ALL indices with scale 1 == grad.
        let data = synthetic::dna_like(21, 40);
        let prob = Problem::logistic(data, 2, 0.02);
        let mut rng = Pcg64::seeded(9);
        let theta: Vec<f64> = (0..prob.d).map(|_| rng.normal() * 0.1).collect();
        let l = &prob.locals[0];
        let idx: Vec<usize> = (0..l.shard.n()).collect();
        let mut g1 = vec![0.0; prob.d];
        let mut g2 = vec![0.0; prob.d];
        l.grad(&theta, &mut g1);
        l.grad_indices(&theta, &idx, 1.0, &mut g2);
        for j in 0..prob.d {
            assert!((g1[j] - g2[j]).abs() < 1e-14);
        }
    }

    #[test]
    fn fstar_below_trajectory() {
        let data = synthetic::dna_like(31, 100);
        let prob = Problem::linear(data, 2, 0.1);
        let fstar = prob.estimate_fstar(2000);
        assert!(fstar <= prob.value(&vec![0.0; prob.d]));
        assert!(fstar.is_finite());
    }

    #[test]
    fn grad_pooled_matches_grad_numerically() {
        use crate::util::pool::Pool;
        for kind in [
            ObjectiveKind::LinReg,
            ObjectiveKind::LogReg,
            ObjectiveKind::Lasso,
            ObjectiveKind::Nlls,
        ] {
            let prob = Problem::new(kind, synthetic::dna_like(17, 90), 3, 0.05);
            let mut rng = Pcg64::seeded(11);
            // Away from lasso's kink so sign(θ_j) is stable under ±ε.
            let theta: Vec<f64> =
                (0..prob.d).map(|_| rng.normal() * 0.05 + 0.2 * rng.sign()).collect();
            let mut serial = vec![0.0; prob.d];
            prob.grad(&theta, &mut serial);
            // Awkward row block (7) so shards split unevenly.
            let mut split = GradSplit::new(&prob, 7);
            assert!(split.lanes() > prob.m(), "row-split produced no extra lanes");
            let mut pooled = vec![0.0; prob.d];
            prob.grad_pooled(&theta, &mut pooled, &mut split, &Pool::new(3));
            for j in 0..prob.d {
                let denom = serial[j].abs().max(1e-9);
                assert!(
                    (pooled[j] - serial[j]).abs() / denom < 1e-9,
                    "{kind:?} j={j}: {} vs {}",
                    pooled[j],
                    serial[j]
                );
            }
        }
    }

    #[test]
    fn grad_blocked_single_block_is_bitwise_grad() {
        // A plan whose budget swallows the whole shard degenerates to
        // copy + regularizer == the serial fused pass, bit for bit.
        for kind in [ObjectiveKind::LinReg, ObjectiveKind::Lasso] {
            let prob = Problem::new(kind, synthetic::dna_like(29, 50), 2, 0.05);
            let l = &prob.locals[0];
            let mut rng = Pcg64::seeded(17);
            let theta: Vec<f64> = (0..prob.d).map(|_| rng.normal() * 0.1).collect();
            let mut plan = l.blocked_grad_plan(usize::MAX);
            assert_eq!(plan.blocks(), 1);
            let mut serial = vec![0.0; prob.d];
            let mut blocked = vec![0.0; prob.d];
            l.grad(&theta, &mut serial);
            l.grad_blocked(&theta, &mut plan, &mut blocked);
            for j in 0..prob.d {
                assert_eq!(serial[j].to_bits(), blocked[j].to_bits(), "{kind:?} j={j}");
            }
        }
    }

    #[test]
    fn grad_blocked_multi_block_matches_grad_numerically() {
        let prob = Problem::logistic(synthetic::dna_like(31, 64), 2, 0.02);
        let l = &prob.locals[0];
        let mut rng = Pcg64::seeded(19);
        let theta: Vec<f64> = (0..prob.d).map(|_| rng.normal() * 0.1).collect();
        // Tiny budget forces several blocks even on this tiny shard.
        let mut plan = l.blocked_grad_plan(64);
        assert!(plan.blocks() > 1, "budget did not split the shard");
        let mut serial = vec![0.0; prob.d];
        let mut blocked = vec![0.0; prob.d];
        l.grad(&theta, &mut serial);
        l.grad_blocked(&theta, &mut plan, &mut blocked);
        for j in 0..prob.d {
            let denom = serial[j].abs().max(1e-9);
            assert!(
                (blocked[j] - serial[j]).abs() / denom < 1e-12,
                "j={j}: {} vs {}",
                blocked[j],
                serial[j]
            );
        }
        // The fold tree is fixed: re-running the plan reproduces the
        // exact same bits.
        let mut again = vec![0.0; prob.d];
        l.grad_blocked(&theta, &mut plan, &mut again);
        for j in 0..prob.d {
            assert_eq!(blocked[j].to_bits(), again[j].to_bits());
        }
    }

    #[test]
    fn grad_data_range_splits_sum_to_full() {
        // Fixed-structure split: concatenating range contributions in
        // ascending order must reproduce the full-range pass bitwise
        // (same per-row ops, same out-accumulation order).
        let prob = Problem::logistic(synthetic::dna_like(23, 64), 1, 0.02);
        let l = &prob.locals[0];
        let mut rng = Pcg64::seeded(13);
        let theta: Vec<f64> = (0..prob.d).map(|_| rng.normal() * 0.1).collect();
        let mut full = vec![0.0; prob.d];
        l.grad_data_range(&theta, 0, l.shard.n(), &mut full);
        let mut parts = vec![0.0; prob.d];
        let nm = l.shard.n();
        let mid = nm / 3;
        l.grad_data_range(&theta, 0, mid, &mut parts);
        l.grad_data_range(&theta, mid, nm, &mut parts);
        for j in 0..prob.d {
            assert_eq!(full[j].to_bits(), parts[j].to_bits(), "j={j}");
        }
    }

    #[test]
    fn sigmoid_stability() {
        assert!(sigmoid(1000.0) <= 1.0 && sigmoid(1000.0) > 0.999);
        assert!(sigmoid(-1000.0) >= 0.0 && sigmoid(-1000.0) < 1e-10);
        assert!((log1pexp(-1000.0)).abs() < 1e-10);
        assert!((log1pexp(1000.0) - 1000.0).abs() < 1e-10);
    }
}
