//! `gdsec` — launcher for the GD-SEC distributed learning framework.
//!
//! Subcommands:
//!   train       run one algorithm on one workload (native engine)
//!   experiment  regenerate one or all of the paper's figures
//!   coordinate  run the threaded coordinator (GD-SEC protocol) end to end
//!   info        show platform / artifact inventory
//!
//! Examples:
//!   gdsec train --algo gdsec --objective logreg --dataset paper-logreg \
//!       --xi 400 --beta 0.01 --iters 500 --out results/run.csv
//!   gdsec experiment --fig all --out results
//!   gdsec coordinate --workers 5 --iters 200 --scheduler rr --participation 0.5
//!   gdsec info

use gdsec::algo::gdsec::GdSecConfig;
use gdsec::algo::{cgd, gd, gdsec as gdsec_algo, iag, qgd, sgdsec, topj};
use gdsec::config::RunConfig;
use gdsec::coordinator::scheduler::Scheduler;
use gdsec::data::{libsvm, synthetic, Dataset};
use gdsec::experiments::{run_figure, ExpContext};
use gdsec::objectives::Problem;
use gdsec::runtime::Manifest;
use gdsec::util::cli::{opt, usage, Args};
use gdsec::util::error::Result;
use gdsec::{bail, err};

fn main() {
    let args = match Args::from_env(true) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Some(v) = args.get("verbosity") {
        gdsec::util::set_verbosity(v.parse().unwrap_or(2));
    }
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("coordinate") => cmd_coordinate(&args),
        Some("info") => cmd_info(),
        _ => {
            print!("{}", help());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn help() -> String {
    usage(
        "gdsec",
        "GD-SEC: distributed learning with sparsified gradient differences",
        &[
            ("train", "run one algorithm on one workload"),
            ("experiment", "regenerate paper figures (--fig fig1..fig9|all)"),
            ("coordinate", "run the threaded GD-SEC coordinator"),
            ("info", "platform and artifact inventory"),
        ],
        &[
            opt("algo", "gd|gdsec|gdsoec|cgd|topj|qgd|iag|sgd|sgdsec|qsgdsec", Some("gdsec")),
            opt("objective", "linreg|logreg|lasso|nlls", Some("logreg")),
            opt(
                "dataset",
                "mnist|paper-logreg|dna|colon|w2a|rcv1|cifar|coord-lipschitz",
                Some("paper-logreg"),
            ),
            opt("data", "path to a LIBSVM file (overrides --dataset)", None),
            opt("workers", "number of workers M", Some("5")),
            opt("iters", "iterations", Some("500")),
            opt("alpha", "step size (default 1/L)", None),
            opt("beta", "state-variable smoothing", Some("0.01")),
            opt("xi", "censoring threshold ξ (condition uses ξ/M)", Some("400")),
            opt("xi-per-coord", "scale ξ_i = ξ/L^i (flag)", None),
            opt("lambda", "regularization (default 1/N)", None),
            opt("seed", "rng seed", Some("42")),
            opt("out", "CSV output path / results dir", None),
            opt("fig", "experiment figure id", Some("all")),
            opt("quick", "reduced-size experiment run (flag)", None),
            opt("scheduler", "all|rr|random", Some("all")),
            opt("participation", "fraction of workers per round", Some("1.0")),
        ],
    )
}

fn build_dataset(cfg: &RunConfig) -> Result<Dataset> {
    if let Some(path) = &cfg.data_path {
        return Ok(libsvm::load(path, 0)?);
    }
    Ok(match cfg.dataset.as_str() {
        "mnist" | "mnist-like" => synthetic::mnist_like(cfg.seed, 2000),
        "paper-logreg" => synthetic::paper_logreg(cfg.seed, cfg.workers, 50, 300),
        "dna" | "dna-like" => synthetic::dna_like(cfg.seed, 2000),
        "colon" | "colon-like" => synthetic::colon_like(cfg.seed),
        "w2a" | "w2a-like" => synthetic::w2a_like(cfg.seed, 3470),
        "rcv1" | "rcv1-like" => synthetic::rcv1_like(cfg.seed, 6000, 47236, 50),
        "cifar" | "cifar-like" => synthetic::cifar_like(cfg.seed, 2000),
        "coord-lipschitz" => synthetic::coord_lipschitz(cfg.seed),
        other => bail!("unknown dataset '{other}'"),
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_args(args).map_err(|e| err!("{e}"))?;
    let data = build_dataset(&cfg)?;
    let lambda = cfg.lambda.unwrap_or(1.0 / data.n() as f64);
    let prob = Problem::new(cfg.objective, data, cfg.workers, lambda);
    let alpha = cfg.alpha.unwrap_or_else(|| 1.0 / prob.lipschitz());
    let iters = cfg.iters;
    let xi = cfg.resolve_xi(&prob);
    println!(
        "problem {} | n={} d={} M={} | alpha={alpha:.6} lambda={lambda:.6}",
        prob.name,
        prob.n_total,
        prob.d,
        prob.m()
    );
    let trace = match cfg.algo.as_str() {
        "gd" => gd::run(
            &prob,
            &gd::GdConfig { alpha, eval_every: cfg.eval_every, fstar: None },
            iters,
        ),
        "gdsec" | "gdsoec" => gdsec_algo::run(
            &prob,
            &GdSecConfig {
                alpha,
                beta: cfg.beta,
                xi,
                error_correction: cfg.algo == "gdsec",
                eval_every: cfg.eval_every,
                ..Default::default()
            },
            iters,
        ),
        "cgd" => cgd::run(
            &prob,
            &cgd::CgdConfig { alpha, xi: cfg.xi, eval_every: cfg.eval_every, fstar: None },
            iters,
        ),
        "topj" => topj::run(
            &prob,
            &topj::TopJConfig {
                j: 100.min(prob.d),
                gamma0: alpha,
                lambda,
                eval_every: cfg.eval_every,
                fstar: None,
            },
            iters,
        ),
        "qgd" => qgd::run(
            &prob,
            &qgd::QgdConfig {
                alpha,
                s: 255,
                seed: cfg.seed,
                eval_every: cfg.eval_every,
                fstar: None,
            },
            iters,
        ),
        "iag" => iag::run(
            &prob,
            &iag::IagConfig {
                alpha: alpha / (2.0 * prob.m() as f64),
                seed: cfg.seed,
                eval_every: cfg.eval_every,
                fstar: None,
            },
            iters,
        ),
        "sgd" | "sgdsec" | "qsgdsec" => {
            let scfg = sgdsec::SgdSecConfig {
                gamma0: alpha,
                lambda,
                beta: cfg.beta,
                xi,
                batch: cfg.batch.max(1),
                seed: cfg.seed,
                quantize_s: (cfg.algo == "qsgdsec").then_some(255),
                eval_every: cfg.eval_every,
                fstar: None,
            };
            if cfg.algo == "sgd" {
                sgdsec::run_sgd(&prob, &scfg, iters)
            } else {
                sgdsec::run_sgdsec(&prob, &scfg, iters)
            }
        }
        other => bail!("unknown algorithm '{other}'"),
    };
    let last = trace.rows.last().unwrap();
    println!(
        "{}: f-f* = {:.4e} | bits = {} | transmissions = {}",
        trace.algo,
        trace.final_error(),
        last.bits,
        last.transmissions
    );
    if let Some(out) = &cfg.out_csv {
        trace.write_csv(out)?;
        println!("trace -> {out}");
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let fig = args.get_or("fig", "all");
    let out = args.get_or("out", "results");
    let mut ctx = ExpContext::new(out);
    ctx.quick = args.flag("quick");
    ctx.seed = args.get_u64("seed", 42).map_err(|e| err!("{e}"))?;
    let reports = run_figure(fig, &ctx)?;
    for r in &reports {
        r.print();
        println!();
    }
    Ok(())
}

fn cmd_coordinate(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_args(args).map_err(|e| err!("{e}"))?;
    let data = build_dataset(&cfg)?;
    let lambda = cfg.lambda.unwrap_or(1.0 / data.n() as f64);
    let prob = Problem::new(cfg.objective, data, cfg.workers, lambda);
    let alpha = cfg.alpha.unwrap_or_else(|| 1.0 / prob.lipschitz());
    let xi = cfg.resolve_xi(&prob);
    let sched = Scheduler::parse(&cfg.scheduler, cfg.participation, cfg.seed)
        .ok_or_else(|| err!("unknown scheduler '{}'", cfg.scheduler))?;
    let gcfg = GdSecConfig { alpha, beta: cfg.beta, xi, ..Default::default() };
    println!(
        "coordinator: {} workers, {} rounds, scheduler {}",
        prob.m(),
        cfg.iters,
        cfg.scheduler
    );
    let out = gdsec::coordinator::run_native(&prob, gcfg, cfg.iters, sched);
    let payload: u64 = out.rounds.iter().map(|r| r.payload_bits).sum();
    let overhead: u64 = out.rounds.iter().map(|r| r.overhead_bits).sum();
    let down: u64 = out.rounds.iter().map(|r| r.downlink_bits).sum();
    println!(
        "final f-f* = {:.4e}\nuplink payload {payload} bits | protocol overhead {overhead} bits | downlink {down} bits",
        out.trace.final_error(),
    );
    println!(
        "mean round time {:.1} µs | dead workers: {:?}",
        out.rounds.iter().map(|r| r.wall_us as f64).sum::<f64>() / out.rounds.len() as f64,
        out.dead_workers
    );
    let dropped: u64 = out.rounds.iter().map(|r| r.dropped_frames).sum();
    let corrupt: u64 = out.rounds.iter().map(|r| r.corrupt_frames).sum();
    let rejoined: u64 = out.rounds.iter().map(|r| r.rejoined).sum();
    if dropped + corrupt + rejoined > 0 {
        println!("faults: {dropped} frames dropped, {corrupt} corrupted, {rejoined} rejoins");
    }
    if let Some(path) = &cfg.out_csv {
        out.trace.write_csv(path)?;
        println!("trace -> {path}");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("gdsec {} — three-layer GD-SEC stack", env!("CARGO_PKG_VERSION"));
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => {
            println!("artifacts ({}):", m.dir.display());
            let mut names: Vec<_> = m.artifacts.keys().collect();
            names.sort();
            for n in names {
                let a = &m.artifacts[n];
                println!("  {n}: {} inputs, {} outputs", a.inputs.len(), a.outputs.len());
            }
            #[cfg(feature = "pjrt")]
            match gdsec::runtime::Runtime::new(m) {
                Ok(rt) => println!("PJRT platform: {}", rt.platform()),
                Err(e) => println!("PJRT unavailable: {e:#}"),
            }
            #[cfg(not(feature = "pjrt"))]
            println!("PJRT runtime disabled (rebuild with --features pjrt)");
        }
        Err(e) => println!("no artifacts: {e:#}"),
    }
    println!("objectives: linreg logreg lasso nlls");
    println!("algorithms: {}", gdsec::algo::ALGORITHMS.join(" "));
    Ok(())
}
