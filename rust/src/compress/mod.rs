//! Wire codecs and bit accounting.
//!
//! Everything a worker puts on the uplink goes through this module, so
//! "total transmitted bits" — the x-axis of every figure in the paper — is
//! measured from *actually encoded* buffers, not estimated.
//!
//! Conventions (matching §IV of the paper):
//! * values are 32-bit floats,
//! * non-zero locations are RLE gap-coded ([`rle`]),
//! * QGD/QSGD payloads use 8-bit magnitude + 1 sign bit per component plus
//!   one 32-bit norm ([`quantize`]).

pub mod quantize;
pub mod rle;
pub mod topj;

/// A sparse f32-valued update vector (the `Δ̂` of the paper).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseUpdate {
    pub dim: u32,
    /// Strictly increasing component indices.
    pub idx: Vec<u32>,
    /// Component values, f32 precision (wire format).
    pub val: Vec<f32>,
}

impl SparseUpdate {
    pub fn empty(dim: usize) -> SparseUpdate {
        SparseUpdate { dim: dim as u32, idx: Vec::new(), val: Vec::new() }
    }

    /// Gather the non-zeros of a dense vector.
    pub fn from_dense(v: &[f64]) -> SparseUpdate {
        let mut up = SparseUpdate::empty(v.len());
        up.gather_from(v);
        up
    }

    /// Reset to an empty update of dimension `dim`, KEEPING the index and
    /// value allocations — the arena-style reuse that makes the trainers'
    /// steady-state round allocation-free.
    pub fn reset(&mut self, dim: usize) {
        self.dim = dim as u32;
        self.idx.clear();
        self.val.clear();
    }

    /// [`from_dense`](Self::from_dense) into this (reused) buffer.
    pub fn gather_from(&mut self, v: &[f64]) {
        self.reset(v.len());
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                self.idx.push(i as u32);
                self.val.push(x as f32);
            }
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Accumulate into a dense f64 buffer: out[idx] += val.
    pub fn add_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim as usize);
        for k in 0..self.idx.len() {
            out[self.idx[k] as usize] += self.val[k] as f64;
        }
    }

    /// Accumulate the entries whose index falls in
    /// `[j0, j0 + block.len())` into the column block `block`
    /// (`block[i − j0] += val`) — the unit of the coordinator's
    /// column-parallel server aggregation. Indices are strictly
    /// increasing, so the in-range entries are one contiguous subrange
    /// (binary search + early break) and are visited in the same
    /// ascending order as [`add_into`](Self::add_into): per element the
    /// two produce bitwise-identical sums.
    pub fn add_range_into(&self, j0: usize, block: &mut [f64]) {
        let j1 = j0 + block.len();
        let lo = self.idx.partition_point(|&i| (i as usize) < j0);
        for k in lo..self.idx.len() {
            let i = self.idx[k] as usize;
            if i >= j1 {
                break;
            }
            block[i - j0] += self.val[k] as f64;
        }
    }

    /// Cut this update into per-shard `[lo, hi)` entry subranges for
    /// `shards` contiguous coordinate ranges of `width` (last shard
    /// short), appending `shards + 1` offsets to `out`: shard `s` owns
    /// entries `out[base + s]..out[base + s + 1]`. One pass of
    /// `partition_point`s over the strictly increasing indices, each
    /// search restarting from the previous cut — the admission-time
    /// replacement for the per-block binary search
    /// [`add_range_into`](Self::add_range_into) pays on every fold.
    /// Iterating shard `s`'s subrange visits exactly the entries
    /// `add_range_into(s·width, …)` would, in the same ascending order.
    pub fn cut_shards(&self, width: usize, shards: usize, out: &mut Vec<u32>) {
        debug_assert!(width >= 1 && shards >= 1);
        let base = out.len();
        out.resize(base + shards + 1, 0);
        cut_entries(&self.idx, self.dim as usize, width, shards, &mut out[base..]);
    }

    /// Densify.
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.dim as usize];
        self.add_into(&mut out);
        out
    }
}

/// The slice-writing core of [`SparseUpdate::cut_shards`]: cut one
/// strictly increasing index list over `dim` coordinates into `shards`
/// contiguous ranges of `width` (last shard short), writing exactly
/// `shards + 1` offsets into `out` (shard `s` owns entries
/// `out[s]..out[s + 1]`). Split out as a free function so the server's
/// admission cut can fan per-update rows of one flat table across the
/// pool ([`crate::util::shard::ShardPlan::fold`]) — each row is written
/// independently, so the cut parallelizes without changing a single
/// byte of the table.
pub fn cut_entries(idx: &[u32], dim: usize, width: usize, shards: usize, out: &mut [u32]) {
    debug_assert!(width >= 1 && shards >= 1);
    debug_assert_eq!(out.len(), shards + 1);
    out[0] = 0;
    let mut lo = 0usize;
    for s in 1..shards {
        let bound = (s * width).min(dim) as u32;
        lo += idx[lo..].partition_point(|&i| i < bound);
        out[s] = lo as u32;
    }
    out[shards] = idx.len() as u32;
}

/// Uplink payload encoding for sparse worker updates — shared by the
/// threaded coordinator (which encodes real frames) and the
/// single-process trainers (which account bits without materializing
/// bytes, via [`wire_bits`]). The default is [`WireFormat::Adaptive`]:
/// dense first rounds (weak censoring) cost `8 + 32·d` bits instead of
/// the more expensive RLE stream, and well-censored rounds pay only the
/// 1-byte tag over the paper's format. [`WireFormat::Sparse`] reproduces
/// the paper's accounting exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireFormat {
    /// The paper's format: RLE gap-coded indices + f32 values.
    Sparse,
    /// [`encode_adaptive`]: 1 tag byte + the cheaper of sparse and dense.
    /// The tag byte is real payload and is accounted in the reported bit
    /// counts.
    #[default]
    Adaptive,
}

impl WireFormat {
    /// Default with the `GDSEC_WIRE` env override (`sparse` | `adaptive`).
    pub fn from_env() -> WireFormat {
        match std::env::var("GDSEC_WIRE").ok().as_deref() {
            Some("sparse") => WireFormat::Sparse,
            Some("adaptive") => WireFormat::Adaptive,
            _ => WireFormat::default(),
        }
    }
}

/// Message type tags on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum PayloadKind {
    Sparse = 1,
    Dense = 2,
    Quantized = 3,
    /// Deliberate non-transmission (censored round) — costs zero payload
    /// bits; the server infers it from absence.
    Silence = 4,
}

/// Append `vals` as little-endian f32 bytes in ONE bulk copy. On
/// little-endian hosts (every target we run on) the in-memory `[f32]`
/// plane IS the wire image, so this is a single `memcpy` instead of the
/// per-value 4-byte pushes that dominated `encode_sparse` at high nnz;
/// big-endian hosts take a per-value byte-swap fallback with identical
/// wire bytes.
fn put_f32_plane(vals: &[f32], out: &mut Vec<u8>) {
    let old = out.len();
    out.resize(old + 4 * vals.len(), 0);
    let dst = &mut out[old..];
    if cfg!(target_endian = "little") {
        // SAFETY: `[f32; n]` and `[u8; 4n]` have identical size/layout;
        // dst was just sized to exactly 4·n bytes.
        unsafe {
            std::ptr::copy_nonoverlapping(vals.as_ptr().cast::<u8>(), dst.as_mut_ptr(), dst.len());
        }
    } else {
        for (chunk, &v) in dst.chunks_exact_mut(4).zip(vals) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
    }
}

/// Read `n` little-endian f32 values from the front of `src` in one bulk
/// copy (the decode mirror of [`put_f32_plane`]). `src` must hold at
/// least 4·n bytes — callers length-check first.
fn get_f32_plane(src: &[u8], n: usize) -> Vec<f32> {
    assert!(src.len() >= 4 * n);
    let mut vals: Vec<f32> = vec![0.0; n];
    if cfg!(target_endian = "little") {
        // SAFETY: `vals` owns exactly 4·n initialized bytes; on LE hosts
        // the raw copy IS the from_le_bytes conversion.
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), vals.as_mut_ptr().cast::<u8>(), 4 * n);
        }
    } else {
        for (dst, chunk) in vals.iter_mut().zip(src[..4 * n].chunks_exact(4)) {
            *dst = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
    }
    vals
}

/// Encode a sparse update: [nnz varint][gap stream][f32 values LE].
pub fn encode_sparse(u: &SparseUpdate, out: &mut Vec<u8>) {
    rle::put_varint(out, u.idx.len() as u32);
    rle::encode_gaps(&u.idx, out);
    put_f32_plane(&u.val, out);
}

/// Decode a sparse update given the (known) dimension. Rejects truncated
/// buffers, indices ≥ `dim`, and gap streams whose cumulative index
/// overflows u32 (which would alias smaller indices and break the
/// strictly-increasing invariant downstream).
pub fn decode_sparse(buf: &[u8], dim: u32) -> Option<(SparseUpdate, usize)> {
    let (nnz, mut pos) = rle::get_varint(buf)?;
    let mut idx = Vec::new();
    pos += rle::decode_gaps(&buf[pos..], nnz as usize, &mut idx)?;
    if idx.last().is_some_and(|&l| l >= dim) {
        return None;
    }
    let need = nnz as usize * 4;
    if buf.len() < pos + need {
        return None;
    }
    let val = get_f32_plane(&buf[pos..], nnz as usize);
    Some((SparseUpdate { dim, idx, val }, pos + need))
}

/// Encode a dense f32 vector (classical GD / CGD transmissions): raw
/// 32·d bits, as the paper counts them. The f64→f32 narrowing keeps this
/// a per-value loop, but writing through a pre-sized buffer instead of
/// per-value pushes lets it autovectorize.
pub fn encode_dense(v: &[f64], out: &mut Vec<u8>) {
    let old = out.len();
    out.resize(old + 4 * v.len(), 0);
    for (chunk, &x) in out[old..].chunks_exact_mut(4).zip(v) {
        chunk.copy_from_slice(&(x as f32).to_le_bytes());
    }
}

/// Decode `d` dense f32 values.
pub fn decode_dense(buf: &[u8], d: usize) -> Option<(Vec<f64>, usize)> {
    if buf.len() < 4 * d {
        return None;
    }
    let mut out = Vec::with_capacity(d);
    for chunk in buf[..4 * d].chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]) as f64);
    }
    Some((out, 4 * d))
}

/// Exact payload bit cost of a sparse update without materializing bytes —
/// used by the single-threaded trainers; must agree with `encode_sparse`
/// (pinned by tests).
pub fn sparse_bits(u: &SparseUpdate) -> usize {
    8 * rle::varint_len(u.idx.len() as u32) + rle::gap_bits(&u.idx) + 32 * u.val.len()
}

/// Dense payload bit cost (32 bits per entry).
pub fn dense_bits(d: usize) -> usize {
    32 * d
}

/// Adaptive wire format: 1 tag byte + the cheaper of sparse-RLE and dense
/// encodings. When censoring is weak (e.g. the first GD-SEC rounds, where
/// θ^1 = θ^0 makes every threshold zero), the RLE stream costs *more* than
/// 32·d bits; the tag lets the encoder fall back to dense and caps the
/// worst case at `8 + 32·d` bits. An extension beyond the paper (which
/// always pays the sparse format); ablated in the e2e example.
pub fn encode_adaptive(u: &SparseUpdate, out: &mut Vec<u8>) {
    if sparse_bits(u) <= dense_bits(u.dim as usize) {
        out.push(PayloadKind::Sparse as u8);
        encode_sparse(u, out);
    } else {
        out.push(PayloadKind::Dense as u8);
        encode_dense(&u.to_dense(), out);
    }
}

/// Decode an adaptive payload.
pub fn decode_adaptive(buf: &[u8], dim: u32) -> Option<(SparseUpdate, usize)> {
    let (&tag, rest) = buf.split_first()?;
    if tag == PayloadKind::Sparse as u8 {
        let (u, used) = decode_sparse(rest, dim)?;
        Some((u, used + 1))
    } else if tag == PayloadKind::Dense as u8 {
        let (v, used) = decode_dense(rest, dim as usize)?;
        Some((SparseUpdate::from_dense(&v), used + 1))
    } else {
        None
    }
}

/// Exact bit cost of the adaptive encoding.
pub fn adaptive_bits(u: &SparseUpdate) -> usize {
    8 + sparse_bits(u).min(dense_bits(u.dim as usize))
}

/// Exact payload bit cost of a sparse update under `wire` — what the
/// engine rules charge per transmission. Agrees byte-for-byte with the
/// coordinator's encoded frames for either format
/// ([`encode_sparse`] / [`encode_adaptive`]).
pub fn wire_bits(u: &SparseUpdate, wire: WireFormat) -> usize {
    match wire {
        WireFormat::Sparse => sparse_bits(u),
        WireFormat::Adaptive => adaptive_bits(u),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn sparse_roundtrip() {
        let mut v = vec![0.0f64; 100];
        v[3] = 1.5;
        v[4] = -2.25;
        v[99] = 0.125;
        let u = SparseUpdate::from_dense(&v);
        assert_eq!(u.nnz(), 3);
        let mut buf = Vec::new();
        encode_sparse(&u, &mut buf);
        assert_eq!(buf.len() * 8, sparse_bits(&u));
        let (back, used) = decode_sparse(&buf, 100).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(back, u);
        assert_eq!(back.to_dense(), v);
    }

    #[test]
    fn empty_sparse_costs_one_byte() {
        let u = SparseUpdate::empty(1000);
        let mut buf = Vec::new();
        encode_sparse(&u, &mut buf);
        assert_eq!(buf.len(), 1);
        let (back, _) = decode_sparse(&buf, 1000).unwrap();
        assert_eq!(back.nnz(), 0);
    }

    #[test]
    fn dense_roundtrip_and_bits() {
        let v = vec![1.0, -0.5, 3.25, 0.0];
        let mut buf = Vec::new();
        encode_dense(&v, &mut buf);
        assert_eq!(buf.len() * 8, dense_bits(4));
        let (back, used) = decode_dense(&buf, 4).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(back, v);
    }

    #[test]
    fn decode_rejects_out_of_range_index() {
        let mut v = vec![0.0f64; 10];
        v[9] = 1.0;
        let u = SparseUpdate::from_dense(&v);
        let mut buf = Vec::new();
        encode_sparse(&u, &mut buf);
        assert!(decode_sparse(&buf, 9).is_none());
        assert!(decode_sparse(&buf, 10).is_some());
    }

    #[test]
    fn decode_rejects_truncation() {
        let mut v = vec![0.0f64; 10];
        v[2] = 1.0;
        v[7] = 2.0;
        let u = SparseUpdate::from_dense(&v);
        let mut buf = Vec::new();
        encode_sparse(&u, &mut buf);
        for cut in 0..buf.len() {
            assert!(decode_sparse(&buf[..cut], 10).is_none(), "cut={cut}");
        }
    }

    #[test]
    fn bits_match_encoded_len_random() {
        let mut rng = Pcg64::seeded(123);
        for _ in 0..100 {
            let d = 1 + rng.index(2000);
            let v: Vec<f64> =
                (0..d).map(|_| if rng.bernoulli(0.8) { 0.0 } else { rng.normal() }).collect();
            let u = SparseUpdate::from_dense(&v);
            let mut buf = Vec::new();
            encode_sparse(&u, &mut buf);
            assert_eq!(buf.len() * 8, sparse_bits(&u));
        }
    }

    #[test]
    fn sparse_beats_naive_when_sparse() {
        // vs naive (32-bit index + 32-bit value) per entry
        let mut v = vec![0.0f64; 10_000];
        for i in (0..10_000).step_by(100) {
            v[i] = 1.0;
        }
        let u = SparseUpdate::from_dense(&v);
        let naive = 64 * u.nnz();
        assert!(sparse_bits(&u) < naive);
    }

    #[test]
    fn adaptive_picks_cheaper_and_roundtrips() {
        let mut rng = Pcg64::seeded(321);
        for p_zero in [0.0, 0.2, 0.9, 1.0] {
            let d = 500;
            let v: Vec<f64> = (0..d)
                .map(|_| if rng.bernoulli(p_zero) { 0.0 } else { rng.normal() })
                .collect();
            let u = SparseUpdate::from_dense(&v);
            let mut buf = Vec::new();
            encode_adaptive(&u, &mut buf);
            assert_eq!(buf.len() * 8, adaptive_bits(&u));
            assert!(adaptive_bits(&u) <= 8 + dense_bits(d), "worst case exceeded");
            assert!(adaptive_bits(&u) <= 8 + sparse_bits(&u));
            let (back, used) = decode_adaptive(&buf, d as u32).unwrap();
            assert_eq!(used, buf.len());
            // Dense fallback reconstructs the same non-zeros (values f32
            // both ways).
            assert_eq!(back.to_dense(), u.to_dense());
        }
    }

    #[test]
    fn adaptive_rejects_bad_tag() {
        assert!(decode_adaptive(&[99, 0, 0], 4).is_none());
        assert!(decode_adaptive(&[], 4).is_none());
    }

    #[test]
    fn add_range_into_matches_add_into_bitwise() {
        let mut rng = Pcg64::seeded(555);
        for _ in 0..50 {
            let d = 1 + rng.index(400);
            let v: Vec<f64> =
                (0..d).map(|_| if rng.bernoulli(0.6) { 0.0 } else { rng.normal() }).collect();
            let u = SparseUpdate::from_dense(&v);
            let mut whole: Vec<f64> = (0..d).map(|j| (j as f64) * 0.1).collect();
            let mut blocked = whole.clone();
            u.add_into(&mut whole);
            let chunk = 1 + rng.index(d);
            let mut j0 = 0;
            while j0 < d {
                let j1 = (j0 + chunk).min(d);
                u.add_range_into(j0, &mut blocked[j0..j1]);
                j0 = j1;
            }
            for j in 0..d {
                assert_eq!(whole[j].to_bits(), blocked[j].to_bits(), "d={d} j={j}");
            }
        }
    }

    #[test]
    fn cut_shards_matches_add_range_into() {
        let mut rng = Pcg64::seeded(777);
        for _ in 0..50 {
            let d = 1 + rng.index(400);
            let v: Vec<f64> =
                (0..d).map(|_| if rng.bernoulli(0.6) { 0.0 } else { rng.normal() }).collect();
            let u = SparseUpdate::from_dense(&v);
            let shards = 1 + rng.index(9);
            let width = d.div_ceil(shards).max(1);
            let nshards = d.div_ceil(width);
            let mut cuts = Vec::new();
            u.cut_shards(width, nshards, &mut cuts);
            assert_eq!(cuts.len(), nshards + 1);
            assert_eq!(cuts[0], 0);
            assert_eq!(*cuts.last().unwrap() as usize, u.nnz());
            assert!(cuts.windows(2).all(|w| w[0] <= w[1]));
            for s in 0..nshards {
                let j0 = s * width;
                let j1 = (j0 + width).min(d);
                let mut by_range = vec![0.0f64; j1 - j0];
                u.add_range_into(j0, &mut by_range);
                let mut by_cut = vec![0.0f64; j1 - j0];
                for t in cuts[s] as usize..cuts[s + 1] as usize {
                    by_cut[u.idx[t] as usize - j0] += u.val[t] as f64;
                }
                for (a, b) in by_range.iter().zip(&by_cut) {
                    assert_eq!(a.to_bits(), b.to_bits(), "d={d} shards={nshards} s={s}");
                }
            }
        }
    }

    #[test]
    fn from_dense_skips_zeros_keeps_order() {
        let v = vec![0.0, 1.0, 0.0, -1.0];
        let u = SparseUpdate::from_dense(&v);
        assert_eq!(u.idx, vec![1, 3]);
        assert_eq!(u.val, vec![1.0f32, -1.0f32]);
    }
}
