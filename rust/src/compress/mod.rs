//! Wire codecs and bit accounting.
//!
//! Everything a worker puts on the uplink goes through this module, so
//! "total transmitted bits" — the x-axis of every figure in the paper — is
//! measured from *actually encoded* buffers, not estimated.
//!
//! Conventions (matching §IV of the paper):
//! * values are 32-bit floats,
//! * non-zero locations are RLE gap-coded ([`rle`]),
//! * QGD/QSGD payloads use 8-bit magnitude + 1 sign bit per component plus
//!   one 32-bit norm ([`quantize`]).

pub mod quantize;
pub mod rle;
pub mod topj;

/// A sparse f32-valued update vector (the `Δ̂` of the paper).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseUpdate {
    pub dim: u32,
    /// Strictly increasing component indices.
    pub idx: Vec<u32>,
    /// Component values, f32 precision (wire format).
    pub val: Vec<f32>,
}

impl SparseUpdate {
    pub fn empty(dim: usize) -> SparseUpdate {
        SparseUpdate { dim: dim as u32, idx: Vec::new(), val: Vec::new() }
    }

    /// Gather the non-zeros of a dense vector.
    pub fn from_dense(v: &[f64]) -> SparseUpdate {
        let mut up = SparseUpdate::empty(v.len());
        up.gather_from(v);
        up
    }

    /// Reset to an empty update of dimension `dim`, KEEPING the index and
    /// value allocations — the arena-style reuse that makes the trainers'
    /// steady-state round allocation-free.
    pub fn reset(&mut self, dim: usize) {
        self.dim = dim as u32;
        self.idx.clear();
        self.val.clear();
    }

    /// [`from_dense`](Self::from_dense) into this (reused) buffer.
    pub fn gather_from(&mut self, v: &[f64]) {
        self.reset(v.len());
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                self.idx.push(i as u32);
                self.val.push(x as f32);
            }
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Accumulate into a dense f64 buffer: out[idx] += val.
    pub fn add_into(&self, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim as usize);
        for k in 0..self.idx.len() {
            out[self.idx[k] as usize] += self.val[k] as f64;
        }
    }

    /// Densify.
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.dim as usize];
        self.add_into(&mut out);
        out
    }
}

/// Message type tags on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum PayloadKind {
    Sparse = 1,
    Dense = 2,
    Quantized = 3,
    /// Deliberate non-transmission (censored round) — costs zero payload
    /// bits; the server infers it from absence.
    Silence = 4,
}

/// Encode a sparse update: [nnz varint][gap stream][f32 values LE].
pub fn encode_sparse(u: &SparseUpdate, out: &mut Vec<u8>) {
    rle::put_varint(out, u.idx.len() as u32);
    rle::encode_gaps(&u.idx, out);
    for &v in &u.val {
        out.extend_from_slice(&v.to_le_bits_bytes());
    }
}

/// Decode a sparse update given the (known) dimension.
pub fn decode_sparse(buf: &[u8], dim: u32) -> Option<(SparseUpdate, usize)> {
    let (nnz, mut pos) = rle::get_varint(buf)?;
    let mut idx = Vec::new();
    pos += rle::decode_gaps(&buf[pos..], nnz as usize, &mut idx)?;
    if idx.last().is_some_and(|&l| l >= dim) {
        return None;
    }
    let need = nnz as usize * 4;
    if buf.len() < pos + need {
        return None;
    }
    let mut val = Vec::with_capacity(nnz as usize);
    for k in 0..nnz as usize {
        let b = &buf[pos + 4 * k..pos + 4 * k + 4];
        val.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
    }
    Some((SparseUpdate { dim, idx, val }, pos + need))
}

/// Encode a dense f32 vector (classical GD / CGD transmissions): raw
/// 32·d bits, as the paper counts them.
pub fn encode_dense(v: &[f64], out: &mut Vec<u8>) {
    for &x in v {
        out.extend_from_slice(&(x as f32).to_le_bytes());
    }
}

/// Decode `d` dense f32 values.
pub fn decode_dense(buf: &[u8], d: usize) -> Option<(Vec<f64>, usize)> {
    if buf.len() < 4 * d {
        return None;
    }
    let mut out = Vec::with_capacity(d);
    for k in 0..d {
        let b = &buf[4 * k..4 * k + 4];
        out.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]) as f64);
    }
    Some((out, 4 * d))
}

/// Exact payload bit cost of a sparse update without materializing bytes —
/// used by the single-threaded trainers; must agree with `encode_sparse`
/// (pinned by tests).
pub fn sparse_bits(u: &SparseUpdate) -> usize {
    8 * rle::varint_len(u.idx.len() as u32) + rle::gap_bits(&u.idx) + 32 * u.val.len()
}

/// Dense payload bit cost (32 bits per entry).
pub fn dense_bits(d: usize) -> usize {
    32 * d
}

/// Adaptive wire format: 1 tag byte + the cheaper of sparse-RLE and dense
/// encodings. When censoring is weak (e.g. the first GD-SEC rounds, where
/// θ^1 = θ^0 makes every threshold zero), the RLE stream costs *more* than
/// 32·d bits; the tag lets the encoder fall back to dense and caps the
/// worst case at `8 + 32·d` bits. An extension beyond the paper (which
/// always pays the sparse format); ablated in the e2e example.
pub fn encode_adaptive(u: &SparseUpdate, out: &mut Vec<u8>) {
    if sparse_bits(u) <= dense_bits(u.dim as usize) {
        out.push(PayloadKind::Sparse as u8);
        encode_sparse(u, out);
    } else {
        out.push(PayloadKind::Dense as u8);
        encode_dense(&u.to_dense(), out);
    }
}

/// Decode an adaptive payload.
pub fn decode_adaptive(buf: &[u8], dim: u32) -> Option<(SparseUpdate, usize)> {
    let (&tag, rest) = buf.split_first()?;
    if tag == PayloadKind::Sparse as u8 {
        let (u, used) = decode_sparse(rest, dim)?;
        Some((u, used + 1))
    } else if tag == PayloadKind::Dense as u8 {
        let (v, used) = decode_dense(rest, dim as usize)?;
        Some((SparseUpdate::from_dense(&v), used + 1))
    } else {
        None
    }
}

/// Exact bit cost of the adaptive encoding.
pub fn adaptive_bits(u: &SparseUpdate) -> usize {
    8 + sparse_bits(u).min(dense_bits(u.dim as usize))
}

trait F32Bytes {
    fn to_le_bits_bytes(self) -> [u8; 4];
}

impl F32Bytes for f32 {
    #[inline]
    fn to_le_bits_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn sparse_roundtrip() {
        let mut v = vec![0.0f64; 100];
        v[3] = 1.5;
        v[4] = -2.25;
        v[99] = 0.125;
        let u = SparseUpdate::from_dense(&v);
        assert_eq!(u.nnz(), 3);
        let mut buf = Vec::new();
        encode_sparse(&u, &mut buf);
        assert_eq!(buf.len() * 8, sparse_bits(&u));
        let (back, used) = decode_sparse(&buf, 100).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(back, u);
        assert_eq!(back.to_dense(), v);
    }

    #[test]
    fn empty_sparse_costs_one_byte() {
        let u = SparseUpdate::empty(1000);
        let mut buf = Vec::new();
        encode_sparse(&u, &mut buf);
        assert_eq!(buf.len(), 1);
        let (back, _) = decode_sparse(&buf, 1000).unwrap();
        assert_eq!(back.nnz(), 0);
    }

    #[test]
    fn dense_roundtrip_and_bits() {
        let v = vec![1.0, -0.5, 3.25, 0.0];
        let mut buf = Vec::new();
        encode_dense(&v, &mut buf);
        assert_eq!(buf.len() * 8, dense_bits(4));
        let (back, used) = decode_dense(&buf, 4).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(back, v);
    }

    #[test]
    fn decode_rejects_out_of_range_index() {
        let mut v = vec![0.0f64; 10];
        v[9] = 1.0;
        let u = SparseUpdate::from_dense(&v);
        let mut buf = Vec::new();
        encode_sparse(&u, &mut buf);
        assert!(decode_sparse(&buf, 9).is_none());
        assert!(decode_sparse(&buf, 10).is_some());
    }

    #[test]
    fn decode_rejects_truncation() {
        let mut v = vec![0.0f64; 10];
        v[2] = 1.0;
        v[7] = 2.0;
        let u = SparseUpdate::from_dense(&v);
        let mut buf = Vec::new();
        encode_sparse(&u, &mut buf);
        for cut in 0..buf.len() {
            assert!(decode_sparse(&buf[..cut], 10).is_none(), "cut={cut}");
        }
    }

    #[test]
    fn bits_match_encoded_len_random() {
        let mut rng = Pcg64::seeded(123);
        for _ in 0..100 {
            let d = 1 + rng.index(2000);
            let v: Vec<f64> =
                (0..d).map(|_| if rng.bernoulli(0.8) { 0.0 } else { rng.normal() }).collect();
            let u = SparseUpdate::from_dense(&v);
            let mut buf = Vec::new();
            encode_sparse(&u, &mut buf);
            assert_eq!(buf.len() * 8, sparse_bits(&u));
        }
    }

    #[test]
    fn sparse_beats_naive_when_sparse() {
        // vs naive (32-bit index + 32-bit value) per entry
        let mut v = vec![0.0f64; 10_000];
        for i in (0..10_000).step_by(100) {
            v[i] = 1.0;
        }
        let u = SparseUpdate::from_dense(&v);
        let naive = 64 * u.nnz();
        assert!(sparse_bits(&u) < naive);
    }

    #[test]
    fn adaptive_picks_cheaper_and_roundtrips() {
        let mut rng = Pcg64::seeded(321);
        for p_zero in [0.0, 0.2, 0.9, 1.0] {
            let d = 500;
            let v: Vec<f64> = (0..d)
                .map(|_| if rng.bernoulli(p_zero) { 0.0 } else { rng.normal() })
                .collect();
            let u = SparseUpdate::from_dense(&v);
            let mut buf = Vec::new();
            encode_adaptive(&u, &mut buf);
            assert_eq!(buf.len() * 8, adaptive_bits(&u));
            assert!(adaptive_bits(&u) <= 8 + dense_bits(d), "worst case exceeded");
            assert!(adaptive_bits(&u) <= 8 + sparse_bits(&u));
            let (back, used) = decode_adaptive(&buf, d as u32).unwrap();
            assert_eq!(used, buf.len());
            // Dense fallback reconstructs the same non-zeros (values f32
            // both ways).
            assert_eq!(back.to_dense(), u.to_dense());
        }
    }

    #[test]
    fn adaptive_rejects_bad_tag() {
        assert!(decode_adaptive(&[99, 0, 0], 4).is_none());
        assert!(decode_adaptive(&[], 4).is_none());
    }

    #[test]
    fn from_dense_skips_zeros_keeps_order() {
        let v = vec![0.0, 1.0, 0.0, -1.0];
        let u = SparseUpdate::from_dense(&v);
        assert_eq!(u.idx, vec![1, 3]);
        assert_eq!(u.val, vec![1.0f32, -1.0f32]);
    }
}
