//! Run-length index coding for sparse vectors.
//!
//! The paper transmits, per non-zero component, a 32-bit value, and encodes
//! the *locations* of non-zeros by "counting the number of consecutive
//! zeros between two non-zero components" (§IV, RLE [55]). We realize the
//! gap stream with LEB128 varints: gaps are small when the vector is dense
//! in non-zeros (1 byte) and grow logarithmically when it is very sparse —
//! strictly better than the naive (index, value) pairing the paper compares
//! against, and byte-exact for accounting.

/// Append a u32 as LEB128 varint (1–5 bytes).
#[inline]
pub fn put_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read a varint; returns (value, bytes consumed) or None on truncation.
#[inline]
pub fn get_varint(buf: &[u8]) -> Option<(u32, usize)> {
    let mut v: u32 = 0;
    let mut shift = 0;
    for (i, &b) in buf.iter().enumerate().take(5) {
        v |= ((b & 0x7f) as u32) << shift;
        if b & 0x80 == 0 {
            return Some((v, i + 1));
        }
        shift += 7;
    }
    None
}

/// Encode strictly-increasing indices as zero-run gaps.
/// Gap semantics: first gap = idx[0]; subsequent gap = idx[k] − idx[k−1] − 1
/// (the count of zeros strictly between consecutive non-zeros).
pub fn encode_gaps(indices: &[u32], out: &mut Vec<u8>) {
    let mut prev: i64 = -1;
    for &i in indices {
        debug_assert!((i as i64) > prev, "indices must be strictly increasing");
        put_varint(out, (i as i64 - prev - 1) as u32);
        prev = i as i64;
    }
}

/// Decode `n` gaps back to indices. Returns bytes consumed. Fails on
/// truncation AND on cumulative-index overflow past u32: a wrapped index
/// would silently alias a smaller one and break the strictly-increasing
/// invariant every consumer (and [`crate::compress::decode_sparse`]'s
/// tail-only range check) relies on, so such streams are rejected here.
pub fn decode_gaps(buf: &[u8], n: usize, out: &mut Vec<u32>) -> Option<usize> {
    let mut pos = 0usize;
    let mut prev: i64 = -1;
    out.reserve(n);
    for _ in 0..n {
        let (gap, used) = get_varint(&buf[pos..])?;
        pos += used;
        let idx = prev + 1 + gap as i64;
        if idx > u32::MAX as i64 {
            return None;
        }
        out.push(idx as u32);
        prev = idx;
    }
    Some(pos)
}

/// Exact encoded size in bytes for a gap value.
#[inline]
pub fn varint_len(v: u32) -> usize {
    match v {
        0..=0x7f => 1,
        0x80..=0x3fff => 2,
        0x4000..=0x1f_ffff => 3,
        0x20_0000..=0xfff_ffff => 4,
        _ => 5,
    }
}

/// Exact RLE index cost in bits for an index set (used by analytical bit
/// accounting without materializing buffers).
pub fn gap_bits(indices: &[u32]) -> usize {
    let mut prev: i64 = -1;
    let mut bytes = 0usize;
    for &i in indices {
        bytes += varint_len((i as i64 - prev - 1) as u32);
        prev = i as i64;
    }
    bytes * 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn varint_roundtrip_boundaries() {
        for v in [0u32, 1, 127, 128, 16383, 16384, 2097151, 2097152, u32::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            assert_eq!(buf.len(), varint_len(v));
            let (back, used) = get_varint(&buf).unwrap();
            assert_eq!(back, v);
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn gaps_roundtrip() {
        let idx = vec![0u32, 1, 2, 10, 500, 501, 100_000];
        let mut buf = Vec::new();
        encode_gaps(&idx, &mut buf);
        assert_eq!(buf.len() * 8, gap_bits(&idx));
        let mut back = Vec::new();
        let used = decode_gaps(&buf, idx.len(), &mut back).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(back, idx);
    }

    #[test]
    fn empty_index_set() {
        let mut buf = Vec::new();
        encode_gaps(&[], &mut buf);
        assert!(buf.is_empty());
        let mut back = Vec::new();
        assert_eq!(decode_gaps(&buf, 0, &mut back), Some(0));
        assert!(back.is_empty());
    }

    #[test]
    fn dense_runs_cost_one_byte_each() {
        // Consecutive indices → all gaps zero → 1 byte per index.
        let idx: Vec<u32> = (0..1000).collect();
        assert_eq!(gap_bits(&idx), 8000);
    }

    #[test]
    fn truncated_buffer_fails() {
        let idx = vec![300u32];
        let mut buf = Vec::new();
        encode_gaps(&idx, &mut buf);
        assert!(buf.len() >= 2);
        let mut back = Vec::new();
        assert!(decode_gaps(&buf[..1], 1, &mut back).is_none());
    }

    #[test]
    fn overflowing_gap_stream_rejected() {
        // First index lands exactly on u32::MAX (legal), a second entry
        // must overflow and be rejected rather than wrap non-monotonically.
        let mut buf = Vec::new();
        put_varint(&mut buf, u32::MAX); // gap → idx0 = u32::MAX
        put_varint(&mut buf, 0); // idx1 = u32::MAX + 1 → overflow
        let mut one = Vec::new();
        assert_eq!(decode_gaps(&buf, 1, &mut one), Some(varint_len(u32::MAX)));
        assert_eq!(one, vec![u32::MAX]);
        let mut two = Vec::new();
        assert!(decode_gaps(&buf, 2, &mut two).is_none());
    }

    #[test]
    fn random_roundtrip_many() {
        let mut rng = Pcg64::seeded(77);
        for _ in 0..200 {
            let n = 1 + rng.index(300);
            let mut idx: Vec<u32> = (0..n).map(|_| rng.below(1 << 20) as u32).collect();
            idx.sort_unstable();
            idx.dedup();
            let mut buf = Vec::new();
            encode_gaps(&idx, &mut buf);
            let mut back = Vec::new();
            let used = decode_gaps(&buf, idx.len(), &mut back).unwrap();
            assert_eq!(used, buf.len());
            assert_eq!(back, idx);
        }
    }
}
