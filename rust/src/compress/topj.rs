//! Top-j sparsifier — the fixed-budget baseline (Stich et al. 2018,
//! "Sparsified SGD with Memory") the paper compares against: keep the j
//! largest-magnitude components, zero the rest, accumulate the residual in
//! local memory.

use super::SparseUpdate;

/// Indices of the `j` largest-|v| components, written sorted ascending
/// into `out` (cleared first, capacity kept). O(d) selection via
/// `select_nth_unstable` (no full sort). Single home of the selection
/// comparator so index reporting and the wire update can never diverge.
fn top_j_indices_into(v: &[f64], j: usize, out: &mut Vec<u32>) {
    out.clear();
    let d = v.len();
    if j == 0 {
        return;
    }
    if j >= d {
        out.extend(0..d as u32);
        return;
    }
    let mut order: Vec<u32> = (0..d as u32).collect();
    order.select_nth_unstable_by(j - 1, |&a, &b| {
        v[b as usize]
            .abs()
            .partial_cmp(&v[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    out.extend_from_slice(&order[..j]);
    out.sort_unstable();
}

/// Indices of the `j` largest-|v| components, returned sorted ascending.
pub fn top_j_indices(v: &[f64], j: usize) -> Vec<u32> {
    let mut out = Vec::new();
    top_j_indices_into(v, j, &mut out);
    out
}

/// Sparsify `v` to its top-j components as a wire update.
pub fn top_j_update(v: &[f64], j: usize) -> SparseUpdate {
    let mut out = SparseUpdate::empty(v.len());
    top_j_update_into(v, j, &mut out);
    out
}

/// [`top_j_update`] into a reused buffer: indices/values land in `out`
/// with capacity kept across rounds (the trainers' arena-reuse pattern).
/// The O(d) selection scratch still allocates; top-j is a baseline, not
/// the zero-alloc hot path.
pub fn top_j_update_into(v: &[f64], j: usize, out: &mut SparseUpdate) {
    out.reset(v.len());
    top_j_indices_into(v, j, &mut out.idx);
    out.val.extend(out.idx.iter().map(|&i| v[i as usize] as f32));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn picks_largest_magnitudes() {
        let v = vec![0.1, -5.0, 2.0, 0.0, 3.0];
        let idx = top_j_indices(&v, 2);
        assert_eq!(idx, vec![1, 4]);
    }

    #[test]
    fn j_zero_and_j_ge_d() {
        let v = vec![1.0, 2.0];
        assert!(top_j_indices(&v, 0).is_empty());
        assert_eq!(top_j_indices(&v, 2), vec![0, 1]);
        assert_eq!(top_j_indices(&v, 10), vec![0, 1]);
    }

    #[test]
    fn update_carries_values() {
        let v = vec![0.0, -4.0, 1.0];
        let u = top_j_update(&v, 1);
        assert_eq!(u.idx, vec![1]);
        assert_eq!(u.val, vec![-4.0f32]);
        assert_eq!(u.dim, 3);
    }

    #[test]
    fn selection_matches_sort(){
        let mut rng = Pcg64::seeded(42);
        for _ in 0..50 {
            let d = 1 + rng.index(200);
            let j = rng.index(d + 1);
            let v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let fast = top_j_indices(&v, j);
            let mut order: Vec<u32> = (0..d as u32).collect();
            order.sort_by(|&a, &b| {
                v[b as usize].abs().partial_cmp(&v[a as usize].abs()).unwrap()
            });
            let mut slow = order[..j].to_vec();
            slow.sort_unstable();
            // With ties the *sets of magnitudes* must agree even if index
            // choices differ.
            let mag = |ix: &[u32]| {
                let mut m: Vec<f64> = ix.iter().map(|&i| v[i as usize].abs()).collect();
                m.sort_by(|a, b| a.partial_cmp(b).unwrap());
                m
            };
            assert_eq!(mag(&fast), mag(&slow));
        }
    }
}
