//! Top-j sparsifier — the fixed-budget baseline (Stich et al. 2018,
//! "Sparsified SGD with Memory") the paper compares against: keep the j
//! largest-magnitude components, zero the rest, accumulate the residual in
//! local memory.

use super::SparseUpdate;

/// Indices of the `j` largest-|v| components, returned sorted ascending.
/// O(d) selection via `select_nth_unstable` (no full sort).
pub fn top_j_indices(v: &[f64], j: usize) -> Vec<u32> {
    let d = v.len();
    if j == 0 {
        return Vec::new();
    }
    if j >= d {
        return (0..d as u32).collect();
    }
    let mut order: Vec<u32> = (0..d as u32).collect();
    order.select_nth_unstable_by(j - 1, |&a, &b| {
        v[b as usize]
            .abs()
            .partial_cmp(&v[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut keep = order[..j].to_vec();
    keep.sort_unstable();
    keep
}

/// Sparsify `v` to its top-j components as a wire update.
pub fn top_j_update(v: &[f64], j: usize) -> SparseUpdate {
    let idx = top_j_indices(v, j);
    let val = idx.iter().map(|&i| v[i as usize] as f32).collect();
    SparseUpdate { dim: v.len() as u32, idx, val }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn picks_largest_magnitudes() {
        let v = vec![0.1, -5.0, 2.0, 0.0, 3.0];
        let idx = top_j_indices(&v, 2);
        assert_eq!(idx, vec![1, 4]);
    }

    #[test]
    fn j_zero_and_j_ge_d() {
        let v = vec![1.0, 2.0];
        assert!(top_j_indices(&v, 0).is_empty());
        assert_eq!(top_j_indices(&v, 2), vec![0, 1]);
        assert_eq!(top_j_indices(&v, 10), vec![0, 1]);
    }

    #[test]
    fn update_carries_values() {
        let v = vec![0.0, -4.0, 1.0];
        let u = top_j_update(&v, 1);
        assert_eq!(u.idx, vec![1]);
        assert_eq!(u.val, vec![-4.0f32]);
        assert_eq!(u.dim, 3);
    }

    #[test]
    fn selection_matches_sort(){
        let mut rng = Pcg64::seeded(42);
        for _ in 0..50 {
            let d = 1 + rng.index(200);
            let j = rng.index(d + 1);
            let v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let fast = top_j_indices(&v, j);
            let mut order: Vec<u32> = (0..d as u32).collect();
            order.sort_by(|&a, &b| {
                v[b as usize].abs().partial_cmp(&v[a as usize].abs()).unwrap()
            });
            let mut slow = order[..j].to_vec();
            slow.sort_unstable();
            // With ties the *sets of magnitudes* must agree even if index
            // choices differ.
            let mag = |ix: &[u32]| {
                let mut m: Vec<f64> = ix.iter().map(|&i| v[i as usize].abs()).collect();
                m.sort_by(|a, b| a.partial_cmp(b).unwrap());
                m
            };
            assert_eq!(mag(&fast), mag(&slow));
        }
    }
}
