//! QSGD-style low-precision unbiased quantizer (Alistarh et al. 2017),
//! exactly as the paper's QGD baseline and QSGD-SEC extension define it:
//!
//! `Q_s([v]_i) = ‖v‖ · sign([v]_i) · η_i(v, s)` where `η_i` takes value
//! `(l+1)/s` with probability `p = |v_i|·s/‖v‖ − l` and `l/s` otherwise,
//! with `l = ⌊|v_i|·s/‖v‖⌋`.
//!
//! Wire cost (paper §IV): 8 bits magnitude level + 1 bit sign per non-zero
//! component, plus 32 bits for ‖v‖. We additionally RLE-gap-code the
//! non-zero locations (levels quantized to 0 transmit nothing), which only
//! helps the baseline.

use super::rle;
use crate::linalg;
use crate::util::rng::Pcg64;

/// Quantized vector: norm + sparse (index, signed level) pairs.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedVec {
    pub dim: u32,
    pub norm: f32,
    /// Number of quantization bins s (level fits in 8 bits ⇒ s ≤ 255).
    pub s: u8,
    pub idx: Vec<u32>,
    /// Signed levels: |level| ∈ 1..=s, sign carries the component sign.
    pub levels: Vec<i16>,
}

/// Quantize `v` with `s` bins using `rng` for the stochastic rounding.
pub fn quantize(v: &[f64], s: u8, rng: &mut Pcg64) -> QuantizedVec {
    assert!(s >= 1);
    let norm = linalg::nrm2(v);
    let mut q = QuantizedVec {
        dim: v.len() as u32,
        norm: norm as f32,
        s,
        idx: Vec::new(),
        levels: Vec::new(),
    };
    if norm <= 0.0 {
        return q;
    }
    let sf = s as f64;
    for (i, &x) in v.iter().enumerate() {
        if x == 0.0 {
            continue;
        }
        let ratio = (x.abs() / norm * sf).min(sf);
        let l = ratio.floor();
        let p = ratio - l;
        let level = l as i64 + i64::from(rng.uniform() < p);
        if level == 0 {
            continue;
        }
        q.idx.push(i as u32);
        q.levels.push(if x > 0.0 { level as i16 } else { -(level as i16) });
    }
    q
}

/// Dequantize to a dense vector.
pub fn dequantize(q: &QuantizedVec) -> Vec<f64> {
    let mut out = vec![0.0; q.dim as usize];
    dequantize_into(q, &mut out);
    out
}

/// [`dequantize`] into a caller-owned buffer (zeroed first) — lets the
/// pooled QGD/QSGD-SEC lanes reuse their dense scratch across rounds.
pub fn dequantize_into(q: &QuantizedVec, out: &mut [f64]) {
    assert_eq!(out.len(), q.dim as usize);
    linalg::zero(out);
    let norm = q.norm as f64;
    let sf = q.s as f64;
    for k in 0..q.idx.len() {
        let lvl = q.levels[k] as f64;
        out[q.idx[k] as usize] = norm * lvl / sf;
    }
}

/// Exact wire cost in bits: 32 (norm) + per non-zero (8 level + 1 sign)
/// + RLE gap bits, + varint nnz header.
pub fn quantized_bits(q: &QuantizedVec) -> usize {
    32 + 8 * rle::varint_len(q.idx.len() as u32)
        + rle::gap_bits(&q.idx)
        + 9 * q.idx.len()
}

/// Encode to bytes: [norm f32][s u8][nnz varint][gaps][levels: u8 mag]
/// [packed sign bits]. Byte-aligned (sign bits padded to whole bytes);
/// `quantized_bits` reports the information-theoretic 9-bit accounting the
/// paper uses, while this function produces a decodable byte stream —
/// tests pin |encoded|·8 ≥ quantized_bits ≥ |encoded|·8 − 7 − pad.
pub fn encode(q: &QuantizedVec, out: &mut Vec<u8>) {
    out.extend_from_slice(&q.norm.to_le_bytes());
    out.push(q.s);
    rle::put_varint(out, q.idx.len() as u32);
    rle::encode_gaps(&q.idx, out);
    for &l in &q.levels {
        out.push(l.unsigned_abs() as u8);
    }
    // Pack signs, 8 per byte.
    let mut byte = 0u8;
    for (k, &l) in q.levels.iter().enumerate() {
        if l < 0 {
            byte |= 1 << (k % 8);
        }
        if k % 8 == 7 {
            out.push(byte);
            byte = 0;
        }
    }
    if q.levels.len() % 8 != 0 {
        out.push(byte);
    }
}

/// Decode from bytes.
pub fn decode(buf: &[u8], dim: u32) -> Option<(QuantizedVec, usize)> {
    if buf.len() < 5 {
        return None;
    }
    let norm = f32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    let s = buf[4];
    let (nnz, used) = rle::get_varint(&buf[5..])?;
    let mut pos = 5 + used;
    let mut idx = Vec::new();
    pos += rle::decode_gaps(&buf[pos..], nnz as usize, &mut idx)?;
    if idx.last().is_some_and(|&l| l >= dim) {
        return None;
    }
    let nnz = nnz as usize;
    let sign_bytes = nnz.div_ceil(8);
    if buf.len() < pos + nnz + sign_bytes {
        return None;
    }
    let mut levels = Vec::with_capacity(nnz);
    for k in 0..nnz {
        let mag = buf[pos + k] as i16;
        let sign_byte = buf[pos + nnz + k / 8];
        let neg = sign_byte >> (k % 8) & 1 == 1;
        levels.push(if neg { -mag } else { mag });
    }
    Some((QuantizedVec { dim, norm, s, idx, levels }, pos + nnz + sign_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_vector_quantizes_empty() {
        let mut rng = Pcg64::seeded(1);
        let q = quantize(&[0.0, 0.0, 0.0], 8, &mut rng);
        assert_eq!(q.idx.len(), 0);
        assert_eq!(dequantize(&q), vec![0.0; 3]);
    }

    #[test]
    fn levels_bounded_by_s() {
        let mut rng = Pcg64::seeded(2);
        let v: Vec<f64> = (0..200).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
        let q = quantize(&v, 255, &mut rng);
        assert!(q.levels.iter().all(|&l| l != 0 && l.unsigned_abs() <= 255));
    }

    #[test]
    fn unbiasedness() {
        // E[Q(v)] == v, tested componentwise by averaging many draws.
        let mut rng = Pcg64::seeded(3);
        let v = vec![0.3, -0.8, 0.05, 0.0, 1.2];
        let trials = 20_000;
        let mut acc = vec![0.0; v.len()];
        for _ in 0..trials {
            let q = quantize(&v, 4, &mut rng);
            let dq = dequantize(&q);
            for i in 0..v.len() {
                acc[i] += dq[i];
            }
        }
        for i in 0..v.len() {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - v[i]).abs() < 0.02,
                "component {i}: mean {mean} vs true {}",
                v[i]
            );
        }
    }

    #[test]
    fn signs_preserved() {
        let mut rng = Pcg64::seeded(4);
        let v = vec![5.0, -5.0, 2.5, -2.5];
        let q = quantize(&v, 16, &mut rng);
        let dq = dequantize(&q);
        for i in 0..4 {
            assert!(dq[i] * v[i] >= 0.0, "sign flipped at {i}");
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Pcg64::seeded(5);
        for _ in 0..50 {
            let d = 1 + rng.index(500);
            let v: Vec<f64> =
                (0..d).map(|_| if rng.bernoulli(0.3) { 0.0 } else { rng.normal() }).collect();
            let q = quantize(&v, 200, &mut rng);
            let mut buf = Vec::new();
            encode(&q, &mut buf);
            let (back, used) = decode(&buf, d as u32).unwrap();
            assert_eq!(used, buf.len());
            assert_eq!(back, q);
        }
    }

    #[test]
    fn bit_accounting_close_to_bytes() {
        let mut rng = Pcg64::seeded(6);
        let v: Vec<f64> = (0..1000).map(|_| rng.normal()).collect();
        let q = quantize(&v, 255, &mut rng);
        let mut buf = Vec::new();
        encode(&q, &mut buf);
        let bits = quantized_bits(&q);
        let bytes_bits = buf.len() * 8;
        // encoded stream carries s (8 bits) + sign padding; accounting is
        // the paper's 9-bit-per-component model.
        assert!(bytes_bits >= bits, "{bytes_bits} < {bits}");
        assert!(bytes_bits - bits <= 8 + 8 + 7, "slack too large: {}", bytes_bits - bits);
    }

    #[test]
    fn quantization_error_bounded() {
        // ‖Q(v) − v‖ ≤ ‖v‖·sqrt(d)/s (standard QSGD bound, loose form).
        let mut rng = Pcg64::seeded(7);
        let d = 64;
        let v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let norm = linalg::nrm2(&v);
        let s = 128u8;
        let q = quantize(&v, s, &mut rng);
        let dq = dequantize(&q);
        let mut diff = vec![0.0; d];
        linalg::sub(&dq, &v, &mut diff);
        let bound = norm * (d as f64).sqrt() / s as f64;
        assert!(linalg::nrm2(&diff) <= bound * 1.5, "err too large");
    }
}
