//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from the Rust request path. Python is never invoked at
//! run time — the interchange is HLO *text* (see DESIGN.md and
//! /opt/xla-example/README.md for why text, not serialized protos).
//!
//! The artifact **manifest** (this module) is dependency-free and always
//! compiled, so the CLI can report artifact inventory offline. The PJRT
//! **execution engine** ([`engine`], incl. [`engine::Runtime`]) needs the
//! external `xla`/`anyhow` crates and is gated behind the off-by-default
//! `pjrt` feature — the offline image has no crate registry, so the
//! default build must not reference external crates at all.

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub use engine::Runtime;

use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: HashMap<String, f64>,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load from an artifacts directory.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let v = json::parse(&text).map_err(|e| err!("manifest: {e}"))?;
        let mut artifacts = HashMap::new();
        for a in v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("manifest: missing artifacts[]"))?
        {
            let spec = parse_artifact(a)?;
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| err!("artifact '{name}' not in manifest"))
    }

    /// Find the first artifact (alphabetically) whose name has the prefix.
    pub fn find_prefix(&self, prefix: &str) -> Option<&ArtifactSpec> {
        let mut names: Vec<&String> = self.artifacts.keys().collect();
        names.sort();
        names
            .into_iter()
            .find(|n| n.starts_with(prefix))
            .map(|n| &self.artifacts[n])
    }

    /// Default artifacts directory: $GDSEC_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("GDSEC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

fn parse_artifact(a: &Json) -> Result<ArtifactSpec> {
    let name = a
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| err!("artifact missing name"))?
        .to_string();
    let file = a
        .get("file")
        .and_then(Json::as_str)
        .ok_or_else(|| err!("artifact {name}: missing file"))?
        .to_string();
    let tensors = |key: &str| -> Vec<TensorSpec> {
        let mut out = Vec::new();
        for t in a.get(key).and_then(Json::as_arr).unwrap_or(&[]) {
            out.push(TensorSpec {
                name: t.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|s| s.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
                dtype: t.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
            });
        }
        out
    };
    let mut meta = HashMap::new();
    if let Some(m) = a.get("meta").and_then(Json::as_obj) {
        for (k, v) in m {
            if let Some(x) = v.as_f64() {
                meta.insert(k.clone(), x);
            }
        }
    }
    Ok(ArtifactSpec { name, file, inputs: tensors("inputs"), outputs: tensors("outputs"), meta })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> &'static str {
        r#"{
          "format": "hlo-text",
          "artifacts": [
            {"name": "a", "file": "a.hlo.txt",
             "inputs": [{"name": "x", "shape": [2, 3], "dtype": "float32"}],
             "outputs": [{"name": "out0", "shape": [3], "dtype": "float32"}],
             "meta": {"d": 3}}
          ]
        }"#
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join(format!("gdsec_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("a").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].elements(), 6);
        assert_eq!(a.meta["d"], 3.0);
        assert!(m.get("zzz").is_err());
        assert!(m.find_prefix("a").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_context_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
