//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from the Rust request path. Python is never invoked at
//! run time — the interchange is HLO *text* (see DESIGN.md and
//! /opt/xla-example/README.md for why text, not serialized protos).

pub mod engine;

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: HashMap<String, f64>,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load from an artifacts directory.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut artifacts = HashMap::new();
        for a in v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing artifacts[]"))?
        {
            let spec = parse_artifact(a)?;
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Find the first artifact (alphabetically) whose name has the prefix.
    pub fn find_prefix(&self, prefix: &str) -> Option<&ArtifactSpec> {
        let mut names: Vec<&String> = self.artifacts.keys().collect();
        names.sort();
        names
            .into_iter()
            .find(|n| n.starts_with(prefix))
            .map(|n| &self.artifacts[n])
    }

    /// Default artifacts directory: $GDSEC_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("GDSEC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

fn parse_artifact(a: &Json) -> Result<ArtifactSpec> {
    let name = a
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("artifact missing name"))?
        .to_string();
    let file = a
        .get("file")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("artifact {name}: missing file"))?
        .to_string();
    let tensors = |key: &str| -> Vec<TensorSpec> {
        let mut out = Vec::new();
        for t in a.get(key).and_then(Json::as_arr).unwrap_or(&[]) {
            out.push(TensorSpec {
                name: t.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|s| s.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
                dtype: t.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
            });
        }
        out
    };
    let mut meta = HashMap::new();
    if let Some(m) = a.get("meta").and_then(Json::as_obj) {
        for (k, v) in m {
            if let Some(x) = v.as_f64() {
                meta.insert(k.clone(), x);
            }
        }
    }
    Ok(ArtifactSpec { name, file, inputs: tensors("inputs"), outputs: tensors("outputs"), meta })
}

/// A PJRT CPU client with a compiled-executable cache.
///
/// NOT `Send` (the underlying PJRT wrappers hold raw pointers); create one
/// per thread via [`Runtime::new`] inside the thread. Compilation is
/// per-instance; the HLO text load + compile for the artifacts in this
/// repo takes tens of milliseconds.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, exes: HashMap::new() })
    }

    pub fn from_dir<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        Runtime::new(Manifest::load(dir)?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact. Outputs come back as f32 vectors.
    pub fn exec(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let spec = self.manifest.get(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let n_outputs = spec.outputs.len();
        let exe = &self.exes[name];
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose.
        let parts = result.to_tuple()?;
        if parts.len() != n_outputs {
            bail!("artifact {name}: expected {n_outputs} outputs, got {}", parts.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// f32 literal with the given dims.
    pub fn lit_f32(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(values).reshape(dims)?)
    }

    /// f32 literal from f64 values (wire/compute precision boundary).
    pub fn lit_from_f64(values: &[f64], dims: &[i64]) -> Result<xla::Literal> {
        let v32: Vec<f32> = values.iter().map(|&x| x as f32).collect();
        Self::lit_f32(&v32, dims)
    }

    /// i32 literal.
    pub fn lit_i32(values: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(values).reshape(dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> &'static str {
        r#"{
          "format": "hlo-text",
          "artifacts": [
            {"name": "a", "file": "a.hlo.txt",
             "inputs": [{"name": "x", "shape": [2, 3], "dtype": "float32"}],
             "outputs": [{"name": "out0", "shape": [3], "dtype": "float32"}],
             "meta": {"d": 3}}
          ]
        }"#
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join(format!("gdsec_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("a").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].elements(), 6);
        assert_eq!(a.meta["d"], 3.0);
        assert!(m.get("zzz").is_err());
        assert!(m.find_prefix("a").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_context_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
