//! Execution engines bridging the coordinator to compiled artifacts.
//!
//! * [`XlaWorkerStep`] — the fused Algorithm-1 worker iteration
//!   (objective gradient + Pallas censor/EC) as ONE PJRT execution.
//! * [`XlaGradProvider`] — adapts a worker-step artifact to the
//!   coordinator's [`GradProvider`] seam (h = e = ξ = 0 turns the fused
//!   step into a plain loss+gradient evaluation).
//! * [`TfmEngine`] — transformer init / loss+grad for the e2e example.

use anyhow::{anyhow, bail, Result};
use crate::coordinator::worker::GradProvider;
use std::collections::HashMap;
use std::path::Path;
use super::Manifest;

/// A PJRT CPU client with a compiled-executable cache.
///
/// NOT `Send` (the underlying PJRT wrappers hold raw pointers); create one
/// per thread via [`Runtime::new`] inside the thread. Compilation is
/// per-instance; the HLO text load + compile for the artifacts in this
/// repo takes tens of milliseconds.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, exes: HashMap::new() })
    }

    pub fn from_dir<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        Ok(Runtime::new(Manifest::load(dir).map_err(|e| anyhow!("{e:#}"))?)?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name).map_err(|e| anyhow!("{e:#}"))?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact. Outputs come back as f32 vectors.
    pub fn exec(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let spec = self.manifest.get(name).map_err(|e| anyhow!("{e:#}"))?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let n_outputs = spec.outputs.len();
        let exe = &self.exes[name];
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose.
        let parts = result.to_tuple()?;
        if parts.len() != n_outputs {
            bail!("artifact {name}: expected {n_outputs} outputs, got {}", parts.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// f32 literal with the given dims.
    pub fn lit_f32(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(values).reshape(dims)?)
    }

    /// f32 literal from f64 values (wire/compute precision boundary).
    pub fn lit_from_f64(values: &[f64], dims: &[i64]) -> Result<xla::Literal> {
        let v32: Vec<f32> = values.iter().map(|&x| x as f32).collect();
        Self::lit_f32(&v32, dims)
    }

    /// i32 literal.
    pub fn lit_i32(values: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(values).reshape(dims)?)
    }
}

/// Scalars layout shared with `python/compile/model.py::make_worker_step`.
#[derive(Debug, Clone, Copy)]
pub struct WorkerScalars {
    pub beta: f64,
    pub m_inv: f64,
    pub n_inv: f64,
    pub lambda: f64,
}

impl WorkerScalars {
    fn to_f32(self) -> [f32; 4] {
        [self.beta as f32, self.m_inv as f32, self.n_inv as f32, self.lambda as f32]
    }
}

/// Output of one fused worker step.
pub struct WorkerStepOut {
    /// Dense Δ̂ (zeros where censored) — L3 RLE-encodes this.
    pub wire: Vec<f32>,
    pub h_new: Vec<f32>,
    pub e_new: Vec<f32>,
    pub loss: f64,
}

/// One worker's compiled fused step over a fixed shard.
pub struct XlaWorkerStep {
    rt: Runtime,
    artifact: String,
    x_lit: xla::Literal,
    y_lit: xla::Literal,
    pub n: usize,
    pub d: usize,
}

impl XlaWorkerStep {
    /// Build for an artifact named `worker_step_<kind>_<n>x<d>` with the
    /// given shard data (row-major X).
    pub fn new(manifest: Manifest, artifact: &str, x: &[f64], y: &[f64]) -> Result<XlaWorkerStep> {
        let mut rt = Runtime::new(manifest)?;
        let spec = rt.manifest().get(artifact)?.clone();
        let n = spec.inputs[0].shape[0];
        let d = spec.inputs[0].shape[1];
        if x.len() != n * d || y.len() != n {
            return Err(anyhow!(
                "shard shape mismatch: artifact wants {n}x{d}, got x={} y={}",
                x.len(),
                y.len()
            ));
        }
        let x_lit = Runtime::lit_from_f64(x, &[n as i64, d as i64])?;
        let y_lit = Runtime::lit_from_f64(y, &[n as i64])?;
        rt.load(artifact)?;
        Ok(XlaWorkerStep { rt, artifact: artifact.to_string(), x_lit, y_lit, n, d })
    }

    /// Run the fused step.
    pub fn step(
        &mut self,
        theta: &[f64],
        theta_prev: &[f64],
        h: &[f32],
        e: &[f32],
        xi: &[f64],
        scalars: WorkerScalars,
    ) -> Result<WorkerStepOut> {
        let d = self.d as i64;
        let inputs = vec![
            self.x_lit.clone(),
            self.y_lit.clone(),
            Runtime::lit_from_f64(theta, &[d])?,
            Runtime::lit_from_f64(theta_prev, &[d])?,
            Runtime::lit_f32(h, &[d])?,
            Runtime::lit_f32(e, &[d])?,
            Runtime::lit_from_f64(xi, &[d])?,
            Runtime::lit_f32(&scalars.to_f32(), &[4])?,
        ];
        let mut out = self.rt.exec(&self.artifact, &inputs)?;
        let loss = out[3][0] as f64;
        let e_new = out.remove(2);
        let h_new = out.remove(1);
        let wire = out.remove(0);
        Ok(WorkerStepOut { wire, h_new, e_new, loss })
    }
}

/// Adapts a worker-step artifact into a plain loss+gradient provider:
/// with h = e = 0 and ξ = 0 the fused step's `wire` equals the local
/// gradient (every non-zero survives a zero threshold).
pub struct XlaGradProvider {
    step: XlaWorkerStep,
    scalars: WorkerScalars,
    zeros32: Vec<f32>,
    zeros64: Vec<f64>,
}

impl XlaGradProvider {
    pub fn new(
        manifest: Manifest,
        artifact: &str,
        x: &[f64],
        y: &[f64],
        scalars: WorkerScalars,
    ) -> Result<XlaGradProvider> {
        let step = XlaWorkerStep::new(manifest, artifact, x, y)?;
        let d = step.d;
        Ok(XlaGradProvider { step, scalars, zeros32: vec![0.0; d], zeros64: vec![0.0; d] })
    }
}

impl GradProvider for XlaGradProvider {
    fn dim(&self) -> usize {
        self.step.d
    }

    fn loss_grad(&mut self, theta: &[f64], out: &mut [f64]) -> f64 {
        // β=0 keeps the artifact's internal h update inert; h=e=ξ=0 makes
        // wire == gradient.
        let scal = WorkerScalars { beta: 0.0, ..self.scalars };
        let res = self
            .step
            .step(theta, theta, &self.zeros32, &self.zeros32, &self.zeros64, scal)
            .expect("xla worker step failed");
        for (o, w) in out.iter_mut().zip(&res.wire) {
            *o = *w as f64;
        }
        res.loss
    }
}

/// Transformer engine for the e2e example: compiled init + loss/grad +
/// the standalone Pallas sparsify artifact over the flat parameter vector.
pub struct TfmEngine {
    rt: Runtime,
    pub n_params: usize,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    sparsify_name: String,
}

impl TfmEngine {
    pub fn new(manifest: Manifest) -> Result<TfmEngine> {
        let spec = manifest.get("tfm_loss_grad")?.clone();
        let n_params = spec.inputs[0].shape[0];
        let batch = spec.inputs[1].shape[0];
        let seq = spec.inputs[1].shape[1];
        let vocab = *spec.meta.get("vocab").unwrap_or(&256.0) as usize;
        let sparsify_name = manifest
            .find_prefix("gdsec_sparsify_")
            .map(|a| a.name.clone())
            .ok_or_else(|| anyhow!("no gdsec_sparsify artifact"))?;
        let rt = Runtime::new(manifest)?;
        Ok(TfmEngine { rt, n_params, batch, seq, vocab, sparsify_name })
    }

    /// Materialize the jax initialization (identical across workers/server).
    pub fn init_params(&mut self, seed: i32) -> Result<Vec<f32>> {
        let seed_lit = Runtime::lit_i32(&[seed], &[1])?;
        let mut out = self.rt.exec("tfm_init", &[seed_lit])?;
        Ok(out.remove(0))
    }

    /// Loss + gradient on a token batch (i32[batch, seq]).
    pub fn loss_grad(&mut self, params: &[f32], tokens: &[i32]) -> Result<(f64, Vec<f32>)> {
        let p = Runtime::lit_f32(params, &[self.n_params as i64])?;
        let t = Runtime::lit_i32(tokens, &[self.batch as i64, self.seq as i64])?;
        let mut out = self.rt.exec("tfm_loss_grad", &[p, t])?;
        let grad = out.remove(1);
        let loss = out[0][0] as f64;
        Ok((loss, grad))
    }

    /// The L1 Pallas censor/EC kernel over the flat parameter vector.
    #[allow(clippy::too_many_arguments)]
    pub fn sparsify(
        &mut self,
        grad: &[f32],
        h: &[f32],
        e: &[f32],
        theta_diff: &[f32],
        xi: f32,
        beta: f32,
        m_inv: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.n_params as i64;
        let xi_vec = vec![xi; self.n_params];
        let inputs = vec![
            Runtime::lit_f32(grad, &[d])?,
            Runtime::lit_f32(h, &[d])?,
            Runtime::lit_f32(e, &[d])?,
            Runtime::lit_f32(theta_diff, &[d])?,
            Runtime::lit_f32(&xi_vec, &[d])?,
            Runtime::lit_f32(&[beta, m_inv], &[2])?,
        ];
        let mut out = self.rt.exec(&self.sparsify_name, &inputs)?;
        let e_new = out.remove(2);
        let h_new = out.remove(1);
        let wire = out.remove(0);
        Ok((wire, h_new, e_new))
    }
}
