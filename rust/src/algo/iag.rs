//! NoUnif-IAG — nonuniform sampling of incremental aggregated gradient
//! (Schmidt et al. [57]): one worker per iteration transmits a fresh
//! gradient (chosen with probability ∝ L_m); the server aggregates it with
//! the stale gradients of the others.
//!
//! Runs through the unified round [`engine`]: the participation schedule
//! samples exactly one worker per round (the engine skips every other
//! lane's gradient), the pre-loop seeding round fills all M gradient
//! memories ([`engine::CompressRule::seeds_memories`]), and the
//! per-iteration aggregation of all M stored gradients fans over
//! [`Pool::scatter_blocks`] column blocks — each block summed over
//! workers in ascending order ⇒ bitwise equal to the serial fold for any
//! thread count.

use super::engine::{self, CompressRule, EngineLane, EngineOpts, RoundCtx, Sent};
use super::gdsec::{fstar_iters, ServerState};
use super::trace::Trace;
use crate::compress;
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct IagConfig {
    pub alpha: f64,
    pub seed: u64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

/// One IAG worker lane: the server-side memory of this worker's last
/// transmitted (f32-rounded) gradient. The engine computes fresh
/// gradients directly into it; `compress`/`seed` round it to the wire
/// precision in place.
pub struct IagLane {
    mem: Vec<f64>,
}

/// Incremental-aggregated-gradient rule: dense transmissions, stale
/// memories for everyone but the sampled worker.
pub struct IagRule {
    cfg: IagConfig,
    agg: Vec<f64>,
}

impl IagRule {
    pub fn new(cfg: IagConfig, d: usize) -> IagRule {
        IagRule { cfg, agg: vec![0.0; d] }
    }

    fn dense_sent(d: usize) -> Sent {
        Sent { bits: compress::dense_bits(d) as u64, entries: d as u64 }
    }
}

impl CompressRule for IagRule {
    type Lane = IagLane;

    fn name(&self) -> String {
        "NoUnif-IAG".into()
    }

    fn make_lane(&self, prob: &Problem, _w: usize) -> IagLane {
        IagLane { mem: vec![0.0; prob.d] }
    }

    fn grad_buf<'l>(&self, lane: &'l mut IagLane) -> &'l mut [f64] {
        &mut lane.mem
    }

    fn seeds_memories(&self) -> bool {
        true
    }

    fn seed(&self, _w: usize, lane: &mut IagLane) -> Sent {
        for v in lane.mem.iter_mut() {
            *v = *v as f32 as f64;
        }
        IagRule::dense_sent(lane.mem.len())
    }

    fn compress(&self, _ctx: &RoundCtx, w: usize, lane: &mut IagLane) -> Option<Sent> {
        Some(self.seed(w, lane))
    }

    fn apply(
        &mut self,
        _k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<IagLane>],
        pool: &Pool,
    ) {
        // agg = Σ_w mem[w], parallelized over column blocks. Every element
        // is summed over workers in ascending order regardless of which
        // thread owns its block, so the result is bitwise identical to
        // the serial fold.
        pool.scatter_blocks(&mut self.agg, |j0, block| {
            linalg::zero(block);
            for el in lanes {
                linalg::axpy(1.0, &el.lane.mem[j0..j0 + block.len()], block);
            }
        });
        linalg::axpy(-self.cfg.alpha, &self.agg, &mut server.theta);
    }

    fn defers_late(&self) -> bool {
        // IAG is stale by construction: every round aggregates ALL M
        // gradient memories, fresh or not, and `compress` refreshes the
        // sampled worker's memory in place — a "late" refresh lands in
        // the current aggregation regardless, so cuts cannot defer it.
        false
    }

    fn fold_stale(
        &mut self,
        _k: usize,
        _server: &mut ServerState,
        _w: usize,
        _lane: &mut IagLane,
        _age: u32,
    ) {
        // Unreachable while `defers_late` is false; the memory IS the
        // fold.
    }
}

pub fn run(prob: &Problem, cfg: &IagConfig, iters: usize) -> Trace {
    run_pooled(prob, cfg, iters, Pool::global())
}

/// NoUnif-IAG through the engine on an explicit pool. The engine's
/// nested lanes parallelize the two O(M·d)-plus parts — the seeding
/// round and the sampled worker's fresh gradient — and `apply` the
/// per-iteration memory aggregation.
pub fn run_pooled(prob: &Problem, cfg: &IagConfig, iters: usize, pool: &Pool) -> Trace {
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let mut rng = Pcg64::seeded(cfg.seed);
    let weights = prob.worker_lipschitz();
    engine::run_rule(
        prob,
        IagRule::new(cfg.clone(), prob.d),
        iters,
        cfg.eval_every,
        fstar,
        |_k| Some(vec![rng.categorical(&weights)]),
        pool,
        &EngineOpts::from_env(),
    )
    .trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn one_transmission_per_iteration() {
        let prob = Problem::linear(synthetic::dna_like(3, 60), 5, 0.1);
        let cfg = IagConfig {
            alpha: 1.0 / (2.0 * 5.0 * prob.lipschitz()),
            seed: 1,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 50);
        // M init + 50 rounds
        assert_eq!(t.total_transmissions(), 55);
        assert_eq!(t.total_bits(), (55 * 32 * prob.d) as u64);
    }

    #[test]
    fn converges_with_conservative_step() {
        let prob = Problem::logistic(synthetic::dna_like(3, 60), 5, 0.1);
        // paper: alpha' = alpha/(2ML) style for stability
        let cfg = IagConfig {
            alpha: 1.0 / (2.0 * 5.0 * prob.lipschitz()),
            seed: 3,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 600);
        let errs = t.errors();
        assert!(errs[600] < errs[0] * 0.5, "{} -> {}", errs[0], errs[600]);
    }

    #[test]
    fn sampling_follows_lipschitz() {
        // Workers with larger L_m get picked more — indirectly visible via
        // deterministic seeding: just verify categorical weights order.
        let prob = Problem::linear(synthetic::coord_lipschitz(5), 10, 0.0);
        let w = prob.worker_lipschitz();
        assert!(w[9] > w[0], "worker L ordering violated");
    }
}
