//! NoUnif-IAG — nonuniform sampling of incremental aggregated gradient
//! (Schmidt et al. [57]): one worker per iteration transmits a fresh
//! gradient (chosen with probability ∝ L_m); the server aggregates it with
//! the stale gradients of the others.

use super::gdsec::{fstar_iters, record_pooled};
use super::trace::Trace;
use crate::compress;
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct IagConfig {
    pub alpha: f64,
    pub seed: u64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

pub fn run(prob: &Problem, cfg: &IagConfig, iters: usize) -> Trace {
    run_pooled(prob, cfg, iters, Pool::global())
}

/// NoUnif-IAG. Only one worker computes a fresh gradient per iteration,
/// so unlike the synchronous baselines there is no per-worker fan-out in
/// the steady state; the pool instead parallelizes the two O(M·d) parts —
/// the initialization round (per-worker lanes) and the per-iteration
/// aggregation of all M stored gradients (column blocks, each block
/// summed over workers in ascending order ⇒ bitwise equal to the serial
/// fold for any thread count).
pub fn run_pooled(prob: &Problem, cfg: &IagConfig, iters: usize, pool: &Pool) -> Trace {
    let d = prob.d;
    let m = prob.m();
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let mut trace = Trace::new("NoUnif-IAG", &prob.name, fstar);
    let mut rng = Pcg64::seeded(cfg.seed);
    let weights = prob.worker_lipschitz();
    let mut theta = vec![0.0; d];
    let mut g = vec![0.0; d];
    let mut memory: Vec<Vec<f64>> = vec![vec![0.0; d]; m];
    let mut agg = vec![0.0; d];
    let (mut bits, mut tx, mut entries) = (0u64, 0u64, 0u64);
    record_pooled(&mut trace, prob, &theta, pool, 0, bits, tx, entries);
    // Initialization round: every worker seeds the server memory once
    // (bits counted — the aggregate needs all M gradients before IAG can
    // make its first sensible step). Fanned out per worker.
    {
        let theta = &theta;
        pool.scatter(&mut memory, |w, mem| {
            prob.locals[w].grad(theta, mem);
            for v in mem.iter_mut() {
                *v = *v as f32 as f64;
            }
        });
    }
    bits += (m * compress::dense_bits(d)) as u64;
    tx += m as u64;
    entries += (m * d) as u64;
    for k in 1..=iters {
        let w = rng.categorical(&weights);
        prob.locals[w].grad(&theta, &mut g);
        for i in 0..d {
            memory[w][i] = g[i] as f32 as f64;
        }
        bits += compress::dense_bits(d) as u64;
        tx += 1;
        entries += d as u64;
        sum_memories(&memory, &mut agg, pool);
        linalg::axpy(-cfg.alpha, &agg, &mut theta);
        if k % cfg.eval_every == 0 || k == iters {
            record_pooled(&mut trace, prob, &theta, pool, k, bits, tx, entries);
        }
    }
    trace
}

/// agg = Σ_w memory[w], parallelized over column blocks. Every element is
/// summed over workers in ascending order regardless of which thread owns
/// its block, so the result is bitwise identical to the serial fold.
fn sum_memories(memory: &[Vec<f64>], agg: &mut [f64], pool: &Pool) {
    let d = agg.len();
    if pool.threads() == 1 || d == 0 {
        linalg::zero(agg);
        for mem in memory {
            linalg::axpy(1.0, mem, agg);
        }
        return;
    }
    let chunk = d.div_ceil(pool.threads());
    let mut blocks: Vec<(usize, &mut [f64])> =
        agg.chunks_mut(chunk).enumerate().map(|(b, s)| (b * chunk, s)).collect();
    pool.scatter(&mut blocks, |_, item| {
        let j0 = item.0;
        let block: &mut [f64] = &mut *item.1;
        linalg::zero(block);
        for mem in memory {
            linalg::axpy(1.0, &mem[j0..j0 + block.len()], block);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn one_transmission_per_iteration() {
        let prob = Problem::linear(synthetic::dna_like(3, 60), 5, 0.1);
        let cfg = IagConfig {
            alpha: 1.0 / (2.0 * 5.0 * prob.lipschitz()),
            seed: 1,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 50);
        // M init + 50 rounds
        assert_eq!(t.total_transmissions(), 55);
        assert_eq!(t.total_bits(), (55 * 32 * prob.d) as u64);
    }

    #[test]
    fn converges_with_conservative_step() {
        let prob = Problem::logistic(synthetic::dna_like(3, 60), 5, 0.1);
        // paper: alpha' = alpha/(2ML) style for stability
        let cfg = IagConfig {
            alpha: 1.0 / (2.0 * 5.0 * prob.lipschitz()),
            seed: 3,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 600);
        let errs = t.errors();
        assert!(errs[600] < errs[0] * 0.5, "{} -> {}", errs[0], errs[600]);
    }

    #[test]
    fn sampling_follows_lipschitz() {
        // Workers with larger L_m get picked more — indirectly visible via
        // deterministic seeding: just verify categorical weights order.
        let prob = Problem::linear(synthetic::coord_lipschitz(5), 10, 0.0);
        let w = prob.worker_lipschitz();
        assert!(w[9] > w[0], "worker L ordering violated");
    }
}
