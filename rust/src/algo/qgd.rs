//! Quantized GD (QGD) — QSGD-style unbiased quantization of the full
//! gradient, per the paper's baseline ([30], [56]): 8-bit magnitude +
//! 1 sign bit per non-zero component + 32 bits for the norm.
//!
//! Stochastic rounding draws come from **per-worker** seeded streams
//! (`SplitMix64::child(seed, w)`, the same scheme the SGD extensions
//! use), so the worker fan-out over the [`Pool`] is deterministic and
//! thread-count independent.

use super::gdsec::{fstar_iters, record_pooled};
use super::trace::Trace;
use crate::compress::quantize;
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;
use crate::util::rng::{Pcg64, SplitMix64};

#[derive(Debug, Clone)]
pub struct QgdConfig {
    pub alpha: f64,
    /// Quantization bins (8-bit levels ⇒ up to 255).
    pub s: u8,
    pub seed: u64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

pub fn run(prob: &Problem, cfg: &QgdConfig, iters: usize) -> Trace {
    run_pooled(prob, cfg, iters, Pool::global())
}

/// QGD with per-worker gradient + quantization fanned out over `pool`;
/// dequantized lanes are folded in worker-id order.
pub fn run_pooled(prob: &Problem, cfg: &QgdConfig, iters: usize, pool: &Pool) -> Trace {
    let d = prob.d;
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let mut trace = Trace::new("QGD", &prob.name, fstar);
    let mut theta = vec![0.0; d];
    let mut agg = vec![0.0; d];
    struct Lane {
        g: Vec<f64>,
        dq: Vec<f64>,
        rng: Pcg64,
        q_bits: u64,
        q_entries: u64,
    }
    let mut lanes: Vec<Lane> = (0..prob.m())
        .map(|w| Lane {
            g: vec![0.0; d],
            dq: vec![0.0; d],
            rng: Pcg64::seeded(SplitMix64::child(cfg.seed, w as u64)),
            q_bits: 0,
            q_entries: 0,
        })
        .collect();
    let (mut bits, mut tx, mut entries) = (0u64, 0u64, 0u64);
    record_pooled(&mut trace, prob, &theta, pool, 0, bits, tx, entries);
    for k in 1..=iters {
        {
            let theta = &theta;
            pool.scatter(&mut lanes, |w, lane| {
                prob.locals[w].grad(theta, &mut lane.g);
                let q = quantize::quantize(&lane.g, cfg.s, &mut lane.rng);
                lane.q_bits = quantize::quantized_bits(&q) as u64;
                lane.q_entries = q.idx.len() as u64;
                quantize::dequantize_into(&q, &mut lane.dq);
            });
        }
        linalg::zero(&mut agg);
        for lane in &lanes {
            linalg::axpy(1.0, &lane.dq, &mut agg);
            bits += lane.q_bits;
            tx += 1;
            entries += lane.q_entries;
        }
        linalg::axpy(-cfg.alpha, &agg, &mut theta);
        if k % cfg.eval_every == 0 || k == iters {
            record_pooled(&mut trace, prob, &theta, pool, k, bits, tx, entries);
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn converges_noisily() {
        let prob = Problem::logistic(synthetic::dna_like(2, 80), 3, 0.1);
        let cfg = QgdConfig {
            alpha: 1.0 / prob.lipschitz(),
            s: 255,
            seed: 1,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 300);
        let errs = t.errors();
        assert!(errs[300] < errs[0] * 0.05, "{} -> {}", errs[0], errs[300]);
    }

    #[test]
    fn cheaper_per_round_than_dense_gd() {
        let prob = Problem::linear(synthetic::dna_like(2, 80), 3, 0.1);
        let cfg = QgdConfig {
            alpha: 1.0 / prob.lipschitz(),
            s: 255,
            seed: 2,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 10);
        let gd_bits = (10 * 3 * 32 * prob.d) as u64;
        // 9 bits/component + RLE gaps ≈ 17/32 of dense cost.
        assert!(t.total_bits() < gd_bits * 6 / 10, "{} vs {gd_bits}", t.total_bits());
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = Problem::linear(synthetic::dna_like(2, 40), 2, 0.1);
        let cfg = QgdConfig {
            alpha: 1.0 / prob.lipschitz(),
            s: 100,
            seed: 7,
            eval_every: 1,
            fstar: None,
        };
        let a = run(&prob, &cfg, 20);
        let b = run(&prob, &cfg, 20);
        assert_eq!(a.total_bits(), b.total_bits());
        assert_eq!(a.rows.last().unwrap().fval, b.rows.last().unwrap().fval);
    }
}
