//! Quantized GD (QGD) — QSGD-style unbiased quantization of the full
//! gradient, per the paper's baseline ([30], [56]): 8-bit magnitude +
//! 1 sign bit per non-zero component + 32 bits for the norm.
//!
//! Runs through the unified round [`engine`]. Stochastic rounding draws
//! come from **per-worker** seeded streams (`SplitMix64::child(seed, w)`,
//! the same scheme the SGD extensions use), so the worker fan-out over
//! the pool is deterministic and thread-count independent.

use super::engine::{self, CompressRule, EngineLane, EngineOpts, RoundCtx, Sent};
use super::gdsec::{fstar_iters, ServerState};
use super::trace::Trace;
use crate::compress::quantize;
use crate::objectives::Problem;
use crate::util::pool::Pool;
use crate::util::rng::{Pcg64, SplitMix64};

#[derive(Debug, Clone)]
pub struct QgdConfig {
    pub alpha: f64,
    /// Quantization bins (8-bit levels ⇒ up to 255).
    pub s: u8,
    pub seed: u64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

/// One QGD worker lane: gradient scratch, dequantized wire image, and the
/// worker's private rounding stream.
pub struct QgdLane {
    g: Vec<f64>,
    dq: Vec<f64>,
    rng: Pcg64,
}

/// QSGD quantization rule.
pub struct QgdRule {
    cfg: QgdConfig,
    agg: Vec<f64>,
    /// Dequantized updates parked by a quorum cut; folded ahead of the
    /// fresh lanes by the next apply.
    stale: engine::StalePending,
}

impl QgdRule {
    pub fn new(cfg: QgdConfig, d: usize) -> QgdRule {
        QgdRule { cfg, agg: vec![0.0; d], stale: engine::StalePending::new(d) }
    }
}

impl CompressRule for QgdRule {
    type Lane = QgdLane;

    fn name(&self) -> String {
        "QGD".into()
    }

    fn make_lane(&self, prob: &Problem, w: usize) -> QgdLane {
        QgdLane {
            g: vec![0.0; prob.d],
            dq: vec![0.0; prob.d],
            rng: Pcg64::seeded(SplitMix64::child(self.cfg.seed, w as u64)),
        }
    }

    fn grad_buf<'l>(&self, lane: &'l mut QgdLane) -> &'l mut [f64] {
        &mut lane.g
    }

    fn compress(&self, _ctx: &RoundCtx, _w: usize, lane: &mut QgdLane) -> Option<Sent> {
        let q = quantize::quantize(&lane.g, self.cfg.s, &mut lane.rng);
        let sent = Sent {
            bits: quantize::quantized_bits(&q) as u64,
            entries: q.idx.len() as u64,
        };
        quantize::dequantize_into(&q, &mut lane.dq);
        Some(sent)
    }

    fn apply(
        &mut self,
        _k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<QgdLane>],
        _pool: &Pool,
    ) {
        let staged = self.stale.staged();
        engine::apply_dense_fold(
            self.cfg.alpha,
            staged.into_iter().chain(
                lanes
                    .iter()
                    .filter(|el| el.sent.is_some())
                    .map(|el| el.lane.dq.as_slice()),
            ),
            &mut self.agg,
            &mut server.theta,
        );
        self.stale.consume();
    }

    fn fold_stale(
        &mut self,
        _k: usize,
        _server: &mut ServerState,
        _w: usize,
        lane: &mut QgdLane,
        _age: u32,
    ) {
        // The dequantized wire image of the parked transmission is still
        // in the lane; fold it as if on time, `age` rounds late.
        self.stale.fold(&lane.dq);
    }
}

pub fn run(prob: &Problem, cfg: &QgdConfig, iters: usize) -> Trace {
    run_pooled(prob, cfg, iters, Pool::global())
}

/// QGD through the engine on an explicit pool.
pub fn run_pooled(prob: &Problem, cfg: &QgdConfig, iters: usize, pool: &Pool) -> Trace {
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    engine::run_rule(
        prob,
        QgdRule::new(cfg.clone(), prob.d),
        iters,
        cfg.eval_every,
        fstar,
        |_k| None,
        pool,
        &EngineOpts::from_env(),
    )
    .trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn converges_noisily() {
        let prob = Problem::logistic(synthetic::dna_like(2, 80), 3, 0.1);
        let cfg = QgdConfig {
            alpha: 1.0 / prob.lipschitz(),
            s: 255,
            seed: 1,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 300);
        let errs = t.errors();
        assert!(errs[300] < errs[0] * 0.05, "{} -> {}", errs[0], errs[300]);
    }

    #[test]
    fn cheaper_per_round_than_dense_gd() {
        let prob = Problem::linear(synthetic::dna_like(2, 80), 3, 0.1);
        let cfg = QgdConfig {
            alpha: 1.0 / prob.lipschitz(),
            s: 255,
            seed: 2,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 10);
        let gd_bits = (10 * 3 * 32 * prob.d) as u64;
        // 9 bits/component + RLE gaps ≈ 17/32 of dense cost.
        assert!(t.total_bits() < gd_bits * 6 / 10, "{} vs {gd_bits}", t.total_bits());
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = Problem::linear(synthetic::dna_like(2, 40), 2, 0.1);
        let cfg = QgdConfig {
            alpha: 1.0 / prob.lipschitz(),
            s: 100,
            seed: 7,
            eval_every: 1,
            fstar: None,
        };
        let a = run(&prob, &cfg, 20);
        let b = run(&prob, &cfg, 20);
        assert_eq!(a.total_bits(), b.total_bits());
        assert_eq!(a.rows.last().unwrap().fval, b.rows.last().unwrap().fval);
    }
}
