//! Censoring-based GD (CGD) with RLE — the paper's LAG-style baseline
//! ([48] Chen et al., "LAG: Lazily aggregated gradient").
//!
//! Worker m transmits its **entire** current gradient iff it differs
//! sufficiently from its previously transmitted one:
//! `‖∇f_m(θ^k) − g_last_m‖ > (ξ̃/M)·‖θ^k − θ^{k−1}‖`; otherwise it sends
//! nothing and the server reuses `g_last_m`. Transmitted vectors are
//! RLE-encoded (structural zeros from sparse data are skipped), per the
//! paper's "CGD with RLE" variant.
//!
//! Runs through the unified round [`engine`]: [`CgdRule`] owns the shared
//! per-round threshold, each lane its gradient scratch, wire-update
//! buffer and last-transmitted memory; the server folds the (possibly
//! stale) memories in worker-id order, so the trajectory matches the
//! serial one bit-for-bit at any thread count.

use super::engine::{self, CompressRule, EngineLane, EngineOpts, RoundCtx, Sent};
use super::gdsec::{fstar_iters, ServerState};
use super::trace::Trace;
use crate::compress::{self, SparseUpdate};
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;

#[derive(Debug, Clone)]
pub struct CgdConfig {
    pub alpha: f64,
    /// Censoring threshold ξ̃ (the comparison uses ξ̃/M).
    pub xi: f64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

/// One CGD worker lane.
pub struct CgdLane {
    g: Vec<f64>,
    up: SparseUpdate,
    /// Server-side memory of this worker's last transmitted gradient.
    last: Vec<f64>,
}

/// Whole-gradient censoring rule.
pub struct CgdRule {
    cfg: CgdConfig,
    agg: Vec<f64>,
    /// This round's censor threshold (ξ̃/M)·‖θ^k − θ^{k−1}‖, computed
    /// once in `begin_round` and shared by every lane.
    thresh: f64,
}

impl CgdRule {
    pub fn new(cfg: CgdConfig, d: usize) -> CgdRule {
        CgdRule { cfg, agg: vec![0.0; d], thresh: 0.0 }
    }
}

impl CompressRule for CgdRule {
    type Lane = CgdLane;

    fn name(&self) -> String {
        "CGD".into()
    }

    fn make_lane(&self, prob: &Problem, _w: usize) -> CgdLane {
        CgdLane {
            g: vec![0.0; prob.d],
            up: SparseUpdate::empty(prob.d),
            last: vec![0.0; prob.d],
        }
    }

    fn wants_theta_diff(&self) -> bool {
        true
    }

    fn grad_buf<'l>(&self, lane: &'l mut CgdLane) -> &'l mut [f64] {
        &mut lane.g
    }

    fn begin_round(&mut self, ctx: &RoundCtx) {
        self.thresh = self.cfg.xi / ctx.m as f64 * linalg::nrm2(ctx.theta_diff);
    }

    fn compress(&self, ctx: &RoundCtx, _w: usize, lane: &mut CgdLane) -> Option<Sent> {
        let mut dist_sq = 0.0;
        for (gi, li) in lane.g.iter().zip(&lane.last) {
            let dgi = gi - li;
            dist_sq += dgi * dgi;
        }
        if dist_sq.sqrt() <= self.thresh {
            return None;
        }
        // Transmit the full gradient, RLE-coding structural zeros; the
        // server stores the f32-rounded wire values.
        lane.up.gather_from(&lane.g);
        linalg::zero(&mut lane.last);
        lane.up.add_into(&mut lane.last);
        Some(Sent {
            bits: compress::wire_bits(&lane.up, ctx.wire) as u64,
            entries: lane.up.nnz() as u64,
        })
    }

    fn apply(
        &mut self,
        _k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<CgdLane>],
        _pool: &Pool,
    ) {
        // The θ update folds the (possibly stale) gradient memories of
        // ALL workers, in worker-id order.
        linalg::zero(&mut self.agg);
        for el in lanes {
            linalg::axpy(1.0, &el.lane.last, &mut self.agg);
        }
        server.theta_prev.copy_from_slice(&server.theta);
        linalg::axpy(-self.cfg.alpha, &self.agg, &mut server.theta);
    }

    fn defers_late(&self) -> bool {
        // CGD's LAG-style apply folds EVERY worker's `last` memory each
        // round, transmitted or not, and `compress` refreshes that
        // memory in place — a "late" transmission therefore lands in the
        // CURRENT aggregation regardless. Quorum cuts cannot defer it,
        // so the engine neither parks these lanes nor counts stale
        // folds.
        false
    }

    fn fold_stale(
        &mut self,
        _k: usize,
        _server: &mut ServerState,
        _w: usize,
        _lane: &mut CgdLane,
        _age: u32,
    ) {
        // Unreachable while `defers_late` is false; nothing to stage —
        // the server-side memory IS the fold.
    }
}

pub fn run(prob: &Problem, cfg: &CgdConfig, iters: usize) -> Trace {
    run_pooled(prob, cfg, iters, Pool::global())
}

/// CGD through the engine on an explicit pool.
pub fn run_pooled(prob: &Problem, cfg: &CgdConfig, iters: usize, pool: &Pool) -> Trace {
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    engine::run_rule(
        prob,
        CgdRule::new(cfg.clone(), prob.d),
        iters,
        cfg.eval_every,
        fstar,
        |_k| None,
        pool,
        &EngineOpts::from_env(),
    )
    .trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn xi_zero_equals_gd_trajectory() {
        let prob = Problem::logistic(synthetic::dna_like(7, 60), 3, 0.1);
        let alpha = 1.0 / prob.lipschitz();
        let cgd = run(&prob, &CgdConfig { alpha, xi: 0.0, eval_every: 1, fstar: None }, 50);
        let gd = super::super::gd::run(
            &prob,
            &super::super::gd::GdConfig { alpha, eval_every: 1, fstar: None },
            50,
        );
        for (a, b) in cgd.rows.iter().zip(gd.rows.iter()) {
            assert!((a.fval - b.fval).abs() < 1e-9 * b.fval.abs().max(1.0));
        }
        // CGD transmits every round at xi=0 (first diff always > 0 after
        // round 1 gradient is nonzero).
        assert_eq!(cgd.total_transmissions(), 150);
    }

    #[test]
    fn censoring_reduces_transmissions() {
        let prob = Problem::logistic(synthetic::dna_like(7, 60), 3, 0.1);
        let alpha = 1.0 / prob.lipschitz();
        let t = run(&prob, &CgdConfig { alpha, xi: 3.0, eval_every: 1, fstar: None }, 200);
        assert!(
            t.total_transmissions() < 600,
            "no censoring happened: {}",
            t.total_transmissions()
        );
        assert!(t.final_error() < 1e-3, "diverged: {}", t.final_error());
    }
}
