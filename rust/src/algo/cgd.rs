//! Censoring-based GD (CGD) with RLE — the paper's LAG-style baseline
//! ([48] Chen et al., "LAG: Lazily aggregated gradient").
//!
//! Worker m transmits its **entire** current gradient iff it differs
//! sufficiently from its previously transmitted one:
//! `‖∇f_m(θ^k) − g_last_m‖ > (ξ̃/M)·‖θ^k − θ^{k−1}‖`; otherwise it sends
//! nothing and the server reuses `g_last_m`. Transmitted vectors are
//! RLE-encoded (structural zeros from sparse data are skipped), per the
//! paper's "CGD with RLE" variant.

use super::gdsec::{fstar_iters, record_pooled};
use super::trace::Trace;
use crate::compress::{self, SparseUpdate};
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;

#[derive(Debug, Clone)]
pub struct CgdConfig {
    pub alpha: f64,
    /// Censoring threshold ξ̃ (the comparison uses ξ̃/M).
    pub xi: f64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

pub fn run(prob: &Problem, cfg: &CgdConfig, iters: usize) -> Trace {
    run_pooled(prob, cfg, iters, Pool::global())
}

/// CGD with the per-worker gradient + censor test + RLE cost fanned out
/// over `pool`. Each lane owns its gradient scratch, wire-update buffer
/// and last-transmitted memory; the server folds the (possibly stale)
/// memories in worker-id order, so the trajectory matches the serial one
/// bit-for-bit.
pub fn run_pooled(prob: &Problem, cfg: &CgdConfig, iters: usize, pool: &Pool) -> Trace {
    let d = prob.d;
    let m = prob.m();
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let mut trace = Trace::new("CGD", &prob.name, fstar);
    let mut theta = vec![0.0; d];
    let mut theta_prev = vec![0.0; d];
    let mut diff = vec![0.0; d];
    let mut agg = vec![0.0; d];
    struct Lane {
        g: Vec<f64>,
        up: SparseUpdate,
        /// Server-side memory of this worker's last transmitted gradient.
        last: Vec<f64>,
        sent_bits: u64,
        sent_entries: u64,
        sent: bool,
    }
    let mut lanes: Vec<Lane> = (0..m)
        .map(|_| Lane {
            g: vec![0.0; d],
            up: SparseUpdate::empty(d),
            last: vec![0.0; d],
            sent_bits: 0,
            sent_entries: 0,
            sent: false,
        })
        .collect();
    let (mut bits, mut tx, mut entries) = (0u64, 0u64, 0u64);
    record_pooled(&mut trace, prob, &theta, pool, 0, bits, tx, entries);
    for k in 1..=iters {
        linalg::sub(&theta, &theta_prev, &mut diff);
        let thresh = cfg.xi / m as f64 * linalg::nrm2(&diff);
        {
            let theta = &theta;
            pool.scatter(&mut lanes, |w, lane| {
                lane.sent = false;
                prob.locals[w].grad(theta, &mut lane.g);
                let mut dist_sq = 0.0;
                for (gi, li) in lane.g.iter().zip(&lane.last) {
                    let dgi = gi - li;
                    dist_sq += dgi * dgi;
                }
                if dist_sq.sqrt() > thresh {
                    // Transmit the full gradient, RLE-coding structural
                    // zeros; the server stores the f32-rounded wire values.
                    lane.up.gather_from(&lane.g);
                    lane.sent_bits = compress::sparse_bits(&lane.up) as u64;
                    lane.sent_entries = lane.up.nnz() as u64;
                    lane.sent = true;
                    linalg::zero(&mut lane.last);
                    lane.up.add_into(&mut lane.last);
                }
            });
        }
        // Deterministic fold: bit accounting and the θ update from the
        // (possibly stale) gradient memories, in worker-id order.
        for lane in lanes.iter().filter(|l| l.sent) {
            bits += lane.sent_bits;
            tx += 1;
            entries += lane.sent_entries;
        }
        linalg::zero(&mut agg);
        for lane in &lanes {
            linalg::axpy(1.0, &lane.last, &mut agg);
        }
        theta_prev.copy_from_slice(&theta);
        linalg::axpy(-cfg.alpha, &agg, &mut theta);
        if k % cfg.eval_every == 0 || k == iters {
            record_pooled(&mut trace, prob, &theta, pool, k, bits, tx, entries);
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn xi_zero_equals_gd_trajectory() {
        let prob = Problem::logistic(synthetic::dna_like(7, 60), 3, 0.1);
        let alpha = 1.0 / prob.lipschitz();
        let cgd = run(&prob, &CgdConfig { alpha, xi: 0.0, eval_every: 1, fstar: None }, 50);
        let gd = super::super::gd::run(
            &prob,
            &super::super::gd::GdConfig { alpha, eval_every: 1, fstar: None },
            50,
        );
        for (a, b) in cgd.rows.iter().zip(gd.rows.iter()) {
            assert!((a.fval - b.fval).abs() < 1e-9 * b.fval.abs().max(1.0));
        }
        // CGD transmits every round at xi=0 (first diff always > 0 after
        // round 1 gradient is nonzero).
        assert_eq!(cgd.total_transmissions(), 150);
    }

    #[test]
    fn censoring_reduces_transmissions() {
        let prob = Problem::logistic(synthetic::dna_like(7, 60), 3, 0.1);
        let alpha = 1.0 / prob.lipschitz();
        let t = run(&prob, &CgdConfig { alpha, xi: 3.0, eval_every: 1, fstar: None }, 200);
        assert!(
            t.total_transmissions() < 600,
            "no censoring happened: {}",
            t.total_transmissions()
        );
        assert!(t.final_error() < 1e-3, "diverged: {}", t.final_error());
    }
}
