//! Censoring-based GD (CGD) with RLE — the paper's LAG-style baseline
//! ([48] Chen et al., "LAG: Lazily aggregated gradient").
//!
//! Worker m transmits its **entire** current gradient iff it differs
//! sufficiently from its previously transmitted one:
//! `‖∇f_m(θ^k) − g_last_m‖ > (ξ̃/M)·‖θ^k − θ^{k−1}‖`; otherwise it sends
//! nothing and the server reuses `g_last_m`. Transmitted vectors are
//! RLE-encoded (structural zeros from sparse data are skipped), per the
//! paper's "CGD with RLE" variant.

use super::gdsec::{fstar_iters, record};
use super::trace::Trace;
use crate::compress::{self, SparseUpdate};
use crate::linalg;
use crate::objectives::Problem;

#[derive(Debug, Clone)]
pub struct CgdConfig {
    pub alpha: f64,
    /// Censoring threshold ξ̃ (the comparison uses ξ̃/M).
    pub xi: f64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

pub fn run(prob: &Problem, cfg: &CgdConfig, iters: usize) -> Trace {
    let d = prob.d;
    let m = prob.m();
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let mut trace = Trace::new("CGD", &prob.name, fstar);
    let mut theta = vec![0.0; d];
    let mut theta_prev = vec![0.0; d];
    let mut g = vec![0.0; d];
    let mut diff = vec![0.0; d];
    // Server-side memory of each worker's last transmitted gradient.
    let mut last: Vec<Vec<f64>> = vec![vec![0.0; d]; m];
    let (mut bits, mut tx, mut entries) = (0u64, 0u64, 0u64);
    record(&mut trace, prob, &theta, 0, bits, tx, entries);
    for k in 1..=iters {
        linalg::sub(&theta, &theta_prev, &mut diff);
        let thresh = cfg.xi / m as f64 * linalg::nrm2(&diff);
        for (w, l) in prob.locals.iter().enumerate() {
            l.grad(&theta, &mut g);
            let mut dist_sq = 0.0;
            for i in 0..d {
                let dgi = g[i] - last[w][i];
                dist_sq += dgi * dgi;
            }
            if dist_sq.sqrt() > thresh {
                // Transmit the full gradient, RLE-coding structural zeros.
                let up = SparseUpdate::from_dense(&g);
                bits += compress::sparse_bits(&up) as u64;
                tx += 1;
                entries += up.nnz() as u64;
                // Server stores the f32-rounded wire values.
                let dense = up.to_dense();
                last[w].copy_from_slice(&dense);
            }
        }
        // θ update from the (possibly stale) gradient memory.
        theta_prev.copy_from_slice(&theta);
        for i in 0..d {
            let total: f64 = last.iter().map(|lw| lw[i]).sum();
            theta[i] -= cfg.alpha * total;
        }
        if k % cfg.eval_every == 0 || k == iters {
            record(&mut trace, prob, &theta, k, bits, tx, entries);
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn xi_zero_equals_gd_trajectory() {
        let prob = Problem::logistic(synthetic::dna_like(7, 60), 3, 0.1);
        let alpha = 1.0 / prob.lipschitz();
        let cgd = run(&prob, &CgdConfig { alpha, xi: 0.0, eval_every: 1, fstar: None }, 50);
        let gd = super::super::gd::run(
            &prob,
            &super::super::gd::GdConfig { alpha, eval_every: 1, fstar: None },
            50,
        );
        for (a, b) in cgd.rows.iter().zip(gd.rows.iter()) {
            assert!((a.fval - b.fval).abs() < 1e-9 * b.fval.abs().max(1.0));
        }
        // CGD transmits every round at xi=0 (first diff always > 0 after
        // round 1 gradient is nonzero).
        assert_eq!(cgd.total_transmissions(), 150);
    }

    #[test]
    fn censoring_reduces_transmissions() {
        let prob = Problem::logistic(synthetic::dna_like(7, 60), 3, 0.1);
        let alpha = 1.0 / prob.lipschitz();
        let t = run(&prob, &CgdConfig { alpha, xi: 3.0, eval_every: 1, fstar: None }, 200);
        assert!(
            t.total_transmissions() < 600,
            "no censoring happened: {}",
            t.total_transmissions()
        );
        assert!(t.final_error() < 1e-3, "diverged: {}", t.final_error());
    }
}
