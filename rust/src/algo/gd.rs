//! Classical distributed GD — the paper's baseline. Every worker
//! transmits its full gradient every iteration (32·d bits each).

use super::gdsec::{fstar_iters, record};
use super::trace::Trace;
use crate::compress;
use crate::linalg;
use crate::objectives::Problem;

#[derive(Debug, Clone)]
pub struct GdConfig {
    pub alpha: f64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

/// Run distributed GD for `iters` iterations.
pub fn run(prob: &Problem, cfg: &GdConfig, iters: usize) -> Trace {
    run_scheduled(prob, cfg, iters, |_k| None)
}

/// GD with a participation schedule (Fig 8's "GD with half transmissions"):
/// only active workers compute + transmit; the server aggregates what it
/// receives (no rescaling, matching the paper's setup).
pub fn run_scheduled<F>(prob: &Problem, cfg: &GdConfig, iters: usize, mut active: F) -> Trace
where
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    let d = prob.d;
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let mut trace = Trace::new("GD", &prob.name, fstar);
    let mut theta = vec![0.0; d];
    let mut g = vec![0.0; d];
    let mut agg = vec![0.0; d];
    let (mut bits, mut tx, mut entries) = (0u64, 0u64, 0u64);
    record(&mut trace, prob, &theta, 0, bits, tx, entries);
    for k in 1..=iters {
        let act = active(k);
        linalg::zero(&mut agg);
        for (w, l) in prob.locals.iter().enumerate() {
            if let Some(set) = &act {
                if !set.contains(&w) {
                    continue;
                }
            }
            l.grad(&theta, &mut g);
            // Wire: dense f32 vector, 32·d bits.
            for i in 0..d {
                agg[i] += g[i] as f32 as f64;
            }
            bits += compress::dense_bits(d) as u64;
            tx += 1;
            entries += d as u64;
        }
        linalg::axpy(-cfg.alpha, &agg, &mut theta);
        if k % cfg.eval_every == 0 || k == iters {
            record(&mut trace, prob, &theta, k, bits, tx, entries);
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn linear_convergence_strongly_convex() {
        // err_{k+1}/err_k should be ~constant < 1 for strongly-convex
        // logistic regression with α = 1/L.
        let prob = Problem::logistic(synthetic::dna_like(1, 80), 2, 0.1);
        let cfg = GdConfig { alpha: 1.0 / prob.lipschitz(), eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 200);
        let errs = t.errors();
        assert!(errs[199] < errs[0] * 1e-3, "not converging: {} -> {}", errs[0], errs[199]);
        // monotone decrease
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "objective increased");
        }
    }

    #[test]
    fn bit_accounting_exact() {
        let prob = Problem::linear(synthetic::dna_like(2, 50), 5, 0.1);
        let cfg = GdConfig { alpha: 1.0 / prob.lipschitz(), eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 10);
        assert_eq!(t.total_bits(), (10 * 5 * 32 * prob.d) as u64);
        assert_eq!(t.total_transmissions(), 50);
    }

    #[test]
    fn half_participation_slower() {
        let prob = Problem::linear(synthetic::dna_like(4, 100), 4, 0.1);
        let cfg = GdConfig { alpha: 1.0 / prob.lipschitz(), eval_every: 1, fstar: None };
        let full = run(&prob, &cfg, 150);
        let half = run_scheduled(&prob, &cfg, 150, |k| {
            Some(if k % 2 == 0 { vec![0, 1] } else { vec![2, 3] })
        });
        assert!(half.final_error() >= full.final_error() * 0.5);
        assert!(half.total_bits() < full.total_bits());
    }
}
