//! Classical distributed GD — the paper's baseline. Every worker
//! transmits its full gradient every iteration (32·d bits each).
//!
//! Worker gradients fan out over the [`Pool`]; each lane owns a reusable
//! gradient buffer and rounds it to the f32 wire precision in-thread, and
//! the server folds lanes in worker-id order — bit-for-bit identical to
//! the serial trajectory for any thread count.

use super::gdsec::{fstar_iters, record_pooled};
use super::trace::Trace;
use crate::compress;
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;

#[derive(Debug, Clone)]
pub struct GdConfig {
    pub alpha: f64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

/// Run distributed GD for `iters` iterations.
pub fn run(prob: &Problem, cfg: &GdConfig, iters: usize) -> Trace {
    run_scheduled(prob, cfg, iters, |_k| None)
}

/// [`run`] with a participation schedule (threads from the shared [`Pool::global`]).
pub fn run_scheduled<F>(prob: &Problem, cfg: &GdConfig, iters: usize, active: F) -> Trace
where
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    run_scheduled_pooled(prob, cfg, iters, active, Pool::global())
}

/// GD with a participation schedule (Fig 8's "GD with half transmissions"):
/// only active workers compute + transmit; the server aggregates what it
/// receives (no rescaling, matching the paper's setup).
pub fn run_scheduled_pooled<F>(
    prob: &Problem,
    cfg: &GdConfig,
    iters: usize,
    mut active: F,
    pool: &Pool,
) -> Trace
where
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    let d = prob.d;
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let mut trace = Trace::new("GD", &prob.name, fstar);
    let mut theta = vec![0.0; d];
    let mut agg = vec![0.0; d];
    struct Lane {
        g: Vec<f64>,
        active: bool,
    }
    let mut lanes: Vec<Lane> =
        (0..prob.m()).map(|_| Lane { g: vec![0.0; d], active: true }).collect();
    let (mut bits, mut tx, mut entries) = (0u64, 0u64, 0u64);
    record_pooled(&mut trace, prob, &theta, pool, 0, bits, tx, entries);
    for k in 1..=iters {
        let act = active(k);
        for (w, lane) in lanes.iter_mut().enumerate() {
            lane.active = act.as_ref().map_or(true, |set| set.contains(&w));
        }
        {
            let theta = &theta;
            pool.scatter(&mut lanes, |w, lane| {
                if !lane.active {
                    return;
                }
                prob.locals[w].grad(theta, &mut lane.g);
                // Wire: dense f32 vector, 32·d bits — round in-thread.
                for v in lane.g.iter_mut() {
                    *v = *v as f32 as f64;
                }
            });
        }
        linalg::zero(&mut agg);
        for lane in lanes.iter().filter(|l| l.active) {
            linalg::axpy(1.0, &lane.g, &mut agg);
            bits += compress::dense_bits(d) as u64;
            tx += 1;
            entries += d as u64;
        }
        linalg::axpy(-cfg.alpha, &agg, &mut theta);
        if k % cfg.eval_every == 0 || k == iters {
            record_pooled(&mut trace, prob, &theta, pool, k, bits, tx, entries);
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn linear_convergence_strongly_convex() {
        // err_{k+1}/err_k should be ~constant < 1 for strongly-convex
        // logistic regression with α = 1/L.
        let prob = Problem::logistic(synthetic::dna_like(1, 80), 2, 0.1);
        let cfg = GdConfig { alpha: 1.0 / prob.lipschitz(), eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 200);
        let errs = t.errors();
        assert!(errs[199] < errs[0] * 1e-3, "not converging: {} -> {}", errs[0], errs[199]);
        // monotone decrease
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "objective increased");
        }
    }

    #[test]
    fn bit_accounting_exact() {
        let prob = Problem::linear(synthetic::dna_like(2, 50), 5, 0.1);
        let cfg = GdConfig { alpha: 1.0 / prob.lipschitz(), eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 10);
        assert_eq!(t.total_bits(), (10 * 5 * 32 * prob.d) as u64);
        assert_eq!(t.total_transmissions(), 50);
    }

    #[test]
    fn half_participation_slower() {
        let prob = Problem::linear(synthetic::dna_like(4, 100), 4, 0.1);
        let cfg = GdConfig { alpha: 1.0 / prob.lipschitz(), eval_every: 1, fstar: None };
        let full = run(&prob, &cfg, 150);
        let half = run_scheduled(&prob, &cfg, 150, |k| {
            Some(if k % 2 == 0 { vec![0, 1] } else { vec![2, 3] })
        });
        assert!(half.final_error() >= full.final_error() * 0.5);
        assert!(half.total_bits() < full.total_bits());
    }
}
