//! Classical distributed GD — the paper's baseline. Every worker
//! transmits its full gradient every iteration (32·d bits each).
//!
//! Runs through the unified round [`engine`]: [`GdRule`] rounds each
//! lane's gradient to the f32 wire precision in-thread and the server
//! folds lanes in worker-id order — bit-for-bit identical to the serial
//! trajectory for any thread count.

use super::engine::{self, CompressRule, EngineLane, EngineOpts, RoundCtx, Sent};
use super::gdsec::{fstar_iters, ServerState};
use super::trace::Trace;
use crate::compress;
use crate::objectives::Problem;
use crate::util::pool::Pool;

#[derive(Debug, Clone)]
pub struct GdConfig {
    pub alpha: f64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

/// One GD worker lane: the reusable gradient buffer.
pub struct GdLane {
    g: Vec<f64>,
}

/// Dense full-gradient "compression": f32 wire rounding only.
pub struct GdRule {
    cfg: GdConfig,
    agg: Vec<f64>,
    /// Gradients parked by a quorum cut; the next apply folds the staged
    /// sum ahead of the fresh lanes.
    stale: engine::StalePending,
}

impl GdRule {
    pub fn new(cfg: GdConfig, d: usize) -> GdRule {
        GdRule { cfg, agg: vec![0.0; d], stale: engine::StalePending::new(d) }
    }
}

impl CompressRule for GdRule {
    type Lane = GdLane;

    fn name(&self) -> String {
        "GD".into()
    }

    fn make_lane(&self, prob: &Problem, _w: usize) -> GdLane {
        GdLane { g: vec![0.0; prob.d] }
    }

    fn grad_buf<'l>(&self, lane: &'l mut GdLane) -> &'l mut [f64] {
        &mut lane.g
    }

    fn compress(&self, _ctx: &RoundCtx, _w: usize, lane: &mut GdLane) -> Option<Sent> {
        // Wire: dense f32 vector, 32·d bits — round in-thread.
        for v in lane.g.iter_mut() {
            *v = *v as f32 as f64;
        }
        let d = lane.g.len();
        Some(Sent { bits: compress::dense_bits(d) as u64, entries: d as u64 })
    }

    fn apply(
        &mut self,
        _k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<GdLane>],
        _pool: &Pool,
    ) {
        // Stale-first fold order: the staged late gradients, then this
        // round's lanes in worker-id order. The synchronous path never
        // stages anything, so its fold sequence — and every bit of the
        // trajectory — is unchanged.
        let staged = self.stale.staged();
        engine::apply_dense_fold(
            self.cfg.alpha,
            staged
                .into_iter()
                .chain(lanes.iter().filter(|el| el.sent.is_some()).map(|el| el.lane.g.as_slice())),
            &mut self.agg,
            &mut server.theta,
        );
        self.stale.consume();
    }

    fn fold_stale(
        &mut self,
        _k: usize,
        _server: &mut ServerState,
        _w: usize,
        lane: &mut GdLane,
        _age: u32,
    ) {
        self.stale.fold(&lane.g);
    }
}

/// Run distributed GD for `iters` iterations.
pub fn run(prob: &Problem, cfg: &GdConfig, iters: usize) -> Trace {
    run_scheduled(prob, cfg, iters, |_k| None)
}

/// [`run`] with a participation schedule (threads from the shared [`Pool::global`]).
pub fn run_scheduled<F>(prob: &Problem, cfg: &GdConfig, iters: usize, active: F) -> Trace
where
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    run_scheduled_pooled(prob, cfg, iters, active, Pool::global())
}

/// GD with a participation schedule (Fig 8's "GD with half transmissions"):
/// only active workers compute + transmit; the server aggregates what it
/// receives (no rescaling, matching the paper's setup).
pub fn run_scheduled_pooled<F>(
    prob: &Problem,
    cfg: &GdConfig,
    iters: usize,
    active: F,
    pool: &Pool,
) -> Trace
where
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    engine::run_rule(
        prob,
        GdRule::new(cfg.clone(), prob.d),
        iters,
        cfg.eval_every,
        fstar,
        active,
        pool,
        &EngineOpts::from_env(),
    )
    .trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn linear_convergence_strongly_convex() {
        // err_{k+1}/err_k should be ~constant < 1 for strongly-convex
        // logistic regression with α = 1/L.
        let prob = Problem::logistic(synthetic::dna_like(1, 80), 2, 0.1);
        let cfg = GdConfig { alpha: 1.0 / prob.lipschitz(), eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 200);
        let errs = t.errors();
        assert!(errs[199] < errs[0] * 1e-3, "not converging: {} -> {}", errs[0], errs[199]);
        // monotone decrease
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "objective increased");
        }
    }

    #[test]
    fn bit_accounting_exact() {
        let prob = Problem::linear(synthetic::dna_like(2, 50), 5, 0.1);
        let cfg = GdConfig { alpha: 1.0 / prob.lipschitz(), eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 10);
        assert_eq!(t.total_bits(), (10 * 5 * 32 * prob.d) as u64);
        assert_eq!(t.total_transmissions(), 50);
    }

    #[test]
    fn half_participation_slower() {
        let prob = Problem::linear(synthetic::dna_like(4, 100), 4, 0.1);
        let cfg = GdConfig { alpha: 1.0 / prob.lipschitz(), eval_every: 1, fstar: None };
        let full = run(&prob, &cfg, 150);
        let half = run_scheduled(&prob, &cfg, 150, |k| {
            Some(if k % 2 == 0 { vec![0, 1] } else { vec![2, 3] })
        });
        assert!(half.final_error() >= full.final_error() * 0.5);
        assert!(half.total_bits() < full.total_bits());
    }
}
