//! Algorithm implementations: GD-SEC (the paper's contribution) and every
//! baseline from the evaluation section, all emitting [`trace::Trace`]
//! rows with byte-exact uplink bit accounting.
//!
//! Every method runs through the unified round [`engine`] — one generic
//! trainer loop with nested (worker × nnz-balanced row-block) pool
//! parallelism — and each module below is just its configuration plus a
//! [`engine::CompressRule`] implementation:
//!
//! | Module | Algorithm | Paper role |
//! |---|---|---|
//! | [`gdsec`] | GD-SEC (+ GD-SOEC / no-state-variable ablations) | contribution |
//! | [`gd`] | classical distributed GD | baseline |
//! | [`cgd`] | censoring GD (LAG-style) with RLE | baseline |
//! | [`topj`] | top-j + error correction, decreasing step | baseline |
//! | [`qgd`] | quantized GD (QSGD quantizer) | baseline |
//! | [`iag`] | NoUnif-IAG | baseline |
//! | [`sgdsec`] | SGD, SGD-SEC, QSGD-SEC | extensions (§IV-G) |

pub mod cgd;
pub mod engine;
pub mod gd;
pub mod gdsec;
pub mod iag;
pub mod qgd;
pub mod sgdsec;
pub mod topj;
pub mod trace;

/// Canonical list of algorithm names the CLI accepts.
pub const ALGORITHMS: &[&str] =
    &["gd", "gdsec", "gdsoec", "cgd", "topj", "qgd", "iag", "sgd", "sgdsec", "qsgdsec"];
