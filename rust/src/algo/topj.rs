//! Top-j sparsification with error correction (Stich et al. [35]) — the
//! fixed-budget baseline. Each worker keeps the j largest-|·| components
//! of its error-corrected gradient, transmits them (RLE-coded indices),
//! and accumulates the residual. Converges only with a decreasing step
//! size `α_k = γ₀(1 + γ₀λk)^{-1}` (paper §IV), which we use.

use super::gdsec::{fstar_iters, record_pooled};
use super::trace::Trace;
use crate::compress::{self, topj, SparseUpdate};
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;

#[derive(Debug, Clone)]
pub struct TopJConfig {
    /// Components kept per worker per iteration.
    pub j: usize,
    /// Step schedule α_k = gamma0 / (1 + gamma0·lambda·k).
    pub gamma0: f64,
    pub lambda: f64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

pub fn run(prob: &Problem, cfg: &TopJConfig, iters: usize) -> Trace {
    run_pooled(prob, cfg, iters, Pool::global())
}

/// Top-j with the per-worker gradient + selection + error-memory update
/// fanned out over `pool`; lane updates are folded into the aggregate in
/// worker-id order (bit-for-bit equal to the serial trajectory).
pub fn run_pooled(prob: &Problem, cfg: &TopJConfig, iters: usize, pool: &Pool) -> Trace {
    let d = prob.d;
    let m = prob.m();
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let mut trace = Trace::new(&format!("top-{}", cfg.j), &prob.name, fstar);
    let mut theta = vec![0.0; d];
    let mut agg = vec![0.0; d];
    struct Lane {
        g: Vec<f64>,
        delta: Vec<f64>,
        err: Vec<f64>,
        up: SparseUpdate,
    }
    let mut lanes: Vec<Lane> = (0..m)
        .map(|_| Lane {
            g: vec![0.0; d],
            delta: vec![0.0; d],
            err: vec![0.0; d],
            up: SparseUpdate::empty(d),
        })
        .collect();
    let (mut bits, mut tx, mut entries) = (0u64, 0u64, 0u64);
    record_pooled(&mut trace, prob, &theta, pool, 0, bits, tx, entries);
    for k in 1..=iters {
        let alpha_k = cfg.gamma0 / (1.0 + cfg.gamma0 * cfg.lambda * k as f64);
        {
            let theta = &theta;
            pool.scatter(&mut lanes, |w, lane| {
                prob.locals[w].grad(theta, &mut lane.g);
                for i in 0..d {
                    lane.delta[i] = lane.g[i] + lane.err[i];
                }
                topj::top_j_update_into(&lane.delta, cfg.j, &mut lane.up);
                // error memory = residual (transmitted values f32-rounded)
                lane.err.copy_from_slice(&lane.delta);
                for t in 0..lane.up.idx.len() {
                    let i = lane.up.idx[t] as usize;
                    lane.err[i] = lane.delta[i] - lane.up.val[t] as f64;
                }
            });
        }
        linalg::zero(&mut agg);
        for lane in &lanes {
            lane.up.add_into(&mut agg);
            if lane.up.nnz() > 0 {
                bits += compress::sparse_bits(&lane.up) as u64;
                tx += 1;
                entries += lane.up.nnz() as u64;
            }
        }
        linalg::axpy(-alpha_k, &agg, &mut theta);
        if k % cfg.eval_every == 0 || k == iters {
            record_pooled(&mut trace, prob, &theta, pool, k, bits, tx, entries);
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn fixed_budget_bits() {
        let prob = Problem::linear(synthetic::dna_like(5, 60), 3, 0.1);
        let cfg = TopJConfig { j: 10, gamma0: 0.1, lambda: 0.1, eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 20);
        assert_eq!(t.total_transmissions(), 60);
        let last = t.rows.last().unwrap();
        assert_eq!(last.entries, 20 * 3 * 10);
    }

    #[test]
    fn makes_progress() {
        let prob = Problem::linear(synthetic::dna_like(5, 200), 5, 0.01);
        let l = prob.lipschitz();
        let cfg = TopJConfig { j: 40, gamma0: 1.0 / l, lambda: 0.01, eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 300);
        let errs = t.errors();
        assert!(errs[300] < errs[0] * 0.2, "{} -> {}", errs[0], errs[300]);
    }

    #[test]
    fn j_equals_d_close_to_gd_first_step() {
        let prob = Problem::linear(synthetic::dna_like(5, 40), 2, 0.1);
        let l = prob.lipschitz();
        let cfg = TopJConfig {
            j: prob.d,
            gamma0: 1.0 / l,
            lambda: 0.0,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 5);
        let gd_cfg = super::super::gd::GdConfig { alpha: 1.0 / l, eval_every: 1, fstar: None };
        let gd = super::super::gd::run(&prob, &gd_cfg, 5);
        // With j=d and lambda=0 (constant step), trajectories agree to f32
        // rounding.
        for (a, b) in t.rows.iter().zip(gd.rows.iter()) {
            assert!((a.fval - b.fval).abs() < 1e-6 * b.fval.abs().max(1.0));
        }
    }
}
