//! Top-j sparsification with error correction (Stich et al. [35]) — the
//! fixed-budget baseline. Each worker keeps the j largest-|·| components
//! of its error-corrected gradient, transmits them (RLE-coded indices),
//! and accumulates the residual. Converges only with a decreasing step
//! size `α_k = γ₀(1 + γ₀λk)^{-1}` (paper §IV), which we use.
//!
//! Runs through the unified round [`engine`]; lane updates fold into the
//! aggregate in worker-id order (bit-for-bit equal to the serial
//! trajectory at any thread count).

use super::engine::{self, CompressRule, EngineLane, EngineOpts, RoundCtx, Sent};
use super::gdsec::{fstar_iters, ServerState};
use super::trace::Trace;
use crate::compress::{self, topj, SparseUpdate};
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;

#[derive(Debug, Clone)]
pub struct TopJConfig {
    /// Components kept per worker per iteration.
    pub j: usize,
    /// Step schedule α_k = gamma0 / (1 + gamma0·lambda·k).
    pub gamma0: f64,
    pub lambda: f64,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

impl TopJConfig {
    fn alpha(&self, k: usize) -> f64 {
        self.gamma0 / (1.0 + self.gamma0 * self.lambda * k as f64)
    }
}

/// One top-j worker lane: gradient scratch, error-corrected delta, error
/// memory, reusable wire update.
pub struct TopJLane {
    g: Vec<f64>,
    delta: Vec<f64>,
    err: Vec<f64>,
    up: SparseUpdate,
}

/// Fixed-budget top-j selection rule with error correction.
pub struct TopJRule {
    cfg: TopJConfig,
    agg: Vec<f64>,
    /// Sparse updates parked by a quorum cut, staged dense; folded
    /// ahead of the fresh lanes by the next apply (the values already
    /// left the workers' error memories, so dropping them would lose
    /// them for good).
    stale: engine::StalePending,
}

impl TopJRule {
    pub fn new(cfg: TopJConfig, d: usize) -> TopJRule {
        TopJRule { cfg, agg: vec![0.0; d], stale: engine::StalePending::new(d) }
    }
}

impl CompressRule for TopJRule {
    type Lane = TopJLane;

    fn name(&self) -> String {
        format!("top-{}", self.cfg.j)
    }

    fn make_lane(&self, prob: &Problem, _w: usize) -> TopJLane {
        TopJLane {
            g: vec![0.0; prob.d],
            delta: vec![0.0; prob.d],
            err: vec![0.0; prob.d],
            up: SparseUpdate::empty(prob.d),
        }
    }

    fn grad_buf<'l>(&self, lane: &'l mut TopJLane) -> &'l mut [f64] {
        &mut lane.g
    }

    fn compress(&self, ctx: &RoundCtx, _w: usize, lane: &mut TopJLane) -> Option<Sent> {
        let d = lane.g.len();
        for i in 0..d {
            lane.delta[i] = lane.g[i] + lane.err[i];
        }
        topj::top_j_update_into(&lane.delta, self.cfg.j, &mut lane.up);
        // error memory = residual (transmitted values f32-rounded)
        lane.err.copy_from_slice(&lane.delta);
        for t in 0..lane.up.idx.len() {
            let i = lane.up.idx[t] as usize;
            lane.err[i] = lane.delta[i] - lane.up.val[t] as f64;
        }
        if lane.up.nnz() == 0 {
            return None;
        }
        Some(Sent {
            bits: compress::wire_bits(&lane.up, ctx.wire) as u64,
            entries: lane.up.nnz() as u64,
        })
    }

    fn apply(
        &mut self,
        k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<TopJLane>],
        _pool: &Pool,
    ) {
        // Only this round's transmissions fold into the step: unlike
        // CGD/IAG, top-j has no stale-memory semantics (the transmitted
        // values already left the error memory), so a lane that sat the
        // round out must not be re-applied. An active-but-empty update
        // also carries `sent: None`, and skipping its no-op add is
        // bitwise identical to folding it.
        linalg::zero(&mut self.agg);
        if let Some(staged) = self.stale.staged() {
            linalg::axpy(1.0, staged, &mut self.agg);
        }
        self.stale.consume();
        for el in lanes.iter().filter(|el| el.sent.is_some()) {
            el.lane.up.add_into(&mut self.agg);
        }
        linalg::axpy(-self.cfg.alpha(k), &self.agg, &mut server.theta);
    }

    fn fold_stale(
        &mut self,
        _k: usize,
        _server: &mut ServerState,
        _w: usize,
        lane: &mut TopJLane,
        _age: u32,
    ) {
        self.stale.fold_sparse(&lane.up);
    }
}

pub fn run(prob: &Problem, cfg: &TopJConfig, iters: usize) -> Trace {
    run_pooled(prob, cfg, iters, Pool::global())
}

/// Top-j through the engine on an explicit pool.
pub fn run_pooled(prob: &Problem, cfg: &TopJConfig, iters: usize, pool: &Pool) -> Trace {
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    engine::run_rule(
        prob,
        TopJRule::new(cfg.clone(), prob.d),
        iters,
        cfg.eval_every,
        fstar,
        |_k| None,
        pool,
        &EngineOpts::from_env(),
    )
    .trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn fixed_budget_bits() {
        let prob = Problem::linear(synthetic::dna_like(5, 60), 3, 0.1);
        let cfg = TopJConfig { j: 10, gamma0: 0.1, lambda: 0.1, eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 20);
        assert_eq!(t.total_transmissions(), 60);
        let last = t.rows.last().unwrap();
        assert_eq!(last.entries, 20 * 3 * 10);
    }

    #[test]
    fn makes_progress() {
        let prob = Problem::linear(synthetic::dna_like(5, 200), 5, 0.01);
        let l = prob.lipschitz();
        let cfg = TopJConfig { j: 40, gamma0: 1.0 / l, lambda: 0.01, eval_every: 1, fstar: None };
        let t = run(&prob, &cfg, 300);
        let errs = t.errors();
        assert!(errs[300] < errs[0] * 0.2, "{} -> {}", errs[0], errs[300]);
    }

    #[test]
    fn j_equals_d_close_to_gd_first_step() {
        let prob = Problem::linear(synthetic::dna_like(5, 40), 2, 0.1);
        let l = prob.lipschitz();
        let cfg = TopJConfig {
            j: prob.d,
            gamma0: 1.0 / l,
            lambda: 0.0,
            eval_every: 1,
            fstar: None,
        };
        let t = run(&prob, &cfg, 5);
        let gd_cfg = super::super::gd::GdConfig { alpha: 1.0 / l, eval_every: 1, fstar: None };
        let gd = super::super::gd::run(&prob, &gd_cfg, 5);
        // With j=d and lambda=0 (constant step), trajectories agree to f32
        // rounding.
        for (a, b) in t.rows.iter().zip(gd.rows.iter()) {
            assert!((a.fval - b.fval).abs() < 1e-6 * b.fval.abs().max(1.0));
        }
    }
}
