//! Convergence/communication traces — the data behind every figure.

use crate::util::csv::CsvWriter;
use std::path::Path;

/// Histogram bins for the staleness age of folded updates: ages 1, 2, 3
/// land in their own bin, everything ≥ 4 in the last (the bound
/// `GDSEC_STALE_WINDOW` defaults to 1, so the tail bin only fills under
/// deliberately wide windows). Fixed-size so [`TraceRow`] stays `Copy`
/// and the accounting stays allocation-free.
pub const STALE_AGE_BINS: usize = 4;

/// The histogram bin for a fold `age` rounds after transmission.
#[inline]
pub fn stale_age_bin(age: u32) -> usize {
    (age.max(1) as usize - 1).min(STALE_AGE_BINS - 1)
}

/// One recorded iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct TraceRow {
    pub iter: usize,
    /// Objective value f(θ^k).
    pub fval: f64,
    /// Cumulative uplink payload bits through this iteration.
    pub bits: u64,
    /// Cumulative worker→server transmissions (suppressed rounds absent).
    pub transmissions: u64,
    /// Cumulative non-zero entries put on the wire.
    pub entries: u64,
    /// Cumulative stale updates folded late (semi-synchronous quorum
    /// rounds; always 0 in the synchronous protocol).
    pub stale: u64,
    /// Cumulative staleness-age histogram of those folds
    /// ([`stale_age_bin`]): how many folded 1, 2, 3, or ≥ 4 rounds after
    /// transmission. Sums to `stale`; ages are hard-bounded by the
    /// staleness window, so bins past `GDSEC_STALE_WINDOW` stay 0.
    pub stale_ages: [u64; STALE_AGE_BINS],
    /// Workers dead (struck out or disconnected) as of this iteration.
    /// A level, not a cumulative count: a re-admitted worker leaves it.
    pub dead: u64,
    /// Cumulative re-admissions (crash → restart handshakes) completed.
    pub rejoined: u64,
    /// Cumulative uplink frames the fault-injected link dropped.
    pub dropped_frames: u64,
    /// Cumulative uplink frames that failed to decode (corrupted on the
    /// link or genuinely malformed) — each one costs its worker a
    /// liveness strike.
    pub corrupt_frames: u64,
}

/// A full run trace for one algorithm on one problem.
#[derive(Debug, Clone)]
pub struct Trace {
    pub algo: String,
    pub problem: String,
    pub rows: Vec<TraceRow>,
    /// Estimated optimum for objective-error plots.
    pub fstar: f64,
}

impl Trace {
    pub fn new(algo: &str, problem: &str, fstar: f64) -> Trace {
        Trace { algo: algo.to_string(), problem: problem.to_string(), rows: Vec::new(), fstar }
    }

    pub fn push(&mut self, row: TraceRow) {
        self.rows.push(row);
    }

    pub fn total_bits(&self) -> u64 {
        self.rows.last().map_or(0, |r| r.bits)
    }

    pub fn total_transmissions(&self) -> u64 {
        self.rows.last().map_or(0, |r| r.transmissions)
    }

    pub fn final_error(&self) -> f64 {
        self.rows.last().map_or(f64::NAN, |r| r.fval - self.fstar)
    }

    /// Total stale updates folded over the run (quorum rounds).
    pub fn total_stale(&self) -> u64 {
        self.rows.last().map_or(0, |r| r.stale)
    }

    /// Objective error series (f(θ^k) − f*).
    pub fn errors(&self) -> Vec<f64> {
        self.rows.iter().map(|r| r.fval - self.fstar).collect()
    }

    /// First iteration whose objective error ≤ eps.
    pub fn iters_to_reach(&self, eps: f64) -> Option<usize> {
        self.rows.iter().find(|r| r.fval - self.fstar <= eps).map(|r| r.iter)
    }

    /// Cumulative bits at the first iteration whose error ≤ eps.
    pub fn bits_to_reach(&self, eps: f64) -> Option<u64> {
        self.rows.iter().find(|r| r.fval - self.fstar <= eps).map(|r| r.bits)
    }

    /// Write CSV: iter, err, fval, bits, transmissions, entries, stale,
    /// the staleness-age histogram columns (`stale_age1..3`,
    /// `stale_age4p` = ages ≥ 4), and the fault columns (`dead`,
    /// `rejoined`, `dropped`, `corrupt`).
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "iter",
                "err",
                "fval",
                "bits",
                "transmissions",
                "entries",
                "stale",
                "stale_age1",
                "stale_age2",
                "stale_age3",
                "stale_age4p",
                "dead",
                "rejoined",
                "dropped",
                "corrupt",
            ],
        )?;
        for r in &self.rows {
            w.row_f64(&[
                r.iter as f64,
                r.fval - self.fstar,
                r.fval,
                r.bits as f64,
                r.transmissions as f64,
                r.entries as f64,
                r.stale as f64,
                r.stale_ages[0] as f64,
                r.stale_ages[1] as f64,
                r.stale_ages[2] as f64,
                r.stale_ages[3] as f64,
                r.dead as f64,
                r.rejoined as f64,
                r.dropped_frames as f64,
                r.corrupt_frames as f64,
            ])?;
        }
        w.flush()
    }

    /// Bit savings vs a reference trace at target error eps:
    /// 1 − bits_self/bits_ref (NaN when either never reaches eps).
    pub fn savings_vs(&self, reference: &Trace, eps: f64) -> f64 {
        match (self.bits_to_reach(eps), reference.bits_to_reach(eps)) {
            (Some(a), Some(b)) if b > 0 => 1.0 - a as f64 / b as f64,
            _ => f64::NAN,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(rows: &[(usize, f64, u64)]) -> Trace {
        let mut t = Trace::new("test", "prob", 1.0);
        for &(iter, fval, bits) in rows {
            t.push(TraceRow {
                iter,
                fval,
                bits,
                transmissions: iter as u64,
                ..TraceRow::default()
            });
        }
        t
    }

    #[test]
    fn reach_queries() {
        let t = mk(&[(0, 3.0, 0), (1, 2.0, 100), (2, 1.5, 150), (3, 1.01, 190)]);
        assert_eq!(t.iters_to_reach(1.0), Some(1)); // err = 2.0-1.0 = 1.0
        assert_eq!(t.bits_to_reach(0.5), Some(150));
        assert_eq!(t.iters_to_reach(1e-9), None);
        assert_eq!(t.total_bits(), 190);
    }

    #[test]
    fn savings() {
        let a = mk(&[(0, 3.0, 0), (1, 1.1, 10)]);
        let b = mk(&[(0, 3.0, 0), (1, 1.1, 100)]);
        let s = a.savings_vs(&b, 0.2);
        assert!((s - 0.9).abs() < 1e-12);
        assert!(a.savings_vs(&b, 1e-12).is_nan());
    }

    #[test]
    fn stale_age_bins_saturate() {
        assert_eq!(stale_age_bin(1), 0);
        assert_eq!(stale_age_bin(2), 1);
        assert_eq!(stale_age_bin(3), 2);
        assert_eq!(stale_age_bin(4), 3);
        assert_eq!(stale_age_bin(250), 3);
        // Defensive: age 0 cannot occur (a fold is at least one round
        // after transmission) but must not underflow.
        assert_eq!(stale_age_bin(0), 0);
    }

    #[test]
    fn csv_writes() {
        let t = mk(&[(0, 3.0, 0), (1, 2.0, 64)]);
        let dir = std::env::temp_dir().join(format!("gdsec_trace_{}", std::process::id()));
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("iter,err,fval,bits"));
        assert_eq!(text.lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
