//! Stochastic extensions (paper §IV-G2): SGD baseline, SGD-SEC, and
//! QSGD-SEC (sparsify-then-quantize). All use the decreasing step schedule
//! `α_k = γ₀(1 + γ₀λk)^{-1}` from the paper's Fig 9 setup, and minibatch
//! gradients drawn uniformly from each worker's shard (scaled to be
//! unbiased for the local data term).

use super::gdsec::{fstar_iters, record_pooled, GdSecConfig, ServerState, WorkerState, Xi};
use super::trace::Trace;
use crate::compress::{self, quantize, SparseUpdate};
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;
use crate::util::rng::{Pcg64, SplitMix64};

#[derive(Debug, Clone)]
pub struct SgdSecConfig {
    pub gamma0: f64,
    pub lambda: f64,
    pub beta: f64,
    pub xi: Xi,
    pub batch: usize,
    pub seed: u64,
    /// None ⇒ SGD-SEC; Some(s) ⇒ QSGD-SEC with s quantization bins.
    pub quantize_s: Option<u8>,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

/// Plain distributed SGD baseline (dense transmissions).
pub fn run_sgd(prob: &Problem, cfg: &SgdSecConfig, iters: usize) -> Trace {
    run_sgd_pooled(prob, cfg, iters, Pool::global())
}

/// [`run_sgd`] with the per-worker minibatch gradients fanned out over
/// `pool` (per-worker seeded RNG streams keep the draw sequence — and so
/// the trajectory — identical for any thread count).
pub fn run_sgd_pooled(prob: &Problem, cfg: &SgdSecConfig, iters: usize, pool: &Pool) -> Trace {
    let d = prob.d;
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let mut trace = Trace::new("SGD", &prob.name, fstar);
    let mut theta = vec![0.0; d];
    let mut agg = vec![0.0; d];
    struct Lane {
        g: Vec<f64>,
        rng: Pcg64,
    }
    let mut lanes: Vec<Lane> = (0..prob.m())
        .map(|w| Lane {
            g: vec![0.0; d],
            rng: Pcg64::seeded(SplitMix64::child(cfg.seed, w as u64)),
        })
        .collect();
    let (mut bits, mut tx, mut entries) = (0u64, 0u64, 0u64);
    record_pooled(&mut trace, prob, &theta, pool, 0, bits, tx, entries);
    for k in 1..=iters {
        let alpha_k = cfg.gamma0 / (1.0 + cfg.gamma0 * cfg.lambda * k as f64);
        {
            let theta = &theta;
            pool.scatter(&mut lanes, |w, lane| {
                stochastic_grad(&prob.locals[w], theta, cfg.batch, &mut lane.rng, &mut lane.g);
                // Wire: dense f32 vector — round in-thread.
                for v in lane.g.iter_mut() {
                    *v = *v as f32 as f64;
                }
            });
        }
        linalg::zero(&mut agg);
        for lane in &lanes {
            linalg::axpy(1.0, &lane.g, &mut agg);
            bits += compress::dense_bits(d) as u64;
            tx += 1;
            entries += d as u64;
        }
        linalg::axpy(-alpha_k, &agg, &mut theta);
        if k % cfg.eval_every == 0 || k == iters {
            record_pooled(&mut trace, prob, &theta, pool, k, bits, tx, entries);
        }
    }
    trace
}

/// SGD-SEC / QSGD-SEC.
pub fn run_sgdsec(prob: &Problem, cfg: &SgdSecConfig, iters: usize) -> Trace {
    run_sgdsec_pooled(prob, cfg, iters, Pool::global())
}

/// [`run_sgdsec`] with the per-worker minibatch gradient + censor (+
/// optional QSGD re-quantization) fanned out over `pool`. Each lane owns
/// its worker state, RNG stream and wire buffers; the server folds lanes
/// in worker-id order — bit-for-bit thread-count independent.
pub fn run_sgdsec_pooled(prob: &Problem, cfg: &SgdSecConfig, iters: usize, pool: &Pool) -> Trace {
    let d = prob.d;
    let m = prob.m();
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let name = if cfg.quantize_s.is_some() { "QSGD-SEC" } else { "SGD-SEC" };
    let mut trace = Trace::new(name, &prob.name, fstar);
    let mut server = ServerState::new(d);
    struct Lane {
        ws: WorkerState,
        rng: Pcg64,
        /// Censored update Δ̂ (pre-quantization).
        up: SparseUpdate,
        /// What actually goes on the wire (== `up` unless quantizing).
        wire: SparseUpdate,
        dense: Vec<f64>,
        sent_bits: u64,
        sent_entries: u64,
        sent: bool,
    }
    let mut lanes: Vec<Lane> = (0..m)
        .map(|w| Lane {
            ws: WorkerState::new(d),
            rng: Pcg64::seeded(SplitMix64::child(cfg.seed, w as u64)),
            up: SparseUpdate::empty(d),
            wire: SparseUpdate::empty(d),
            dense: vec![0.0; d],
            sent_bits: 0,
            sent_entries: 0,
            sent: false,
        })
        .collect();
    let mut theta_diff = vec![0.0; d];
    let (mut bits, mut tx, mut entries) = (0u64, 0u64, 0u64);
    let quantizing = cfg.quantize_s.is_some();
    record_pooled(&mut trace, prob, &server.theta, pool, 0, bits, tx, entries);
    for k in 1..=iters {
        let alpha_k = cfg.gamma0 / (1.0 + cfg.gamma0 * cfg.lambda * k as f64);
        let step_cfg = GdSecConfig {
            alpha: alpha_k,
            beta: cfg.beta,
            xi: cfg.xi.clone(),
            error_correction: true,
            state_variable: true,
            eval_every: cfg.eval_every,
            fstar: None,
        };
        server.theta_diff(&mut theta_diff);
        {
            let theta = &server.theta;
            let theta_diff = &theta_diff;
            let step_cfg = &step_cfg;
            pool.scatter(&mut lanes, |w, lane| {
                let (ws, rng) = (&mut lane.ws, &mut lane.rng);
                stochastic_grad(&prob.locals[w], theta, cfg.batch, rng, ws.grad_mut());
                lane.ws.sparsify_into(step_cfg, m, theta_diff, &mut lane.up);
                if lane.up.nnz() == 0 {
                    lane.sent = false;
                    return;
                }
                lane.sent = true;
                match cfg.quantize_s {
                    None => {
                        lane.sent_bits = compress::sparse_bits(&lane.up) as u64;
                        lane.sent_entries = lane.up.nnz() as u64;
                    }
                    Some(s) => {
                        // Quantize the surviving values; EC + h must track
                        // the *dequantized* wire values so worker and
                        // server stay mirrored.
                        linalg::zero(&mut lane.dense);
                        lane.up.add_into(&mut lane.dense);
                        let q = quantize::quantize(&lane.dense, s, &mut lane.rng);
                        lane.sent_bits = quantize::quantized_bits(&q) as u64;
                        lane.sent_entries = q.idx.len() as u64;
                        quantize::dequantize_into(&q, &mut lane.dense);
                        lane.wire.gather_from(&lane.dense);
                        lane.ws.requantize_fixup(step_cfg, &lane.up, &lane.wire);
                    }
                }
            });
        }
        for lane in lanes.iter().filter(|l| l.sent) {
            bits += lane.sent_bits;
            tx += 1;
            entries += lane.sent_entries;
        }
        server.apply_round(
            &step_cfg,
            lanes
                .iter()
                .filter(|l| l.sent)
                .map(|l| if quantizing { &l.wire } else { &l.up }),
        );
        if k % cfg.eval_every == 0 || k == iters {
            record_pooled(&mut trace, prob, &server.theta, pool, k, bits, tx, entries);
        }
    }
    trace
}

/// Unbiased minibatch gradient of the local objective.
fn stochastic_grad(
    l: &crate::objectives::LocalObjective,
    theta: &[f64],
    batch: usize,
    rng: &mut Pcg64,
    out: &mut [f64],
) {
    let nm = l.shard.n();
    if nm == 0 {
        linalg::zero(out);
        return;
    }
    let b = batch.min(nm);
    let idx: Vec<usize> = (0..b).map(|_| rng.index(nm)).collect();
    let scale = nm as f64 / b as f64;
    l.grad_indices(theta, &idx, scale, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn base_cfg(seed: u64) -> SgdSecConfig {
        SgdSecConfig {
            gamma0: 0.01,
            lambda: 0.01,
            beta: 0.01,
            xi: Xi::Uniform(50.0),
            batch: 1,
            seed,
            quantize_s: None,
            eval_every: 5,
            fstar: None,
        }
    }

    #[test]
    fn sgd_makes_progress() {
        let prob = Problem::linear(synthetic::mnist_like(4, 300), 10, 1.0 / 300.0);
        let mut cfg = base_cfg(1);
        cfg.gamma0 = 0.05;
        let t = run_sgd(&prob, &cfg, 400);
        let errs = t.errors();
        assert!(
            errs.last().unwrap() < &(errs[0] * 0.5),
            "{} -> {}",
            errs[0],
            errs.last().unwrap()
        );
    }

    #[test]
    fn sgdsec_saves_bits_vs_sgd() {
        let prob = Problem::linear(synthetic::mnist_like(4, 300), 10, 1.0 / 300.0);
        let mut cfg = base_cfg(2);
        cfg.gamma0 = 0.05;
        let sgd = run_sgd(&prob, &cfg, 200);
        let sec = run_sgdsec(&prob, &cfg, 200);
        let (a, b) = (sec.total_bits(), sgd.total_bits());
        assert!(a < b, "{a} vs {b}");
        // still converging in the same ballpark
        assert!(sec.final_error() < sgd.final_error() * 10.0 + 1e-9);
    }

    #[test]
    fn qsgdsec_cheaper_than_sgdsec() {
        let prob = Problem::linear(synthetic::mnist_like(4, 200), 5, 1.0 / 200.0);
        let mut cfg = base_cfg(3);
        cfg.gamma0 = 0.05;
        let sec = run_sgdsec(&prob, &cfg, 150);
        cfg.quantize_s = Some(255);
        let qsec = run_sgdsec(&prob, &cfg, 150);
        assert!(
            qsec.total_bits() < sec.total_bits(),
            "{} vs {}",
            qsec.total_bits(),
            sec.total_bits()
        );
        assert!(qsec.final_error().is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = Problem::linear(synthetic::mnist_like(4, 100), 5, 0.01);
        let cfg = base_cfg(9);
        let a = run_sgdsec(&prob, &cfg, 50);
        let b = run_sgdsec(&prob, &cfg, 50);
        assert_eq!(a.total_bits(), b.total_bits());
        assert_eq!(a.rows.last().unwrap().fval.to_bits(), b.rows.last().unwrap().fval.to_bits());
    }
}
