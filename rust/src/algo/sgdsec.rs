//! Stochastic extensions (paper §IV-G2): SGD baseline, SGD-SEC, and
//! QSGD-SEC (sparsify-then-quantize). All use the decreasing step schedule
//! `α_k = γ₀(1 + γ₀λk)^{-1}` from the paper's Fig 9 setup, and minibatch
//! gradients drawn uniformly from each worker's shard (scaled to be
//! unbiased for the local data term).
//!
//! Runs through the unified round [`engine`] in
//! [`GradMode::Custom`]: the rules compute their own minibatch gradients
//! from per-worker seeded RNG streams inside `compress`, which keeps the
//! draw sequence — and so the trajectory — identical for any thread
//! count (nested row-split lanes don't apply to index-sampled
//! gradients).

use super::engine::{self, CompressRule, EngineLane, EngineOpts, GradMode, RoundCtx, Sent};
use super::gdsec::{fstar_iters, GdSecConfig, ServerState, WorkerState, Xi};
use super::trace::Trace;
use crate::compress::{self, quantize, SparseUpdate};
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;
use crate::util::rng::{Pcg64, SplitMix64};

#[derive(Debug, Clone)]
pub struct SgdSecConfig {
    pub gamma0: f64,
    pub lambda: f64,
    pub beta: f64,
    pub xi: Xi,
    pub batch: usize,
    pub seed: u64,
    /// None ⇒ SGD-SEC; Some(s) ⇒ QSGD-SEC with s quantization bins.
    pub quantize_s: Option<u8>,
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

impl SgdSecConfig {
    fn alpha(&self, k: usize) -> f64 {
        self.gamma0 / (1.0 + self.gamma0 * self.lambda * k as f64)
    }
}

/// One plain-SGD worker lane: minibatch gradient scratch + draw stream.
pub struct SgdLane {
    g: Vec<f64>,
    rng: Pcg64,
}

/// Dense minibatch-SGD rule (no compression beyond f32 wire rounding).
pub struct SgdRule {
    cfg: SgdSecConfig,
    agg: Vec<f64>,
    /// Minibatch gradients parked by a quorum cut; folded ahead of the
    /// fresh lanes by the next apply.
    stale: engine::StalePending,
}

impl SgdRule {
    pub fn new(cfg: SgdSecConfig, d: usize) -> SgdRule {
        SgdRule { cfg, agg: vec![0.0; d], stale: engine::StalePending::new(d) }
    }
}

impl CompressRule for SgdRule {
    type Lane = SgdLane;

    fn name(&self) -> String {
        "SGD".into()
    }

    fn make_lane(&self, prob: &Problem, w: usize) -> SgdLane {
        SgdLane {
            g: vec![0.0; prob.d],
            rng: Pcg64::seeded(SplitMix64::child(self.cfg.seed, w as u64)),
        }
    }

    fn grad_mode(&self) -> GradMode {
        GradMode::Custom
    }

    fn compress(&self, ctx: &RoundCtx, w: usize, lane: &mut SgdLane) -> Option<Sent> {
        stochastic_grad(&ctx.prob.locals[w], ctx.theta, self.cfg.batch, &mut lane.rng, &mut lane.g);
        // Wire: dense f32 vector — round in-thread.
        for v in lane.g.iter_mut() {
            *v = *v as f32 as f64;
        }
        let d = lane.g.len();
        Some(Sent { bits: compress::dense_bits(d) as u64, entries: d as u64 })
    }

    fn apply(
        &mut self,
        k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<SgdLane>],
        _pool: &Pool,
    ) {
        let staged = self.stale.staged();
        engine::apply_dense_fold(
            self.cfg.alpha(k),
            staged.into_iter().chain(
                lanes
                    .iter()
                    .filter(|el| el.sent.is_some())
                    .map(|el| el.lane.g.as_slice()),
            ),
            &mut self.agg,
            &mut server.theta,
        );
        self.stale.consume();
    }

    fn fold_stale(
        &mut self,
        _k: usize,
        _server: &mut ServerState,
        _w: usize,
        lane: &mut SgdLane,
        _age: u32,
    ) {
        self.stale.fold(&lane.g);
    }
}

/// Plain distributed SGD baseline (dense transmissions).
pub fn run_sgd(prob: &Problem, cfg: &SgdSecConfig, iters: usize) -> Trace {
    run_sgd_pooled(prob, cfg, iters, Pool::global())
}

/// [`run_sgd`] through the engine on an explicit pool.
pub fn run_sgd_pooled(prob: &Problem, cfg: &SgdSecConfig, iters: usize, pool: &Pool) -> Trace {
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    engine::run_rule(
        prob,
        SgdRule::new(cfg.clone(), prob.d),
        iters,
        cfg.eval_every,
        fstar,
        |_k| None,
        pool,
        &EngineOpts::from_env(),
    )
    .trace
}

/// One SGD-SEC / QSGD-SEC worker lane.
pub struct SgdSecLane {
    ws: WorkerState,
    rng: Pcg64,
    /// Censored update Δ̂ (pre-quantization).
    up: SparseUpdate,
    /// What actually goes on the wire (== `up` unless quantizing).
    wire: SparseUpdate,
    dense: Vec<f64>,
}

/// SGD-SEC / QSGD-SEC rule: minibatch gradient, GD-SEC censor + error
/// correction, optional QSGD re-quantization of the survivors.
pub struct SgdSecRule {
    cfg: SgdSecConfig,
    /// Per-round GD-SEC step config (α_k refreshed in `begin_round`).
    step_cfg: GdSecConfig,
}

impl SgdSecRule {
    pub fn new(cfg: SgdSecConfig) -> SgdSecRule {
        let step_cfg = GdSecConfig {
            alpha: cfg.gamma0,
            beta: cfg.beta,
            xi: cfg.xi.clone(),
            error_correction: true,
            state_variable: true,
            eval_every: cfg.eval_every,
            fstar: None,
        };
        SgdSecRule { cfg, step_cfg }
    }
}

impl CompressRule for SgdSecRule {
    type Lane = SgdSecLane;

    fn name(&self) -> String {
        if self.cfg.quantize_s.is_some() { "QSGD-SEC".into() } else { "SGD-SEC".into() }
    }

    fn make_lane(&self, prob: &Problem, w: usize) -> SgdSecLane {
        SgdSecLane {
            ws: WorkerState::new(prob.d),
            rng: Pcg64::seeded(SplitMix64::child(self.cfg.seed, w as u64)),
            up: SparseUpdate::empty(prob.d),
            wire: SparseUpdate::empty(prob.d),
            dense: vec![0.0; prob.d],
        }
    }

    fn grad_mode(&self) -> GradMode {
        GradMode::Custom
    }

    fn wants_theta_diff(&self) -> bool {
        true
    }

    fn begin_round(&mut self, ctx: &RoundCtx) {
        self.step_cfg.alpha = self.cfg.alpha(ctx.k);
    }

    fn compress(&self, ctx: &RoundCtx, w: usize, lane: &mut SgdSecLane) -> Option<Sent> {
        stochastic_grad(
            &ctx.prob.locals[w],
            ctx.theta,
            self.cfg.batch,
            &mut lane.rng,
            lane.ws.grad_mut(),
        );
        lane.ws.sparsify_into(&self.step_cfg, ctx.m, ctx.theta_diff, &mut lane.up);
        if lane.up.nnz() == 0 {
            return None;
        }
        match self.cfg.quantize_s {
            None => Some(Sent {
                bits: compress::wire_bits(&lane.up, ctx.wire) as u64,
                entries: lane.up.nnz() as u64,
            }),
            Some(s) => {
                // Quantize the surviving values; EC + h must track the
                // *dequantized* wire values so worker and server stay
                // mirrored.
                linalg::zero(&mut lane.dense);
                lane.up.add_into(&mut lane.dense);
                let q = quantize::quantize(&lane.dense, s, &mut lane.rng);
                let sent = Sent {
                    bits: quantize::quantized_bits(&q) as u64,
                    entries: q.idx.len() as u64,
                };
                quantize::dequantize_into(&q, &mut lane.dense);
                lane.wire.gather_from(&lane.dense);
                lane.ws.requantize_fixup(&self.step_cfg, &lane.up, &lane.wire);
                Some(sent)
            }
        }
    }

    fn apply(
        &mut self,
        _k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<SgdSecLane>],
        _pool: &Pool,
    ) {
        let quantizing = self.cfg.quantize_s.is_some();
        server.apply_round(
            &self.step_cfg,
            lanes
                .iter()
                .filter(|el| el.sent.is_some())
                .map(|el| if quantizing { &el.lane.wire } else { &el.lane.up }),
        );
    }

    fn fold_stale(
        &mut self,
        _k: usize,
        server: &mut ServerState,
        _w: usize,
        lane: &mut SgdSecLane,
        _age: u32,
    ) {
        // Same late Eq. 6 fold as GD-SEC; the wire image (dequantized
        // when QSGD-SEC re-quantizes) is what the worker's h_m/e_m
        // already tracked, at any fold age.
        let quantizing = self.cfg.quantize_s.is_some();
        server.fold_update(if quantizing { &lane.wire } else { &lane.up });
    }

    fn rejoin_worker(&mut self, server: &mut ServerState, _w: usize, lane: &mut SgdSecLane) {
        // Same EC-safe re-admission as GD-SEC: retire the restarted
        // worker's h share from the server mirror (the lane still holds
        // the pre-crash h_m exactly) and restart its memories cold.
        if self.step_cfg.state_variable {
            for (hi, wi) in server.h.iter_mut().zip(lane.ws.h.iter()) {
                *hi -= *wi;
            }
        }
        lane.ws.reset();
        lane.up.idx.clear();
        lane.up.val.clear();
        lane.wire.idx.clear();
        lane.wire.val.clear();
    }
}

/// SGD-SEC / QSGD-SEC.
pub fn run_sgdsec(prob: &Problem, cfg: &SgdSecConfig, iters: usize) -> Trace {
    run_sgdsec_pooled(prob, cfg, iters, Pool::global())
}

/// [`run_sgdsec`] through the engine on an explicit pool.
pub fn run_sgdsec_pooled(prob: &Problem, cfg: &SgdSecConfig, iters: usize, pool: &Pool) -> Trace {
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    engine::run_rule(
        prob,
        SgdSecRule::new(cfg.clone()),
        iters,
        cfg.eval_every,
        fstar,
        |_k| None,
        pool,
        &EngineOpts::from_env(),
    )
    .trace
}

/// Unbiased minibatch gradient of the local objective.
fn stochastic_grad(
    l: &crate::objectives::LocalObjective,
    theta: &[f64],
    batch: usize,
    rng: &mut Pcg64,
    out: &mut [f64],
) {
    let nm = l.shard.n();
    if nm == 0 {
        linalg::zero(out);
        return;
    }
    let b = batch.min(nm);
    let idx: Vec<usize> = (0..b).map(|_| rng.index(nm)).collect();
    let scale = nm as f64 / b as f64;
    l.grad_indices(theta, &idx, scale, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn base_cfg(seed: u64) -> SgdSecConfig {
        SgdSecConfig {
            gamma0: 0.01,
            lambda: 0.01,
            beta: 0.01,
            xi: Xi::Uniform(50.0),
            batch: 1,
            seed,
            quantize_s: None,
            eval_every: 5,
            fstar: None,
        }
    }

    #[test]
    fn sgd_makes_progress() {
        let prob = Problem::linear(synthetic::mnist_like(4, 300), 10, 1.0 / 300.0);
        let mut cfg = base_cfg(1);
        cfg.gamma0 = 0.05;
        let t = run_sgd(&prob, &cfg, 400);
        let errs = t.errors();
        assert!(
            errs.last().unwrap() < &(errs[0] * 0.5),
            "{} -> {}",
            errs[0],
            errs.last().unwrap()
        );
    }

    #[test]
    fn sgdsec_saves_bits_vs_sgd() {
        let prob = Problem::linear(synthetic::mnist_like(4, 300), 10, 1.0 / 300.0);
        let mut cfg = base_cfg(2);
        cfg.gamma0 = 0.05;
        let sgd = run_sgd(&prob, &cfg, 200);
        let sec = run_sgdsec(&prob, &cfg, 200);
        let (a, b) = (sec.total_bits(), sgd.total_bits());
        assert!(a < b, "{a} vs {b}");
        // still converging in the same ballpark
        assert!(sec.final_error() < sgd.final_error() * 10.0 + 1e-9);
    }

    #[test]
    fn qsgdsec_cheaper_than_sgdsec() {
        let prob = Problem::linear(synthetic::mnist_like(4, 200), 5, 1.0 / 200.0);
        let mut cfg = base_cfg(3);
        cfg.gamma0 = 0.05;
        let sec = run_sgdsec(&prob, &cfg, 150);
        cfg.quantize_s = Some(255);
        let qsec = run_sgdsec(&prob, &cfg, 150);
        assert!(
            qsec.total_bits() < sec.total_bits(),
            "{} vs {}",
            qsec.total_bits(),
            sec.total_bits()
        );
        assert!(qsec.final_error().is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let prob = Problem::linear(synthetic::mnist_like(4, 100), 5, 0.01);
        let cfg = base_cfg(9);
        let a = run_sgdsec(&prob, &cfg, 50);
        let b = run_sgdsec(&prob, &cfg, 50);
        assert_eq!(a.total_bits(), b.total_bits());
        assert_eq!(a.rows.last().unwrap().fval.to_bits(), b.rows.last().unwrap().fval.to_bits());
    }
}
