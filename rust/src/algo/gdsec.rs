//! GD-SEC (Algorithm 1 of the paper) — the core contribution.
//!
//! Per iteration `k`, worker `m`:
//! 1. `Δ_m = ∇f_m(θ^k) − h_m + e_m`
//! 2. censor component-wise: suppress `i` when
//!    `|[Δ_m]_i| ≤ (ξ_i/M)·|[θ^k − θ^{k−1}]_i|`      (Eq. 2)
//! 3. transmit the survivors `Δ̂_m` (nothing at all if none survive),
//! 4. `h_m ← h_m + β·Δ̂_m`,  `e_m ← Δ_m − Δ̂_m`.
//!
//! Server: `θ^{k+1} = θ^k − α(h + Σ_m Δ̂_m)`, `h ← h + β·Σ_m Δ̂_m` (Eq. 6).
//!
//! The wire carries f32 values (paper §IV); the error memory absorbs the
//! f32 rounding too (`e` is computed against the *transmitted* value), so
//! the server-side mirror `h == Σ_m h_m` holds bit-for-bit — pinned by the
//! property tests.
//!
//! This module is the single-process reference implementation. The
//! threaded, byte-on-the-wire version lives in [`crate::coordinator`]; an
//! integration test pins both to identical trajectories.
//!
//! ## The unified round engine
//!
//! The trainer loop itself lives in [`crate::algo::engine`]: this module
//! only contributes [`GdSecRule`] (the censor + error-correction
//! compression rule, Eq. 2) and the GD-SEC server semantics
//! ([`ServerState::apply_round`], Eq. 6). The engine fans the nested
//! (worker × nnz-balanced row-block) gradient lanes and the per-worker
//! sparsify step across the persistent [`Pool`] and reduces in worker-id
//! order, so the trajectory is **bit-for-bit identical for any thread
//! count** (pinned by `tests/prop_parallel_parity.rs`). Per-worker lanes
//! own their [`WorkerState`] and a reusable [`SparseUpdate`] buffer
//! (arena-style `reset()` + capacity reuse), and the fused
//! [`ServerState::apply_round`] re-zeroes its aggregation scratch inside
//! the update pass — after warm-up, an optimizer round performs **zero
//! heap allocations** at ANY thread count: the pool dispatches a round as
//! a stack context + function pointer, no spawns, no boxing (pinned by
//! `tests/alloc_free_round.rs`, which drives real engine rounds under a
//! counting allocator).

use super::engine::{self, CompressRule, EngineLane, EngineOpts, RoundCtx, Sent};
use super::trace::Trace;
use crate::compress::{self, SparseUpdate};
use crate::linalg;
use crate::objectives::Problem;
use crate::util::pool::Pool;
use crate::util::shard::{ShardApply, ShardPlan};

/// Censoring thresholds ξ_i. The paper's experiments report ξ/M; configs
/// here carry ξ (the threshold used is ξ_i/M · |θ_i diff|).
#[derive(Debug, Clone)]
pub enum Xi {
    /// ξ_1 = … = ξ_d = ξ.
    Uniform(f64),
    /// Per-coordinate ξ_i (Fig 7 uses ξ_i = ξ/L^i).
    PerCoord(Vec<f64>),
}

impl Xi {
    /// ξ scaled by the coordinate-wise Lipschitz constants: ξ_i = ξ/L^i.
    pub fn scaled_by_lipschitz(xi: f64, coord_l: &[f64]) -> Xi {
        Xi::PerCoord(coord_l.iter().map(|&l| xi / l.max(1e-12)).collect())
    }

    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        match self {
            Xi::Uniform(x) => *x,
            Xi::PerCoord(v) => v[i],
        }
    }

    pub fn max(&self) -> f64 {
        match self {
            Xi::Uniform(x) => *x,
            Xi::PerCoord(v) => v.iter().fold(0.0f64, |a, &b| a.max(b)),
        }
    }
}

/// GD-SEC configuration.
#[derive(Debug, Clone)]
pub struct GdSecConfig {
    /// Step size α.
    pub alpha: f64,
    /// State-variable smoothing β ∈ (0, 1].
    pub beta: f64,
    /// Censoring thresholds.
    pub xi: Xi,
    /// Error correction on (off ⇒ the paper's GD-SOEC ablation).
    pub error_correction: bool,
    /// Worker/server state variables on (off ⇒ Fig 4's "without state
    /// variables" ablation: h ≡ 0 and the server uses only Σ Δ̂).
    pub state_variable: bool,
    /// Evaluate/record f(θ) every `eval_every` iterations (1 = each).
    pub eval_every: usize,
    /// Known/precomputed f* (skips the internal estimate when set).
    pub fstar: Option<f64>,
}

impl Default for GdSecConfig {
    fn default() -> Self {
        GdSecConfig {
            alpha: 0.01,
            beta: 0.01,
            xi: Xi::Uniform(0.0),
            error_correction: true,
            state_variable: true,
            eval_every: 1,
            fstar: None,
        }
    }
}

/// Per-worker GD-SEC state (h_m, e_m) plus reusable scratch.
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub h: Vec<f64>,
    pub e: Vec<f64>,
    grad: Vec<f64>,
}

impl WorkerState {
    pub fn new(d: usize) -> WorkerState {
        WorkerState { h: vec![0.0; d], e: vec![0.0; d], grad: vec![0.0; d] }
    }

    /// Mutable access to the gradient buffer (filled by the caller before
    /// `sparsify_step`, e.g. from a stochastic or XLA-computed gradient).
    pub fn grad_mut(&mut self) -> &mut [f64] {
        &mut self.grad
    }

    /// Zero the state-variable and error memories — a crashed worker
    /// restarts cold. Keeps allocations (re-admission is not a
    /// steady-state path, but there is no reason to churn the heap).
    pub fn reset(&mut self) {
        linalg::zero(&mut self.h);
        linalg::zero(&mut self.e);
    }

    /// After-the-fact correction when the transmitted values change again
    /// post-sparsification (QSGD-SEC quantizes the survivors): rewrites h
    /// and e as if `wire` (the dequantized message) had been transmitted
    /// instead of `original`. Keeps the worker/server h-mirror and the EC
    /// identity `Δ = wire + e` exact.
    pub fn requantize_fixup(
        &mut self,
        cfg: &GdSecConfig,
        original: &SparseUpdate,
        wire: &SparseUpdate,
    ) {
        // Walk the two strictly-increasing index lists directly (the old
        // dense round-trip allocated two full-d vectors per call). `wire`
        // holds values at a subset of `original`'s indices — quantizing a
        // survivor to level 0 drops it — so an index missing from `wire`
        // means "wire value 0".
        let mut kw = 0;
        for (ko, &i) in original.idx.iter().enumerate() {
            while kw < wire.idx.len() && wire.idx[kw] < i {
                kw += 1;
            }
            let wire_val = if kw < wire.idx.len() && wire.idx[kw] == i {
                wire.val[kw] as f64
            } else {
                0.0
            };
            let delta_wire = wire_val - original.val[ko] as f64;
            let i = i as usize;
            if cfg.state_variable {
                self.h[i] += cfg.beta * delta_wire;
            }
            if cfg.error_correction {
                self.e[i] -= delta_wire;
            }
        }
    }

    /// Run the worker-side step on an already-computed gradient
    /// (`self.grad` must hold ∇f_m(θ^k)): censor, update h/e, and return
    /// the wire update. `theta_diff[i] = θ^k_i − θ^{k−1}_i`.
    ///
    /// This is the L3 hot path mirrored by the Pallas kernel
    /// `gdsec_sparsify` at L1 (same math, same outputs).
    pub fn sparsify_step(
        &mut self,
        cfg: &GdSecConfig,
        m_workers: usize,
        theta_diff: &[f64],
    ) -> SparseUpdate {
        let mut up = SparseUpdate::empty(self.h.len());
        self.sparsify_into(cfg, m_workers, theta_diff, &mut up);
        up
    }

    /// [`sparsify_step`](Self::sparsify_step) into a caller-owned buffer:
    /// `up` is reset (dimension set, indices/values cleared) but keeps
    /// its allocations, so a lane that reuses one buffer across rounds
    /// allocates nothing once capacity has grown to the largest update.
    pub fn sparsify_into(
        &mut self,
        cfg: &GdSecConfig,
        m_workers: usize,
        theta_diff: &[f64],
        up: &mut SparseUpdate,
    ) {
        up.reset(self.h.len());
        let minv = 1.0 / m_workers as f64;
        // Hoist the ξ representation out of the hot loop (uniform ξ is the
        // common case; per-coordinate pays one extra load per element).
        match &cfg.xi {
            Xi::Uniform(x) => self.sparsify_inner::<false>(cfg, *x * minv, &[], theta_diff, up),
            Xi::PerCoord(v) => {
                assert_eq!(v.len(), self.h.len(), "per-coord ξ length");
                self.sparsify_inner::<true>(cfg, minv, v, theta_diff, up)
            }
        }
    }

    #[inline]
    fn sparsify_inner<const PER_COORD: bool>(
        &mut self,
        cfg: &GdSecConfig,
        scale: f64,
        xi_per: &[f64],
        theta_diff: &[f64],
        up: &mut SparseUpdate,
    ) {
        let d = self.h.len();
        let ec = cfg.error_correction;
        let sv = cfg.state_variable;
        let beta = cfg.beta;
        for i in 0..d {
            // Δ_i = g_i − h_i + e_i  (e ≡ 0 when EC disabled)
            let delta = self.grad[i] - self.h[i] + if ec { self.e[i] } else { 0.0 };
            let xi_scaled = if PER_COORD { xi_per[i] * scale } else { scale };
            let tau = xi_scaled * theta_diff[i].abs();
            if delta.abs() > tau {
                // transmit: wire value is the f32 rounding of Δ_i
                let wire = delta as f32;
                up.idx.push(i as u32);
                up.val.push(wire);
                let wire64 = wire as f64;
                if sv {
                    self.h[i] += beta * wire64;
                }
                if ec {
                    self.e[i] = delta - wire64;
                }
            } else if ec {
                // suppressed: error memory keeps the whole component
                self.e[i] = delta;
            }
        }
    }
}

/// Server-side state: θ, θ^{k−1}, mirrored h, aggregation scratch, and
/// the persistent coordinate-shard plan behind
/// [`apply_round_pooled`](Self::apply_round_pooled).
#[derive(Debug, Clone)]
pub struct ServerState {
    pub theta: Vec<f64>,
    pub theta_prev: Vec<f64>,
    pub h: Vec<f64>,
    agg: Vec<f64>,
    /// Shard boundaries + cut scratch for the pooled apply; empty of
    /// borrowed state between rounds, so the Clone derive stays sound.
    plan: ShardPlan,
}

impl ServerState {
    pub fn new(d: usize) -> ServerState {
        ServerState {
            theta: vec![0.0; d],
            theta_prev: vec![0.0; d],
            h: vec![0.0; d],
            agg: vec![0.0; d],
            plan: ShardPlan::new(),
        }
    }

    /// Pre-build the shard plan for this model's dimension on `pool` so
    /// the first pooled round doesn't pay the slot-table build inside
    /// the zero-alloc steady state.
    pub fn warm_shard_plan(&mut self, pool: &Pool) {
        let d = self.theta.len();
        self.plan.ensure(d, pool);
    }

    /// θ^k − θ^{k−1} into `out`.
    pub fn theta_diff(&self, out: &mut [f64]) {
        linalg::sub(&self.theta, &self.theta_prev, out);
    }

    /// θ^k − θ^{k−1} into `out` plus `max_i |out_i|` in the same fused
    /// pass — the stationarity measure behind the censoring thresholds,
    /// surfaced by the engine's per-round debug telemetry.
    pub fn theta_diff_max(&self, out: &mut [f64]) -> f64 {
        linalg::sub_abs_max(&self.theta, &self.theta_prev, out)
    }

    /// Stage a late-arriving update into the aggregation scratch ahead
    /// of the next [`apply_round`](Self::apply_round): `agg` is all-zeros
    /// between rounds, so the staged entries fold into the upcoming
    /// Σ_m Δ̂_m exactly as if the update had arrived on time — the
    /// mechanism behind [`CompressRule::fold_stale`] for the
    /// GD-SEC-family rules (semi-synchronous quorum rounds). The worker
    /// already moved its h_m/e_m at transmission, so the delayed server
    /// fold keeps the h-mirror consistent one round later.
    pub fn fold_update(&mut self, u: &SparseUpdate) {
        u.add_into(&mut self.agg);
    }

    /// Apply one aggregated round: θ^{k+1} = θ^k − α(h + Δ̂), h += β·Δ̂
    /// (Eq. 6), accepting any in-order sequence of update references.
    ///
    /// The server step is ONE fused pass over d: it snapshots θ into
    /// θ_prev, applies the θ and h updates, and re-zeroes the aggregation
    /// scratch for the next round in the same loop — `agg` is all-zeros
    /// between calls (established by `new`, maintained here), which is
    /// what makes the steady-state round sweep- and allocation-free.
    pub fn apply_round<'a, I>(&mut self, cfg: &GdSecConfig, updates: I)
    where
        I: IntoIterator<Item = &'a SparseUpdate>,
    {
        for u in updates {
            u.add_into(&mut self.agg);
        }
        let d = self.theta.len();
        if cfg.state_variable {
            for i in 0..d {
                let a = self.agg[i];
                let t = self.theta[i];
                self.theta_prev[i] = t;
                self.theta[i] = t - cfg.alpha * (self.h[i] + a);
                self.h[i] += cfg.beta * a;
                self.agg[i] = 0.0;
            }
        } else {
            for i in 0..d {
                let a = self.agg[i];
                let t = self.theta[i];
                self.theta_prev[i] = t;
                self.theta[i] = t - cfg.alpha * a;
                self.agg[i] = 0.0;
            }
        }
    }

    /// [`apply_round`](Self::apply_round), fanned over the persistent
    /// coordinate-shard plan on `pool` — the engine-side mirror of the
    /// coordinator's sharded server fold. Same contract as the serial
    /// apply: `agg` may carry staged stale entries
    /// ([`fold_update`](Self::fold_update)), the fresh updates fold on
    /// top in the order `updates` yields them, θ snapshots into θ_prev,
    /// and `agg` is all-zeros again on return. Per element the operation
    /// sequence matches the serial loop (fold → step; the snapshot and
    /// the re-zero touch no other element), so the result is bitwise
    /// identical at any shard and thread count.
    pub fn apply_round_pooled<'a, I>(&mut self, cfg: &GdSecConfig, updates: I, pool: &Pool)
    where
        I: IntoIterator<Item = (usize, &'a SparseUpdate)>,
    {
        let ServerState { theta, theta_prev, h, agg, plan } = self;
        plan.fold(
            pool,
            updates,
            ShardApply {
                theta,
                h,
                agg,
                theta_prev: Some(theta_prev),
                alpha: cfg.alpha,
                beta: cfg.beta,
                state_variable: cfg.state_variable,
                fold_scale: 1.0,
                staged_agg: true,
                shares: None,
            },
        );
    }
}

/// One worker's slot in the engine fan-out: its GD-SEC state and a
/// reusable wire-update buffer. Everything a lane touches in the
/// parallel section is lane-local.
#[derive(Debug, Clone)]
pub struct WorkerLane {
    pub ws: WorkerState,
    pub up: SparseUpdate,
}

impl WorkerLane {
    pub fn new(d: usize) -> WorkerLane {
        WorkerLane { ws: WorkerState::new(d), up: SparseUpdate::empty(d) }
    }
}

/// The GD-SEC compression rule for the unified round [`engine`]: censor
/// the gradient difference component-wise (Eq. 2) with error correction
/// and state variables on the worker, apply Eq. 6 on the server.
pub struct GdSecRule {
    cfg: GdSecConfig,
}

impl GdSecRule {
    pub fn new(cfg: GdSecConfig) -> GdSecRule {
        GdSecRule { cfg }
    }
}

impl CompressRule for GdSecRule {
    type Lane = WorkerLane;

    fn name(&self) -> String {
        "GD-SEC".into()
    }

    fn make_lane(&self, prob: &Problem, _w: usize) -> WorkerLane {
        WorkerLane::new(prob.d)
    }

    fn wants_theta_diff(&self) -> bool {
        true
    }

    fn grad_buf<'l>(&self, lane: &'l mut WorkerLane) -> &'l mut [f64] {
        lane.ws.grad_mut()
    }

    fn compress(&self, ctx: &RoundCtx, _w: usize, lane: &mut WorkerLane) -> Option<Sent> {
        lane.ws.sparsify_into(&self.cfg, ctx.m, ctx.theta_diff, &mut lane.up);
        if lane.up.nnz() == 0 {
            return None;
        }
        Some(Sent {
            bits: compress::wire_bits(&lane.up, ctx.wire) as u64,
            entries: lane.up.nnz() as u64,
        })
    }

    fn apply(
        &mut self,
        _k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<WorkerLane>],
        pool: &Pool,
    ) {
        server.apply_round_pooled(
            &self.cfg,
            lanes
                .iter()
                .enumerate()
                .filter(|(_, el)| el.sent.is_some())
                .map(|(w, el)| (w, &el.lane.up)),
            pool,
        );
    }

    fn fold_stale(
        &mut self,
        _k: usize,
        server: &mut ServerState,
        _w: usize,
        lane: &mut WorkerLane,
        _age: u32,
    ) {
        // The parked Δ̂ is still in the lane's wire buffer (the worker
        // computes nothing while it is in flight); stage it into the
        // server scratch so the upcoming apply performs Eq. 6 on it
        // exactly as if it had arrived on time (h += β·Δ̂ included). The
        // worker moved its h_m/e_m at transmission, so the EC identity
        // holds at any fold age — no aging factor needed.
        server.fold_update(&lane.up);
    }

    fn rejoin_worker(&mut self, server: &mut ServerState, _w: usize, lane: &mut WorkerLane) {
        // The restarted worker comes back with h_m = e_m = 0, so the
        // server must retire this worker's share of its mirrored h:
        // h = Σ_m h_m, and the lane still holds the pre-crash h_m
        // exactly, so subtracting it componentwise is the exact
        // retirement (bitwise: h_after = h_before − h_m per component).
        if self.cfg.state_variable {
            for (hi, wi) in server.h.iter_mut().zip(lane.ws.h.iter()) {
                *hi -= *wi;
            }
        }
        lane.ws.reset();
        lane.up.idx.clear();
        lane.up.val.clear();
    }
}

/// Full output of a GD-SEC run — final server and worker states alongside
/// the trace, so tests can pin serial/parallel parity bit-for-bit.
#[derive(Debug, Clone)]
pub struct GdSecRun {
    pub trace: Trace,
    pub server: ServerState,
    pub workers: Vec<WorkerState>,
}

/// Run GD-SEC for `iters` iterations with all workers participating,
/// fanning worker steps across the shared [`Pool::global`] threads.
pub fn run(prob: &Problem, cfg: &GdSecConfig, iters: usize) -> Trace {
    run_scheduled(prob, cfg, iters, |_k| None)
}

/// [`run`] with a participation schedule (threads from the shared [`Pool::global`]).
pub fn run_scheduled<F>(prob: &Problem, cfg: &GdSecConfig, iters: usize, active: F) -> Trace
where
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    run_scheduled_pooled(prob, cfg, iters, active, Pool::global())
}

/// Run GD-SEC with a participation schedule: `active(k)` returns the set
/// of participating worker ids at iteration k (None = all). Inactive
/// workers keep h/e frozen (they neither compute nor transmit), matching
/// the paper's bandwidth-limited extension (§IV-G1).
///
/// Worker gradient + sparsify steps fan out over `pool`; reduction
/// (bit accounting and server aggregation) happens on the calling thread
/// in worker-id order, so the result is identical for every thread count.
pub fn run_scheduled_pooled<F>(
    prob: &Problem,
    cfg: &GdSecConfig,
    iters: usize,
    active: F,
    pool: &Pool,
) -> Trace
where
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    run_states(prob, cfg, iters, active, pool).trace
}

/// [`run_scheduled_pooled`] returning the final states as well
/// (engine defaults; `GDSEC_NNZ_BUDGET` tunes the nested lanes).
pub fn run_states<F>(
    prob: &Problem,
    cfg: &GdSecConfig,
    iters: usize,
    active: F,
    pool: &Pool,
) -> GdSecRun
where
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    run_states_opts(prob, cfg, iters, active, pool, &EngineOpts::from_env())
}

/// [`run_states`] with explicit [`EngineOpts`] (tests force multi-block
/// nested lanes through this).
pub fn run_states_opts<F>(
    prob: &Problem,
    cfg: &GdSecConfig,
    iters: usize,
    active: F,
    pool: &Pool,
    opts: &EngineOpts,
) -> GdSecRun
where
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    let fstar = cfg.fstar.unwrap_or_else(|| prob.estimate_fstar(fstar_iters(iters)));
    let run = engine::run_rule(
        prob,
        GdSecRule::new(cfg.clone()),
        iters,
        cfg.eval_every,
        fstar,
        active,
        pool,
        opts,
    );
    GdSecRun {
        trace: run.trace,
        server: run.server,
        workers: run.lanes.into_iter().map(|l| l.ws).collect(),
    }
}

/// Heuristic horizon for the f* estimate: far past the experiment length.
pub fn fstar_iters(iters: usize) -> usize {
    (iters * 4).max(3000)
}

/// Per-(worker, coordinate) transmission counts — the Fig 6 heatmap.
pub fn transmission_heatmap(prob: &Problem, cfg: &GdSecConfig, iters: usize) -> Vec<Vec<u64>> {
    let d = prob.d;
    let m = prob.m();
    let mut counts = vec![vec![0u64; d]; m];
    let mut server = ServerState::new(d);
    let mut workers: Vec<WorkerState> = (0..m).map(|_| WorkerState::new(d)).collect();
    let mut theta_diff = vec![0.0; d];
    for _k in 1..=iters {
        server.theta_diff(&mut theta_diff);
        let mut updates = Vec::with_capacity(m);
        for (w, ws) in workers.iter_mut().enumerate() {
            prob.locals[w].grad(&server.theta, &mut ws.grad);
            let up = ws.sparsify_step(cfg, m, &theta_diff);
            for &i in &up.idx {
                counts[w][i as usize] += 1;
            }
            if up.nnz() > 0 {
                updates.push(up);
            }
        }
        server.apply_round(cfg, &updates);
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::objectives::Problem;

    fn small_problem() -> Problem {
        Problem::logistic(synthetic::dna_like(3, 60), 3, 0.05)
    }

    #[test]
    fn engine_rejoin_retires_h_share_bitwise() {
        // Re-admission EC identity: after `rejoin_worker(0)` the server's
        // mirrored h must equal (component-wise, bitwise) its old value
        // minus worker 0's lane h_m — the exact retirement of the share
        // the restarted worker will never again account for — and worker
        // 0 restarts with zeroed memories while every other lane is
        // untouched. Pinned by running the same deterministic engine
        // twice, with and without the rejoin.
        let prob = small_problem();
        let alpha = 1.0 / prob.lipschitz();
        let cfg = GdSecConfig { alpha, ..Default::default() };
        let pool = Pool::new(2);
        let opts = EngineOpts::default();
        let run_to = |rejoin: bool| {
            let mut eng =
                engine::Engine::new(&prob, GdSecRule::new(cfg.clone()), &pool, &opts, 0.0);
            for _ in 0..5 {
                eng.step(None);
            }
            if rejoin {
                eng.rejoin_worker(0);
            }
            eng.into_run()
        };
        let before = run_to(false);
        let after = run_to(true);
        let h0 = &before.lanes[0].ws.h;
        assert!(h0.iter().any(|&v| v != 0.0), "worker 0 accrued no h — vacuous test");
        for i in 0..prob.d {
            assert_eq!(
                after.server.h[i].to_bits(),
                (before.server.h[i] - h0[i]).to_bits(),
                "server h share not retired exactly at coord {i}"
            );
        }
        assert!(after.lanes[0].ws.h.iter().all(|&v| v == 0.0));
        assert!(after.lanes[0].ws.e.iter().all(|&v| v == 0.0));
        assert_eq!(after.lanes[0].up.nnz(), 0);
        for i in 0..prob.d {
            assert_eq!(after.lanes[1].ws.h[i].to_bits(), before.lanes[1].ws.h[i].to_bits());
        }
    }

    #[test]
    fn xi_accessors() {
        let u = Xi::Uniform(2.0);
        assert_eq!(u.get(5), 2.0);
        assert_eq!(u.max(), 2.0);
        let p = Xi::PerCoord(vec![1.0, 3.0]);
        assert_eq!(p.get(1), 3.0);
        assert_eq!(p.max(), 3.0);
        let s = Xi::scaled_by_lipschitz(6.0, &[2.0, 3.0]);
        assert_eq!(s.get(0), 3.0);
        assert_eq!(s.get(1), 2.0);
    }

    #[test]
    fn reduces_to_gd_when_xi_zero_beta_zero() {
        // ξ ≤ 0 ⇒ condition (2) only suppresses exact-zero components with
        // zero threshold; with β=0 and h¹=0 the trajectory equals GD up to
        // f32 wire rounding.
        let prob = small_problem();
        let alpha = 1.0 / prob.lipschitz();
        let cfg = GdSecConfig {
            alpha,
            beta: 0.0,
            xi: Xi::Uniform(-1.0),
            ..Default::default()
        };
        let trace = run(&prob, &cfg, 30);
        // Explicit GD with f32-rounded per-worker gradients:
        let mut theta = vec![0.0; prob.d];
        let mut fvals = vec![prob.value(&theta)];
        let mut e: Vec<Vec<f64>> = vec![vec![0.0; prob.d]; prob.m()];
        let mut g = vec![0.0; prob.d];
        for _ in 0..30 {
            let mut agg = vec![0.0; prob.d];
            for (w, l) in prob.locals.iter().enumerate() {
                l.grad(&theta, &mut g);
                for i in 0..prob.d {
                    let delta = g[i] + e[w][i];
                    let wire = delta as f32;
                    e[w][i] = delta - wire as f64;
                    agg[i] += wire as f64;
                }
            }
            linalg::axpy(-alpha, &agg, &mut theta);
            fvals.push(prob.value(&theta));
        }
        for (row, expect) in trace.rows.iter().zip(&fvals) {
            assert!(
                (row.fval - expect).abs() < 1e-9 * expect.abs().max(1.0),
                "iter {}: {} vs {}",
                row.iter,
                row.fval,
                expect
            );
        }
    }

    #[test]
    fn converges_and_saves_bits() {
        let prob = small_problem();
        let alpha = 1.0 / prob.lipschitz();
        let gd_like = run(
            &prob,
            &GdSecConfig { alpha, beta: 0.0, xi: Xi::Uniform(-1.0), ..Default::default() },
            300,
        );
        let sec = run(
            &prob,
            &GdSecConfig { alpha, beta: 0.01, xi: Xi::Uniform(30.0), ..Default::default() },
            300,
        );
        let eps = 1e-6;
        let e_gd = gd_like.final_error();
        let e_sec = sec.final_error();
        assert!(e_sec < 1e-4, "GD-SEC stalls: err {e_sec}");
        assert!(e_sec <= e_gd * 50.0 + eps, "convergence badly degraded");
        assert!(
            sec.total_bits() < gd_like.total_bits() / 2,
            "no savings: {} vs {}",
            sec.total_bits(),
            gd_like.total_bits()
        );
    }

    #[test]
    fn first_iteration_transmits_everything() {
        // θ^1 = θ^0 ⇒ thresholds all zero ⇒ every non-zero Δ component
        // transmits at k=1.
        let prob = small_problem();
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            xi: Xi::Uniform(1e6),
            ..Default::default()
        };
        let trace = run(&prob, &cfg, 1);
        let last = trace.rows.last().unwrap();
        assert_eq!(last.transmissions, prob.m() as u64);
        assert!(last.entries > 0);
    }

    #[test]
    fn huge_xi_suppresses_later_rounds() {
        let prob = small_problem();
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.01,
            xi: Xi::Uniform(1e9),
            ..Default::default()
        };
        let trace = run(&prob, &cfg, 50);
        // After the first full round the enormous threshold censors almost
        // everything.
        let last = trace.rows.last().unwrap();
        let first_round_entries = trace.rows[1].entries;
        assert!(
            last.entries < first_round_entries * 3,
            "censoring ineffective: {} vs {}",
            last.entries,
            first_round_entries
        );
    }

    #[test]
    fn sparsify_invariants() {
        // Δ̂ + e' == Δ exactly (EC) and h moves only on transmitted comps.
        let prob = small_problem();
        let d = prob.d;
        let cfg = GdSecConfig { xi: Xi::Uniform(50.0), beta: 0.3, ..Default::default() };
        let mut ws = WorkerState::new(d);
        let theta = vec![0.1; d];
        prob.locals[0].grad(&theta, &mut ws.grad);
        let h_before = ws.h.clone();
        let diff: Vec<f64> = (0..d).map(|i| (i as f64 - 3.0) * 1e-4).collect();
        let e_before = ws.e.clone();
        let up = ws.sparsify_step(&cfg, prob.m(), &diff);
        let dense = up.to_dense();
        for i in 0..d {
            let delta = ws.grad[i] - h_before[i] + e_before[i];
            // reconstructed: wire + error == delta
            assert!(
                (dense[i] + ws.e[i] - delta).abs() < 1e-12,
                "EC identity violated at {i}"
            );
            if dense[i] == 0.0 {
                assert_eq!(ws.h[i], h_before[i], "h moved on suppressed comp");
            }
        }
    }

    #[test]
    fn heatmap_shape_and_totals() {
        let prob = Problem::linear(synthetic::coord_lipschitz(3), 10, 0.0);
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.01,
            xi: Xi::Uniform(50_000.0 * 10.0),
            ..Default::default()
        };
        let hm = transmission_heatmap(&prob, &cfg, 50);
        assert_eq!(hm.len(), 10);
        assert_eq!(hm[0].len(), 50);
        let total: u64 = hm.iter().flat_map(|r| r.iter()).sum();
        assert!(total > 0);
        assert!(hm.iter().flat_map(|r| r.iter()).all(|&c| c <= 50));
    }

    #[test]
    fn scheduled_half_participation_runs() {
        let prob = small_problem();
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.01,
            xi: Xi::Uniform(10.0),
            ..Default::default()
        };
        let m = prob.m();
        let trace = run_scheduled(&prob, &cfg, 100, |k| {
            // round robin halves
            let half = m / 2 + 1;
            Some((0..m).filter(|w| (w + k) % 2 == 0).take(half).collect())
        });
        assert!(trace.final_error().is_finite());
        assert!(trace.total_bits() > 0);
    }

    #[test]
    fn sparsify_into_reuses_buffer_and_matches_step() {
        let prob = small_problem();
        let d = prob.d;
        let cfg = GdSecConfig { xi: Xi::Uniform(20.0), beta: 0.1, ..Default::default() };
        let diff: Vec<f64> = (0..d).map(|i| (i as f64) * 1e-4).collect();
        let mut a = WorkerState::new(d);
        let mut b = WorkerState::new(d);
        let theta = vec![0.05; d];
        let mut reused = SparseUpdate::empty(d);
        for round in 0..3 {
            prob.locals[0].grad(&theta, a.grad_mut());
            prob.locals[0].grad(&theta, b.grad_mut());
            let fresh = a.sparsify_step(&cfg, prob.m(), &diff);
            b.sparsify_into(&cfg, prob.m(), &diff, &mut reused);
            assert_eq!(fresh, reused, "round {round}");
            assert_eq!(a.h, b.h);
            assert_eq!(a.e, b.e);
        }
        // Reuse keeps capacity: re-running the FIRST round's inputs on a
        // fresh state (same nnz as round 0) must not grow the buffer.
        let cap = (reused.idx.capacity(), reused.val.capacity());
        let mut c = WorkerState::new(d);
        prob.locals[0].grad(&theta, c.grad_mut());
        c.sparsify_into(&cfg, prob.m(), &diff, &mut reused);
        assert_eq!((reused.idx.capacity(), reused.val.capacity()), cap, "capacity churned");
    }

    #[test]
    fn requantize_fixup_matches_dense_reference() {
        // The sparse two-pointer walk must reproduce the old dense
        // round-trip exactly, including survivors quantized to level 0
        // (present in `original`, absent from `wire`).
        let d = 50;
        let cfg = GdSecConfig { beta: 0.3, ..Default::default() };
        let mut original = SparseUpdate::empty(d);
        let mut wire = SparseUpdate::empty(d);
        for (i, v) in [(3u32, 1.5f32), (7, -0.25), (20, 3.0), (21, 0.125), (49, -2.0)] {
            original.idx.push(i);
            original.val.push(v);
        }
        // wire: index 7 dropped (level 0), others re-quantized.
        for (i, v) in [(3u32, 1.25f32), (20, 3.5), (21, 0.125), (49, -1.75)] {
            wire.idx.push(i);
            wire.val.push(v);
        }
        let mut ws = WorkerState::new(d);
        for i in 0..d {
            ws.h[i] = (i as f64) * 0.01;
            ws.e[i] = -(i as f64) * 0.02;
        }
        let mut reference = ws.clone();
        ws.requantize_fixup(&cfg, &original, &wire);
        // Dense reference (the pre-optimization implementation).
        let orig_dense = original.to_dense();
        let wire_dense = wire.to_dense();
        for &i in &original.idx {
            let i = i as usize;
            let delta_wire = wire_dense[i] - orig_dense[i];
            reference.h[i] += cfg.beta * delta_wire;
            reference.e[i] -= delta_wire;
        }
        for i in 0..d {
            assert_eq!(ws.h[i].to_bits(), reference.h[i].to_bits(), "h[{i}]");
            assert_eq!(ws.e[i].to_bits(), reference.e[i].to_bits(), "e[{i}]");
        }
    }

    #[test]
    fn pooled_run_matches_serial_bitwise() {
        use crate::util::pool::Pool;
        let prob = small_problem();
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.05,
            xi: Xi::Uniform(40.0),
            fstar: Some(0.0),
            ..Default::default()
        };
        let serial = run_states(&prob, &cfg, 40, |_k| None, &Pool::new(1));
        let pooled = run_states(&prob, &cfg, 40, |_k| None, &Pool::new(4));
        for (a, b) in serial.trace.rows.iter().zip(&pooled.trace.rows) {
            assert_eq!(a.fval.to_bits(), b.fval.to_bits(), "iter {}", a.iter);
            assert_eq!(a.bits, b.bits);
            assert_eq!(a.entries, b.entries);
        }
        for i in 0..prob.d {
            assert_eq!(serial.server.theta[i].to_bits(), pooled.server.theta[i].to_bits());
            assert_eq!(serial.server.h[i].to_bits(), pooled.server.h[i].to_bits());
        }
    }

    #[test]
    fn quorum_fold_matches_manual_reference() {
        // One worker is late EVERY round through the engine's quorum
        // path (`step_quorum`): its transmission is parked by the cut
        // and folded into the next round's aggregation via
        // `fold_stale`, as if on time one round later. A hand-rolled
        // loop implementing exactly that semantics must match θ, server
        // h, and every worker's h/e bit-for-bit.
        use crate::algo::engine::Engine;
        use crate::util::pool::Pool;
        let prob = small_problem();
        let (m, d) = (prob.m(), prob.d);
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.05,
            xi: Xi::Uniform(20.0),
            fstar: Some(0.0),
            ..Default::default()
        };
        let late = [m - 1];
        let pool = Pool::new(1);
        let iters = 15;
        let mut eng =
            Engine::new(&prob, GdSecRule::new(cfg.clone()), &pool, &EngineOpts::default(), 0.0);
        for _ in 0..iters {
            eng.step_quorum(None, Some(&late));
        }
        eng.record();
        let run = eng.into_run();

        let mut server = ServerState::new(d);
        let mut workers: Vec<WorkerState> = (0..m).map(|_| WorkerState::new(d)).collect();
        let mut theta_diff = vec![0.0; d];
        let mut parked: Option<SparseUpdate> = None;
        for _k in 1..=iters {
            // Previous round's parked update folds first (stale-before-
            // fresh order), staged into the aggregation scratch.
            if let Some(s) = parked.take() {
                server.fold_update(&s);
            }
            server.theta_diff(&mut theta_diff);
            let mut ups: Vec<SparseUpdate> = Vec::new();
            for (w, ws) in workers.iter_mut().enumerate() {
                prob.locals[w].grad(&server.theta, ws.grad_mut());
                let up = ws.sparsify_step(&cfg, m, &theta_diff);
                if up.nnz() == 0 {
                    continue;
                }
                if w == m - 1 {
                    parked = Some(up); // cut: arrives next round
                } else {
                    ups.push(up);
                }
            }
            server.apply_round(&cfg, &ups);
        }
        for i in 0..d {
            assert_eq!(run.server.theta[i].to_bits(), server.theta[i].to_bits(), "theta[{i}]");
            assert_eq!(run.server.h[i].to_bits(), server.h[i].to_bits(), "h[{i}]");
        }
        for (w, (el, ws)) in run.lanes.iter().zip(&workers).enumerate() {
            for i in 0..d {
                assert_eq!(el.ws.h[i].to_bits(), ws.h[i].to_bits(), "worker {w} h[{i}]");
                assert_eq!(el.ws.e[i].to_bits(), ws.e[i].to_bits(), "worker {w} e[{i}]");
            }
        }
        // The straggler's updates really were deferred (stale folds
        // happened) — otherwise this test proves nothing.
        assert!(run.trace.total_stale() > 0, "no stale update was ever folded");
    }

    #[test]
    fn quorum_aged_fold_matches_manual_reference() {
        // Multi-round bounded staleness: the straggler's transmission
        // spends TWO rounds in flight (it computes nothing while its
        // update is in transit), folding via `fold_stale` at age 2. A
        // hand-rolled loop with exactly those semantics — park with a due
        // round, skip the worker's compute while in flight, fold the
        // parked Δ̂ ahead of the fresh updates at its due round — must
        // match θ, server h, and every worker's h/e bit-for-bit: the
        // aged fold is the same Eq. 6 step, just later, so the EC
        // identity survives any age within the window.
        use crate::algo::engine::Engine;
        use crate::util::pool::Pool;
        let prob = small_problem();
        let (m, d) = (prob.m(), prob.d);
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.05,
            xi: Xi::Uniform(20.0),
            fstar: Some(0.0),
            ..Default::default()
        };
        let straggler = m - 1;
        let late = [(straggler, 2u32)];
        let pool = Pool::new(1);
        let iters = 16;
        let opts = EngineOpts { stale_window: 3, ..EngineOpts::default() };
        let mut eng = Engine::new(&prob, GdSecRule::new(cfg.clone()), &pool, &opts, 0.0);
        for _ in 0..iters {
            // Parked rounds are a no-op for the straggler (nothing
            // transmitted while in flight), so passing the pair every
            // round parks each of its transmissions at age 2.
            eng.step_quorum_aged(None, Some(&late));
        }
        eng.record();
        let run = eng.into_run();

        let mut server = ServerState::new(d);
        let mut workers: Vec<WorkerState> = (0..m).map(|_| WorkerState::new(d)).collect();
        let mut theta_diff = vec![0.0; d];
        let mut parked: Option<(usize, SparseUpdate)> = None; // (due round, Δ̂)
        for k in 1..=iters {
            if parked.as_ref().is_some_and(|(due, _)| *due == k) {
                let (_, u) = parked.take().unwrap();
                server.fold_update(&u);
            }
            server.theta_diff(&mut theta_diff);
            let mut ups: Vec<SparseUpdate> = Vec::new();
            for (w, ws) in workers.iter_mut().enumerate() {
                if w == straggler && parked.is_some() {
                    continue; // mid-flight: the worker computes nothing
                }
                prob.locals[w].grad(&server.theta, ws.grad_mut());
                let up = ws.sparsify_step(&cfg, m, &theta_diff);
                if up.nnz() == 0 {
                    continue;
                }
                if w == straggler {
                    parked = Some((k + 2, up)); // in flight for 2 rounds
                } else {
                    ups.push(up);
                }
            }
            server.apply_round(&cfg, &ups);
        }
        for i in 0..d {
            assert_eq!(run.server.theta[i].to_bits(), server.theta[i].to_bits(), "theta[{i}]");
            assert_eq!(run.server.h[i].to_bits(), server.h[i].to_bits(), "h[{i}]");
        }
        for (w, (el, ws)) in run.lanes.iter().zip(&workers).enumerate() {
            for i in 0..d {
                assert_eq!(el.ws.h[i].to_bits(), ws.h[i].to_bits(), "worker {w} h[{i}]");
                assert_eq!(el.ws.e[i].to_bits(), ws.e[i].to_bits(), "worker {w} e[{i}]");
            }
        }
        // Age-2 folds really happened, and ONLY age-2 folds.
        let last = run.trace.rows.last().unwrap();
        assert!(run.trace.total_stale() > 0, "no stale update was ever folded");
        assert_eq!(last.stale_ages[1], run.trace.total_stale(), "folds not all age 2");
        assert_eq!(last.stale_ages[0] + last.stale_ages[2] + last.stale_ages[3], 0);
    }

    #[test]
    fn soec_variant_differs() {
        let prob = small_problem();
        let alpha = 1.0 / prob.lipschitz();
        let with_ec = run(
            &prob,
            &GdSecConfig { alpha, xi: Xi::Uniform(100.0), ..Default::default() },
            150,
        );
        let no_ec = run(
            &prob,
            &GdSecConfig {
                alpha,
                xi: Xi::Uniform(100.0),
                error_correction: false,
                ..Default::default()
            },
            150,
        );
        // EC should not be worse in final error (usually much better).
        assert!(with_ec.final_error() <= no_ec.final_error() * 1.5 + 1e-12);
    }
}
