//! The unified round engine: ONE generic trainer loop shared by GD-SEC
//! and every baseline.
//!
//! Every synchronous method in this repo has the same round shape — each
//! worker computes a local gradient, applies a *compression rule*
//! (censor / quantize / top-j / nothing), the server folds the surviving
//! updates in worker-id order and steps θ. The rule is the ONLY
//! per-method degree of freedom (exactly the framing of LAQ-style
//! analyses), so the engine owns everything else:
//!
//! * the per-round fan-out over the persistent [`Pool`] (parked workers,
//!   zero-alloc dispatch),
//! * the θ / θ-diff bookkeeping and the trace rows with byte-exact bit
//!   accounting,
//! * the **nested (worker × row-block) gradient lanes**: every worker's
//!   shard is pre-cut into contiguous row blocks by an **nnz budget**
//!   ([`Features::split_rows_by_nnz`](crate::data::Features::split_rows_by_nnz)),
//!   the flattened (worker, block) units scatter across the pool — so M
//!   workers saturate many more than M cores — and each worker's blocks
//!   fold in ascending row order
//!   ([`LocalObjective::fold_block_grads`](crate::objectives::LocalObjective::fold_block_grads)).
//!
//! ## Determinism contract
//!
//! The block tree is fixed by the problem and the
//! [`EngineOpts::nnz_budget`] — never by the pool's thread count — and
//! both reductions (block→gradient and lane→server) run in a fixed
//! order, so trajectories are **bit-for-bit identical for any thread
//! count** (pinned by `tests/prop_parallel_parity.rs`, including forced
//! multi-block lanes). With the default (cache-derived, ≥64k-scale)
//! budget, test-suite shards stay single-block, and a one-block fold is
//! bitwise equal to the serial fused gradient pass — which is how the
//! engine also stays
//! bit-identical to the threaded [`crate::coordinator`] (whose native
//! workers run the same tree via
//! [`LocalObjective::grad_blocked`](crate::objectives::LocalObjective::grad_blocked)).
//!
//! Steady-state rounds allocate nothing: lanes, block buffers, and the
//! θ-diff scratch are built once, and a [`Pool::scatter`] round is a
//! stack context + fn pointer (pinned by `tests/alloc_free_round.rs`,
//! which drives real [`Engine::step`] rounds under a counting
//! allocator). Future scenarios — async rounds, device placement,
//! straggler schedules — plug in as rules or engine hooks without
//! touching the trainers.

use super::gdsec::ServerState;
use super::trace::{stale_age_bin, Trace, TraceRow, STALE_AGE_BINS};
use crate::compress::{SparseUpdate, WireFormat};
use crate::objectives::{GradSplit, Problem};
use crate::util::pool::Pool;

/// Parse a staleness-window spec: a positive round count.
pub fn parse_stale_window(s: &str) -> Result<usize, String> {
    match s.parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        Ok(n) => Err(format!("window {n} rejected (an update must be allowed to fold at \
                              least one round late)")),
        Err(_) => Err(format!("got {s:?}")),
    }
}

/// The staleness window S from `GDSEC_STALE_WINDOW` (default 1): the
/// maximum number of rounds a transmitted update may spend in flight
/// before it MUST fold (or, at the bound, be dropped). S = 1 is the PR 4
/// behavior — every parked update folds exactly one round late — and the
/// setting the synchronous bitwise pins are stated under. Shared by
/// [`EngineOpts::from_env`] and the coordinator's
/// [`CoordConfig`](crate::coordinator::CoordConfig).
///
/// Panics on `0` or garbage, matching the strict `GDSEC_QUORUM` error
/// style: the historical lenient parse silently fell back to 1, so a CI
/// leg exporting `GDSEC_STALE_WINDOW=O3` (a typo) would quietly pin the
/// synchronous window while claiming to test multi-round staleness.
pub fn stale_window_from_env() -> usize {
    match std::env::var("GDSEC_STALE_WINDOW").ok().as_deref() {
        None | Some("") => 1,
        Some(s) => parse_stale_window(s).unwrap_or_else(|e| {
            panic!("GDSEC_STALE_WINDOW must be a positive round count: {e}")
        }),
    }
}

/// Wire accounting for one worker's transmission in one round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sent {
    /// Payload bits put on the uplink (the paper's metric).
    pub bits: u64,
    /// Non-zero entries carried by the message.
    pub entries: u64,
}

/// Immutable shared state a rule sees during a round's parallel phase.
#[derive(Clone, Copy)]
pub struct RoundCtx<'a> {
    /// The problem (shard access for `Custom`-gradient rules).
    pub prob: &'a Problem,
    /// Iteration number (1-based; 0 is the initial iterate).
    pub k: usize,
    /// Worker count M.
    pub m: usize,
    /// θ^k.
    pub theta: &'a [f64],
    /// θ^k − θ^{k−1} (all zeros unless the rule wants it).
    pub theta_diff: &'a [f64],
    /// max_i |θ^k_i − θ^{k−1}_i| (0.0 unless the rule wants the diff).
    pub diff_max: f64,
    /// Uplink accounting format for sparse-update rules
    /// ([`crate::compress::wire_bits`]); dense/quantized payloads are
    /// format-independent.
    pub wire: WireFormat,
}

/// Who computes the worker gradient.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradMode {
    /// The engine computes the full local gradient into
    /// [`CompressRule::grad_buf`] through the nested block lanes before
    /// calling `compress` (deterministic full-batch methods).
    Full,
    /// The rule computes its own gradient inside `compress` (stochastic
    /// methods with per-lane RNG streams — row-split lanes cannot apply).
    Custom,
}

/// One worker's slot in the engine's fan-out: the rule's lane state plus
/// this round's wire accounting (`None` = inactive or censored-silent).
pub struct EngineLane<L> {
    pub lane: L,
    pub sent: Option<Sent>,
}

/// A compression rule: the per-method degree of freedom the engine is
/// parameterized by. Parallel-phase methods take `&self` (they run
/// concurrently across lanes); sequential hooks take `&mut self`.
pub trait CompressRule: Sync {
    /// Per-worker state (error memories, RNG streams, wire buffers …).
    type Lane: Send;

    /// Trace label (e.g. "GD-SEC", "top-10").
    fn name(&self) -> String;

    /// Build worker `w`'s lane.
    fn make_lane(&self, prob: &Problem, w: usize) -> Self::Lane;

    /// See [`GradMode`].
    fn grad_mode(&self) -> GradMode {
        GradMode::Full
    }

    /// Rule needs θ^k − θ^{k−1} each round (censoring thresholds).
    fn wants_theta_diff(&self) -> bool {
        false
    }

    /// Where the engine writes the full local gradient (`Full` mode).
    fn grad_buf<'l>(&self, _lane: &'l mut Self::Lane) -> &'l mut [f64] {
        &mut []
    }

    /// Sequential hook before the fan-out (per-round step sizes, shared
    /// censoring thresholds).
    fn begin_round(&mut self, _ctx: &RoundCtx) {}

    /// Worker `w`'s compression step (parallel; lane-local state only).
    /// Returns the wire accounting, or `None` for a silent round.
    fn compress(&self, ctx: &RoundCtx, w: usize, lane: &mut Self::Lane) -> Option<Sent>;

    /// Rule performs a pre-loop memory-seeding round (NoUnif-IAG): every
    /// worker's gradient is computed and [`seed`](Self::seed) transmits it
    /// before iteration 1.
    fn seeds_memories(&self) -> bool {
        false
    }

    /// Seeding transmission for worker `w` (parallel, `Full` mode only).
    fn seed(&self, _w: usize, _lane: &mut Self::Lane) -> Sent {
        unreachable!("rule does not seed memories")
    }

    /// Server-side fold + θ step (sequential, worker-id order is the
    /// caller's guarantee). `k` is the 1-based iteration.
    fn apply(
        &mut self,
        k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<Self::Lane>],
        pool: &Pool,
    );

    /// Whether a quorum cut actually defers this rule's late
    /// transmissions. Memory-based rules (CGD, NoUnif-IAG) return
    /// false: their `apply` folds every worker's server-side memory
    /// each round regardless of `sent`, so a "late" transmission lands
    /// in the current aggregation anyway — the engine neither parks
    /// their lanes nor counts stale folds for them.
    fn defers_late(&self) -> bool {
        true
    }

    /// Fold worker `w`'s update from an EARLIER round — still in its
    /// lane, parked by a quorum cut ([`Engine::step_quorum`] /
    /// [`Engine::step_quorum_aged`]) — into round `k`'s upcoming
    /// [`apply`](Self::apply), **as if it had arrived on time**: staged
    /// ahead of the fresh updates so the server performs the same step
    /// `age` rounds late rather than dropping bits on the floor. `age ∈
    /// [1, S]` (the engine's staleness window) is how many rounds the
    /// update spent in flight; a worker whose update is in flight does
    /// not compute, so the lane still holds the parked wire image.
    /// Called sequentially in `(origin round, worker)` order before the
    /// fan-out overwrites the lane. Synchronous runs (no quorum cuts)
    /// never call this, which is what keeps them bit-identical to the
    /// pre-quorum engine; neither do rules with
    /// [`defers_late`](Self::defers_late) = false.
    ///
    /// GD-SEC-family rules stage into [`ServerState::fold_update`] (the
    /// worker already moved its h_m/e_m at transmission, so the late
    /// fold preserves the EC identity at any age); dense rules
    /// accumulate into a [`StalePending`] buffer their `apply` folds
    /// first. No rule currently weights by `age` — the EC identity is
    /// exact without aging — but the parameter is the seam where
    /// LAQ-style aging factors would plug in.
    fn fold_stale(
        &mut self,
        k: usize,
        server: &mut ServerState,
        w: usize,
        lane: &mut Self::Lane,
        age: u32,
    );

    /// Reset worker `w`'s server-side slot for a crash → restart
    /// re-admission ([`Engine::rejoin_worker`]): the restarted worker
    /// comes back with zeroed local memories (h_m, e_m), so any
    /// server-side mirror of its state must be retired — otherwise the
    /// server keeps stepping with an h share the worker will never
    /// again account for and the EC identity is permanently broken.
    /// GD-SEC-family rules subtract the lane's h_m from the server's h
    /// and zero the lane; stateless rules need nothing.
    fn rejoin_worker(&mut self, _server: &mut ServerState, _w: usize, _lane: &mut Self::Lane) {}
}

/// Staging buffer behind the dense rules' [`CompressRule::fold_stale`]:
/// late wire images accumulate here (in the engine's `(origin round,
/// worker)` fold order — oldest transmissions first, ages capped at the
/// staleness window S) and the next `apply` folds the staged sum ahead
/// of the fresh lanes — `agg = 0 + staged + Σ fresh`, bitwise the same
/// sequence as if the late updates had led the fold on time. All-zero and
/// [`staged`](StalePending::staged) = `None` when no cut occurred, so
/// synchronous applies are untouched op-for-op. Reuses one pre-sized
/// buffer: the stale path stays allocation-free.
#[derive(Debug, Clone)]
pub struct StalePending {
    buf: Vec<f64>,
    dirty: bool,
}

impl StalePending {
    pub fn new(d: usize) -> StalePending {
        StalePending { buf: vec![0.0; d], dirty: false }
    }

    /// Stage a late dense wire image.
    pub fn fold(&mut self, v: &[f64]) {
        crate::linalg::axpy(1.0, v, &mut self.buf);
        self.dirty = true;
    }

    /// Stage a late sparse update.
    pub fn fold_sparse(&mut self, u: &SparseUpdate) {
        u.add_into(&mut self.buf);
        self.dirty = true;
    }

    /// The staged sum to fold ahead of the fresh lanes (`None` when
    /// nothing is pending — the synchronous fast path).
    pub fn staged(&self) -> Option<&[f64]> {
        self.dirty.then_some(self.buf.as_slice())
    }

    /// Re-zero after an `apply` consumed the staged sum.
    pub fn consume(&mut self) {
        if self.dirty {
            crate::linalg::zero(&mut self.buf);
            self.dirty = false;
        }
    }
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineOpts {
    /// nnz budget per nested row-block lane. Default: the shared cache
    /// model's L2-resident budget
    /// ([`crate::util::cache::auto_nnz_budget`]; 64k on the 1 MiB-L2
    /// reference machine, the old fixed constant). Smaller ⇒ more
    /// intra-worker parallelism (and a different — still
    /// thread-count-independent — summation tree);
    /// `GDSEC_NNZ_BUDGET=<n>` pins the tree for cross-machine
    /// reproduction.
    pub nnz_budget: usize,
    /// Uplink accounting format for sparse-update rules. Default
    /// [`WireFormat::Adaptive`] (tag byte + cheaper of sparse/dense —
    /// matches the coordinator's encoded frames byte-for-byte);
    /// `Sparse` reproduces the paper's accounting.
    pub wire: WireFormat,
    /// Staleness window S (≥ 1): the maximum age, in rounds, a
    /// quorum-parked update may reach before it folds. `step_quorum`
    /// always parks at age 1; [`Engine::step_quorum_aged`] may park up
    /// to S. Default 1 (the PR 4 one-round-late behavior;
    /// `GDSEC_STALE_WINDOW` overrides via [`from_env`](Self::from_env)).
    pub stale_window: usize,
}

impl Default for EngineOpts {
    fn default() -> EngineOpts {
        EngineOpts {
            nnz_budget: crate::util::cache::auto_nnz_budget(),
            wire: WireFormat::default(),
            stale_window: 1,
        }
    }
}

impl EngineOpts {
    /// Default opts with the `GDSEC_NNZ_BUDGET` / `GDSEC_WIRE` /
    /// `GDSEC_STALE_WINDOW` env overrides (cached/constant within a
    /// process, so every run in a process sees the same block tree and
    /// accounting). `GDSEC_NNZ_BUDGET` accepts `auto` (or unset) for
    /// the cache-derived L2-resident budget, or a positive integer to
    /// pin the tree ([`crate::util::cache::nnz_budget_from_env`]).
    pub fn from_env() -> EngineOpts {
        EngineOpts {
            nnz_budget: crate::util::cache::nnz_budget_from_env(),
            wire: WireFormat::from_env(),
            stale_window: stale_window_from_env(),
        }
    }
}

/// How a quorum round's late set is specified (internal seam between
/// [`Engine::step_quorum`] and [`Engine::step_quorum_aged`]).
enum LateSpec<'a> {
    /// Worker ids, all parked at age 1 (the PR 4 semantics).
    Uniform(&'a [usize]),
    /// `(worker, delivery age)` pairs, ages within the staleness window.
    Aged(&'a [(usize, u32)]),
}

/// Final state of an engine run.
pub struct EngineRun<R: CompressRule> {
    pub trace: Trace,
    pub server: ServerState,
    pub rule: R,
    pub lanes: Vec<R::Lane>,
}

/// Cumulative wire accounting.
#[derive(Debug, Clone, Copy, Default)]
struct Acct {
    bits: u64,
    tx: u64,
    entries: u64,
    /// Stale updates folded late via [`CompressRule::fold_stale`].
    stale: u64,
    /// Staleness-age histogram of those folds ([`stale_age_bin`]).
    stale_ages: [u64; STALE_AGE_BINS],
}

/// The resumable engine: [`new`](Engine::new) builds every buffer once,
/// [`step`](Engine::step) runs one allocation-free optimizer round, and
/// [`record`](Engine::record) appends a trace row. [`run_rule`] is the
/// convenience driver the trainers use.
pub struct Engine<'p, R: CompressRule> {
    prob: &'p Problem,
    pool: &'p Pool,
    pub rule: R,
    pub server: ServerState,
    lanes: Vec<EngineLane<R::Lane>>,
    /// Fixed nested (worker, row-block) lane tree (`Full`-grad rules).
    split: Option<GradSplit>,
    /// Lane-index span of each worker's blocks inside `split`.
    spans: Vec<(usize, usize)>,
    /// Per-round participation flags (reused).
    flags: Vec<bool>,
    /// Per-worker in-flight state for quorum-parked transmissions: the
    /// absolute round at which the parked update folds (0 = nothing in
    /// flight). While `parked_due[w] > k` the worker is mid-transit —
    /// it computes nothing, so the lane keeps holding the parked wire
    /// image — and at round `parked_due[w]` the update folds via
    /// [`CompressRule::fold_stale`].
    parked_due: Vec<usize>,
    /// The round each in-flight update was transmitted in (its fold age
    /// is `due − origin`, bounded by [`EngineOpts::stale_window`]).
    parked_round: Vec<usize>,
    /// Staleness window S (see [`EngineOpts::stale_window`]).
    stale_window: usize,
    theta_diff: Vec<f64>,
    wire: WireFormat,
    acct: Acct,
    trace: Trace,
    k: usize,
}

impl<'p, R: CompressRule> Engine<'p, R> {
    pub fn new(prob: &'p Problem, rule: R, pool: &'p Pool, opts: &EngineOpts, fstar: f64) -> Self {
        assert!(opts.stale_window >= 1, "stale_window must be at least 1");
        let m = prob.m();
        let d = prob.d;
        let lanes: Vec<EngineLane<R::Lane>> = (0..m)
            .map(|w| EngineLane { lane: rule.make_lane(prob, w), sent: None })
            .collect();
        let (split, spans) = match rule.grad_mode() {
            GradMode::Full => {
                let split = GradSplit::new_by_nnz(prob, opts.nnz_budget);
                let spans = split.worker_spans(m);
                (Some(split), spans)
            }
            GradMode::Custom => (None, Vec::new()),
        };
        let trace = Trace::new(&rule.name(), &prob.name, fstar);
        // Pre-build the server's coordinate-shard plan so the first
        // pooled apply doesn't pay the slot-table build inside the
        // zero-alloc steady state.
        let mut server = ServerState::new(d);
        server.warm_shard_plan(pool);
        Engine {
            prob,
            pool,
            rule,
            server,
            lanes,
            split,
            spans,
            flags: vec![true; m],
            parked_due: vec![0; m],
            parked_round: vec![0; m],
            stale_window: opts.stale_window,
            theta_diff: vec![0.0; d],
            wire: opts.wire,
            acct: Acct::default(),
            trace,
            k: 0,
        }
    }

    /// The current iteration (0 before the first [`step`](Engine::step)).
    pub fn iter(&self) -> usize {
        self.k
    }

    /// Record a trace row for the current iterate, evaluating f(θ) with
    /// per-worker local values fanned out over the pool and summed in
    /// worker order (bitwise equal to the serial evaluation).
    pub fn record(&mut self) {
        self.trace.push(TraceRow {
            iter: self.k,
            fval: self.prob.value_pooled(&self.server.theta, self.pool),
            bits: self.acct.bits,
            transmissions: self.acct.tx,
            entries: self.acct.entries,
            stale: self.acct.stale,
            stale_ages: self.acct.stale_ages,
            ..TraceRow::default()
        });
    }

    /// Re-admit worker `w` after a crash → restart: drop any in-flight
    /// parked transmission (the pre-crash computation never folds) and
    /// let the rule retire the worker's server-side state mirror
    /// ([`CompressRule::rejoin_worker`]). The distributed coordinator
    /// calls the same rule hook through its re-admission handshake; this
    /// engine-side entry point exists for in-process simulation and for
    /// unit-testing the hook's EC identity.
    pub fn rejoin_worker(&mut self, w: usize) {
        self.parked_due[w] = 0;
        self.parked_round[w] = 0;
        let lane = &mut self.lanes[w];
        lane.sent = None;
        self.rule.rejoin_worker(&mut self.server, w, &mut lane.lane);
    }

    /// The pre-loop memory-seeding round (rules with
    /// [`CompressRule::seeds_memories`]): every worker's gradient is
    /// computed through the nested lanes and [`CompressRule::seed`]
    /// transmits it; accounting folds in worker-id order. No θ step.
    pub fn seed_round(&mut self) {
        debug_assert!(matches!(self.rule.grad_mode(), GradMode::Full));
        self.flags.fill(true);
        self.fan_out_full(0, 0.0, true);
        self.fold_accounting();
    }

    /// One optimizer round: θ-diff, participation flags, rule pre-hook,
    /// nested gradient + compress fan-out, accounting fold (worker-id
    /// order), server apply. Allocation-free after warm-up (for `act ==
    /// None` schedules and allocation-free rules).
    pub fn step(&mut self, act: Option<&[usize]>) {
        self.step_quorum(act, None);
    }

    /// [`step`](Engine::step) with a semi-synchronous quorum cut: lanes
    /// in `late` (worker ids whose virtual reply misses this round's
    /// quorum) still compute and transmit — their bits are accounted
    /// this round — but their updates are **parked** instead of applied,
    /// and folded into the NEXT round's apply through
    /// [`CompressRule::fold_stale`], as if they had arrived on time one
    /// round later. `late: None` (or an empty set) is the synchronous
    /// round, bit-identical to the pre-quorum engine. Allocation-free
    /// after warm-up, including the stale-fold path (pinned by
    /// `tests/alloc_free_round.rs`).
    pub fn step_quorum(&mut self, act: Option<&[usize]>, late: Option<&[usize]>) {
        self.step_inner(act, LateSpec::Uniform(late.unwrap_or(&[])));
    }

    /// [`step_quorum`](Engine::step_quorum) with per-worker delivery
    /// ages: each `(w, age)` pair parks worker `w`'s transmission for
    /// `age ∈ [1, S]` rounds (S = [`EngineOpts::stale_window`]; ages
    /// outside the window panic — the window is a hard bound). While an
    /// update is in flight its worker computes nothing — the physical
    /// straggler semantics: a worker that takes `age` rounds to deliver
    /// was busy for those rounds — and the lane keeps the parked wire
    /// image until the fold. Folds happen at the start of the due round
    /// in `(origin round, worker)` order. `age = 1` for every pair
    /// reproduces [`step_quorum`](Engine::step_quorum) exactly.
    /// Allocation-free after warm-up.
    pub fn step_quorum_aged(&mut self, act: Option<&[usize]>, late: Option<&[(usize, u32)]>) {
        self.step_inner(act, LateSpec::Aged(late.unwrap_or(&[])));
    }

    fn step_inner(&mut self, act: Option<&[usize]>, late: LateSpec) {
        self.k += 1;
        let k = self.k;
        // Fold in-flight updates that come due THIS round, before the
        // fan-out can overwrite their lanes: they reach the server
        // "during" this round, staged ahead of the fresh updates, in
        // (origin round, worker) order — oldest transmissions first.
        // With the default window S = 1 this scans exactly the previous
        // round in ascending worker order: op-for-op the PR 4 fold loop.
        for origin in k.saturating_sub(self.stale_window)..k {
            for w in 0..self.lanes.len() {
                if self.parked_due[w] == k && self.parked_round[w] == origin {
                    self.parked_due[w] = 0;
                    let age = (k - origin) as u32;
                    self.rule.fold_stale(k, &mut self.server, w, &mut self.lanes[w].lane, age);
                    self.acct.stale += 1;
                    self.acct.stale_ages[stale_age_bin(age)] += 1;
                }
            }
        }
        let diff_max = if self.rule.wants_theta_diff() {
            // Fused diff + stationarity max — the quantity censoring
            // thresholds scale with, surfaced as debug telemetry. The
            // `enabled` gate keeps the disabled path format-free (the
            // zero-alloc round invariant).
            let dm = self.server.theta_diff_max(&mut self.theta_diff);
            if crate::util::enabled(crate::util::Level::Debug) {
                crate::debugln!("{} k={k}: max|Δθ| = {dm:.3e}", self.trace.algo);
            }
            dm
        } else {
            0.0
        };
        for (w, f) in self.flags.iter_mut().enumerate() {
            // A worker whose transmission is still in flight computes
            // nothing this round, whatever the schedule says.
            *f = self.parked_due[w] == 0 && act.map_or(true, |set| set.contains(&w));
        }
        {
            let ctx = RoundCtx {
                prob: self.prob,
                k,
                m: self.lanes.len(),
                theta: &self.server.theta,
                theta_diff: &self.theta_diff,
                diff_max,
                wire: self.wire,
            };
            self.rule.begin_round(&ctx);
        }
        match self.rule.grad_mode() {
            GradMode::Full => self.fan_out_full(k, diff_max, false),
            GradMode::Custom => self.fan_out_custom(k, diff_max),
        }
        self.fold_accounting();
        // Park the quorum cut's late transmissions: accounted above (the
        // bits went on the wire this round), excluded from this apply,
        // folded at the start of their due round (origin + age, age ≤
        // S). Silent late lanes have nothing to park, and memory-based
        // rules (`defers_late` false) are never parked — their apply
        // folds the refreshed memory this round regardless. A lane still
        // parked when the run ends is an in-flight transmission at
        // shutdown: dropped, bits charged.
        if self.rule.defers_late() {
            match late {
                LateSpec::Uniform(set) => {
                    for &w in set {
                        self.park(w, 1);
                    }
                }
                LateSpec::Aged(pairs) => {
                    for &(w, age) in pairs {
                        self.park(w, age);
                    }
                }
            }
        }
        self.rule.apply(k, &mut self.server, &self.lanes, self.pool);
    }

    /// Park worker `w`'s fresh transmission (if any) for `age` rounds.
    fn park(&mut self, w: usize, age: u32) {
        assert!(
            age >= 1 && age as usize <= self.stale_window,
            "delivery age {age} outside the staleness window [1, {}]",
            self.stale_window
        );
        if self.lanes[w].sent.is_some() {
            self.lanes[w].sent = None;
            self.parked_due[w] = self.k + age as usize;
            self.parked_round[w] = self.k;
        }
    }

    /// `Full`-grad fan-out: phase 1 scatters the flattened (worker,
    /// row-block) units — each block accumulates its private partial —
    /// and phase 2 scatters the worker lanes, folding each worker's
    /// blocks in ascending row order into the rule's gradient buffer
    /// before running `compress` (or `seed`). Both phases assign work by
    /// fixed chunking, so results are thread-count independent.
    fn fan_out_full(&mut self, k: usize, diff_max: f64, seeding: bool) {
        let prob = self.prob;
        let split = self.split.as_mut().expect("Full-grad rule without a block tree");
        let flags = &self.flags;
        let theta: &[f64] = &self.server.theta;
        self.pool.scatter(&mut split.lanes, |_, bl| {
            if !flags[bl.worker] {
                return;
            }
            crate::linalg::zero(&mut bl.buf);
            prob.locals[bl.worker].grad_data_range(theta, bl.start, bl.end, &mut bl.buf);
        });
        let split = &*split;
        let spans = &self.spans;
        let rule = &self.rule;
        let ctx = RoundCtx {
            prob,
            k,
            m: self.lanes.len(),
            theta,
            theta_diff: &self.theta_diff,
            diff_max,
            wire: self.wire,
        };
        self.pool.scatter(&mut self.lanes, |w, el| {
            if !flags[w] {
                el.sent = None;
                return;
            }
            let (b0, b1) = spans[w];
            {
                let grad = rule.grad_buf(&mut el.lane);
                prob.locals[w].fold_block_grads(
                    theta,
                    split.lanes[b0..b1].iter().map(|bl| bl.buf.as_slice()),
                    grad,
                );
            }
            el.sent = if seeding {
                Some(rule.seed(w, &mut el.lane))
            } else {
                rule.compress(&ctx, w, &mut el.lane)
            };
        });
    }

    /// `Custom`-grad fan-out: one scatter; the rule computes its own
    /// gradient inside `compress` (per-lane RNG streams stay per-lane).
    fn fan_out_custom(&mut self, k: usize, diff_max: f64) {
        let flags = &self.flags;
        let rule = &self.rule;
        let ctx = RoundCtx {
            prob: self.prob,
            k,
            m: self.lanes.len(),
            theta: &self.server.theta,
            theta_diff: &self.theta_diff,
            diff_max,
            wire: self.wire,
        };
        self.pool.scatter(&mut self.lanes, |w, el| {
            if !flags[w] {
                el.sent = None;
                return;
            }
            el.sent = rule.compress(&ctx, w, &mut el.lane);
        });
    }

    /// Fold this round's per-lane wire accounting in worker-id order.
    fn fold_accounting(&mut self) {
        for el in &self.lanes {
            if let Some(s) = el.sent {
                self.acct.bits += s.bits;
                self.acct.tx += 1;
                self.acct.entries += s.entries;
            }
        }
    }

    pub fn into_run(self) -> EngineRun<R> {
        EngineRun {
            trace: self.trace,
            server: self.server,
            rule: self.rule,
            lanes: self.lanes.into_iter().map(|el| el.lane).collect(),
        }
    }
}

/// The dense server fold shared by the uncompressed-wire rules (GD, QGD,
/// SGD): `agg = Σ vecs` in the caller's iteration order (worker-id order,
/// with the rule's own participation filter), then `θ -= α·agg`.
/// Op-for-op the baselines' historical apply loop, so a rule switching to
/// this helper never moves a bit.
pub fn apply_dense_fold<'a, I>(alpha: f64, vecs: I, agg: &mut [f64], theta: &mut [f64])
where
    I: Iterator<Item = &'a [f64]>,
{
    crate::linalg::zero(agg);
    for v in vecs {
        crate::linalg::axpy(1.0, v, agg);
    }
    crate::linalg::axpy(-alpha, agg, theta);
}

/// Run `rule` for `iters` rounds with a participation schedule
/// (`active(k)`: participating worker ids at iteration k, `None` = all)
/// and the standard eval cadence (record at iteration 0, every
/// `eval_every`-th round, and the final round).
#[allow(clippy::too_many_arguments)]
pub fn run_rule<R, F>(
    prob: &Problem,
    rule: R,
    iters: usize,
    eval_every: usize,
    fstar: f64,
    mut active: F,
    pool: &Pool,
    opts: &EngineOpts,
) -> EngineRun<R>
where
    R: CompressRule,
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    let mut eng = Engine::new(prob, rule, pool, opts, fstar);
    eng.record();
    if eng.rule.seeds_memories() {
        eng.seed_round();
    }
    for k in 1..=iters {
        let act = active(k);
        eng.step(act.as_deref());
        if k % eval_every == 0 || k == iters {
            eng.record();
        }
    }
    eng.into_run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stale_window_parse_contract() {
        assert_eq!(parse_stale_window("1"), Ok(1));
        assert_eq!(parse_stale_window("3"), Ok(3));
        // Zero and garbage are loud errors, not silent fallbacks to 1.
        assert!(parse_stale_window("0").is_err());
        assert!(parse_stale_window("-1").is_err());
        assert!(parse_stale_window("2.5").is_err());
        assert!(parse_stale_window("O3").is_err());
    }
}
