//! The unified round engine: ONE generic trainer loop shared by GD-SEC
//! and every baseline.
//!
//! Every synchronous method in this repo has the same round shape — each
//! worker computes a local gradient, applies a *compression rule*
//! (censor / quantize / top-j / nothing), the server folds the surviving
//! updates in worker-id order and steps θ. The rule is the ONLY
//! per-method degree of freedom (exactly the framing of LAQ-style
//! analyses), so the engine owns everything else:
//!
//! * the per-round fan-out over the persistent [`Pool`] (parked workers,
//!   zero-alloc dispatch),
//! * the θ / θ-diff bookkeeping and the trace rows with byte-exact bit
//!   accounting,
//! * the **nested (worker × row-block) gradient lanes**: every worker's
//!   shard is pre-cut into contiguous row blocks by an **nnz budget**
//!   ([`Features::split_rows_by_nnz`](crate::data::Features::split_rows_by_nnz)),
//!   the flattened (worker, block) units scatter across the pool — so M
//!   workers saturate many more than M cores — and each worker's blocks
//!   fold in ascending row order
//!   ([`LocalObjective::fold_block_grads`](crate::objectives::LocalObjective::fold_block_grads)).
//!
//! ## Determinism contract
//!
//! The block tree is fixed by the problem and the
//! [`EngineOpts::nnz_budget`] — never by the pool's thread count — and
//! both reductions (block→gradient and lane→server) run in a fixed
//! order, so trajectories are **bit-for-bit identical for any thread
//! count** (pinned by `tests/prop_parallel_parity.rs`, including forced
//! multi-block lanes). With the default budget, shards below ~64k nnz
//! stay single-block, and a one-block fold is bitwise equal to the
//! serial fused gradient pass — which is how the engine also stays
//! bit-identical to the threaded [`crate::coordinator`] (whose native
//! workers run the same tree via
//! [`LocalObjective::grad_blocked`](crate::objectives::LocalObjective::grad_blocked)).
//!
//! Steady-state rounds allocate nothing: lanes, block buffers, and the
//! θ-diff scratch are built once, and a [`Pool::scatter`] round is a
//! stack context + fn pointer (pinned by `tests/alloc_free_round.rs`,
//! which drives real [`Engine::step`] rounds under a counting
//! allocator). Future scenarios — async rounds, device placement,
//! straggler schedules — plug in as rules or engine hooks without
//! touching the trainers.

use super::gdsec::ServerState;
use super::trace::{Trace, TraceRow};
use crate::objectives::{GradSplit, Problem};
use crate::util::pool::Pool;

/// Wire accounting for one worker's transmission in one round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sent {
    /// Payload bits put on the uplink (the paper's metric).
    pub bits: u64,
    /// Non-zero entries carried by the message.
    pub entries: u64,
}

/// Immutable shared state a rule sees during a round's parallel phase.
#[derive(Clone, Copy)]
pub struct RoundCtx<'a> {
    /// The problem (shard access for `Custom`-gradient rules).
    pub prob: &'a Problem,
    /// Iteration number (1-based; 0 is the initial iterate).
    pub k: usize,
    /// Worker count M.
    pub m: usize,
    /// θ^k.
    pub theta: &'a [f64],
    /// θ^k − θ^{k−1} (all zeros unless the rule wants it).
    pub theta_diff: &'a [f64],
    /// max_i |θ^k_i − θ^{k−1}_i| (0.0 unless the rule wants the diff).
    pub diff_max: f64,
}

/// Who computes the worker gradient.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradMode {
    /// The engine computes the full local gradient into
    /// [`CompressRule::grad_buf`] through the nested block lanes before
    /// calling `compress` (deterministic full-batch methods).
    Full,
    /// The rule computes its own gradient inside `compress` (stochastic
    /// methods with per-lane RNG streams — row-split lanes cannot apply).
    Custom,
}

/// One worker's slot in the engine's fan-out: the rule's lane state plus
/// this round's wire accounting (`None` = inactive or censored-silent).
pub struct EngineLane<L> {
    pub lane: L,
    pub sent: Option<Sent>,
}

/// A compression rule: the per-method degree of freedom the engine is
/// parameterized by. Parallel-phase methods take `&self` (they run
/// concurrently across lanes); sequential hooks take `&mut self`.
pub trait CompressRule: Sync {
    /// Per-worker state (error memories, RNG streams, wire buffers …).
    type Lane: Send;

    /// Trace label (e.g. "GD-SEC", "top-10").
    fn name(&self) -> String;

    /// Build worker `w`'s lane.
    fn make_lane(&self, prob: &Problem, w: usize) -> Self::Lane;

    /// See [`GradMode`].
    fn grad_mode(&self) -> GradMode {
        GradMode::Full
    }

    /// Rule needs θ^k − θ^{k−1} each round (censoring thresholds).
    fn wants_theta_diff(&self) -> bool {
        false
    }

    /// Where the engine writes the full local gradient (`Full` mode).
    fn grad_buf<'l>(&self, _lane: &'l mut Self::Lane) -> &'l mut [f64] {
        &mut []
    }

    /// Sequential hook before the fan-out (per-round step sizes, shared
    /// censoring thresholds).
    fn begin_round(&mut self, _ctx: &RoundCtx) {}

    /// Worker `w`'s compression step (parallel; lane-local state only).
    /// Returns the wire accounting, or `None` for a silent round.
    fn compress(&self, ctx: &RoundCtx, w: usize, lane: &mut Self::Lane) -> Option<Sent>;

    /// Rule performs a pre-loop memory-seeding round (NoUnif-IAG): every
    /// worker's gradient is computed and [`seed`](Self::seed) transmits it
    /// before iteration 1.
    fn seeds_memories(&self) -> bool {
        false
    }

    /// Seeding transmission for worker `w` (parallel, `Full` mode only).
    fn seed(&self, _w: usize, _lane: &mut Self::Lane) -> Sent {
        unreachable!("rule does not seed memories")
    }

    /// Server-side fold + θ step (sequential, worker-id order is the
    /// caller's guarantee). `k` is the 1-based iteration.
    fn apply(
        &mut self,
        k: usize,
        server: &mut ServerState,
        lanes: &[EngineLane<Self::Lane>],
        pool: &Pool,
    );
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineOpts {
    /// nnz budget per nested row-block lane
    /// ([`GradSplit::DEFAULT_NNZ_BUDGET`] unless overridden). Smaller ⇒
    /// more intra-worker parallelism (and a different — still
    /// thread-count-independent — summation tree).
    pub nnz_budget: usize,
}

impl Default for EngineOpts {
    fn default() -> EngineOpts {
        EngineOpts { nnz_budget: GradSplit::DEFAULT_NNZ_BUDGET }
    }
}

impl EngineOpts {
    /// Default opts with the `GDSEC_NNZ_BUDGET` env override (read per
    /// call; constant within a process, so every run in a process sees
    /// the same block tree).
    pub fn from_env() -> EngineOpts {
        let nnz_budget = std::env::var("GDSEC_NNZ_BUDGET")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&b| b >= 1)
            .unwrap_or(GradSplit::DEFAULT_NNZ_BUDGET);
        EngineOpts { nnz_budget }
    }
}

/// Final state of an engine run.
pub struct EngineRun<R: CompressRule> {
    pub trace: Trace,
    pub server: ServerState,
    pub rule: R,
    pub lanes: Vec<R::Lane>,
}

/// Cumulative wire accounting.
#[derive(Debug, Clone, Copy, Default)]
struct Acct {
    bits: u64,
    tx: u64,
    entries: u64,
}

/// The resumable engine: [`new`](Engine::new) builds every buffer once,
/// [`step`](Engine::step) runs one allocation-free optimizer round, and
/// [`record`](Engine::record) appends a trace row. [`run_rule`] is the
/// convenience driver the trainers use.
pub struct Engine<'p, R: CompressRule> {
    prob: &'p Problem,
    pool: &'p Pool,
    pub rule: R,
    pub server: ServerState,
    lanes: Vec<EngineLane<R::Lane>>,
    /// Fixed nested (worker, row-block) lane tree (`Full`-grad rules).
    split: Option<GradSplit>,
    /// Lane-index span of each worker's blocks inside `split`.
    spans: Vec<(usize, usize)>,
    /// Per-round participation flags (reused).
    flags: Vec<bool>,
    theta_diff: Vec<f64>,
    acct: Acct,
    trace: Trace,
    k: usize,
}

impl<'p, R: CompressRule> Engine<'p, R> {
    pub fn new(prob: &'p Problem, rule: R, pool: &'p Pool, opts: &EngineOpts, fstar: f64) -> Self {
        let m = prob.m();
        let d = prob.d;
        let lanes: Vec<EngineLane<R::Lane>> = (0..m)
            .map(|w| EngineLane { lane: rule.make_lane(prob, w), sent: None })
            .collect();
        let (split, spans) = match rule.grad_mode() {
            GradMode::Full => {
                let split = GradSplit::new_by_nnz(prob, opts.nnz_budget);
                let spans = split.worker_spans(m);
                (Some(split), spans)
            }
            GradMode::Custom => (None, Vec::new()),
        };
        let trace = Trace::new(&rule.name(), &prob.name, fstar);
        Engine {
            prob,
            pool,
            rule,
            server: ServerState::new(d),
            lanes,
            split,
            spans,
            flags: vec![true; m],
            theta_diff: vec![0.0; d],
            acct: Acct::default(),
            trace,
            k: 0,
        }
    }

    /// The current iteration (0 before the first [`step`](Engine::step)).
    pub fn iter(&self) -> usize {
        self.k
    }

    /// Record a trace row for the current iterate, evaluating f(θ) with
    /// per-worker local values fanned out over the pool and summed in
    /// worker order (bitwise equal to the serial evaluation).
    pub fn record(&mut self) {
        self.trace.push(TraceRow {
            iter: self.k,
            fval: self.prob.value_pooled(&self.server.theta, self.pool),
            bits: self.acct.bits,
            transmissions: self.acct.tx,
            entries: self.acct.entries,
        });
    }

    /// The pre-loop memory-seeding round (rules with
    /// [`CompressRule::seeds_memories`]): every worker's gradient is
    /// computed through the nested lanes and [`CompressRule::seed`]
    /// transmits it; accounting folds in worker-id order. No θ step.
    pub fn seed_round(&mut self) {
        debug_assert!(matches!(self.rule.grad_mode(), GradMode::Full));
        self.flags.fill(true);
        self.fan_out_full(0, 0.0, true);
        self.fold_accounting();
    }

    /// One optimizer round: θ-diff, participation flags, rule pre-hook,
    /// nested gradient + compress fan-out, accounting fold (worker-id
    /// order), server apply. Allocation-free after warm-up (for `act ==
    /// None` schedules and allocation-free rules).
    pub fn step(&mut self, act: Option<&[usize]>) {
        self.k += 1;
        let k = self.k;
        let diff_max = if self.rule.wants_theta_diff() {
            // Fused diff + stationarity max — the quantity censoring
            // thresholds scale with, surfaced as debug telemetry. The
            // `enabled` gate keeps the disabled path format-free (the
            // zero-alloc round invariant).
            let dm = self.server.theta_diff_max(&mut self.theta_diff);
            if crate::util::enabled(crate::util::Level::Debug) {
                crate::debugln!("{} k={k}: max|Δθ| = {dm:.3e}", self.trace.algo);
            }
            dm
        } else {
            0.0
        };
        for (w, f) in self.flags.iter_mut().enumerate() {
            *f = act.map_or(true, |set| set.contains(&w));
        }
        {
            let ctx = RoundCtx {
                prob: self.prob,
                k,
                m: self.lanes.len(),
                theta: &self.server.theta,
                theta_diff: &self.theta_diff,
                diff_max,
            };
            self.rule.begin_round(&ctx);
        }
        match self.rule.grad_mode() {
            GradMode::Full => self.fan_out_full(k, diff_max, false),
            GradMode::Custom => self.fan_out_custom(k, diff_max),
        }
        self.fold_accounting();
        self.rule.apply(k, &mut self.server, &self.lanes, self.pool);
    }

    /// `Full`-grad fan-out: phase 1 scatters the flattened (worker,
    /// row-block) units — each block accumulates its private partial —
    /// and phase 2 scatters the worker lanes, folding each worker's
    /// blocks in ascending row order into the rule's gradient buffer
    /// before running `compress` (or `seed`). Both phases assign work by
    /// fixed chunking, so results are thread-count independent.
    fn fan_out_full(&mut self, k: usize, diff_max: f64, seeding: bool) {
        let prob = self.prob;
        let split = self.split.as_mut().expect("Full-grad rule without a block tree");
        let flags = &self.flags;
        let theta: &[f64] = &self.server.theta;
        self.pool.scatter(&mut split.lanes, |_, bl| {
            if !flags[bl.worker] {
                return;
            }
            crate::linalg::zero(&mut bl.buf);
            prob.locals[bl.worker].grad_data_range(theta, bl.start, bl.end, &mut bl.buf);
        });
        let split = &*split;
        let spans = &self.spans;
        let rule = &self.rule;
        let ctx = RoundCtx {
            prob,
            k,
            m: self.lanes.len(),
            theta,
            theta_diff: &self.theta_diff,
            diff_max,
        };
        self.pool.scatter(&mut self.lanes, |w, el| {
            if !flags[w] {
                el.sent = None;
                return;
            }
            let (b0, b1) = spans[w];
            {
                let grad = rule.grad_buf(&mut el.lane);
                prob.locals[w].fold_block_grads(
                    theta,
                    split.lanes[b0..b1].iter().map(|bl| bl.buf.as_slice()),
                    grad,
                );
            }
            el.sent = if seeding {
                Some(rule.seed(w, &mut el.lane))
            } else {
                rule.compress(&ctx, w, &mut el.lane)
            };
        });
    }

    /// `Custom`-grad fan-out: one scatter; the rule computes its own
    /// gradient inside `compress` (per-lane RNG streams stay per-lane).
    fn fan_out_custom(&mut self, k: usize, diff_max: f64) {
        let flags = &self.flags;
        let rule = &self.rule;
        let ctx = RoundCtx {
            prob: self.prob,
            k,
            m: self.lanes.len(),
            theta: &self.server.theta,
            theta_diff: &self.theta_diff,
            diff_max,
        };
        self.pool.scatter(&mut self.lanes, |w, el| {
            if !flags[w] {
                el.sent = None;
                return;
            }
            el.sent = rule.compress(&ctx, w, &mut el.lane);
        });
    }

    /// Fold this round's per-lane wire accounting in worker-id order.
    fn fold_accounting(&mut self) {
        for el in &self.lanes {
            if let Some(s) = el.sent {
                self.acct.bits += s.bits;
                self.acct.tx += 1;
                self.acct.entries += s.entries;
            }
        }
    }

    pub fn into_run(self) -> EngineRun<R> {
        EngineRun {
            trace: self.trace,
            server: self.server,
            rule: self.rule,
            lanes: self.lanes.into_iter().map(|el| el.lane).collect(),
        }
    }
}

/// The dense server fold shared by the uncompressed-wire rules (GD, QGD,
/// SGD): `agg = Σ vecs` in the caller's iteration order (worker-id order,
/// with the rule's own participation filter), then `θ -= α·agg`.
/// Op-for-op the baselines' historical apply loop, so a rule switching to
/// this helper never moves a bit.
pub fn apply_dense_fold<'a, I>(alpha: f64, vecs: I, agg: &mut [f64], theta: &mut [f64])
where
    I: Iterator<Item = &'a [f64]>,
{
    crate::linalg::zero(agg);
    for v in vecs {
        crate::linalg::axpy(1.0, v, agg);
    }
    crate::linalg::axpy(-alpha, agg, theta);
}

/// Run `rule` for `iters` rounds with a participation schedule
/// (`active(k)`: participating worker ids at iteration k, `None` = all)
/// and the standard eval cadence (record at iteration 0, every
/// `eval_every`-th round, and the final round).
#[allow(clippy::too_many_arguments)]
pub fn run_rule<R, F>(
    prob: &Problem,
    rule: R,
    iters: usize,
    eval_every: usize,
    fstar: f64,
    mut active: F,
    pool: &Pool,
    opts: &EngineOpts,
) -> EngineRun<R>
where
    R: CompressRule,
    F: FnMut(usize) -> Option<Vec<usize>>,
{
    let mut eng = Engine::new(prob, rule, pool, opts, fstar);
    eng.record();
    if eng.rule.seeds_memories() {
        eng.seed_round();
    }
    for k in 1..=iters {
        let act = active(k);
        eng.step(act.as_deref());
        if k % eval_every == 0 || k == iters {
            eng.record();
        }
    }
    eng.into_run()
}
