//! # gdsec — Distributed Learning with Sparsified Gradient Differences
//!
//! A production-grade reproduction of **GD-SEC** (Chen, Blum, Takáč, Sadler,
//! IEEE 2022): communication-efficient distributed gradient descent where
//! each worker transmits an adaptively **sparsified gradient difference**
//! with **error correction** and dual **state variables** (worker + server).
//!
//! The library is the L3 (coordinator) layer of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the fused
//!   censor + error-correction step and the shard gradient.
//! * **L2** — JAX worker-step functions and a small transformer LM
//!   (`python/compile/model.py`), AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the synchronous worker–server coordinator, the
//!   wire codecs (RLE / QSGD), every baseline algorithm from the paper's
//!   evaluation, the experiment harness that regenerates Figures 1–9, and
//!   a PJRT runtime (`runtime`) that loads the AOT artifacts so Python is
//!   never on the request path.
//!
//! See `examples/quickstart.rs` for a 20-line end-to-end run.
//!
//! ## Performance architecture
//!
//! Every trainer (GD-SEC and all six baselines) runs through ONE unified
//! round engine, [`algo::engine`], parameterized by a per-method
//! [`algo::engine::CompressRule`]. The engine's per-round hot path is
//! parallel and allocation-free: nested (worker × nnz-balanced
//! row-block) gradient lanes, compress steps, column-blocked
//! sparse/dense kernels, and server aggregation fan out over a
//! persistent [`util::pool::Pool`] (parked threads + round barrier,
//! zero-alloc dispatch) with fixed reduction orders (bit-for-bit
//! identical trajectories for any thread count), per-worker lanes reuse
//! their update buffers arena-style, and the kernels in [`linalg`] /
//! [`sparse`] are blocked/unrolled for autovectorization with
//! [`objectives::GradSplit`] lanes covering the M < cores regime.
//! The threaded [`coordinator`] runs the same math over framed links
//! with an event-driven round state machine: semi-synchronous quorum
//! rounds ([`coordinator::round::Quorum`] — fixed K, or adapted online
//! to the observed delay distribution by
//! [`coordinator::scheduler::QuorumController`]; deterministic virtual
//! straggler schedules via [`coordinator::transport::DelayPlan`]) fold
//! late updates up to `GDSEC_STALE_WINDOW` rounds later through
//! [`algo::engine::CompressRule::fold_stale`] instead of dropping them;
//! `quorum = All` with window 1 stays bit-identical to the serial
//! reference. `GDSEC_THREADS` sets the fan-out width of the shared pool
//! ([`util::pool::Pool::global`]); `GDSEC_NNZ_BUDGET` tunes the nested
//! lane cut; `GDSEC_QUORUM` / `GDSEC_STALE_WINDOW` / `GDSEC_WIRE`
//! select the coordinator quorum, the staleness bound, and the
//! (default-adaptive) uplink codec/accounting;
//! `benches/hotpath_micro.rs` writes the machine-readable perf
//! trajectory to `BENCH_hotpath.json`. See EXPERIMENTS.md §Perf.

// Indexed loops over multiple same-length slices are the house style for
// the numeric kernels — clearer than zip pyramids and equally fast once
// bounds checks are hoisted.
#![allow(clippy::needless_range_loop)]

pub mod algo;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod objectives;
pub mod runtime;
pub mod sparse;
pub mod testing;
pub mod util;

/// Convenience re-exports for examples and binaries.
pub mod prelude {
    pub use crate::algo::gdsec::{GdSecConfig, Xi};
    pub use crate::algo::trace::Trace;
    pub use crate::data::Dataset;
    pub use crate::objectives::Problem;
    pub use crate::util::pool::Pool;
    pub use crate::util::rng::Pcg64;
}
