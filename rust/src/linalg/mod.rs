//! Dense linear algebra kernels used by the native objectives.
//!
//! Only what the paper's workloads need: BLAS-1 vector ops and a blocked
//! row-major GEMV (+ transposed GEMV) tuned for tall-skinny data matrices
//! `X ∈ R^{N_m × d}`. f64 throughout — the paper's experiments are
//! full-precision; the wire format (32-bit) is a property of the codec,
//! not of the compute.

/// y += a * x
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += a * x[i];
    }
}

/// Dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // 4-way unrolled accumulation: measurably faster at d≈50k and improves
    // summation accuracy vs a single serial accumulator.
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..n {
        tail += x[i] * y[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Squared L2 norm.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// L2 norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    nrm2_sq(x).sqrt()
}

/// L1 norm.
#[inline]
pub fn nrm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Infinity norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// x - y into out.
#[inline]
pub fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] - y[i];
    }
}

/// Scale in place.
#[inline]
pub fn scal(a: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Fill with zeros.
#[inline]
pub fn zero(x: &mut [f64]) {
    for v in x.iter_mut() {
        *v = 0.0;
    }
}

/// Dense row-major matrix view over a flat buffer.
#[derive(Debug, Clone)]
pub struct DenseMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DenseMat {
    pub fn zeros(rows: usize, cols: usize) -> DenseMat {
        DenseMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows_data: &[Vec<f64>]) -> DenseMat {
        let rows = rows_data.len();
        let cols = rows_data.first().map_or(0, |r| r.len());
        let mut data = Vec::with_capacity(rows * cols);
        for r in rows_data {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        DenseMat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// out = A * x   (out: rows)
    pub fn gemv(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = dot(self.row(i), x);
        }
    }

    /// out += alpha * A^T * r   (out: cols). Row-major-friendly: streams A
    /// once, accumulating axpy per row — the hot loop of every objective
    /// gradient here.
    pub fn gemv_t_acc(&self, alpha: f64, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        for i in 0..self.rows {
            let a = alpha * r[i];
            if a != 0.0 {
                axpy(a, self.row(i), out);
            }
        }
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        nrm2(&self.data)
    }
}

/// Estimate the largest eigenvalue of `A^T A` (i.e. squared spectral norm
/// of A) by power iteration — used for Lipschitz constants of quadratic
/// losses. Deterministic start vector for reproducibility.
pub fn power_iter_ata(a: &DenseMat, iters: usize) -> f64 {
    let d = a.cols;
    if d == 0 || a.rows == 0 {
        return 0.0;
    }
    let mut v = vec![1.0 / (d as f64).sqrt(); d];
    let mut av = vec![0.0; a.rows];
    let mut atav = vec![0.0; d];
    let mut lambda = 0.0;
    for _ in 0..iters {
        a.gemv(&v, &mut av);
        zero(&mut atav);
        a.gemv_t_acc(1.0, &av, &mut atav);
        lambda = nrm2(&atav);
        if lambda <= 1e-300 {
            return 0.0;
        }
        for i in 0..d {
            v[i] = atav[i] / lambda;
        }
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..103).map(|i| (i as f64) * 0.5 - 20.0).collect();
        let y: Vec<f64> = (0..103).map(|i| ((i * 7) % 13) as f64).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9 * naive.abs().max(1.0));
    }

    #[test]
    fn axpy_and_norms() {
        let x = vec![3.0, -4.0];
        assert_eq!(nrm2(&x), 5.0);
        assert_eq!(nrm1(&x), 7.0);
        assert_eq!(nrm_inf(&x), 4.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![7.0, -7.0]);
    }

    #[test]
    fn gemv_small() {
        let a = DenseMat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let x = vec![1.0, -1.0];
        let mut out = vec![0.0; 3];
        a.gemv(&x, &mut out);
        assert_eq!(out, vec![-1.0, -1.0, -1.0]);
    }

    #[test]
    fn gemv_t_matches_explicit_transpose() {
        let a = DenseMat::from_rows(&[vec![1.0, 2.0, 0.5], vec![3.0, 4.0, -1.0]]);
        let r = vec![2.0, -1.0];
        let mut out = vec![0.0; 3];
        a.gemv_t_acc(1.0, &r, &mut out);
        // A^T r = [1*2+3*-1, 2*2+4*-1, 0.5*2+(-1)*(-1)] = [-1, 0, 2]
        assert_eq!(out, vec![-1.0, 0.0, 2.0]);
    }

    #[test]
    fn power_iteration_diag() {
        // A = diag(1, 2, 3) => sigma_max(A)^2 = 9.
        let a = DenseMat::from_rows(&[
            vec![1.0, 0.0, 0.0],
            vec![0.0, 2.0, 0.0],
            vec![0.0, 0.0, 3.0],
        ]);
        let l = power_iter_ata(&a, 200);
        assert!((l - 9.0).abs() < 1e-6, "l={l}");
    }

    #[test]
    fn power_iteration_empty() {
        let a = DenseMat::zeros(0, 0);
        assert_eq!(power_iter_ata(&a, 10), 0.0);
    }

    #[test]
    fn from_rows_layout() {
        let a = DenseMat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.row(1), &[3.0, 4.0]);
        assert_eq!(a.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_rejected() {
        DenseMat::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }
}
