//! Dense linear algebra kernels used by the native objectives.
//!
//! Only what the paper's workloads need: BLAS-1 vector ops and a blocked
//! row-major GEMV (+ transposed GEMV) tuned for tall-skinny data matrices
//! `X ∈ R^{N_m × d}`. f64 throughout — the paper's experiments are
//! full-precision; the wire format (32-bit) is a property of the codec,
//! not of the compute.
//!
//! ## Fixed-lane kernel contract (EXPERIMENTS.md §Perf)
//!
//! Every kernel is an explicit fixed-width lane kernel: data streams in
//! [`LANE`]-wide (4× f64) groups, reductions carry whole lane vectors as
//! accumulators ([`dot`]/[`dot2`] carry two, for eight independent
//! chains), lanes collapse through ONE documented reduction tree, and
//! the sub-lane remainder is a deterministic scalar tail. That shape is
//! the whole determinism story: per-element floating-point accumulation
//! ORDER is part of each kernel's contract — it must not depend on
//! thread count, blocking, or instruction set, so serial and pooled
//! trainer runs stay bit-for-bit identical.
//!
//! Two implementations share the contract:
//!
//! * [`scalar`] — the portable default: plain Rust structured exactly as
//!   the lane kernels above (LLVM autovectorizes the lane bodies).
//! * An AVX path (`core::arch` intrinsics, `--features simd`,
//!   x86_64 + runtime AVX detection): one 256-bit vector per lane
//!   group, multiply-then-add (never FMA — fusing would change
//!   rounding), lanes extracted and folded through the same tree.
//!
//! The public kernels dispatch between them; results are **bitwise
//! identical** either way (pinned per tail remainder and per thread
//! count by `tests/prop_simd_parity.rs`). NaN inputs are outside the
//! [`sub_abs_max`] contract: its max-reduction folds lanes in tree
//! order, which only agrees with a sequential scan for non-NaN values.
//!
//! `gemv` processes row pairs to reuse the `x` stream; `gemv_t_acc` is
//! blocked over column ranges so the `out` accumulator stays
//! cache-resident instead of being re-streamed per row — the block width
//! comes from the shared cache model ([`crate::util::cache`]).

/// Lane width of every kernel in this module: 4 × f64 = one 256-bit
/// vector. The lane count is part of the bitwise contract (it fixes the
/// accumulation order), NOT a tuning knob.
pub const LANE: usize = 4;

/// Whether the dispatching kernels currently take the `core::arch` SIMD
/// path (compiled in via `--features simd` AND supported by this CPU).
/// `false` means the [`scalar`] lane kernels run everywhere.
pub fn simd_active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        simd::usable()
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// Column-block width (in f64 slots) for [`DenseMat::gemv_t_acc`]: a
/// quarter of L1d, leaving room for the streamed `A` rows (1024 on the
/// 32 KiB reference machine — the pre-cache-model constant). Blocking
/// never changes per-element accumulation order, so this width is a pure
/// tuning quantity, not part of the bitwise contract.
#[inline]
fn col_block() -> usize {
    (crate::util::cache::model().l1d_bytes / 32).max(LANE)
}

/// The lane-structured scalar reference kernels — the portable default
/// implementation AND the bitwise oracle the SIMD path is pinned
/// against. Each function documents the exact lane/fold order the
/// dispatching kernel of the same name must reproduce.
pub mod scalar {
    use super::{col_block, DenseMat, LANE};

    /// y += a * x. Element-wise (no loop-carried dependency): the lane
    /// grouping fixes nothing here, but keeps the code shape identical
    /// to the SIMD path.
    #[inline]
    pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        let n = y.len();
        let nl = n - n % LANE;
        for (yc, xc) in y[..nl].chunks_exact_mut(LANE).zip(x[..nl].chunks_exact(LANE)) {
            for j in 0..LANE {
                yc[j] += a * xc[j];
            }
        }
        for (yk, &xk) in y[nl..].iter_mut().zip(&x[nl..]) {
            *yk += a * xk;
        }
    }

    /// x - y into out. Element-wise, same shape argument as [`axpy`].
    #[inline]
    pub fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len(), out.len());
        let n = out.len();
        let nl = n - n % LANE;
        for ((oc, xc), yc) in out[..nl]
            .chunks_exact_mut(LANE)
            .zip(x[..nl].chunks_exact(LANE))
            .zip(y[..nl].chunks_exact(LANE))
        {
            for j in 0..LANE {
                oc[j] = xc[j] - yc[j];
            }
        }
        for ((ok, &xk), &yk) in out[nl..].iter_mut().zip(&x[nl..]).zip(&y[nl..]) {
            *ok = xk - yk;
        }
    }

    /// Dot product: two LANE-wide accumulator groups (eight independent
    /// chains, one per FMA port times unroll) streamed through
    /// `chunks_exact(2·LANE)`. Fold order — part of the contract because
    /// `gemv` promises bitwise-identical per-row results:
    /// `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7)) + tail`, i.e. each lane
    /// group collapses pairwise, the two groups add, the scalar tail
    /// adds last.
    #[inline]
    pub fn dot(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let mut s = [0.0f64; 2 * LANE];
        let xc = x.chunks_exact(2 * LANE);
        let yc = y.chunks_exact(2 * LANE);
        let (xr, yr) = (xc.remainder(), yc.remainder());
        for (a, b) in xc.zip(yc) {
            for j in 0..2 * LANE {
                s[j] += a[j] * b[j];
            }
        }
        let mut tail = 0.0;
        for (a, b) in xr.iter().zip(yr) {
            tail += a * b;
        }
        ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail
    }

    /// Two dot products against a shared `x` in one streaming pass — the
    /// row blocking inside [`DenseMat::gemv`]. Each row uses the SAME
    /// lane/fold order as [`dot`], so `dot2(r0, r1, x) == (dot(r0, x),
    /// dot(r1, x))` bitwise while loading `x` once instead of twice.
    #[inline]
    pub fn dot2(r0: &[f64], r1: &[f64], x: &[f64]) -> (f64, f64) {
        debug_assert_eq!(r0.len(), x.len());
        debug_assert_eq!(r1.len(), x.len());
        let mut s = [0.0f64; 2 * LANE];
        let mut t = [0.0f64; 2 * LANE];
        let xc = x.chunks_exact(2 * LANE);
        let r0c = r0.chunks_exact(2 * LANE);
        let r1c = r1.chunks_exact(2 * LANE);
        let (xr, r0r, r1r) = (xc.remainder(), r0c.remainder(), r1c.remainder());
        for ((b, a0), a1) in xc.zip(r0c).zip(r1c) {
            for j in 0..2 * LANE {
                s[j] += a0[j] * b[j];
            }
            for j in 0..2 * LANE {
                t[j] += a1[j] * b[j];
            }
        }
        let (mut tail0, mut tail1) = (0.0, 0.0);
        for (k, &b) in xr.iter().enumerate() {
            tail0 += r0r[k] * b;
            tail1 += r1r[k] * b;
        }
        (
            ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail0,
            ((t[0] + t[1]) + (t[2] + t[3])) + ((t[4] + t[5]) + (t[6] + t[7])) + tail1,
        )
    }

    /// Fused `out = x - y` + `max_i |out_i|` in ONE pass — bitwise the
    /// same `out` as [`sub`], without a second sweep over a d≈47k
    /// vector. The max carries one LANE-wide group: lane `j` sees
    /// elements `i ≡ j (mod LANE)`, lanes fold as
    /// `(m0.max(m1)).max(m2.max(m3)).max(tail)`. For non-NaN inputs
    /// (the contract) this equals the sequential running max bitwise.
    #[inline]
    pub fn sub_abs_max(x: &[f64], y: &[f64], out: &mut [f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len(), out.len());
        let n = out.len();
        let nl = n - n % LANE;
        let mut m = [0.0f64; LANE];
        for ((oc, xc), yc) in out[..nl]
            .chunks_exact_mut(LANE)
            .zip(x[..nl].chunks_exact(LANE))
            .zip(y[..nl].chunks_exact(LANE))
        {
            for j in 0..LANE {
                let v = xc[j] - yc[j];
                oc[j] = v;
                m[j] = m[j].max(v.abs());
            }
        }
        let mut mt = 0.0f64;
        for ((ok, &xk), &yk) in out[nl..].iter_mut().zip(&x[nl..]).zip(&y[nl..]) {
            let v = xk - yk;
            *ok = v;
            mt = mt.max(v.abs());
        }
        (m[0].max(m[1])).max(m[2].max(m[3])).max(mt)
    }

    /// out = A * x — the reference for [`DenseMat::gemv`]: row pairs via
    /// [`dot2`], odd last row via [`dot`].
    pub fn gemv(a: &DenseMat, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), a.cols);
        assert_eq!(out.len(), a.rows);
        let mut i = 0;
        while i + 2 <= a.rows {
            let (d0, d1) = dot2(a.row(i), a.row(i + 1), x);
            out[i] = d0;
            out[i + 1] = d1;
            i += 2;
        }
        if i < a.rows {
            out[i] = dot(a.row(i), x);
        }
    }

    /// out += alpha * A^T * r — the reference for
    /// [`DenseMat::gemv_t_acc`]: identical column blocking, [`axpy`]
    /// inner loop.
    pub fn gemv_t_acc(a: &DenseMat, alpha: f64, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), a.rows);
        assert_eq!(out.len(), a.cols);
        let block = col_block();
        let cols = a.cols;
        let mut j0 = 0;
        while j0 < cols {
            let j1 = (j0 + block).min(cols);
            let ob = &mut out[j0..j1];
            for i in 0..a.rows {
                let s = alpha * r[i];
                if s != 0.0 {
                    axpy(s, &a.data[i * cols + j0..i * cols + j1], ob);
                }
            }
            j0 = j1;
        }
    }
}

/// AVX implementations of the lane kernels (see module docs): one
/// `__m256d` per lane group, multiply-then-add (no FMA — contraction
/// would change rounding vs the scalar reference), lane extraction +
/// the documented scalar fold at the end. Every function here is pinned
/// bitwise against its [`scalar`] twin by `tests/prop_simd_parity.rs`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd {
    use super::LANE;
    use core::arch::x86_64::*;

    /// Runtime gate (std caches the CPUID probe in an atomic, so this is
    /// a load + test on the hot path — and never allocates).
    #[inline]
    pub fn usable() -> bool {
        std::arch::is_x86_feature_detected!("avx")
    }

    /// Collapse one lane group with the contract fold `(l0+l1)+(l2+l3)`.
    #[inline]
    #[target_feature(enable = "avx")]
    unsafe fn fold4(v: __m256d) -> f64 {
        let a: [f64; LANE] = core::mem::transmute(v);
        (a[0] + a[1]) + (a[2] + a[3])
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn dot(x: &[f64], y: &[f64]) -> f64 {
        let n = x.len();
        let nl = n - n % (2 * LANE);
        let (xp, yp) = (x.as_ptr(), y.as_ptr());
        let mut lo = _mm256_setzero_pd();
        let mut hi = _mm256_setzero_pd();
        let mut i = 0;
        while i < nl {
            let p0 = _mm256_mul_pd(_mm256_loadu_pd(xp.add(i)), _mm256_loadu_pd(yp.add(i)));
            lo = _mm256_add_pd(lo, p0);
            let p1 =
                _mm256_mul_pd(_mm256_loadu_pd(xp.add(i + LANE)), _mm256_loadu_pd(yp.add(i + LANE)));
            hi = _mm256_add_pd(hi, p1);
            i += 2 * LANE;
        }
        let mut tail = 0.0;
        for k in nl..n {
            tail += *xp.add(k) * *yp.add(k);
        }
        (fold4(lo) + fold4(hi)) + tail
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn dot2(r0: &[f64], r1: &[f64], x: &[f64]) -> (f64, f64) {
        let n = x.len();
        let nl = n - n % (2 * LANE);
        let (xp, p0, p1) = (x.as_ptr(), r0.as_ptr(), r1.as_ptr());
        let mut s_lo = _mm256_setzero_pd();
        let mut s_hi = _mm256_setzero_pd();
        let mut t_lo = _mm256_setzero_pd();
        let mut t_hi = _mm256_setzero_pd();
        let mut i = 0;
        while i < nl {
            let b0 = _mm256_loadu_pd(xp.add(i));
            let b1 = _mm256_loadu_pd(xp.add(i + LANE));
            s_lo = _mm256_add_pd(s_lo, _mm256_mul_pd(_mm256_loadu_pd(p0.add(i)), b0));
            s_hi = _mm256_add_pd(s_hi, _mm256_mul_pd(_mm256_loadu_pd(p0.add(i + LANE)), b1));
            t_lo = _mm256_add_pd(t_lo, _mm256_mul_pd(_mm256_loadu_pd(p1.add(i)), b0));
            t_hi = _mm256_add_pd(t_hi, _mm256_mul_pd(_mm256_loadu_pd(p1.add(i + LANE)), b1));
            i += 2 * LANE;
        }
        let (mut tail0, mut tail1) = (0.0, 0.0);
        for k in nl..n {
            let b = *xp.add(k);
            tail0 += *p0.add(k) * b;
            tail1 += *p1.add(k) * b;
        }
        ((fold4(s_lo) + fold4(s_hi)) + tail0, (fold4(t_lo) + fold4(t_hi)) + tail1)
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
        let n = y.len();
        let nl = n - n % LANE;
        let va = _mm256_set1_pd(a);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0;
        while i < nl {
            let v = _mm256_add_pd(
                _mm256_loadu_pd(yp.add(i)),
                _mm256_mul_pd(va, _mm256_loadu_pd(xp.add(i))),
            );
            _mm256_storeu_pd(yp.add(i), v);
            i += LANE;
        }
        for k in nl..n {
            *yp.add(k) += a * *xp.add(k);
        }
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
        let n = out.len();
        let nl = n - n % LANE;
        let (xp, yp, op) = (x.as_ptr(), y.as_ptr(), out.as_mut_ptr());
        let mut i = 0;
        while i < nl {
            let v = _mm256_sub_pd(_mm256_loadu_pd(xp.add(i)), _mm256_loadu_pd(yp.add(i)));
            _mm256_storeu_pd(op.add(i), v);
            i += LANE;
        }
        for k in nl..n {
            *op.add(k) = *xp.add(k) - *yp.add(k);
        }
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn sub_abs_max(x: &[f64], y: &[f64], out: &mut [f64]) -> f64 {
        let n = out.len();
        let nl = n - n % LANE;
        let (xp, yp, op) = (x.as_ptr(), y.as_ptr(), out.as_mut_ptr());
        // abs = clear the sign bit (andnot with -0.0).
        let sign = _mm256_set1_pd(-0.0);
        let mut vm = _mm256_setzero_pd();
        let mut i = 0;
        while i < nl {
            let v = _mm256_sub_pd(_mm256_loadu_pd(xp.add(i)), _mm256_loadu_pd(yp.add(i)));
            _mm256_storeu_pd(op.add(i), v);
            vm = _mm256_max_pd(vm, _mm256_andnot_pd(sign, v));
            i += LANE;
        }
        let m: [f64; LANE] = core::mem::transmute(vm);
        let mut mt = 0.0f64;
        for k in nl..n {
            let v = *xp.add(k) - *yp.add(k);
            *op.add(k) = v;
            mt = mt.max(v.abs());
        }
        (m[0].max(m[1])).max(m[2].max(m[3])).max(mt)
    }
}

/// y += a * x (dispatching lane kernel; see [`scalar::axpy`]).
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::usable() {
        // SAFETY: AVX availability checked at runtime; bitwise parity
        // with the scalar path pinned by tests/prop_simd_parity.rs.
        return unsafe { simd::axpy(a, x, y) };
    }
    scalar::axpy(a, x, y)
}

/// Dot product (dispatching lane kernel; fold order in [`scalar::dot`]).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::usable() {
        // SAFETY: see axpy.
        return unsafe { simd::dot(x, y) };
    }
    scalar::dot(x, y)
}

/// Two dot products against a shared `x` in one streaming pass
/// (dispatching; see [`scalar::dot2`]). Public so the parity property
/// tests and benches can pin it directly.
#[inline]
pub fn dot2(r0: &[f64], r1: &[f64], x: &[f64]) -> (f64, f64) {
    debug_assert_eq!(r0.len(), x.len());
    debug_assert_eq!(r1.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::usable() {
        // SAFETY: see axpy.
        return unsafe { simd::dot2(r0, r1, x) };
    }
    scalar::dot2(r0, r1, x)
}

/// Squared L2 norm.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// L2 norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    nrm2_sq(x).sqrt()
}

/// L1 norm.
#[inline]
pub fn nrm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Infinity norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// x - y into out (dispatching lane kernel; see [`scalar::sub`]).
#[inline]
pub fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::usable() {
        // SAFETY: see axpy.
        return unsafe { simd::sub(x, y, out) };
    }
    scalar::sub(x, y, out)
}

/// Fused `out = x - y` + `max_i |out_i|` in ONE pass (dispatching; lane
/// max-fold order in [`scalar::sub_abs_max`] — NaN inputs are outside
/// the contract).
#[inline]
pub fn sub_abs_max(x: &[f64], y: &[f64], out: &mut [f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd::usable() {
        // SAFETY: see axpy.
        return unsafe { simd::sub_abs_max(x, y, out) };
    }
    scalar::sub_abs_max(x, y, out)
}

/// Scale in place.
#[inline]
pub fn scal(a: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Fill with zeros.
#[inline]
pub fn zero(x: &mut [f64]) {
    for v in x.iter_mut() {
        *v = 0.0;
    }
}

/// Dense row-major matrix view over a flat buffer.
#[derive(Debug, Clone)]
pub struct DenseMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DenseMat {
    pub fn zeros(rows: usize, cols: usize) -> DenseMat {
        DenseMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows_data: &[Vec<f64>]) -> DenseMat {
        let rows = rows_data.len();
        let cols = rows_data.first().map_or(0, |r| r.len());
        let mut data = Vec::with_capacity(rows * cols);
        for r in rows_data {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        DenseMat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// out = A * x   (out: rows). Row pairs share one pass over `x`
    /// ([`dot2`]), halving `x` memory traffic; each row's result is
    /// bitwise what `dot(row, x)` returns.
    pub fn gemv(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        let mut i = 0;
        while i + 2 <= self.rows {
            let (d0, d1) = dot2(self.row(i), self.row(i + 1), x);
            out[i] = d0;
            out[i + 1] = d1;
            i += 2;
        }
        if i < self.rows {
            out[i] = dot(self.row(i), x);
        }
    }

    /// out += alpha * A^T * r   (out: cols) — the hot loop of every
    /// objective gradient here.
    ///
    /// Blocked over column ranges: the unblocked form re-streams the full
    /// d-length `out` accumulator from L2/L3 for every row, tripling
    /// memory traffic at RCV1 scale (d=47236 ⇒ 370 KB per row). Each
    /// block-wide slice of `out` (width from the shared cache model —
    /// see [`crate::util::cache`]) instead stays L1-resident while all
    /// rows accumulate into it. Per element the accumulation order is
    /// still "rows in ascending order", and rows with `alpha·r_i == 0`
    /// are skipped entirely — both bitwise identical to the naive loop
    /// (pinned by `gemv_t_blocked_matches_naive`).
    pub fn gemv_t_acc(&self, alpha: f64, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        let block = col_block();
        let cols = self.cols;
        let mut j0 = 0;
        while j0 < cols {
            let j1 = (j0 + block).min(cols);
            let ob = &mut out[j0..j1];
            for i in 0..self.rows {
                let a = alpha * r[i];
                if a != 0.0 {
                    let row = &self.data[i * cols + j0..i * cols + j1];
                    axpy(a, row, ob);
                }
            }
            j0 = j1;
        }
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        nrm2(&self.data)
    }
}

/// Estimate the largest eigenvalue of `A^T A` (i.e. squared spectral norm
/// of A) by power iteration — used for Lipschitz constants of quadratic
/// losses. Deterministic start vector for reproducibility.
pub fn power_iter_ata(a: &DenseMat, iters: usize) -> f64 {
    let d = a.cols;
    if d == 0 || a.rows == 0 {
        return 0.0;
    }
    let mut v = vec![1.0 / (d as f64).sqrt(); d];
    let mut av = vec![0.0; a.rows];
    let mut atav = vec![0.0; d];
    let mut lambda = 0.0;
    for _ in 0..iters {
        a.gemv(&v, &mut av);
        zero(&mut atav);
        a.gemv_t_acc(1.0, &av, &mut atav);
        lambda = nrm2(&atav);
        if lambda <= 1e-300 {
            return 0.0;
        }
        for i in 0..d {
            v[i] = atav[i] / lambda;
        }
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..103).map(|i| (i as f64) * 0.5 - 20.0).collect();
        let y: Vec<f64> = (0..103).map(|i| ((i * 7) % 13) as f64).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9 * naive.abs().max(1.0));
    }

    #[test]
    fn axpy_and_norms() {
        let x = vec![3.0, -4.0];
        assert_eq!(nrm2(&x), 5.0);
        assert_eq!(nrm1(&x), 7.0);
        assert_eq!(nrm_inf(&x), 4.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![7.0, -7.0]);
    }

    #[test]
    fn gemv_small() {
        let a = DenseMat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let x = vec![1.0, -1.0];
        let mut out = vec![0.0; 3];
        a.gemv(&x, &mut out);
        assert_eq!(out, vec![-1.0, -1.0, -1.0]);
    }

    #[test]
    fn gemv_t_matches_explicit_transpose() {
        let a = DenseMat::from_rows(&[vec![1.0, 2.0, 0.5], vec![3.0, 4.0, -1.0]]);
        let r = vec![2.0, -1.0];
        let mut out = vec![0.0; 3];
        a.gemv_t_acc(1.0, &r, &mut out);
        // A^T r = [1*2+3*-1, 2*2+4*-1, 0.5*2+(-1)*(-1)] = [-1, 0, 2]
        assert_eq!(out, vec![-1.0, 0.0, 2.0]);
    }

    #[test]
    fn power_iteration_diag() {
        // A = diag(1, 2, 3) => sigma_max(A)^2 = 9.
        let a = DenseMat::from_rows(&[
            vec![1.0, 0.0, 0.0],
            vec![0.0, 2.0, 0.0],
            vec![0.0, 0.0, 3.0],
        ]);
        let l = power_iter_ata(&a, 200);
        assert!((l - 9.0).abs() < 1e-6, "l={l}");
    }

    #[test]
    fn power_iteration_empty() {
        let a = DenseMat::zeros(0, 0);
        assert_eq!(power_iter_ata(&a, 10), 0.0);
    }

    #[test]
    fn from_rows_layout() {
        let a = DenseMat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.row(1), &[3.0, 4.0]);
        assert_eq!(a.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_rejected() {
        DenseMat::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }

    fn pseudo_vec(seed: u64, n: usize) -> Vec<f64> {
        // Deterministic, sign-mixed, no RNG dependency needed here.
        (0..n).map(|i| (((i as f64) + seed as f64 * 0.37).sin()) * 3.0).collect()
    }

    #[test]
    fn dot2_bitwise_matches_dot() {
        for n in [0usize, 1, 7, 8, 9, 31, 64, 129] {
            let x = pseudo_vec(1, n);
            let r0 = pseudo_vec(2, n);
            let r1 = pseudo_vec(3, n);
            let (d0, d1) = dot2(&r0, &r1, &x);
            assert_eq!(d0.to_bits(), dot(&r0, &x).to_bits(), "n={n}");
            assert_eq!(d1.to_bits(), dot(&r1, &x).to_bits(), "n={n}");
        }
    }

    #[test]
    fn gemv_matches_per_row_dot_bitwise() {
        for (rows, cols) in [(1usize, 5usize), (2, 8), (5, 33), (8, 100)] {
            let a = DenseMat {
                rows,
                cols,
                data: pseudo_vec(7, rows * cols),
            };
            let x = pseudo_vec(11, cols);
            let mut out = vec![0.0; rows];
            a.gemv(&x, &mut out);
            for i in 0..rows {
                assert_eq!(out[i].to_bits(), dot(a.row(i), x.as_slice()).to_bits());
            }
        }
    }

    #[test]
    fn gemv_t_blocked_matches_naive() {
        // Bitwise contract: column blocking must not change per-element
        // accumulation order; zero rows must be skipped exactly.
        for (rows, cols) in [(3usize, 5usize), (7, 1024), (5, 1500), (9, 2060)] {
            let a = DenseMat {
                rows,
                cols,
                data: pseudo_vec(13, rows * cols),
            };
            let mut r = pseudo_vec(17, rows);
            r[rows / 2] = 0.0;
            let mut blocked = pseudo_vec(19, cols);
            let mut naive = blocked.clone();
            a.gemv_t_acc(0.35, &r, &mut blocked);
            for i in 0..rows {
                let s = 0.35 * r[i];
                if s != 0.0 {
                    for j in 0..cols {
                        naive[j] += s * a.row(i)[j];
                    }
                }
            }
            for j in 0..cols {
                assert_eq!(blocked[j].to_bits(), naive[j].to_bits(), "({rows},{cols}) j={j}");
            }
        }
    }

    #[test]
    fn sub_abs_max_fused() {
        let x = vec![1.0, -5.0, 2.0];
        let y = vec![0.5, 1.0, 9.0];
        let mut out = vec![0.0; 3];
        let m = sub_abs_max(&x, &y, &mut out);
        assert_eq!(out, vec![0.5, -6.0, -7.0]);
        assert_eq!(m, 7.0);
        let zeros = vec![0.0; 3];
        assert_eq!(sub_abs_max(&zeros, &zeros, &mut out), 0.0);
    }

    #[test]
    fn dispatch_matches_scalar_reference_bitwise() {
        // Unit-level smoke of the contract tests/prop_simd_parity.rs
        // pins exhaustively: whatever path dispatch takes, every kernel
        // must equal its scalar lane reference bitwise, across lengths
        // covering every tail remainder mod 2·LANE.
        for n in 0..=(4 * LANE + 3) {
            let x = pseudo_vec(21, n);
            let y = pseudo_vec(22, n);
            assert_eq!(dot(&x, &y).to_bits(), scalar::dot(&x, &y).to_bits(), "dot n={n}");
            let (a0, a1) = dot2(&x, &y, &x);
            let (b0, b1) = scalar::dot2(&x, &y, &x);
            assert_eq!((a0.to_bits(), a1.to_bits()), (b0.to_bits(), b1.to_bits()), "dot2 n={n}");
            let mut y1 = y.clone();
            let mut y2 = y.clone();
            axpy(0.73, &x, &mut y1);
            scalar::axpy(0.73, &x, &mut y2);
            assert_eq!(y1, y2, "axpy n={n}");
            let mut o1 = vec![0.0; n];
            let mut o2 = vec![0.0; n];
            sub(&x, &y, &mut o1);
            scalar::sub(&x, &y, &mut o2);
            assert_eq!(o1, o2, "sub n={n}");
            let m1 = sub_abs_max(&x, &y, &mut o1);
            let m2 = scalar::sub_abs_max(&x, &y, &mut o2);
            assert_eq!(m1.to_bits(), m2.to_bits(), "sub_abs_max n={n}");
            assert_eq!(o1, o2, "sub_abs_max out n={n}");
        }
    }

    #[test]
    fn scalar_sub_abs_max_lane_fold_equals_sequential_scan() {
        // For non-NaN inputs the lane-grouped max fold must agree with
        // the old sequential running max (max is order-insensitive on
        // finite values), so pre-lane trajectories are preserved.
        for n in [0usize, 1, 3, 4, 5, 11, 64, 103] {
            let x = pseudo_vec(31, n);
            let y = pseudo_vec(32, n);
            let mut out = vec![0.0; n];
            let m = scalar::sub_abs_max(&x, &y, &mut out);
            let mut seq = 0.0f64;
            for j in 0..n {
                seq = seq.max((x[j] - y[j]).abs());
            }
            assert_eq!(m.to_bits(), seq.to_bits(), "n={n}");
        }
    }
}
