//! Dense linear algebra kernels used by the native objectives.
//!
//! Only what the paper's workloads need: BLAS-1 vector ops and a blocked
//! row-major GEMV (+ transposed GEMV) tuned for tall-skinny data matrices
//! `X ∈ R^{N_m × d}`. f64 throughout — the paper's experiments are
//! full-precision; the wire format (32-bit) is a property of the codec,
//! not of the compute.
//!
//! Kernel design (EXPERIMENTS.md §Perf): reductions carry 8 independent
//! accumulators streamed through `chunks_exact` so LLVM autovectorizes
//! without bounds checks; `gemv` processes row pairs to reuse the `x`
//! stream; `gemv_t_acc` is blocked over column ranges so the `out`
//! accumulator stays cache-resident instead of being re-streamed per row.
//! Per-element floating-point accumulation ORDER is part of each kernel's
//! contract: it must not depend on thread count or blocking, so serial
//! and pooled trainer runs stay bit-for-bit identical.

/// y += a * x
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    // Element-wise with no loop-carried dependency; the zip form drops
    // the bounds checks that block vectorization of an indexed loop.
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Dot product. 8 independent accumulation chains (one FMA port each),
/// combined pairwise — the combine order is fixed and documented because
/// `gemv` promises bitwise-identical per-row results.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut s = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    for (a, b) in xc.zip(yc) {
        s[0] += a[0] * b[0];
        s[1] += a[1] * b[1];
        s[2] += a[2] * b[2];
        s[3] += a[3] * b[3];
        s[4] += a[4] * b[4];
        s[5] += a[5] * b[5];
        s[6] += a[6] * b[6];
        s[7] += a[7] * b[7];
    }
    let mut tail = 0.0;
    for (a, b) in xr.iter().zip(yr) {
        tail += a * b;
    }
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail
}

/// Two dot products against a shared `x` in one streaming pass — the row
/// blocking inside [`DenseMat::gemv`]. Each row uses the SAME chain/
/// combine order as [`dot`], so `dot2(r0, r1, x) == (dot(r0, x),
/// dot(r1, x))` bitwise while loading `x` once instead of twice.
#[inline]
fn dot2(r0: &[f64], r1: &[f64], x: &[f64]) -> (f64, f64) {
    debug_assert_eq!(r0.len(), x.len());
    debug_assert_eq!(r1.len(), x.len());
    let mut s = [0.0f64; 8];
    let mut t = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let r0c = r0.chunks_exact(8);
    let r1c = r1.chunks_exact(8);
    let (xr, r0r, r1r) = (xc.remainder(), r0c.remainder(), r1c.remainder());
    for ((b, a0), a1) in xc.zip(r0c).zip(r1c) {
        s[0] += a0[0] * b[0];
        s[1] += a0[1] * b[1];
        s[2] += a0[2] * b[2];
        s[3] += a0[3] * b[3];
        s[4] += a0[4] * b[4];
        s[5] += a0[5] * b[5];
        s[6] += a0[6] * b[6];
        s[7] += a0[7] * b[7];
        t[0] += a1[0] * b[0];
        t[1] += a1[1] * b[1];
        t[2] += a1[2] * b[2];
        t[3] += a1[3] * b[3];
        t[4] += a1[4] * b[4];
        t[5] += a1[5] * b[5];
        t[6] += a1[6] * b[6];
        t[7] += a1[7] * b[7];
    }
    let (mut tail0, mut tail1) = (0.0, 0.0);
    for (k, &b) in xr.iter().enumerate() {
        tail0 += r0r[k] * b;
        tail1 += r1r[k] * b;
    }
    (
        ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail0,
        ((t[0] + t[1]) + (t[2] + t[3])) + ((t[4] + t[5]) + (t[6] + t[7])) + tail1,
    )
}

/// Squared L2 norm.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// L2 norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    nrm2_sq(x).sqrt()
}

/// L1 norm.
#[inline]
pub fn nrm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Infinity norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// x - y into out.
#[inline]
pub fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for (o, (&a, &b)) in out.iter_mut().zip(x.iter().zip(y)) {
        *o = a - b;
    }
}

/// Fused `out = x - y` + `max_i |out_i|` in ONE pass — bitwise the same
/// `out` as [`sub`] and the same max as [`nrm_inf`], without the second
/// sweep over a d≈47k vector.
#[inline]
pub fn sub_abs_max(x: &[f64], y: &[f64], out: &mut [f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    let mut m = 0.0f64;
    for (o, (&a, &b)) in out.iter_mut().zip(x.iter().zip(y)) {
        let v = a - b;
        *o = v;
        m = m.max(v.abs());
    }
    m
}

/// Scale in place.
#[inline]
pub fn scal(a: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Fill with zeros.
#[inline]
pub fn zero(x: &mut [f64]) {
    for v in x.iter_mut() {
        *v = 0.0;
    }
}

/// Dense row-major matrix view over a flat buffer.
#[derive(Debug, Clone)]
pub struct DenseMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DenseMat {
    pub fn zeros(rows: usize, cols: usize) -> DenseMat {
        DenseMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows_data: &[Vec<f64>]) -> DenseMat {
        let rows = rows_data.len();
        let cols = rows_data.first().map_or(0, |r| r.len());
        let mut data = Vec::with_capacity(rows * cols);
        for r in rows_data {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        DenseMat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// out = A * x   (out: rows). Row pairs share one pass over `x`
    /// ([`dot2`]), halving `x` memory traffic; each row's result is
    /// bitwise what `dot(row, x)` returns.
    pub fn gemv(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        let mut i = 0;
        while i + 2 <= self.rows {
            let (d0, d1) = dot2(self.row(i), self.row(i + 1), x);
            out[i] = d0;
            out[i + 1] = d1;
            i += 2;
        }
        if i < self.rows {
            out[i] = dot(self.row(i), x);
        }
    }

    /// out += alpha * A^T * r   (out: cols) — the hot loop of every
    /// objective gradient here.
    ///
    /// Blocked over column ranges: the unblocked form re-streams the full
    /// d-length `out` accumulator from L2/L3 for every row, tripling
    /// memory traffic at RCV1 scale (d=47236 ⇒ 370 KB per row). Each
    /// `COL_BLOCK`-wide slice of `out` instead stays L1-resident while
    /// all rows accumulate into it. Per element the accumulation order is
    /// still "rows in ascending order", and rows with `alpha·r_i == 0`
    /// are skipped entirely — both bitwise identical to the naive loop
    /// (pinned by `gemv_t_blocked_matches_naive`).
    pub fn gemv_t_acc(&self, alpha: f64, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        // 1024 f64 = 8 KB: a quarter of a typical 32 KB L1d, leaving
        // room for the streamed A rows.
        const COL_BLOCK: usize = 1024;
        let cols = self.cols;
        let mut j0 = 0;
        while j0 < cols {
            let j1 = (j0 + COL_BLOCK).min(cols);
            let ob = &mut out[j0..j1];
            for i in 0..self.rows {
                let a = alpha * r[i];
                if a != 0.0 {
                    let row = &self.data[i * cols + j0..i * cols + j1];
                    axpy(a, row, ob);
                }
            }
            j0 = j1;
        }
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        nrm2(&self.data)
    }
}

/// Estimate the largest eigenvalue of `A^T A` (i.e. squared spectral norm
/// of A) by power iteration — used for Lipschitz constants of quadratic
/// losses. Deterministic start vector for reproducibility.
pub fn power_iter_ata(a: &DenseMat, iters: usize) -> f64 {
    let d = a.cols;
    if d == 0 || a.rows == 0 {
        return 0.0;
    }
    let mut v = vec![1.0 / (d as f64).sqrt(); d];
    let mut av = vec![0.0; a.rows];
    let mut atav = vec![0.0; d];
    let mut lambda = 0.0;
    for _ in 0..iters {
        a.gemv(&v, &mut av);
        zero(&mut atav);
        a.gemv_t_acc(1.0, &av, &mut atav);
        lambda = nrm2(&atav);
        if lambda <= 1e-300 {
            return 0.0;
        }
        for i in 0..d {
            v[i] = atav[i] / lambda;
        }
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..103).map(|i| (i as f64) * 0.5 - 20.0).collect();
        let y: Vec<f64> = (0..103).map(|i| ((i * 7) % 13) as f64).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9 * naive.abs().max(1.0));
    }

    #[test]
    fn axpy_and_norms() {
        let x = vec![3.0, -4.0];
        assert_eq!(nrm2(&x), 5.0);
        assert_eq!(nrm1(&x), 7.0);
        assert_eq!(nrm_inf(&x), 4.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![7.0, -7.0]);
    }

    #[test]
    fn gemv_small() {
        let a = DenseMat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let x = vec![1.0, -1.0];
        let mut out = vec![0.0; 3];
        a.gemv(&x, &mut out);
        assert_eq!(out, vec![-1.0, -1.0, -1.0]);
    }

    #[test]
    fn gemv_t_matches_explicit_transpose() {
        let a = DenseMat::from_rows(&[vec![1.0, 2.0, 0.5], vec![3.0, 4.0, -1.0]]);
        let r = vec![2.0, -1.0];
        let mut out = vec![0.0; 3];
        a.gemv_t_acc(1.0, &r, &mut out);
        // A^T r = [1*2+3*-1, 2*2+4*-1, 0.5*2+(-1)*(-1)] = [-1, 0, 2]
        assert_eq!(out, vec![-1.0, 0.0, 2.0]);
    }

    #[test]
    fn power_iteration_diag() {
        // A = diag(1, 2, 3) => sigma_max(A)^2 = 9.
        let a = DenseMat::from_rows(&[
            vec![1.0, 0.0, 0.0],
            vec![0.0, 2.0, 0.0],
            vec![0.0, 0.0, 3.0],
        ]);
        let l = power_iter_ata(&a, 200);
        assert!((l - 9.0).abs() < 1e-6, "l={l}");
    }

    #[test]
    fn power_iteration_empty() {
        let a = DenseMat::zeros(0, 0);
        assert_eq!(power_iter_ata(&a, 10), 0.0);
    }

    #[test]
    fn from_rows_layout() {
        let a = DenseMat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.row(1), &[3.0, 4.0]);
        assert_eq!(a.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_rejected() {
        DenseMat::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }

    fn pseudo_vec(seed: u64, n: usize) -> Vec<f64> {
        // Deterministic, sign-mixed, no RNG dependency needed here.
        (0..n).map(|i| (((i as f64) + seed as f64 * 0.37).sin()) * 3.0).collect()
    }

    #[test]
    fn dot2_bitwise_matches_dot() {
        for n in [0usize, 1, 7, 8, 9, 31, 64, 129] {
            let x = pseudo_vec(1, n);
            let r0 = pseudo_vec(2, n);
            let r1 = pseudo_vec(3, n);
            let (d0, d1) = dot2(&r0, &r1, &x);
            assert_eq!(d0.to_bits(), dot(&r0, &x).to_bits(), "n={n}");
            assert_eq!(d1.to_bits(), dot(&r1, &x).to_bits(), "n={n}");
        }
    }

    #[test]
    fn gemv_matches_per_row_dot_bitwise() {
        for (rows, cols) in [(1usize, 5usize), (2, 8), (5, 33), (8, 100)] {
            let a = DenseMat {
                rows,
                cols,
                data: pseudo_vec(7, rows * cols),
            };
            let x = pseudo_vec(11, cols);
            let mut out = vec![0.0; rows];
            a.gemv(&x, &mut out);
            for i in 0..rows {
                assert_eq!(out[i].to_bits(), dot(a.row(i), x.as_slice()).to_bits());
            }
        }
    }

    #[test]
    fn gemv_t_blocked_matches_naive() {
        // Bitwise contract: column blocking must not change per-element
        // accumulation order; zero rows must be skipped exactly.
        for (rows, cols) in [(3usize, 5usize), (7, 1024), (5, 1500), (9, 2060)] {
            let a = DenseMat {
                rows,
                cols,
                data: pseudo_vec(13, rows * cols),
            };
            let mut r = pseudo_vec(17, rows);
            r[rows / 2] = 0.0;
            let mut blocked = pseudo_vec(19, cols);
            let mut naive = blocked.clone();
            a.gemv_t_acc(0.35, &r, &mut blocked);
            for i in 0..rows {
                let s = 0.35 * r[i];
                if s != 0.0 {
                    for j in 0..cols {
                        naive[j] += s * a.row(i)[j];
                    }
                }
            }
            for j in 0..cols {
                assert_eq!(blocked[j].to_bits(), naive[j].to_bits(), "({rows},{cols}) j={j}");
            }
        }
    }

    #[test]
    fn sub_abs_max_fused() {
        let x = vec![1.0, -5.0, 2.0];
        let y = vec![0.5, 1.0, 9.0];
        let mut out = vec![0.0; 3];
        let m = sub_abs_max(&x, &y, &mut out);
        assert_eq!(out, vec![0.5, -6.0, -7.0]);
        assert_eq!(m, 7.0);
        let zeros = vec![0.0; 3];
        assert_eq!(sub_abs_max(&zeros, &zeros, &mut out), 0.0);
    }
}
