//! Mini property-based testing framework (no `proptest` in the offline
//! image). Randomized inputs from seeded generators, many cases per
//! property, and a failure report that prints the seed + case index so a
//! failure is exactly reproducible.
//!
//! Used by `rust/tests/prop_invariants.rs` for coordinator/codec/algorithm
//! invariants, mirroring the guide's "proptest on coordinator invariants"
//! requirement with an in-tree substrate.

use crate::util::rng::{Pcg64, SplitMix64};

/// Configuration of a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Seed can be overridden for reproduction: GDSEC_PROP_SEED=...
        let seed = std::env::var("GDSEC_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let cases = std::env::var("GDSEC_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        PropConfig { cases, seed }
    }
}

/// Run `prop` against `cases` independently-seeded RNGs. On failure (panic
/// or Err), re-raises with the case seed embedded in the message.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    check_with(PropConfig::default(), name, prop)
}

pub fn check_with<F>(cfg: PropConfig, name: &str, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = SplitMix64::child(cfg.seed, case as u64);
        let mut rng = Pcg64::seeded(case_seed);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "property '{name}' failed at case {case}/{} (seed {case_seed:#x}): {msg}\n\
                 reproduce with GDSEC_PROP_SEED={} (master seed)",
                cfg.cases, cfg.seed
            ),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "panic".to_string());
                panic!(
                    "property '{name}' panicked at case {case}/{} (seed {case_seed:#x}): {msg}",
                    cfg.cases
                );
            }
        }
    }
}

/// Common generators.
pub mod gen {
    use crate::util::rng::Pcg64;

    /// Vector length in [1, max_len].
    pub fn len(rng: &mut Pcg64, max_len: usize) -> usize {
        1 + rng.index(max_len)
    }

    /// Dense vector with mixed magnitudes, exact zeros and sign flips —
    /// the nasty-but-realistic distribution for codec tests.
    pub fn vec_mixed(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| match rng.index(5) {
                0 => 0.0,
                1 => rng.normal() * 1e-8,
                2 => rng.normal(),
                3 => rng.normal() * 1e6,
                _ => rng.sign() * rng.uniform(),
            })
            .collect()
    }

    /// Sparse-ish vector: each component zero with probability `p_zero`.
    pub fn vec_sparse(rng: &mut Pcg64, n: usize, p_zero: f64) -> Vec<f64> {
        (0..n).map(|_| if rng.bernoulli(p_zero) { 0.0 } else { rng.normal() }).collect()
    }

    /// f32-exact vector (values that survive f64→f32→f64 roundtrip), since
    /// the wire format is 32-bit per the paper.
    pub fn vec_f32_exact(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n).map(|_| (rng.normal() as f32) as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::sync::atomic::AtomicUsize::new(0);
        check_with(PropConfig { cases: 10, seed: 1 }, "trivial", |rng| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            let _ = rng.next_u64();
            Ok(())
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports() {
        check_with(PropConfig { cases: 5, seed: 2 }, "fails", |rng| {
            if rng.uniform() >= 0.0 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn panicking_property_reports() {
        check_with(PropConfig { cases: 3, seed: 3 }, "boom", |_rng| {
            panic!("boom inner");
        });
    }

    #[test]
    fn generators_shapes() {
        let mut rng = Pcg64::seeded(5);
        let v = gen::vec_mixed(&mut rng, 100);
        assert_eq!(v.len(), 100);
        let s = gen::vec_sparse(&mut rng, 1000, 0.9);
        let zeros = s.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros > 800, "zeros={zeros}");
        let f = gen::vec_f32_exact(&mut rng, 50);
        assert!(f.iter().all(|&x| (x as f32) as f64 == x));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first = Vec::new();
        check_with(PropConfig { cases: 4, seed: 42 }, "record", |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check_with(PropConfig { cases: 4, seed: 42 }, "record", |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
