//! The crate's ONE cache-size model: probed L1d/L2 capacities and the
//! block/budget defaults every cache-sized tree derives from them.
//!
//! Two block trees key their granularity off the memory hierarchy — the
//! engine's nested (worker, row-block) gradient lanes cut by an **nnz
//! budget** ([`crate::objectives::GradSplit`]), and the server's
//! coordinate shards cut by an **aggregate slice width**
//! ([`crate::util::shard::ShardPlan`]). Before this module each carried
//! its own magic constant (64k nnz, 4096 coordinates) tuned for a
//! 32 KiB L1d / 1 MiB L2 machine. Both now read the same probed model:
//!
//! * **Shard width** = `L1d / 8` coordinates — one f64 aggregate slot
//!   per L1d byte-octet, so a shard lane's scatter window is L1-resident.
//! * **nnz budget** = `L2 / 16` entries — a CSR block streams 12 bytes
//!   per entry (f64 value + u32 index), so the budgeted block plus its
//!   output slice sits inside ¾ of L2 instead of thrashing it.
//!
//! On the historical 32 KiB / 1 MiB reference machine these reproduce
//! the old constants exactly (4096 and 65 536), which is also what the
//! fallback model reports when probing is unavailable.
//!
//! ## Probing and determinism
//!
//! Linux exposes per-level sizes under
//! `/sys/devices/system/cpu/cpu0/cache/index*/`; elsewhere (or when the
//! sysfs tree is absent) the fallback model applies. The probe runs at
//! most once per process ([`OnceLock`]) and every derived quantity is
//! clamped to a sane range, so **within a process** all block trees are
//! built from one immutable model — trajectories stay bitwise
//! reproducible at any thread count, and `GDSEC_NNZ_BUDGET=<n>` /
//! `GDSEC_SHARDS=<n>` still pin the trees exactly for cross-machine
//! reproduction (EXPERIMENTS.md §Cache model).

use std::sync::OnceLock;

/// L1 data-cache capacity assumed when probing is unavailable (32 KiB —
/// the reference machine the pre-probe constants were tuned for).
pub const FALLBACK_L1D_BYTES: usize = 32 * 1024;

/// L2 capacity assumed when probing is unavailable (1 MiB; `/16` gives
/// back the historical 64k nnz budget).
pub const FALLBACK_L2_BYTES: usize = 1024 * 1024;

/// The probed (or fallback) cache capacities.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheModel {
    pub l1d_bytes: usize,
    pub l2_bytes: usize,
    /// `false` when the fallback constants are in use (non-Linux, or the
    /// sysfs cache tree was absent/unparseable).
    pub probed: bool,
}

impl CacheModel {
    /// The compile-time fallback model.
    pub const fn fallback() -> CacheModel {
        CacheModel { l1d_bytes: FALLBACK_L1D_BYTES, l2_bytes: FALLBACK_L2_BYTES, probed: false }
    }
}

/// Parse a sysfs cache size string: decimal digits plus an optional
/// `K`/`M` suffix (sysfs writes e.g. `48K`, `2048K`, `1M`).
fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024usize),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    digits.parse::<usize>().ok().map(|n| n.saturating_mul(mult))
}

/// Probe cpu0's cache levels from sysfs. Returns `None` unless both an
/// L1 data (or unified) size and an L2 size were found and parsed.
#[cfg(target_os = "linux")]
fn probe_sysfs() -> Option<(usize, usize)> {
    let mut l1d = None;
    let mut l2 = None;
    // Cache levels beyond index9 do not occur on cpu0 in practice.
    for index in 0..10 {
        let base = format!("/sys/devices/system/cpu/cpu0/cache/index{index}");
        let Ok(level) = std::fs::read_to_string(format!("{base}/level")) else {
            break; // indices are contiguous; the first miss ends the scan
        };
        let ty = std::fs::read_to_string(format!("{base}/type")).unwrap_or_default();
        let ty = ty.trim();
        let size =
            std::fs::read_to_string(format!("{base}/size")).ok().and_then(|s| parse_size(&s));
        match (level.trim(), ty) {
            ("1", "Data") | ("1", "Unified") => l1d = l1d.or(size),
            ("2", "Data") | ("2", "Unified") => l2 = l2.or(size),
            _ => {}
        }
    }
    Some((l1d?, l2?))
}

#[cfg(not(target_os = "linux"))]
fn probe_sysfs() -> Option<(usize, usize)> {
    None
}

/// The process-wide cache model, probed once on first use. Clamped to
/// [8 KiB, 1 MiB] (L1d) and [128 KiB, 64 MiB] (L2) so a garbled sysfs
/// entry cannot produce a degenerate block tree.
pub fn model() -> &'static CacheModel {
    static MODEL: OnceLock<CacheModel> = OnceLock::new();
    MODEL.get_or_init(|| match probe_sysfs() {
        Some((l1d, l2)) => CacheModel {
            l1d_bytes: l1d.clamp(8 * 1024, 1024 * 1024),
            l2_bytes: l2.clamp(128 * 1024, 64 * 1024 * 1024),
            probed: true,
        },
        None => CacheModel::fallback(),
    })
}

/// Default coordinates per server shard: one L1d-resident slice of f64
/// aggregate slots (`L1d / 8`). 4096 on the 32 KiB reference machine —
/// the value [`crate::util::shard::ShardPlan`] was previously hardcoded
/// to.
pub fn shard_coords() -> usize {
    (model().l1d_bytes / 8).max(1)
}

/// The `GDSEC_NNZ_BUDGET=auto` value: `L2 / 16` nnz entries, i.e. a CSR
/// block whose 12-byte entries fill ¾ of L2. 65 536 on the 1 MiB
/// reference machine (the old fixed budget).
pub fn auto_nnz_budget() -> usize {
    (model().l2_bytes / 16).clamp(1024, 1 << 22)
}

/// Parse a `GDSEC_NNZ_BUDGET` value: `auto` selects the cache-derived
/// budget, a positive integer pins it exactly (the cross-machine
/// reproduction knob). Zero, negatives, fractions, and typos are
/// errors — a silently ignored budget would skew every benchmark that
/// sweeps it.
pub fn parse_nnz_budget(s: &str) -> Result<Option<usize>, String> {
    if s == "auto" {
        return Ok(None);
    }
    match s.parse::<usize>() {
        Ok(0) => Err("0 disables gradient blocking entirely; use `auto` for the \
                      cache-derived default"
            .into()),
        Ok(b) => Ok(Some(b)),
        Err(_) => Err(format!("expected `auto` or a positive nnz count, got {s:?}")),
    }
}

/// `GDSEC_NNZ_BUDGET` policy, parsed once per process: unset, empty or
/// `auto` selects [`auto_nnz_budget`]; a positive integer pins the
/// budget exactly. Anything else panics loudly at first use — the
/// historical lenient parse silently fell back to `auto`, so a typo'd
/// sweep reported auto-budget numbers under the pinned label.
pub fn nnz_budget_from_env() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| match std::env::var("GDSEC_NNZ_BUDGET").ok().as_deref() {
        None | Some("") => auto_nnz_budget(),
        Some(s) => parse_nnz_budget(s)
            .unwrap_or_else(|e| panic!("GDSEC_NNZ_BUDGET must be `auto` or a positive nnz count: {e}"))
            .unwrap_or_else(auto_nnz_budget),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_size_suffixes() {
        assert_eq!(parse_size("48K\n"), Some(48 * 1024));
        assert_eq!(parse_size("2048K"), Some(2048 * 1024));
        assert_eq!(parse_size("1M"), Some(1024 * 1024));
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size(""), None);
        assert_eq!(parse_size("xK"), None);
    }

    #[test]
    fn model_is_sane_and_stable() {
        let m = model();
        assert!(m.l1d_bytes >= 8 * 1024 && m.l1d_bytes <= 1024 * 1024);
        assert!(m.l2_bytes >= 128 * 1024 && m.l2_bytes <= 64 * 1024 * 1024);
        // One immutable model per process.
        assert_eq!(model(), m);
    }

    #[test]
    fn reference_machine_reproduces_historical_constants() {
        let m = CacheModel::fallback();
        assert_eq!(m.l1d_bytes / 8, 4096);
        assert_eq!(m.l2_bytes / 16, 65_536);
    }

    #[test]
    fn derived_quantities_track_the_model() {
        assert_eq!(shard_coords(), model().l1d_bytes / 8);
        assert_eq!(auto_nnz_budget(), (model().l2_bytes / 16).clamp(1024, 1 << 22));
        // The env policy is cached; whatever it returned first, it must
        // keep returning (steady-state rounds may not re-read the env).
        assert_eq!(nnz_budget_from_env(), nnz_budget_from_env());
    }

    #[test]
    fn nnz_budget_parse_contract() {
        assert_eq!(parse_nnz_budget("auto"), Ok(None));
        assert_eq!(parse_nnz_budget("65536"), Ok(Some(65_536)));
        assert_eq!(parse_nnz_budget("1"), Ok(Some(1)));
        assert!(parse_nnz_budget("0").is_err());
        assert!(parse_nnz_budget("-4").is_err());
        assert!(parse_nnz_budget("64K").is_err());
        assert!(parse_nnz_budget("aut0").is_err());
    }
}
