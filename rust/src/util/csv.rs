//! CSV writing/reading for experiment traces (`results/*.csv`).
//!
//! The figure-regeneration harness emits one CSV per paper figure with the
//! exact series plotted; plotting is external (any CSV tool), the repo's
//! contract is the data.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    /// Write a row of f64s (formatted with full precision).
    pub fn row_f64(&mut self, row: &[f64]) -> std::io::Result<()> {
        debug_assert_eq!(row.len(), self.cols);
        let cells: Vec<String> = row.iter().map(|v| format_cell(*v)).collect();
        writeln!(self.out, "{}", cells.join(","))
    }

    /// Write a row of mixed string cells.
    pub fn row(&mut self, row: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(row.len(), self.cols);
        writeln!(self.out, "{}", row.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn format_cell(v: f64) -> String {
    if v.is_finite() && v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.10e}")
    }
}

/// Parse a simple CSV file (no quoted fields needed for our outputs).
pub fn read_csv<P: AsRef<Path>>(path: P) -> std::io::Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .map(|h| h.split(',').map(|s| s.to_string()).collect())
        .unwrap_or_default();
    let rows = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.split(',').map(|s| s.to_string()).collect())
        .collect();
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("gdsec_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["iter", "err", "bits"]).unwrap();
        w.row_f64(&[0.0, 1.5e-3, 32000.0]).unwrap();
        w.row_f64(&[1.0, 7.2e-4, 64000.0]).unwrap();
        w.flush().unwrap();
        let (header, rows) = read_csv(&path).unwrap();
        assert_eq!(header, vec!["iter", "err", "bits"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0][0], "0");
        assert!(rows[0][1].contains('e'));
        assert_eq!(rows[1][2], "64000");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn integers_written_plain() {
        assert_eq!(format_cell(42.0), "42");
        assert!(format_cell(0.125).starts_with("1.25"));
    }
}
