//! Coordinate-sharded server aggregation: the persistent [`ShardPlan`].
//!
//! The server's per-round work — zero/stage the aggregate, fold every
//! admitted [`SparseUpdate`], rescale, step θ/h — is embarrassingly
//! parallel **by coordinate**: each model coordinate's arithmetic is
//! independent of every other's. The pre-shard fold exploited that with
//! one column block per pool thread, but paid a per-round `Vec` of block
//! handles and a per-(block, update) binary search: every block
//! re-searched every update's index list to find its in-range run.
//!
//! The plan inverts that: shard boundaries are cut ONCE (from the
//! canonical [`Pool::block_width_for`] contract, so the chunking rules
//! stay pinned in one place), and each admitted update is cut ONCE into
//! per-shard `[lo, hi)` entry subranges by a single pass of
//! `partition_point`s ([`crate::compress::cut_entries`]). The cut
//! itself rides the pool: each update owns a disjoint row of the flat
//! offset table, so admission cuts fan across threads instead of
//! serializing on the coordinator thread (the last serial stretch of
//! the server round; [`ShardPlan::set_serial_cut`] keeps the old path
//! as a bench baseline). The fold's shard lanes then jump straight to
//! their owned slice of every update — no searches, no per-round
//! allocation (every table lives in the plan and reuses its capacity),
//! and shard count is decoupled from thread count: by default shards
//! are sized so each agg slice is L1-resident per the probed cache
//! model ([`default_shard_coords`]), which is what turns the fold's
//! random scatter-adds into cache-hot writes at large M·nnz.
//! `GDSEC_SHARDS` overrides the count.
//!
//! ## Determinism contract
//!
//! Within each shard the staged updates fold in exactly the order the
//! caller staged them — the coordinator stages due-stale entries in
//! (round, worker) order, then fresh updates in worker-id order — and
//! every per-element operation sequence (accumulate, rescale, step) is
//! identical to the serial reference loop. Since no coordinate's
//! arithmetic ever crosses a shard boundary, the result is **bitwise
//! identical at every shard count and every thread count** (pinned by
//! `tests/prop_parallel_parity.rs` and the coordinator's `Quorum::All`
//! integration pins).

use crate::compress::SparseUpdate;
use crate::util::pool::Pool;

/// Target coordinates per shard when neither `GDSEC_SHARDS` nor
/// [`ShardPlan::with_shards`] pins the count: one L1d-resident slice of
/// f64 aggregate slots from the shared cache model
/// ([`crate::util::cache::shard_coords`] — 4096 ≈ 32 KiB on the
/// reference machine, the pre-probe constant). The shard count is
/// `max(threads, d / this)` so small models still fan one shard per
/// thread.
pub fn default_shard_coords() -> usize {
    crate::util::cache::shard_coords()
}

/// The `GDSEC_SHARDS` override, parsed once per process (the plan calls
/// this on every rebuild check; caching keeps the steady-state round
/// free of env-var reads, which allocate).
fn shards_from_env() -> Option<usize> {
    static CACHE: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    *CACHE.get_or_init(|| match std::env::var("GDSEC_SHARDS").ok().as_deref() {
        None | Some("") => None,
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Some(n),
            _ => panic!("GDSEC_SHARDS must be a positive integer, got {s:?}"),
        },
    })
}

/// One staged update's wire image, borrowed for the duration of a single
/// [`ShardPlan::fold`] call (staged and consumed inside that call, so
/// the raw pointers never outlive the caller's borrows).
#[derive(Debug, Clone, Copy)]
struct UpdRef {
    idx: *const u32,
    val: *const f32,
    nnz: u32,
    worker: u32,
}

// SAFETY: an UpdRef is only dereferenced inside the scatter round of the
// fold() call that created it, while the caller's `&SparseUpdate`
// borrows are provably alive (fold holds them through its iterator
// argument until the scatter barrier clears).
unsafe impl Send for UpdRef {}
unsafe impl Sync for UpdRef {}

/// One shard's slot in the fan-out: its index and owned coordinate range.
#[derive(Debug, Clone, Copy)]
struct Slot {
    s: usize,
    j0: usize,
    j1: usize,
}

/// Base pointers of the round's model buffers, shared read-only across
/// shard lanes; each lane touches only its own `[j0, j1)` range.
#[derive(Clone, Copy)]
struct Bufs {
    theta: *mut f64,
    h: *mut f64,
    agg: *mut f64,
    /// Null when the caller keeps no θ_prev snapshot.
    prev: *mut f64,
}

// SAFETY: every shard lane dereferences these only within its disjoint
// owned range, while the caller's &mut borrows are held across the
// scatter barrier.
unsafe impl Send for Bufs {}
unsafe impl Sync for Bufs {}

/// One worker's h-share ledger base pointer (disjoint-range writes, same
/// argument as [`Bufs`]).
#[derive(Debug, Clone, Copy)]
struct SharePtr(*mut f64);

unsafe impl Send for SharePtr {}
unsafe impl Sync for SharePtr {}

/// Base pointer of the flat cut table during the admission-cut fan-out:
/// update `ui`'s lane writes only row `ui` (a disjoint
/// `stride`-sized slice), same disjointness argument as [`Bufs`].
#[derive(Debug, Clone, Copy)]
struct CutsPtr(*mut u32);

unsafe impl Send for CutsPtr {}
unsafe impl Sync for CutsPtr {}

/// Cut update `u` into row `ui` of the flat offset table — the
/// per-update unit of work the admission cut fans over the pool.
///
/// SAFETY: the caller guarantees the table holds at least
/// `(ui + 1) · stride` offsets and that no other lane touches row `ui`;
/// `u`'s borrowed wire image outlives the scatter barrier (the [`UpdRef`]
/// contract).
unsafe fn cut_row(cuts: CutsPtr, ui: usize, stride: usize, d: usize, width: usize, u: &UpdRef) {
    let row = std::slice::from_raw_parts_mut(cuts.0.add(ui * stride), stride);
    let idx = std::slice::from_raw_parts(u.idx, u.nnz as usize);
    crate::compress::cut_entries(idx, d, width, stride - 1, row);
}

/// One sharded server round's buffers and scalars — the argument block
/// of [`ShardPlan::fold`].
pub struct ShardApply<'a> {
    /// θ (stepped in place).
    pub theta: &'a mut [f64],
    /// The server's state variable h (stepped when `state_variable`).
    pub h: &'a mut [f64],
    /// The aggregation buffer. See [`staged_agg`](Self::staged_agg) for
    /// its two contracts.
    pub agg: &'a mut [f64],
    /// When set, each shard snapshots θ into this buffer before stepping
    /// (the engine's θ_prev bookkeeping); the coordinator passes `None`.
    pub theta_prev: Option<&'a mut [f64]>,
    pub alpha: f64,
    pub beta: f64,
    pub state_variable: bool,
    /// Aggregate rescale (1.0 except under renormalizing degradation;
    /// the `!= 1.0` guard keeps the fault-free path bitwise untouched).
    pub fold_scale: f64,
    /// `false` (coordinator contract): `agg` is scratch — each shard
    /// zeroes its slice first and leaves the scaled aggregate behind.
    /// `true` (engine contract): `agg` arrives pre-staged (stale entries
    /// already folded in by [`ServerState::fold_update`]
    /// (crate::algo::gdsec::ServerState::fold_update)), the fresh
    /// updates fold on top, and the slice is re-zeroed after the step —
    /// all-zeros between rounds, exactly the serial `apply_round`
    /// contract.
    pub staged_agg: bool,
    /// Per-worker h-share ledger booking: each shard books `scale·Δ̂`
    /// into its owned slice of the staging worker's ledger slab — the
    /// one-pass replacement for the post-apply full-dimension
    /// `book_shares` rescan. `None` when the state variable is off (no
    /// ledger exists).
    pub shares: Option<ShareBook<'a>>,
}

/// The h-share ledger view a fold books into: the slab table, an
/// optional worker→slab indirection, and the booking scale
/// (β·fold_scale). With `slot_of: None` the slab table is indexed by
/// worker id directly (the dense always-resident layout — exactly the
/// pre-store tuple); with an evictable
/// [`StateStore`](crate::util::state_store::StateStore) the map routes
/// each staged worker to its resident slab
/// ([`book_view`](crate::util::state_store::StateStore::book_view)).
/// Every staged worker must map to a valid slab — only staged workers'
/// slabs are ever dereferenced, so non-resident workers cost nothing.
pub struct ShareBook<'a> {
    pub slabs: &'a mut [Vec<f64>],
    pub slot_of: Option<&'a [u32]>,
    pub scale: f64,
}

/// The persistent coordinate-shard plan (see module docs). Build one
/// next to the model buffers and call [`fold`](Self::fold) once per
/// round; boundaries, slot table, cut tables, and pointer scratch all
/// live here and reuse their capacity, so steady-state rounds allocate
/// nothing (pinned by `tests/alloc_free_round.rs`).
#[derive(Debug, Clone, Default)]
pub struct ShardPlan {
    /// Model dimension the slots were built for (`usize::MAX` = never).
    d: usize,
    /// Shard width in coordinates (from [`Pool::block_width_for`]).
    width: usize,
    /// The shard count the slots were built for (requested, pre-clamp).
    built_for: usize,
    /// Test/bench override: pin the shard count, ignoring `GDSEC_SHARDS`
    /// and the thread-count default.
    pinned: Option<usize>,
    /// Run the admission cut serially on the calling thread (the
    /// pre-fanout behavior) instead of scattering rows over the pool.
    serial_cut: bool,
    slots: Vec<Slot>,
    /// Flat per-(update, shard) cut table: update `u`'s shard `s` owns
    /// entries `cuts[u·(slots+1) + s] .. cuts[u·(slots+1) + s + 1]`.
    cuts: Vec<u32>,
    ups: Vec<UpdRef>,
    share_ptrs: Vec<SharePtr>,
}

impl ShardPlan {
    pub fn new() -> ShardPlan {
        ShardPlan { d: usize::MAX, ..ShardPlan::default() }
    }

    /// A plan pinned to an explicit shard count (parity tests sweep
    /// counts; benches pin the sweep axis). `GDSEC_SHARDS` and the
    /// cache-sized default are both ignored.
    pub fn with_shards(shards: usize) -> ShardPlan {
        assert!(shards >= 1, "shard count must be positive");
        ShardPlan { pinned: Some(shards), ..ShardPlan::new() }
    }

    /// The number of shard slots the current build fans over (0 before
    /// the first [`fold`](Self::fold)/[`ensure`](Self::ensure)).
    pub fn shards(&self) -> usize {
        self.slots.len()
    }

    /// Force the admission cut back onto the calling thread (the
    /// pre-fanout behavior). The cut table is byte-identical either way
    /// — each update's row is a pure function of its index list — so
    /// this is strictly a measurement seam: `benches/server_saturation`
    /// times fold rounds under both settings to report the
    /// `server_cut_fanout_*` before/after keys.
    pub fn set_serial_cut(&mut self, serial: bool) {
        self.serial_cut = serial;
    }

    /// (Re)build the shard boundaries for dimension `d` if the plan is
    /// not already built for it. Precedence for the requested count:
    /// [`with_shards`](Self::with_shards) pin, then `GDSEC_SHARDS`, then
    /// `max(threads, d / default_shard_coords())` — one L1-sized slice
    /// per lane at scale, one shard per thread for small models.
    /// Boundaries are cut by [`Pool::block_width_for`]; a request beyond
    /// `d` clamps to `d` single-coordinate shards.
    pub fn ensure(&mut self, d: usize, pool: &Pool) {
        let requested = self.pinned.unwrap_or_else(|| {
            shards_from_env()
                .unwrap_or_else(|| pool.threads().max(d.div_ceil(default_shard_coords().max(1))))
        });
        if self.d == d && self.built_for == requested {
            return;
        }
        self.d = d;
        self.built_for = requested;
        self.width = Pool::block_width_for(d, requested);
        self.slots.clear();
        let mut j0 = 0;
        let mut s = 0;
        while j0 < d {
            let j1 = (j0 + self.width).min(d);
            self.slots.push(Slot { s, j0, j1 });
            j0 = j1;
            s += 1;
        }
    }

    /// Run one sharded server round: stage every `(worker, update)` pair
    /// from `staged`, cut each update into per-shard subranges — rows of
    /// one flat offset table, fanned across `pool` (each row is an
    /// independent `partition_point` pass, so the cut leaves the
    /// coordinator thread; [`set_serial_cut`](Self::set_serial_cut)
    /// restores the serial baseline) — then fan the fold + rescale + θ/h
    /// step (+ optional h-share booking) over the shard slots on `pool`.
    /// Updates fold within each shard in exactly the order `staged`
    /// yields them, so the caller's (round, worker) order is the
    /// per-element accumulation order at any shard/thread count.
    pub fn fold<'u, I>(&mut self, pool: &Pool, staged: I, mut a: ShardApply<'_>)
    where
        I: IntoIterator<Item = (usize, &'u SparseUpdate)>,
    {
        let d = a.theta.len();
        debug_assert_eq!(a.h.len(), d);
        debug_assert_eq!(a.agg.len(), d);
        if let Some(prev) = &a.theta_prev {
            debug_assert_eq!(prev.len(), d);
        }
        self.ensure(d, pool);
        self.ups.clear();
        self.cuts.clear();
        self.share_ptrs.clear();
        let nshards = self.slots.len();
        for (w, u) in staged {
            debug_assert_eq!(u.dim as usize, d, "staged update dimension mismatch");
            self.ups.push(UpdRef {
                idx: u.idx.as_ptr(),
                val: u.val.as_ptr(),
                nnz: u.idx.len() as u32,
                worker: w as u32,
            });
        }
        if d == 0 {
            self.ups.clear();
            return;
        }
        // Admission cut: every update owns a disjoint row of the flat
        // table, so rows scatter across the pool (resize reuses the
        // table's capacity at steady state — no allocation).
        {
            let stride = nshards + 1;
            self.cuts.resize(self.ups.len() * stride, 0);
            let cuts = CutsPtr(self.cuts.as_mut_ptr());
            let width = self.width;
            if self.serial_cut {
                for (ui, u) in self.ups.iter().enumerate() {
                    // SAFETY: row ui of the just-sized table; serial, so
                    // trivially exclusive.
                    unsafe { cut_row(cuts, ui, stride, d, width, u) };
                }
            } else {
                pool.scatter(&mut self.ups, |ui, u| {
                    // SAFETY: lane ui writes only row ui of the table
                    // sized above; the caller's update borrows are held
                    // across the scatter barrier.
                    unsafe { cut_row(cuts, ui, stride, d, width, u) };
                });
            }
        }
        let mut book_scale = 0.0;
        let mut slot_of: Option<&[u32]> = None;
        if let Some(book) = &mut a.shares {
            book_scale = book.scale;
            slot_of = book.slot_of;
            for share in book.slabs.iter_mut() {
                assert_eq!(share.len(), d, "h-share ledger dimension mismatch");
                self.share_ptrs.push(SharePtr(share.as_mut_ptr()));
            }
            // Every staged worker must route to a resident slab — the
            // scatter below dereferences exactly these.
            debug_assert!(self.ups.iter().all(|u| {
                let w = u.worker as usize;
                slot_of.map_or(w, |m| m[w] as usize) < self.share_ptrs.len()
            }));
        }
        let bufs = Bufs {
            theta: a.theta.as_mut_ptr(),
            h: a.h.as_mut_ptr(),
            agg: a.agg.as_mut_ptr(),
            prev: a
                .theta_prev
                .as_deref_mut()
                .map_or(std::ptr::null_mut(), |p| p.as_mut_ptr()),
        };
        let stride = nshards + 1;
        let (alpha, beta) = (a.alpha, a.beta);
        let (sv, fold_scale, staged_agg) = (a.state_variable, a.fold_scale, a.staged_agg);
        let ShardPlan { slots, cuts, ups, share_ptrs, .. } = self;
        let cuts: &[u32] = cuts;
        let ups: &[UpdRef] = ups;
        let share_ptrs: &[SharePtr] = share_ptrs;
        pool.scatter(slots, |_, slot| {
            let (s, j0, n) = (slot.s, slot.j0, slot.j1 - slot.j0);
            // SAFETY: this lane owns the disjoint range [j0, j1) of every
            // buffer; the caller's &mut borrows (and the staged updates'
            // & borrows) are held across the scatter barrier.
            unsafe {
                let agg = std::slice::from_raw_parts_mut(bufs.agg.add(j0), n);
                if !staged_agg {
                    crate::linalg::zero(agg);
                }
                for (ui, u) in ups.iter().enumerate() {
                    let lo = cuts[ui * stride + s] as usize;
                    let hi = cuts[ui * stride + s + 1] as usize;
                    let idx = std::slice::from_raw_parts(u.idx, u.nnz as usize);
                    let val = std::slice::from_raw_parts(u.val, u.nnz as usize);
                    for t in lo..hi {
                        agg[idx[t] as usize - j0] += val[t] as f64;
                    }
                }
                if fold_scale != 1.0 {
                    for v in agg.iter_mut() {
                        *v *= fold_scale;
                    }
                }
                let theta = std::slice::from_raw_parts_mut(bufs.theta.add(j0), n);
                let h = std::slice::from_raw_parts_mut(bufs.h.add(j0), n);
                if bufs.prev.is_null() {
                    if sv {
                        for j in 0..n {
                            theta[j] -= alpha * (h[j] + agg[j]);
                            h[j] += beta * agg[j];
                        }
                    } else {
                        for j in 0..n {
                            theta[j] -= alpha * agg[j];
                        }
                    }
                } else {
                    let prev = std::slice::from_raw_parts_mut(bufs.prev.add(j0), n);
                    if sv {
                        for j in 0..n {
                            let t = theta[j];
                            prev[j] = t;
                            theta[j] = t - alpha * (h[j] + agg[j]);
                            h[j] += beta * agg[j];
                        }
                    } else {
                        for j in 0..n {
                            let t = theta[j];
                            prev[j] = t;
                            theta[j] = t - alpha * agg[j];
                        }
                    }
                }
                if staged_agg {
                    crate::linalg::zero(agg);
                }
                if !share_ptrs.is_empty() {
                    for (ui, u) in ups.iter().enumerate() {
                        let lo = cuts[ui * stride + s] as usize;
                        let hi = cuts[ui * stride + s + 1] as usize;
                        let idx = std::slice::from_raw_parts(u.idx, u.nnz as usize);
                        let val = std::slice::from_raw_parts(u.val, u.nnz as usize);
                        let w = u.worker as usize;
                        let share = share_ptrs[slot_of.map_or(w, |m| m[w] as usize)].0;
                        for t in lo..hi {
                            *share.add(idx[t] as usize) += book_scale * val[t] as f64;
                        }
                    }
                }
            }
        });
        // Drop the borrowed wire images before returning: a plan never
        // holds pointers past the fold that staged them.
        self.ups.clear();
        self.cuts.clear();
        self.share_ptrs.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparse(d: usize, entries: &[(u32, f32)]) -> SparseUpdate {
        let mut u = SparseUpdate::empty(d);
        for &(i, v) in entries {
            u.idx.push(i);
            u.val.push(v);
        }
        u
    }

    #[test]
    fn fold_matches_serial_reference_across_shard_counts() {
        let d = 37;
        let ups = [
            (1usize, sparse(d, &[(0, 1.5), (7, -2.0), (36, 0.25)])),
            (0usize, sparse(d, &[(7, 0.5), (8, 1.0), (20, -1.0)])),
            (2usize, sparse(d, &[(3, 4.0)])),
        ];
        let (alpha, beta, fs) = (0.1, 0.3, 1.25);
        // Serial reference: per-element accumulate → rescale → step.
        let mut agg_ref = vec![0.0f64; d];
        for (_, u) in &ups {
            u.add_into(&mut agg_ref);
        }
        for v in agg_ref.iter_mut() {
            *v *= fs;
        }
        let mut theta_ref: Vec<f64> = (0..d).map(|j| j as f64 * 0.01).collect();
        let mut h_ref = vec![0.05f64; d];
        let mut shares_ref = vec![vec![0.0f64; d]; 3];
        for j in 0..d {
            theta_ref[j] -= alpha * (h_ref[j] + agg_ref[j]);
            h_ref[j] += beta * agg_ref[j];
        }
        for (w, u) in &ups {
            for (&i, &v) in u.idx.iter().zip(u.val.iter()) {
                shares_ref[*w][i as usize] += beta * fs * v as f64;
            }
        }
        for shards in [1usize, 2, 5, 37, 64] {
            for threads in [1usize, 3] {
                let pool = Pool::new(threads);
                let mut plan = ShardPlan::with_shards(shards);
                let mut theta: Vec<f64> = (0..d).map(|j| j as f64 * 0.01).collect();
                let mut h = vec![0.05f64; d];
                let mut agg = vec![0.0f64; d];
                let mut shares = vec![vec![0.0f64; d]; 3];
                plan.fold(
                    &pool,
                    ups.iter().map(|(w, u)| (*w, u)),
                    ShardApply {
                        theta: &mut theta,
                        h: &mut h,
                        agg: &mut agg,
                        theta_prev: None,
                        alpha,
                        beta,
                        state_variable: true,
                        fold_scale: fs,
                        staged_agg: false,
                        shares: Some(ShareBook {
                            slabs: &mut shares,
                            slot_of: None,
                            scale: beta * fs,
                        }),
                    },
                );
                assert!(plan.shards() <= shards && plan.shards() >= 1);
                for j in 0..d {
                    assert_eq!(theta[j].to_bits(), theta_ref[j].to_bits(), "θ shards={shards}");
                    assert_eq!(h[j].to_bits(), h_ref[j].to_bits(), "h shards={shards}");
                    assert_eq!(agg[j].to_bits(), agg_ref[j].to_bits(), "agg shards={shards}");
                    for w in 0..3 {
                        assert_eq!(
                            shares[w][j].to_bits(),
                            shares_ref[w][j].to_bits(),
                            "share w={w} shards={shards}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn slot_mapped_booking_matches_identity() {
        // Booking through a worker→slab map lands the same bits as the
        // dense identity layout, with only the staged workers' slabs
        // materialized (worker 1 is absent — its map entry is a poison
        // value the fold must never read).
        let d = 97usize;
        let pool = Pool::new(3);
        let ups = vec![
            (0usize, sparse(d, &[(3, 1.5), (40, -0.25), (96, 2.0)])),
            (2usize, sparse(d, &[(0, 0.5), (40, 1.0)])),
            (0usize, sparse(d, &[(3, -1.5), (77, 4.0)])),
        ];
        let run = |slotted: bool| {
            let mut theta = vec![0.0f64; d];
            let mut h = vec![0.0f64; d];
            let mut agg = vec![0.0f64; d];
            // Identity: 3 worker-indexed slabs. Slotted: 2 slabs, with
            // worker 0 → slab 1 and worker 2 → slab 0.
            let mut slabs = vec![vec![0.0f64; d]; if slotted { 2 } else { 3 }];
            let map = [1u32, u32::MAX, 0];
            let mut plan = ShardPlan::with_shards(5);
            plan.fold(
                &pool,
                ups.iter().map(|(w, u)| (*w, u)),
                ShardApply {
                    theta: &mut theta,
                    h: &mut h,
                    agg: &mut agg,
                    theta_prev: None,
                    alpha: 0.1,
                    beta: 0.5,
                    state_variable: true,
                    fold_scale: 1.0,
                    staged_agg: false,
                    shares: Some(ShareBook {
                        slabs: &mut slabs,
                        slot_of: slotted.then_some(&map[..]),
                        scale: 0.5,
                    }),
                },
            );
            slabs
        };
        let ident = run(false);
        let slotted = run(true);
        for j in 0..d {
            assert_eq!(slotted[1][j].to_bits(), ident[0][j].to_bits(), "w0 j={j}");
            assert_eq!(slotted[0][j].to_bits(), ident[2][j].to_bits(), "w2 j={j}");
            assert_eq!(ident[1][j].to_bits(), 0.0f64.to_bits(), "w1 untouched");
        }
    }

    #[test]
    fn staged_mode_folds_on_top_and_rezeros() {
        let d = 10;
        let u = sparse(d, &[(2, 1.0), (9, -1.0)]);
        let pool = Pool::new(2);
        let mut plan = ShardPlan::with_shards(3);
        let mut theta = vec![1.0f64; d];
        let mut prev = vec![0.0f64; d];
        let mut h = vec![0.0f64; d];
        let mut agg = vec![0.0f64; d];
        agg[2] = 0.5; // pre-staged stale entry
        plan.fold(
            &pool,
            std::iter::once((0usize, &u)),
            ShardApply {
                theta: &mut theta,
                h: &mut h,
                agg: &mut agg,
                theta_prev: Some(&mut prev),
                alpha: 0.5,
                beta: 0.25,
                state_variable: true,
                fold_scale: 1.0,
                staged_agg: true,
                shares: None,
            },
        );
        // agg is re-zeroed (the serial apply_round contract)…
        assert!(agg.iter().all(|&v| v == 0.0));
        // …the staged entry folded on top of the fresh update…
        assert_eq!(theta[2], 1.0 - 0.5 * (0.0 + 1.5));
        assert_eq!(h[2], 0.25 * 1.5);
        // …and θ_prev snapshots the pre-step iterate.
        assert!(prev.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn empty_round_still_steps_theta_from_h() {
        // No updates: with the state variable on, θ still descends along
        // h (the same contract as the block fold it replaces).
        let d = 5;
        let pool = Pool::new(1);
        let mut plan = ShardPlan::with_shards(2);
        let mut theta = vec![1.0f64; d];
        let mut h = vec![0.5f64; d];
        let mut agg = vec![7.0f64; d]; // stale garbage: scratch mode zeroes it
        plan.fold(
            &pool,
            std::iter::empty(),
            ShardApply {
                theta: &mut theta,
                h: &mut h,
                agg: &mut agg,
                theta_prev: None,
                alpha: 0.1,
                beta: 0.9,
                state_variable: true,
                fold_scale: 1.0,
                staged_agg: false,
                shares: None,
            },
        );
        assert!(theta.iter().all(|&t| t == 1.0 - 0.1 * 0.5));
        assert!(agg.iter().all(|&v| v == 0.0));
        assert!(h.iter().all(|&v| v == 0.5));
    }

    #[test]
    fn zero_dimension_is_a_no_op() {
        let pool = Pool::new(2);
        let mut plan = ShardPlan::new();
        plan.fold(
            &pool,
            std::iter::empty(),
            ShardApply {
                theta: &mut [],
                h: &mut [],
                agg: &mut [],
                theta_prev: None,
                alpha: 0.1,
                beta: 0.9,
                state_variable: true,
                fold_scale: 1.0,
                staged_agg: false,
                shares: None,
            },
        );
        assert_eq!(plan.shards(), 0);
    }

    #[test]
    fn ensure_rebuilds_only_on_change() {
        let pool = Pool::new(2);
        let mut plan = ShardPlan::with_shards(4);
        plan.ensure(100, &pool);
        assert_eq!(plan.shards(), 4);
        assert_eq!(plan.width, 25);
        let before = plan.slots.as_ptr();
        plan.ensure(100, &pool);
        assert_eq!(plan.slots.as_ptr(), before, "unchanged ensure must not rebuild");
        plan.ensure(7, &pool);
        assert_eq!(plan.shards(), 4);
        assert_eq!(plan.width, 2);
        // Requests beyond d clamp to single-coordinate shards.
        let mut wide = ShardPlan::with_shards(64);
        wide.ensure(3, &pool);
        assert_eq!(wide.shards(), 3);
    }

    #[test]
    fn serial_and_fanned_admission_cut_fold_identically() {
        // The cut table is a pure per-update function: folding with the
        // serial-cut baseline must produce bitwise identical state to
        // the fanned default at any thread count.
        let d = 301;
        let ups: Vec<(usize, SparseUpdate)> = (0..6)
            .map(|w| {
                let entries: Vec<(u32, f32)> =
                    (0..40).map(|k| ((w as u32 * 7 + k * 7) % d as u32, 0.01 * k as f32 - 0.1)).collect();
                let mut sorted: Vec<(u32, f32)> = entries;
                sorted.sort_by_key(|e| e.0);
                sorted.dedup_by_key(|e| e.0);
                (w, sparse(d, &sorted))
            })
            .collect();
        let run = |serial: bool, threads: usize| {
            let pool = Pool::new(threads);
            let mut plan = ShardPlan::with_shards(9);
            plan.set_serial_cut(serial);
            let mut theta = vec![0.2f64; d];
            let mut h = vec![0.1f64; d];
            let mut agg = vec![0.0f64; d];
            plan.fold(
                &pool,
                ups.iter().map(|(w, u)| (*w, u)),
                ShardApply {
                    theta: &mut theta,
                    h: &mut h,
                    agg: &mut agg,
                    theta_prev: None,
                    alpha: 0.05,
                    beta: 0.2,
                    state_variable: true,
                    fold_scale: 1.0,
                    staged_agg: false,
                    shares: None,
                },
            );
            (theta, h)
        };
        let (t_ref, h_ref) = run(true, 1);
        for threads in [1usize, 3] {
            for serial in [false, true] {
                let (t, h) = run(serial, threads);
                for j in 0..d {
                    assert_eq!(t[j].to_bits(), t_ref[j].to_bits(), "θ serial={serial} j={j}");
                    assert_eq!(h[j].to_bits(), h_ref[j].to_bits(), "h serial={serial} j={j}");
                }
            }
        }
    }

    #[test]
    fn default_plan_is_cache_sized_at_scale() {
        let pool = Pool::new(2);
        let mut plan = ShardPlan::new();
        // Small model: one shard per thread (the pre-shard chunking) —
        // unless GDSEC_SHARDS overrides, in which case just require a
        // valid cover.
        plan.ensure(100, &pool);
        if std::env::var("GDSEC_SHARDS").is_err() {
            assert_eq!(plan.shards(), 2);
            // Large model: L1-sized slices from the probed cache model.
            let coords = default_shard_coords();
            let d = 1usize << 18;
            let mut big = ShardPlan::new();
            big.ensure(d, &pool);
            let requested = pool.threads().max(d.div_ceil(coords));
            let width = Pool::block_width_for(d, requested);
            assert_eq!(big.shards(), d.div_ceil(width));
            assert!(big.width <= coords);
            // The slice really is L1-resident under the shared model.
            assert!(big.width * 8 <= crate::util::cache::model().l1d_bytes);
        }
        let covered: usize = plan.slots.iter().map(|s| s.j1 - s.j0).sum();
        assert_eq!(covered, 100);
    }
}
