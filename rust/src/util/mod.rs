//! Foundation utilities built from scratch for the offline environment:
//! PRNG, JSON, CLI parsing, bench harness, CSV/table output, timing.

pub mod bench;
pub mod cache;
pub mod cli;
pub mod csv;
pub mod error;
pub mod json;
pub mod pool;
pub mod rng;
pub mod shard;
pub mod state_store;
pub mod tablefmt;

use std::time::Instant;

/// Simple scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Log level for the built-in logger (no `log`/`env_logger` runtime deps on
/// the hot path; this is plain stderr with a level gate).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(2);

/// Set the global log verbosity (0=error..3=debug).
pub fn set_verbosity(level: u8) {
    VERBOSITY.store(level.min(3), std::sync::atomic::Ordering::Relaxed);
}

/// Whether a message at `level` should be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= VERBOSITY.load(std::sync::atomic::Ordering::Relaxed)
}

/// Log a line to stderr if the level is enabled.
pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log($crate::util::Level::Info, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => { $crate::util::log($crate::util::Level::Warn, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => { $crate::util::log($crate::util::Level::Debug, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
        assert!(t.elapsed_secs() > 0.0);
    }

    #[test]
    fn verbosity_gate() {
        set_verbosity(1);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_verbosity(2);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
