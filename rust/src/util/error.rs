//! Crate-wide error type for the zero-dependency default build.
//!
//! Mirrors the small slice of `anyhow`'s surface this crate uses —
//! [`Result`], the [`err!`](crate::err)/[`bail!`](crate::bail) macros and
//! a [`Context`] extension trait — so the CLI and experiment harness need
//! no external crates. `{:#}` (alternate) formatting renders the full
//! context chain outermost-first, exactly like `anyhow`'s, which the
//! runtime tests rely on for their "run `make artifacts`?" hint.
//!
//! The real `anyhow` is only used by the PJRT engine behind the `pjrt`
//! feature, where the `xla` bridge already requires external crates.

use std::fmt;

/// A boxed-free error: a chain of human-readable context frames,
/// outermost (most recent context) first.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    pub fn msg<M: Into<String>>(msg: M) -> Error {
        Error { frames: vec![msg.into()] }
    }

    /// Wrap with an outer context frame (what was being attempted).
    pub fn wrap<M: Into<String>>(mut self, msg: M) -> Error {
        self.frames.insert(0, msg.into());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:#}", self)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::msg(s)
    }
}

impl From<crate::data::libsvm::LibsvmError> for Error {
    fn from(e: crate::data::libsvm::LibsvmError) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<crate::util::cli::CliError> for Error {
    fn from(e: crate::util::cli::CliError) -> Error {
        Error::msg(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style extension for results and options.
pub trait Context<T> {
    fn context<M: Into<String>>(self, msg: M) -> Result<T>;
    fn with_context<M: Into<String>, F: FnOnce() -> M>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    // `{:#}` so wrapping one of our own Errors keeps its full context
    // chain (plain Display would print only the outermost frame);
    // foreign error types render identically either way.
    fn context<M: Into<String>>(self, msg: M) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(msg))
    }

    fn with_context<M: Into<String>, F: FnOnce() -> M>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<M: Into<String>>(self, msg: M) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<M: Into<String>, F: FnOnce() -> M>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => { $crate::util::error::Error::msg(format!($($arg)*)) };
}

/// Early-return with an [`Error`](crate::util::error::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::err!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(err!("inner {}", 42))
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = fails().unwrap_err().wrap("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert_eq!(format!("{e:?}"), "outer: inner 42");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.with_context(|| "reading manifest".to_string()).unwrap_err();
        assert!(format!("{e:#}").starts_with("reading manifest: "));
        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    fn context_preserves_inner_chain() {
        // Wrapping one of our own multi-frame errors must keep the root
        // cause in the `{:#}` rendering.
        fn inner() -> Result<()> {
            Err(err!("permission denied").wrap("opening config.json"))
        }
        let e = inner().context("starting run").unwrap_err();
        assert_eq!(
            format!("{e:#}"),
            "starting run: opening config.json: permission denied"
        );
    }

    #[test]
    fn bail_macro_returns() {
        fn f(x: bool) -> Result<u32> {
            if x {
                bail!("nope");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(format!("{}", f(true).unwrap_err()), "nope");
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/here")?)
        }
        assert!(f().is_err());
    }
}
