//! Minimal JSON value model, writer and parser.
//!
//! No `serde` in the offline build image, so we carry our own small JSON
//! layer. It is used for the artifact manifest produced by `python/compile/
//! aot.py` (parser) and for experiment result files (writer). The parser
//! covers the full JSON grammar (RFC 8259) minus `\u` surrogate pairs
//! outside the BMP, which the manifest never contains.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic — important because result files are diffed in tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    item.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no inf/nan; emit null like most encoders.
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (h as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn writer_escapes_roundtrip() {
        let v = Json::str("line1\nline2\t\"quoted\"\\");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::str("fig1")),
            ("iters", Json::num(500.0)),
            ("series", Json::arr(vec![Json::num(1.0), Json::num(0.5)])),
        ]);
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo — ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ✓");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_written_without_point() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..50 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..50 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }
}
