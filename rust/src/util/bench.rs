//! Micro/endtoend benchmark harness (no `criterion` in the offline image).
//!
//! Provides warmup + timed iterations with robust summary statistics
//! (mean, median, p95, min/max, std) and throughput reporting. Bench
//! binaries under `rust/benches/` are `harness = false` and call into this.
//!
//! [`write_json`] emits the machine-readable `BENCH_*.json` artifacts
//! (schema `gdsec-bench-v1`) that track the perf trajectory PR-over-PR —
//! `benches/hotpath_micro.rs` writes `BENCH_hotpath.json` at the repo
//! root; see EXPERIMENTS.md §Perf for how to read it.

use crate::util::json::Json;
use std::path::Path;
use std::time::{Duration, Instant};

/// Summary statistics over per-iteration wall times.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
    /// Optional user-supplied work units per iteration (elements, bytes...).
    pub units_per_iter: Option<f64>,
    pub unit_name: Option<String>,
}

impl BenchStats {
    /// Work-units per second, if units were declared.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / (self.mean_ns * 1e-9))
    }

    /// Machine-readable form for the `BENCH_*.json` artifacts.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("median_ns", Json::num(self.median_ns)),
            ("p95_ns", Json::num(self.p95_ns)),
            ("min_ns", Json::num(self.min_ns)),
            ("max_ns", Json::num(self.max_ns)),
            ("std_ns", Json::num(self.std_ns)),
        ];
        if let (Some(u), Some(unit)) = (self.units_per_iter, &self.unit_name) {
            pairs.push(("units_per_iter", Json::num(u)));
            pairs.push(("unit", Json::str(unit)));
            if let Some(tp) = self.throughput() {
                pairs.push(("throughput_per_s", Json::num(tp)));
            }
        }
        Json::obj(pairs)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<40} {:>10} {:>12} {:>12} {:>12}",
            self.name,
            format!("n={}", self.iters),
            format!("mean {}", fmt_ns(self.mean_ns)),
            format!("p50 {}", fmt_ns(self.median_ns)),
            format!("p95 {}", fmt_ns(self.p95_ns)),
        );
        if let (Some(tp), Some(unit)) = (self.throughput(), &self.unit_name) {
            s.push_str(&format!("  [{}/s: {}]", unit, fmt_count(tp)));
        }
        s
    }
}

/// Format a nanosecond quantity with a readable unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Format a big count (e.g. throughput) with SI prefix.
pub fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(900),
            min_iters: 5,
            max_iters: 100_000,
        }
    }
}

impl Bencher {
    /// Quick mode for CI / smoke runs (env `GDSEC_BENCH_QUICK=1`).
    pub fn from_env() -> Self {
        if std::env::var("GDSEC_BENCH_QUICK").ok().as_deref() == Some("1") {
            Bencher {
                warmup: Duration::from_millis(20),
                measure: Duration::from_millis(80),
                min_iters: 2,
                max_iters: 1_000,
            }
        } else {
            Bencher::default()
        }
    }

    /// Run `f` repeatedly, timing each call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.measure || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        stats_from(name, &mut samples, None, None)
    }

    /// Run with declared throughput units (e.g. elements processed/iter).
    pub fn run_units<F: FnMut()>(
        &self,
        name: &str,
        units_per_iter: f64,
        unit_name: &str,
        mut f: F,
    ) -> BenchStats {
        let mut s = self.run(name, &mut f);
        s.units_per_iter = Some(units_per_iter);
        s.unit_name = Some(unit_name.to_string());
        s
    }

    /// Time a single long-running call (end-to-end experiments): no warmup,
    /// one sample, reported as-is.
    pub fn run_once<F: FnOnce()>(&self, name: &str, f: F) -> BenchStats {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        stats_from(name, &mut vec![ns], None, None)
    }
}

/// Write a `BENCH_*.json` artifact: schema tag, caller-supplied context
/// (host facts, derived ratios…) and one entry per benchmark. Pretty,
/// key-sorted output so the file diffs cleanly PR-over-PR.
pub fn write_json<P: AsRef<Path>>(
    path: P,
    context: Vec<(&str, Json)>,
    stats: &[BenchStats],
) -> std::io::Result<()> {
    let mut pairs = vec![("schema", Json::str("gdsec-bench-v1"))];
    pairs.extend(context);
    pairs.push(("benches", Json::arr(stats.iter().map(BenchStats::to_json))));
    std::fs::write(path, Json::obj(pairs).to_pretty())
}

fn stats_from(
    name: &str,
    samples: &mut Vec<f64>,
    units: Option<f64>,
    unit_name: Option<String>,
) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: samples[n / 2],
        p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        min_ns: samples[0],
        max_ns: samples[n - 1],
        std_ns: var.sqrt(),
        units_per_iter: units,
        unit_name,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 10_000,
        };
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.iters >= 3);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
        assert!(s.report().contains("noop-ish"));
    }

    #[test]
    fn throughput_computed() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 2,
            max_iters: 1000,
        };
        let v = vec![1.0f64; 1024];
        let s = b.run_units("sum1k", 1024.0, "elem", || {
            std::hint::black_box(v.iter().sum::<f64>());
        });
        assert!(s.throughput().unwrap() > 0.0);
        assert!(s.report().contains("elem/s"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert!(fmt_ns(1500.0).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_ns(3.0e9).contains('s'));
        assert!(fmt_count(2.0e6).contains('M'));
    }

    #[test]
    fn run_once_single_sample() {
        let b = Bencher::default();
        let s = b.run_once("single", || std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(s.iters, 1);
        assert!(s.mean_ns >= 1e6);
    }

    #[test]
    fn json_artifact_roundtrips() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(5),
            min_iters: 2,
            max_iters: 100,
        };
        let s = b.run_units("op", 64.0, "elem", || {
            std::hint::black_box(2 + 2);
        });
        let dir = std::env::temp_dir().join(format!("gdsec_benchjson_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        write_json(&path, vec![("threads", Json::num(4.0))], &[s]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("schema").and_then(Json::as_str), Some("gdsec-bench-v1"));
        assert_eq!(v.get("threads").and_then(Json::as_f64), Some(4.0));
        let benches = v.get("benches").and_then(Json::as_arr).unwrap();
        assert_eq!(benches[0].get("name").and_then(Json::as_str), Some("op"));
        assert_eq!(benches[0].get("unit").and_then(Json::as_str), Some("elem"));
        assert!(benches[0].get("mean_ns").and_then(Json::as_f64).unwrap() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
