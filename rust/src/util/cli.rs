//! Tiny command-line argument parser (no `clap` in the offline image).
//!
//! Supports `program <subcommand> --flag --key value --key=value` with typed
//! accessors, defaults, and generated usage text. Enough for the `gdsec`
//! launcher and the bench binaries.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Declared option for usage text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: String,
    pub help: String,
    pub default: Option<String>,
}

/// Parsed arguments: a subcommand, key→value options, bare flags, and
/// positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub opts: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse an argv slice (excluding the program name). The first token
    /// not starting with `-` is the subcommand if `expect_subcommand`.
    pub fn parse(argv: &[String], expect_subcommand: bool) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    args.opts.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if expect_subcommand && args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Parse from `std::env::args()`.
    pub fn from_env(expect_subcommand: bool) -> Result<Args, CliError> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, expect_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected number, got '{v}'"))),
        }
    }

    /// Required string option.
    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError(format!("missing required option --{name}")))
    }
}

/// Render a usage block from option specs.
pub fn usage(program: &str, about: &str, subcommands: &[(&str, &str)], opts: &[OptSpec]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{program} — {about}\n\nUSAGE:\n  {program}"));
    if !subcommands.is_empty() {
        out.push_str(" <subcommand>");
    }
    out.push_str(" [options]\n");
    if !subcommands.is_empty() {
        out.push_str("\nSUBCOMMANDS:\n");
        for (name, help) in subcommands {
            out.push_str(&format!("  {name:<16} {help}\n"));
        }
    }
    if !opts.is_empty() {
        out.push_str("\nOPTIONS:\n");
        for o in opts {
            let left = format!("--{}", o.name);
            match &o.default {
                Some(d) => out.push_str(&format!("  {left:<22} {} [default: {d}]\n", o.help)),
                None => out.push_str(&format!("  {left:<22} {}\n", o.help)),
            }
        }
    }
    out
}

/// Convenience to declare an `OptSpec`.
pub fn opt(name: &str, help: &str, default: Option<&str>) -> OptSpec {
    OptSpec {
        name: name.to_string(),
        help: help.to_string(),
        default: default.map(|s| s.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_opts() {
        let a = Args::parse(&sv(&["train", "--alpha", "0.01", "--iters=500", "--verbose"]), true)
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("alpha"), Some("0.01"));
        assert_eq!(a.get_usize("iters", 0).unwrap(), 500);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["run"]), true).unwrap();
        assert_eq!(a.get_f64("alpha", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_or("mode", "fast"), "fast");
    }

    #[test]
    fn typed_errors() {
        let a = Args::parse(&sv(&["--iters", "abc"]), false).unwrap();
        assert!(a.get_usize("iters", 0).is_err());
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn positional_after_subcommand() {
        let a = Args::parse(&sv(&["bench", "fig1", "fig2", "--quick"]), true).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig1", "fig2"]);
        assert!(a.flag("quick"));
    }

    #[test]
    fn negative_number_as_value() {
        // `--xi -1` parses the -1 as a value because it doesn't start with --.
        let a = Args::parse(&sv(&["--xi", "-1"]), false).unwrap();
        assert_eq!(a.get_f64("xi", 0.0).unwrap(), -1.0);
    }

    #[test]
    fn usage_renders() {
        let u = usage(
            "gdsec",
            "GD-SEC launcher",
            &[("train", "run a training job")],
            &[opt("alpha", "step size", Some("1/L"))],
        );
        assert!(u.contains("SUBCOMMANDS"));
        assert!(u.contains("--alpha"));
        assert!(u.contains("[default: 1/L]"));
    }
}
