//! Persistent worker pool for the per-round fan-out.
//!
//! The trainers' hot loop is embarrassingly parallel across workers: each
//! worker's gradient + sparsify step touches only its own shard and state.
//! [`Pool::scatter`] fans a `&mut [T]` of per-worker lanes out across OS
//! threads and hands every lane its index, so callers keep a
//! **deterministic reduction order** afterwards: results land in the lane
//! they belong to and the main thread folds them in worker-id order.
//! Trajectories are therefore bit-for-bit identical for any thread count —
//! pinned by `tests/prop_parallel_parity.rs`.
//!
//! ## Design: parked threads + a round barrier
//!
//! Earlier revisions spawned scoped threads per `scatter` call (~10 µs per
//! round). That was tolerable for coarse per-worker fan-outs but is pure
//! overhead now that the pool also backs fine-grained kernels (column-
//! blocked `spmv_t_acc`, row-split gradients, blocked server aggregation)
//! that may run several rounds per optimizer iteration. This pool instead
//! spawns its `threads − 1` helper threads ONCE and parks them on a
//! condvar between rounds. Invariants:
//!
//! * **Parking / wake protocol** — a round is published as an (epoch,
//!   job) pair under one mutex; workers sleep on the `start` condvar
//!   until the epoch advances, run their slot, then decrement a
//!   `remaining` counter and signal `done`. The calling thread always
//!   executes slot 0 itself and blocks on `done` until `remaining == 0`,
//!   so the borrowed job data provably outlives the round.
//! * **Zero allocation per round** — the job is a stack-held context plus
//!   a monomorphized `unsafe fn` trampoline (a plain function pointer):
//!   no boxing, no channels. Mutex/condvar are futex-based on Linux and
//!   allocate nothing either (pinned by `tests/alloc_free_round.rs`).
//! * **Determinism** — item→index assignment is a fixed chunking of the
//!   input slice (identical to the old scoped version); each item is
//!   visited exactly once and written only by its owning slot, so results
//!   cannot depend on scheduling. Thread count only changes who computes
//!   a lane, never what lands in it.
//! * **Shutdown on drop** — the pool is an `Arc` internally (`Clone`
//!   shares the same workers); dropping the last handle sets a shutdown
//!   flag, wakes everyone, and joins the helper threads. No detached
//!   threads survive the pool.
//! * **No re-entrancy** — `scatter` must not be called from inside a
//!   scatter job of the same pool, nor of any ancestor pool in a nested
//!   dispatch chain (the round lock that serializes concurrent callers
//!   would deadlock). A thread-local stack of active pool identities
//!   turns that mistake into an immediate panic instead of a silent
//!   hang; dispatching onto an *independent* pool from inside a job is
//!   fine, but cyclic pool graphs driven from several threads at once
//!   are still forbidden (a per-thread check cannot prove a cross-
//!   thread lock cycle). Compose parallelism by flattening work units
//!   instead (see `objectives::GradSplit`).
//!
//! `threads == 1` (or a single item) short-circuits to an inline loop:
//! no helper threads are ever spawned and `scatter` is just the serial
//! fold — which is why the serial path stays allocation- and park-free.
//!
//! ## Core affinity (`GDSEC_PIN_CORES`)
//!
//! With `GDSEC_PIN_CORES=1` (or a [`Pool::with_affinity`] pin) each
//! helper thread pins itself to one CPU (`slot % cores`, via
//! `sched_setaffinity`; Linux only, a no-op elsewhere) ONCE at spawn —
//! before it ever parks — so steady-state rounds stay zero-alloc and
//! syscall-free, and a helper's warm L1/L2 working set (its fixed
//! scatter chunk touches the same lanes every round) stops migrating
//! between cores. The calling thread executes slot 0 and is never
//! pinned: the pool must not constrain its owner. Pinning is a pure
//! placement hint — item→slot assignment, and therefore every result,
//! is identical with it on or off.

use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// The `GDSEC_PIN_CORES` opt-in (`1`/`true`/`yes`), parsed once per
/// process. [`Pool::new`] consults this; [`Pool::with_affinity`]
/// overrides it explicitly (tests, benches).
fn pin_from_env() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| {
        matches!(
            std::env::var("GDSEC_PIN_CORES").ok().as_deref(),
            Some("1") | Some("true") | Some("yes")
        )
    })
}

/// Pin the calling thread to `core` (mod the kernel's view of the CPU
/// set). Best-effort: failure (e.g. a cgroup cpuset that excludes the
/// core) leaves the thread unpinned rather than failing the pool.
/// Allocation-free: the mask lives on the stack and the call goes
/// straight to libc (which std already links — the crate stays
/// zero-dependency).
#[cfg(target_os = "linux")]
fn pin_current_thread(core: usize) {
    extern "C" {
        // glibc/musl prototype: pid 0 = the calling thread.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // cpu_set_t is 1024 bits; core indices wrap into it.
    let mut mask = [0u64; 16];
    let bit = core % (mask.len() * 64);
    mask[bit / 64] |= 1u64 << (bit % 64);
    // SAFETY: the mask pointer/size pair describes a live stack buffer.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_core: usize) {}

/// Poison-tolerant lock: a panic inside a scatter closure unwinds through
/// `run_round` while guards are held, which would poison these mutexes;
/// the protected state is always left consistent (the barrier handshake
/// completes before any re-raise), so poisoning is ignored.
fn lock_pool<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

thread_local! {
    /// Stack of pool identities (Shared addresses) whose jobs THIS
    /// thread is currently executing, innermost last. `run_round`
    /// refuses to dispatch onto ANY pool already on the stack — direct
    /// re-entrancy or an A→B→A chain through another pool — turning
    /// what would be a silent deadlock on `round_lock`/the barrier into
    /// an immediate, attributable panic. Nesting *independent* pools is
    /// allowed. The check is per-thread and therefore best-effort for
    /// cycles: it always catches the dispatching thread's own ancestor
    /// chain, but a cyclic pool graph driven from several threads at
    /// once is a lock cycle no thread-local view can prove — don't
    /// build cyclic pool graphs.
    static ACTIVE_POOLS: std::cell::RefCell<Vec<usize>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// A type-erased round job: a context pointer and a monomorphized
/// trampoline executing one slot's share of the work.
#[derive(Copy, Clone)]
struct Job {
    ctx: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: the context pointed to by `ctx` lives on the scatter caller's
// stack and is only dereferenced between job publication and the
// `remaining == 0` handshake, during which the caller is blocked.
unsafe impl Send for Job {}

struct RoundState {
    epoch: u64,
    job: Option<Job>,
    /// Helper threads still running the current round.
    remaining: usize,
    /// A helper panicked during the current round (re-raised on the
    /// calling thread once the barrier clears, so the borrowed job data
    /// can never dangle and the pool itself stays usable).
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<RoundState>,
    /// Workers park here waiting for the epoch to advance.
    start: Condvar,
    /// The scatter caller parks here waiting for `remaining == 0`.
    done: Condvar,
}

struct Inner {
    shared: Arc<Shared>,
    /// Serializes concurrent `scatter` callers (one round at a time).
    round_lock: Mutex<()>,
    /// Helper thread count (`threads − 1`).
    workers: usize,
    handles: Vec<JoinHandle<()>>,
}

impl Inner {
    /// Publish `job`, run slot 0 inline, wait for the helpers. Panics —
    /// whether from slot 0 or a helper — are re-raised HERE, after the
    /// barrier has cleared, so the stack-held job context never dangles
    /// and the pool survives a panicking scatter closure.
    fn run_round(&self, job: Job) {
        let me = Arc::as_ptr(&self.shared) as *const () as usize;
        ACTIVE_POOLS.with(|s| {
            assert!(
                !s.borrow().contains(&me),
                "re-entrant Pool::scatter: a scatter job must not dispatch a round on a pool \
                 it is (transitively) running on"
            );
        });
        let _round = lock_pool(&self.round_lock);
        {
            let mut st = lock_pool(&self.shared.state);
            st.job = Some(job);
            st.epoch = st.epoch.wrapping_add(1);
            st.remaining = self.workers;
            st.panicked = false;
            self.shared.start.notify_all();
        }
        // SAFETY: ctx outlives the round (we block below until every
        // helper has finished its slot, even if slot 0 panics).
        ACTIVE_POOLS.with(|s| s.borrow_mut().push(me));
        let local = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (job.call)(job.ctx, 0)
        }));
        ACTIVE_POOLS.with(|s| {
            s.borrow_mut().pop();
        });
        let helper_panicked = {
            let mut st = lock_pool(&self.shared.state);
            while st.remaining > 0 {
                st = self.shared.done.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            st.job = None;
            st.panicked
        };
        if let Err(payload) = local {
            std::panic::resume_unwind(payload);
        }
        if helper_panicked {
            panic!("a pool worker panicked during scatter");
        }
    }
}

impl Drop for Inner {
    fn drop(&mut self) {
        {
            let mut st = lock_pool(&self.shared.state);
            st.shutdown = true;
            self.shared.start.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, slot: usize) {
    let me = Arc::as_ptr(&shared) as *const () as usize;
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock_pool(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("epoch advanced without a job");
                }
                st = shared.start.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        // SAFETY: the publisher blocks until `remaining == 0`, so ctx is
        // alive for the whole call. A panicking job must still decrement
        // the barrier (or the publisher would wait forever on dead data).
        ACTIVE_POOLS.with(|s| s.borrow_mut().push(me));
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (job.call)(job.ctx, slot)
        }))
        .is_ok();
        ACTIVE_POOLS.with(|s| {
            s.borrow_mut().pop();
        });
        let mut st = lock_pool(&shared.state);
        if !ok {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_one();
        }
    }
}

/// Stack-held scatter context handed to [`Job`] trampolines.
struct ScatterCtx<T, F> {
    items: *mut T,
    n: usize,
    chunk: usize,
    f: *const F,
}

/// Run slot `slot`'s contiguous chunk of the scatter.
unsafe fn scatter_chunk<T, F: Fn(usize, &mut T) + Sync>(ctx: *const (), slot: usize) {
    let ctx = &*(ctx as *const ScatterCtx<T, F>);
    let start = slot * ctx.chunk;
    if start >= ctx.n {
        return;
    }
    let end = (start + ctx.chunk).min(ctx.n);
    let f = &*ctx.f;
    for i in start..end {
        f(i, &mut *ctx.items.add(i));
    }
}

/// A persistent fan-out pool (see module docs). `Clone` shares the same
/// helper threads; the last clone dropped shuts them down.
pub struct Pool {
    threads: usize,
    inner: Option<Arc<Inner>>,
}

impl Clone for Pool {
    fn clone(&self) -> Pool {
        Pool { threads: self.threads, inner: self.inner.clone() }
    }
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool")
            .field("threads", &self.threads)
            .field("persistent", &self.inner.is_some())
            .finish()
    }
}

impl Pool {
    /// Pool with an explicit thread count (clamped to ≥ 1). `threads − 1`
    /// helper threads are spawned immediately and parked; they pin
    /// themselves to cores iff `GDSEC_PIN_CORES` opts in (module docs).
    pub fn new(threads: usize) -> Pool {
        Pool::with_affinity(threads, pin_from_env())
    }

    /// [`Pool::new`] with the core-affinity decision made explicitly,
    /// ignoring `GDSEC_PIN_CORES` — the seam tests and benches use to
    /// exercise the pinned path without mutating the process env.
    pub fn with_affinity(threads: usize, pin: bool) -> Pool {
        let threads = threads.max(1);
        if threads == 1 {
            return Pool { threads, inner: None };
        }
        // Resolve the core count HERE (available_parallelism may read
        // procfs and allocate): helpers receive a plain number and stay
        // allocation-free from their first instruction.
        let cores = if pin {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            0
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(RoundState {
                epoch: 0,
                job: None,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|slot| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gdsec-pool-{slot}"))
                    .spawn(move || {
                        if cores > 0 {
                            pin_current_thread(slot % cores);
                        }
                        worker_loop(sh, slot)
                    })
                    .expect("failed to spawn pool worker")
            })
            .collect();
        Pool {
            threads,
            inner: Some(Arc::new(Inner {
                shared,
                round_lock: Mutex::new(()),
                workers: threads - 1,
                handles,
            })),
        }
    }

    /// Serial execution (thread count 1); `scatter` runs inline and no
    /// helper threads exist.
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    /// Thread count from `GDSEC_THREADS`, falling back to the machine's
    /// available parallelism. Builds a NEW pool each call — the trainers'
    /// `run()` wrappers share one process-wide pool via [`Pool::global`]
    /// instead, so they do not respawn threads per run.
    pub fn from_env() -> Pool {
        let threads = std::env::var("GDSEC_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        Pool::new(threads)
    }

    /// The process-wide shared pool, lazily built from the environment on
    /// first use (`GDSEC_THREADS` is read once). All `run()` convenience
    /// wrappers in `algo::*` fan out over this instance.
    pub fn global() -> &'static Pool {
        static GLOBAL: OnceLock<Pool> = OnceLock::new();
        GLOBAL.get_or_init(Pool::from_env)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Canonical width of the contiguous blocks this pool fans a
    /// length-`n` range into: one block per thread, last block short.
    /// Every column-blocked kernel in the crate (`scatter_blocks`, the
    /// coordinator's sharded server apply) derives its chunking from
    /// this ONE function, so the bitwise contract — each element owned
    /// by exactly one block, blocks ascending — is pinned in one place.
    pub fn block_width(&self, n: usize) -> usize {
        Pool::block_width_for(n, self.threads)
    }

    /// The same canonical chunk-width contract for an arbitrary number
    /// of parts: `parts` contiguous ascending blocks, last block short,
    /// every element owned by exactly one block. The coordinate-shard
    /// planner ([`crate::util::shard::ShardPlan`]) cuts shard boundaries
    /// with it, which is what decouples shard count from thread count
    /// without forking the chunking contract.
    pub fn block_width_for(n: usize, parts: usize) -> usize {
        n.div_ceil(parts.max(1)).max(1)
    }

    /// Fan `f(j0, block)` over the canonical contiguous blocks of `out`
    /// (`j0` = the block's global start index). Each element of `out`
    /// belongs to exactly one block and blocks are cut by
    /// [`block_width`](Self::block_width), so a kernel whose per-element
    /// accumulation order does not depend on the block boundaries (the
    /// contract all callers uphold) produces bitwise identical results
    /// for any thread count. With 1 thread the whole slice is one block
    /// run inline — no Vec of block handles is built.
    pub fn scatter_blocks<T, F>(&self, out: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = out.len();
        if n == 0 {
            return;
        }
        if self.threads == 1 {
            f(0, out);
            return;
        }
        let w = self.block_width(n);
        let mut blocks: Vec<(usize, &mut [T])> =
            out.chunks_mut(w).enumerate().map(|(b, s)| (b * w, s)).collect();
        self.scatter(&mut blocks, |_, item| {
            let j0 = item.0;
            let block: &mut [T] = &mut *item.1;
            f(j0, block);
        });
    }

    /// Apply `f(index, item)` to every item, fanning contiguous chunks out
    /// across the pool's threads. Each item is visited exactly once; item
    /// order **within** the slice is preserved, so a caller that reduces
    /// `items` front-to-back afterwards sees the same result for any
    /// thread count. With 1 thread (or ≤ 1 item) this runs inline and
    /// allocates nothing; with more threads the parked workers are woken
    /// for one round and the call still allocates nothing (module docs).
    pub fn scatter<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        let inner = match &self.inner {
            Some(inner) if n > 1 => inner,
            _ => {
                for (i, item) in items.iter_mut().enumerate() {
                    f(i, item);
                }
                return;
            }
        };
        let chunk = n.div_ceil(self.threads);
        let ctx = ScatterCtx { items: items.as_mut_ptr(), n, chunk, f: &f as *const F };
        let job = Job {
            ctx: &ctx as *const ScatterCtx<T, F> as *const (),
            call: scatter_chunk::<T, F>,
        };
        inner.run_round(job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_cover_all_items_once() {
        for threads in [1, 2, 3, 8, 17] {
            let pool = Pool::new(threads);
            let mut items = vec![0u32; 13];
            pool.scatter(&mut items, |i, v| *v = i as u32 + 1);
            let expect: Vec<u32> = (1..=13).collect();
            assert_eq!(items, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single() {
        let pool = Pool::new(4);
        let mut empty: Vec<u8> = Vec::new();
        pool.scatter(&mut empty, |_, _| panic!("must not run"));
        let mut one = vec![5u8];
        pool.scatter(&mut one, |i, v| {
            assert_eq!(i, 0);
            *v += 1;
        });
        assert_eq!(one, vec![6]);
    }

    #[test]
    fn clamps_to_one_thread() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::serial().threads(), 1);
    }

    #[test]
    fn parallel_matches_serial_reduction() {
        // Per-lane work + in-order fold must not depend on thread count.
        let work = |i: usize, v: &mut f64| {
            *v = (i as f64 + 1.0).sqrt() * 0.37;
        };
        let mut a = vec![0.0f64; 101];
        let mut b = vec![0.0f64; 101];
        Pool::new(1).scatter(&mut a, work);
        Pool::new(7).scatter(&mut b, work);
        let fold = |xs: &[f64]| xs.iter().fold(0.0f64, |acc, x| acc + x);
        assert_eq!(fold(&a).to_bits(), fold(&b).to_bits());
    }

    #[test]
    fn pool_survives_many_rounds() {
        // The same pool must dispatch thousands of rounds (the persistent
        // workers re-park between rounds, never exit early).
        let pool = Pool::new(3);
        let mut items = vec![0u64; 5];
        for round in 0..2000u64 {
            pool.scatter(&mut items, |i, v| *v += i as u64 + round % 3);
        }
        let serial_expect: Vec<u64> = {
            let mut items = vec![0u64; 5];
            for round in 0..2000u64 {
                for (i, v) in items.iter_mut().enumerate() {
                    *v += i as u64 + round % 3;
                }
            }
            items
        };
        assert_eq!(items, serial_expect);
    }

    #[test]
    fn clones_share_workers_and_drop_cleanly() {
        let pool = Pool::new(4);
        let pool2 = pool.clone();
        let mut items = vec![0u32; 8];
        pool.scatter(&mut items, |i, v| *v = i as u32);
        drop(pool);
        // The clone still drives the same (alive) workers.
        pool2.scatter(&mut items, |i, v| *v += i as u32);
        let expect: Vec<u32> = (0..8).map(|i| 2 * i).collect();
        assert_eq!(items, expect);
        // Dropping the last handle joins the helpers (no hang, no leak —
        // the test finishing at all pins the shutdown path).
        drop(pool2);
    }

    #[test]
    fn scatter_usable_from_any_thread() {
        // The round lock serializes concurrent callers; a pool shared
        // across threads must stay correct.
        let pool = std::sync::Arc::new(Pool::new(3));
        let mut joins = Vec::new();
        for t in 0..4 {
            let p = std::sync::Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                let mut items = vec![0usize; 17];
                for _ in 0..50 {
                    p.scatter(&mut items, |i, v| *v = i * (t + 1));
                }
                items
            }));
        }
        for (t, j) in joins.into_iter().enumerate() {
            let items = j.join().unwrap();
            let expect: Vec<usize> = (0..17).map(|i| i * (t + 1)).collect();
            assert_eq!(items, expect);
        }
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = Pool::new(3);
        let mut items = vec![0u32; 6];
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scatter(&mut items, |i, v| {
                // n=6 over 3 slots ⇒ item 4 runs on a helper thread.
                assert!(i != 4, "boom");
                *v = i as u32;
            });
        }));
        assert!(result.is_err(), "worker panic must re-raise on the caller");
        // The pool (and its parked helpers) must survive a panicked round.
        pool.scatter(&mut items, |i, v| *v = 10 + i as u32);
        assert_eq!(items, (10..16).collect::<Vec<u32>>());
    }

    #[test]
    #[should_panic(expected = "re-entrant")]
    fn reentrant_scatter_panics_instead_of_deadlocking() {
        let pool = Pool::new(2);
        let pool2 = pool.clone();
        let mut items = vec![0u8; 2];
        pool.scatter(&mut items, |_, _| {
            let mut inner = vec![0u8; 2];
            pool2.scatter(&mut inner, |_, v| *v += 1);
        });
    }

    #[test]
    fn cross_pool_nesting_is_allowed() {
        // A scatter job may dispatch rounds on a DIFFERENT pool.
        let outer = Pool::new(2);
        let inner_pool = Pool::new(2);
        let mut items = vec![0u32; 2];
        outer.scatter(&mut items, |i, v| {
            let mut inner = vec![1u32; 2];
            inner_pool.scatter(&mut inner, |j, w| *w += j as u32);
            *v = i as u32 + inner.iter().sum::<u32>();
        });
        assert_eq!(items, vec![3, 4]);
    }

    #[test]
    fn scatter_blocks_covers_every_element_once() {
        for threads in [1usize, 2, 3, 5, 8] {
            let pool = Pool::new(threads);
            let mut out = vec![0usize; 23];
            pool.scatter_blocks(&mut out, |j0, block| {
                for (o, v) in block.iter_mut().enumerate() {
                    *v += j0 + o + 1;
                }
            });
            let expect: Vec<usize> = (1..=23).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
        // Empty slice: no panic, no calls.
        Pool::new(4).scatter_blocks(&mut [] as &mut [u8], |_, _| panic!("must not run"));
    }

    #[test]
    fn block_width_partitions_into_at_most_threads_blocks() {
        for threads in [1usize, 2, 4, 7] {
            let pool = Pool::new(threads);
            for n in [1usize, 2, 5, 100, 101] {
                let w = pool.block_width(n);
                assert!(w >= 1);
                assert!(n.div_ceil(w) <= threads, "n={n} threads={threads} w={w}");
            }
        }
    }

    #[test]
    fn pinned_pool_results_match_unpinned() {
        // Affinity is a placement hint only: same item→slot assignment,
        // same results — and pinned helpers park/wake like unpinned
        // ones across many rounds.
        let pinned = Pool::with_affinity(3, true);
        let plain = Pool::with_affinity(3, false);
        let mut a = vec![0u32; 11];
        let mut b = vec![0u32; 11];
        pinned.scatter(&mut a, |i, v| *v = (i * i) as u32);
        plain.scatter(&mut b, |i, v| *v = (i * i) as u32);
        assert_eq!(a, b);
        for round in 0..200u32 {
            pinned.scatter(&mut a, |i, v| *v += i as u32 + round % 2);
        }
        let mut expect: Vec<u32> = (0..11).map(|i| (i * i) as u32).collect();
        for round in 0..200u32 {
            for (i, v) in expect.iter_mut().enumerate() {
                *v += i as u32 + round % 2;
            }
        }
        assert_eq!(a, expect);
    }

    #[test]
    fn global_pool_is_shared() {
        let a = Pool::global();
        let b = Pool::global();
        assert_eq!(a.threads(), b.threads());
        let mut items = vec![0u8; 4];
        a.scatter(&mut items, |i, v| *v = i as u8);
        assert_eq!(items, vec![0, 1, 2, 3]);
    }
}
