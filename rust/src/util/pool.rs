//! Scoped-thread worker pool for the per-round fan-out.
//!
//! The trainers' hot loop is embarrassingly parallel across workers: each
//! worker's gradient + sparsify step touches only its own shard and state.
//! [`Pool::scatter`] fans a `&mut [T]` of per-worker lanes out across OS
//! threads via [`std::thread::scope`] (no unsafe, no external crates) and
//! hands every lane its index, so callers keep a **deterministic
//! reduction order** afterwards: results land in the lane they belong to
//! and the main thread folds them in worker-id order. Trajectories are
//! therefore bit-for-bit identical for any thread count — pinned by
//! `tests/prop_parallel_parity.rs`.
//!
//! Scoped threads are spawned per call. At the paper's scales one round
//! costs hundreds of microseconds to milliseconds of compute, so the
//! ~10 µs spawn cost is noise; a persistent pool would buy nothing but
//! unsafe code or channels on the hot path.

/// A fan-out policy: how many OS threads to use per [`Pool::scatter`].
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with an explicit thread count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Serial execution (thread count 1); `scatter` runs inline.
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    /// Thread count from `GDSEC_THREADS`, falling back to the machine's
    /// available parallelism.
    pub fn from_env() -> Pool {
        let threads = std::env::var("GDSEC_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        Pool::new(threads)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f(index, item)` to every item, fanning contiguous chunks out
    /// across up to `threads` scoped threads. Each item is visited exactly
    /// once; item order **within** the slice is preserved, so a caller
    /// that reduces `items` front-to-back afterwards sees the same result
    /// for any thread count. With 1 thread (or ≤ 1 item) this runs inline
    /// and allocates nothing.
    pub fn scatter<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        if self.threads == 1 || n <= 1 {
            for (i, item) in items.iter_mut().enumerate() {
                f(i, item);
            }
            return;
        }
        let chunk = n.div_ceil(self.threads);
        std::thread::scope(|s| {
            for (ci, ch) in items.chunks_mut(chunk).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, item) in ch.iter_mut().enumerate() {
                        f(ci * chunk + j, item);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_cover_all_items_once() {
        for threads in [1, 2, 3, 8, 17] {
            let pool = Pool::new(threads);
            let mut items = vec![0u32; 13];
            pool.scatter(&mut items, |i, v| *v = i as u32 + 1);
            let expect: Vec<u32> = (1..=13).collect();
            assert_eq!(items, expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single() {
        let pool = Pool::new(4);
        let mut empty: Vec<u8> = Vec::new();
        pool.scatter(&mut empty, |_, _| panic!("must not run"));
        let mut one = vec![5u8];
        pool.scatter(&mut one, |i, v| {
            assert_eq!(i, 0);
            *v += 1;
        });
        assert_eq!(one, vec![6]);
    }

    #[test]
    fn clamps_to_one_thread() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::serial().threads(), 1);
    }

    #[test]
    fn parallel_matches_serial_reduction() {
        // Per-lane work + in-order fold must not depend on thread count.
        let work = |i: usize, v: &mut f64| {
            *v = (i as f64 + 1.0).sqrt() * 0.37;
        };
        let mut a = vec![0.0f64; 101];
        let mut b = vec![0.0f64; 101];
        Pool::new(1).scatter(&mut a, work);
        Pool::new(7).scatter(&mut b, work);
        let fold = |xs: &[f64]| xs.iter().fold(0.0f64, |acc, x| acc + x);
        assert_eq!(fold(&a).to_bits(), fold(&b).to_bits());
    }
}
