//! Deterministic pseudo-random number generation.
//!
//! The build image has no `rand` crate, so we implement a PCG64-family
//! generator (O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation") plus the
//! distributions the data generators and stochastic algorithms need.
//!
//! Every experiment in this repository is seeded through this module, so
//! figure regeneration is bit-for-bit reproducible.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xor-shift-low + random
/// rotation output. Period 2^128, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let initseq = ((stream as u128) << 64) | (stream as u128 ^ 0xda3e_39cb_94b9_5bdb);
        let mut rng = Pcg64 { state: 0, inc: (initseq << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0x853c_49e6_748f_ea9b)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.uniform_in(lo as f64, hi as f64) as f32
    }

    /// Unbiased integer in [0, bound) via Lemire's multiply-shift rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (uses both outputs? we keep it
    /// stateless-simple: one draw per call, cached pair omitted to keep
    /// reproducibility trivially auditable).
    pub fn normal(&mut self) -> f64 {
        // Avoid u1 == 0 exactly.
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Rademacher ±1 label.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Draw an index from an (unnormalized) non-negative weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: all-zero weights");
        let mut t = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Vector of iid U(lo, hi) f64 values.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Vector of iid N(0,1) f64 values.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

/// SplitMix64 — used to derive independent child seeds from a master seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Derive the i-th child seed deterministically.
    pub fn child(seed: u64, i: u64) -> u64 {
        let mut sm = SplitMix64::new(seed ^ i.wrapping_mul(0xa076_1d64_78bd_642f));
        sm.next_u64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::seeded(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::seeded(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seeded(13);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seeded(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg64::seeded(23);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn splitmix_children_distinct() {
        let a = SplitMix64::child(1, 0);
        let b = SplitMix64::child(1, 1);
        let c = SplitMix64::child(2, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        // reproducible
        assert_eq!(a, SplitMix64::child(1, 0));
    }

    #[test]
    fn sign_is_balanced() {
        let mut r = Pcg64::seeded(29);
        let pos = (0..100_000).filter(|_| r.sign() > 0.0).count();
        assert!((pos as f64 / 100_000.0 - 0.5).abs() < 0.01);
    }
}
