//! Plain-text table rendering for experiment/bench reports — the harness
//! prints "the same rows/series the paper reports" through this.

/// A simple column-aligned table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for i in 0..ncols {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:<width$}", cells[i], width = widths[i]));
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }
}

/// Scientific notation with fixed significant digits, `-` for NaN.
pub fn sci(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:.3e}")
    }
}

/// Human-readable bit count (b, kb, Mb, Gb — decimal, matching the paper's
/// "total transmitted bits" axis).
pub fn bits(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}Gb", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}Mb", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}kb", v / 1e3)
    } else {
        format!("{v:.0}b")
    }
}

/// Percent with 2 decimals.
pub fn pct(v: f64) -> String {
    format!("{:.2}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["algo", "bits", "err"]);
        t.row(vec!["GD".into(), "1.2Mb".into(), "1e-3".into()]);
        t.row(vec!["GD-SEC".into(), "8.1kb".into(), "1e-3".into()]);
        let r = t.render();
        assert!(r.contains("GD-SEC"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // header and rows aligned: 'bits' column starts at same offset
        let off = lines[0].find("bits").unwrap();
        assert_eq!(lines[2].find("1.2Mb").unwrap(), off);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(bits(500.0), "500b");
        assert_eq!(bits(2_500.0), "2.50kb");
        assert_eq!(bits(3.2e6), "3.20Mb");
        assert_eq!(bits(1.5e9), "1.50Gb");
        assert_eq!(pct(0.9934), "99.34%");
        assert_eq!(sci(f64::NAN), "-");
        assert!(sci(5.4e-3).contains("e-3"));
    }
}
