//! Evictable per-worker server state: h-share ledgers as slabs keyed by
//! worker id, resident only while a worker is in the active cohort.
//!
//! The coordinator's per-worker attribution ledger (`h_shares[w]` —
//! exactly the β-scaled mass worker w's folded updates added to the
//! server's state variable h) was a dense `Vec<Vec<f64>>` of M
//! d-vectors: O(M·d) resident memory even when 99% of the fleet sits
//! out every round. At cross-device scale (M = 10k, cohort ≤ 10%) that
//! is the server's dominant allocation, and almost all of it is idle.
//!
//! [`StateStore`] keeps a ledger *slab* materialized only while its
//! worker is recently active:
//!
//! * **admission** ([`stage`](StateStore::stage)): a worker entering
//!   the cohort gets a slab off the free list (dense, length d — the
//!   sharded fold scatters into it by raw coordinate index), rehydrated
//!   bitwise from its parked compact image if it was evicted earlier;
//! * **booking**: [`ShardPlan::fold`](crate::util::shard::ShardPlan)
//!   books into resident slabs through a worker→slot indirection
//!   ([`book_view`](StateStore::book_view) /
//!   [`crate::util::shard::ShareBook::slot_of`]);
//! * **eviction** ([`evict_idle`](StateStore::evict_idle)): a slab
//!   idle for ≥ `horizon` rounds is compacted to its nonzero
//!   (coord, value) pairs — O(touched), not O(d), via the per-slab
//!   touched-coordinate list — zeroed, and returned to the free list;
//! * **restore**: re-admission scatters the parked pairs back. The
//!   round-trip is bitwise exact: slabs start at +0.0 and only ever
//!   accumulate `+=`, and IEEE-754 addition never produces −0.0 from a
//!   +0.0 accumulator, so "nonzero value" is exactly "value that was
//!   ever booked and did not cancel to +0.0" — and a cancelled
//!   coordinate restores to the +0.0 the dense ledger would hold.
//!
//! Server resident per-worker state is thus O(active cohort · d) slabs
//! plus O(Σ touched coords) parked bytes — not O(M·d). The always-
//! resident mode ([`resident`](StateStore::resident)) preallocates all
//! M slabs with an identity slot map and never evicts: bit-for-bit and
//! allocation-for-allocation the pre-store behavior, used whenever no
//! cohort/eviction is configured so the standing bitwise and zero-alloc
//! pins are untouched.
//!
//! Withdrawal ([`withdraw`](StateStore::withdraw)) — death under
//! renormalizing degradation, or EC-safe re-admission after a crash —
//! subtracts the ledger out of h wherever it lives (slab or parked
//! image) and zeroes it. Skipping never-touched coordinates is bitwise
//! safe: `x - 0.0` is bitwise `x` for every f64 `x`.

use std::sync::OnceLock;

/// Sentinel slot/owner id: "no slab" / "no worker".
const NO_SLOT: u32 = u32::MAX;

/// Default idle horizon (rounds a ledger survives untouched) when a
/// cohort is configured but `GDSEC_EVICT_ROUNDS` is not: evict as soon
/// as the worker sits out a round. Restores are O(touched coords), so
/// the cheapest horizon is also the tightest memory bound — one
/// cohort's slabs resident at a time.
pub const DEFAULT_EVICT_ROUNDS: u32 = 1;

/// Parse an eviction-horizon spec: a positive round count.
pub fn parse_evict_rounds(s: &str) -> Result<u32, String> {
    match s.parse::<u32>() {
        Ok(n) if n >= 1 => Ok(n),
        Ok(n) => Err(format!("horizon {n} rejected (a zero horizon would evict ledgers that \
                              are still being booked this round)")),
        Err(_) => Err(format!("got {s:?}")),
    }
}

/// The `GDSEC_EVICT_ROUNDS` override: how many rounds a worker's ledger
/// slab survives untouched before eviction. Unset/empty = the driver's
/// default ([`DEFAULT_EVICT_ROUNDS`] when a cohort is active, never
/// otherwise). Panics loudly on zero or garbage — the strict
/// `GDSEC_QUORUM` error style; a lenient parse silently falling back
/// would turn a memory-bound CI run into an O(M·d) one while staying
/// green.
pub fn evict_rounds_from_env() -> Option<u32> {
    static CACHE: OnceLock<Option<u32>> = OnceLock::new();
    *CACHE.get_or_init(|| match std::env::var("GDSEC_EVICT_ROUNDS").ok().as_deref() {
        None | Some("") => None,
        Some(s) => Some(parse_evict_rounds(s).unwrap_or_else(|e| {
            panic!("GDSEC_EVICT_ROUNDS must be a positive round count: {e}")
        })),
    })
}

/// Evictable per-worker ledger store (see module docs).
#[derive(Debug, Clone)]
pub struct StateStore {
    d: usize,
    /// `None` = always-resident mode (no eviction, identity slot map).
    horizon: Option<u32>,
    /// worker → slab index ([`NO_SLOT`] = not resident).
    slot: Vec<u32>,
    /// Dense d-length ledger slabs (resident + free-listed).
    slabs: Vec<Vec<f64>>,
    /// slab → owning worker ([`NO_SLOT`] = on the free list).
    owner: Vec<u32>,
    /// slab → sorted unique coordinates ever booked while resident
    /// (evicting mode only) — makes evict/withdraw O(touched).
    touched: Vec<Vec<u32>>,
    free: Vec<u32>,
    /// worker → parked compact ledger image (coords ∥ values), empty
    /// while resident or never-touched.
    parked_idx: Vec<Vec<u32>>,
    parked_val: Vec<Vec<f64>>,
    /// worker → last round it was staged.
    last_used: Vec<u32>,
    /// Touched-list merge scratch, reused across stagings.
    scratch: Vec<u32>,
    parked_entries: usize,
    evictions: u64,
    restores: u64,
    peak_bytes: usize,
}

impl StateStore {
    /// Always-resident store: all `m` dense slabs preallocated, identity
    /// slot map, nothing ever evicted — the pre-store `vec![vec![0.0;
    /// d]; m]` ledger, bit-for-bit and allocation-for-allocation
    /// (staging and eviction passes are no-ops).
    pub fn resident(d: usize, m: usize) -> StateStore {
        StateStore {
            d,
            horizon: None,
            slot: (0..m as u32).collect(),
            slabs: vec![vec![0.0; d]; m],
            owner: (0..m as u32).collect(),
            touched: Vec::new(),
            free: Vec::new(),
            parked_idx: vec![Vec::new(); m],
            parked_val: vec![Vec::new(); m],
            last_used: vec![0; m],
            scratch: Vec::new(),
            parked_entries: 0,
            evictions: 0,
            restores: 0,
            peak_bytes: m * d * 8,
        }
    }

    /// Evicting store: no slabs until workers are staged; a slab idle
    /// for ≥ `horizon` rounds is compacted and freed by
    /// [`evict_idle`](Self::evict_idle).
    pub fn evicting(d: usize, m: usize, horizon: u32) -> StateStore {
        assert!(horizon >= 1, "eviction horizon must be >= 1");
        StateStore {
            d,
            horizon: Some(horizon),
            slot: vec![NO_SLOT; m],
            slabs: Vec::new(),
            owner: Vec::new(),
            touched: Vec::new(),
            free: Vec::new(),
            parked_idx: vec![Vec::new(); m],
            parked_val: vec![Vec::new(); m],
            last_used: vec![0; m],
            scratch: Vec::new(),
            parked_entries: 0,
            evictions: 0,
            restores: 0,
            peak_bytes: 0,
        }
    }

    /// Dispatch on an optional horizon (the coordinator's config shape).
    pub fn new(d: usize, m: usize, horizon: Option<u32>) -> StateStore {
        match horizon {
            Some(hz) => StateStore::evicting(d, m, hz),
            None => StateStore::resident(d, m),
        }
    }

    /// Number of workers the store tracks.
    pub fn workers(&self) -> usize {
        self.slot.len()
    }

    pub fn is_resident(&self, w: usize) -> bool {
        self.slot.get(w).is_some_and(|&s| s != NO_SLOT)
    }

    /// Slabs currently owned by a worker (excludes the free list).
    pub fn resident_count(&self) -> usize {
        self.slabs.len() - self.free.len()
    }

    /// Ledger slabs evicted (compacted + freed) so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Evicted ledgers rehydrated on re-admission (only counted when the
    /// parked image was nonempty — restoring an all-zero ledger is a
    /// no-op either way).
    pub fn restores(&self) -> u64 {
        self.restores
    }

    /// Bytes resident for per-worker ledger state right now: every
    /// allocated slab (free-listed ones included — they are held) at
    /// 8 B/coordinate plus every parked entry at 12 B (u32 coord +
    /// f64 value). Length-based, not capacity-based: the information
    /// the store holds, comparable across allocators.
    pub fn resident_bytes(&self) -> usize {
        self.slabs.len() * self.d * 8 + self.parked_entries * 12
    }

    /// High-water [`resident_bytes`](Self::resident_bytes), sampled
    /// after every staging and eviction pass.
    pub fn peak_resident_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Admit worker `w` for round `k` and record the coordinates its
    /// update is about to book (`idx`: the update's sorted index list).
    /// Materializes the slab (rehydrating a parked image bitwise) if the
    /// worker is not resident. No-op in always-resident mode beyond the
    /// idle stamp (which nothing reads there) — zero work on the pinned
    /// full-participation path.
    pub fn stage(&mut self, w: usize, k: u32, idx: &[u32]) {
        if self.horizon.is_none() {
            return;
        }
        self.last_used[w] = k;
        if self.slot[w] == NO_SLOT {
            self.admit(w);
        }
        let s = self.slot[w] as usize;
        merge_sorted(&mut self.touched[s], idx, &mut self.scratch);
        self.peak_bytes = self.peak_bytes.max(self.resident_bytes());
    }

    /// Materialize worker `w`'s slab off the free list (or grow one) and
    /// scatter its parked compact image back in, bitwise.
    fn admit(&mut self, w: usize) {
        let s = match self.free.pop() {
            Some(s) => s as usize,
            None => {
                self.slabs.push(vec![0.0; self.d]);
                self.touched.push(Vec::new());
                self.owner.push(NO_SLOT);
                self.slabs.len() - 1
            }
        };
        debug_assert!(self.touched[s].is_empty());
        // The parked coord list becomes the slab's initial touched list
        // (a swap, so both allocations survive for the next round-trip).
        std::mem::swap(&mut self.touched[s], &mut self.parked_idx[w]);
        let slab = &mut self.slabs[s];
        let vals = &mut self.parked_val[w];
        if !vals.is_empty() {
            for (&i, &v) in self.touched[s].iter().zip(vals.iter()) {
                slab[i as usize] = v;
            }
            self.parked_entries -= vals.len();
            self.restores += 1;
            vals.clear();
        }
        self.slot[w] = s as u32;
        self.owner[s] = w as u32;
    }

    /// Evict every slab whose worker has been idle for ≥ the horizon as
    /// of round `k`. Call at the TOP of each round, before staging: a
    /// horizon of 1 then means exactly one cohort's slabs are resident
    /// at a time. No-op in always-resident mode.
    pub fn evict_idle(&mut self, k: u32) {
        let Some(hz) = self.horizon else { return };
        for s in 0..self.owner.len() {
            let w = self.owner[s];
            if w != NO_SLOT && k.saturating_sub(self.last_used[w as usize]) >= hz {
                self.evict(w as usize);
            }
        }
        self.peak_bytes = self.peak_bytes.max(self.resident_bytes());
    }

    /// Compact worker `w`'s slab to its nonzero (coord, value) pairs,
    /// zero it, and free it. O(touched coords).
    fn evict(&mut self, w: usize) {
        let s = self.slot[w] as usize;
        let slab = &mut self.slabs[s];
        let pi = &mut self.parked_idx[w];
        let pv = &mut self.parked_val[w];
        debug_assert!(pi.is_empty() && pv.is_empty());
        for &i in &self.touched[s] {
            let v = slab[i as usize];
            // A +0.0 accumulator never turns negative-zero under `+=`,
            // so "== 0.0" is exactly "restores to the +0.0 a dense
            // ledger would hold" — dropping it is bitwise-lossless.
            debug_assert!(v.to_bits() != (-0.0f64).to_bits());
            if v != 0.0 {
                pi.push(i);
                pv.push(v);
            }
            slab[i as usize] = 0.0;
        }
        self.touched[s].clear();
        self.parked_entries += pi.len();
        self.slot[w] = NO_SLOT;
        self.owner[s] = NO_SLOT;
        self.free.push(s as u32);
        self.evictions += 1;
    }

    /// Subtract worker `w`'s ledger out of `h` — wherever it lives —
    /// and zero it. Per-component subtraction of exactly what was
    /// booked, so retirement is bitwise-exact for the retired worker
    /// while every other ledger stays untouched. Skipping never-touched
    /// coordinates is bitwise-safe (`x - 0.0` is bitwise `x`). A no-op
    /// for an empty store (state variable off) or an untouched worker.
    pub fn withdraw(&mut self, w: usize, h: &mut [f64]) {
        if w >= self.slot.len() {
            return;
        }
        if self.horizon.is_none() {
            // Always-resident: the dense per-component loop, exactly
            // the pre-store `withdraw_share`.
            let share = &mut self.slabs[w];
            for (hv, sv) in h.iter_mut().zip(share.iter_mut()) {
                *hv -= *sv;
                *sv = 0.0;
            }
            return;
        }
        let s = self.slot[w];
        if s != NO_SLOT {
            let s = s as usize;
            let slab = &mut self.slabs[s];
            for &i in &self.touched[s] {
                h[i as usize] -= slab[i as usize];
                slab[i as usize] = 0.0;
            }
            self.touched[s].clear();
            // The slab stays resident (zeroed) — the worker is still in
            // the cohort; the idle horizon will reclaim it as usual.
        }
        let pi = &mut self.parked_idx[w];
        let pv = &mut self.parked_val[w];
        if !pi.is_empty() {
            for (&i, &v) in pi.iter().zip(pv.iter()) {
                h[i as usize] -= v;
            }
            self.parked_entries -= pi.len();
            pi.clear();
            pv.clear();
        }
    }

    /// The fold's view: the slab table plus the worker→slot map
    /// (`None` = identity, the always-resident fast path). Feed into
    /// [`crate::util::shard::ShareBook`]. Every worker staged this
    /// round is resident, which is all the fold dereferences.
    pub fn book_view(&mut self) -> (&mut [Vec<f64>], Option<&[u32]>) {
        let slot = if self.horizon.is_none() { None } else { Some(self.slot.as_slice()) };
        (&mut self.slabs, slot)
    }

    /// Worker `w`'s full-dimension ledger (slab or parked image
    /// scattered out), for parity tests and oracles.
    pub fn ledger_dense(&self, w: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.d);
        out.fill(0.0);
        let s = self.slot[w];
        if s != NO_SLOT {
            out.copy_from_slice(&self.slabs[s as usize]);
        } else {
            for (&i, &v) in self.parked_idx[w].iter().zip(self.parked_val[w].iter()) {
                out[i as usize] = v;
            }
        }
    }
}

/// Merge sorted-unique `add` into sorted-unique `into` (dedup), via
/// `scratch` — allocation-free once capacities are warm.
fn merge_sorted(into: &mut Vec<u32>, add: &[u32], scratch: &mut Vec<u32>) {
    if add.is_empty() {
        return;
    }
    // Common fast path: strictly new trailing coordinates.
    if into.last().is_none_or(|&last| last < add[0]) {
        into.extend_from_slice(add);
        return;
    }
    scratch.clear();
    let (mut i, mut j) = (0, 0);
    while i < into.len() && j < add.len() {
        match into[i].cmp(&add[j]) {
            std::cmp::Ordering::Less => {
                scratch.push(into[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                scratch.push(add[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                scratch.push(into[i]);
                i += 1;
                j += 1;
            }
        }
    }
    scratch.extend_from_slice(&into[i..]);
    scratch.extend_from_slice(&add[j..]);
    std::mem::swap(into, scratch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Book `scale·val` into a store-resident slab AND a dense oracle.
    fn book(
        store: &mut StateStore,
        dense: &mut [Vec<f64>],
        w: usize,
        k: u32,
        idx: &[u32],
        val: &[f32],
        scale: f64,
    ) {
        store.stage(w, k, idx);
        let (slabs, slot) = store.book_view();
        let s = slot.map_or(w, |m| m[w] as usize);
        for (&i, &v) in idx.iter().zip(val.iter()) {
            slabs[s][i as usize] += scale * v as f64;
            dense[w][i as usize] += scale * v as f64;
        }
    }

    fn random_update(rng: &mut Pcg64, d: usize, nnz: usize) -> (Vec<u32>, Vec<f32>) {
        let mut idx: Vec<u32> = Vec::new();
        while idx.len() < nnz {
            let i = rng.index(d) as u32;
            if !idx.contains(&i) {
                idx.push(i);
            }
        }
        idx.sort_unstable();
        let val: Vec<f32> = idx.iter().map(|_| rng.normal() as f32).collect();
        (idx, val)
    }

    #[test]
    fn evict_restore_roundtrip_is_bitwise_vs_always_resident() {
        let (d, m, rounds) = (64usize, 12usize, 40u32);
        for seed in 0..4u64 {
            let mut rng = Pcg64::new(0xEV1C, seed);
            let mut store = StateStore::evicting(d, m, 1 + (seed as u32 % 3));
            let mut dense = vec![vec![0.0f64; d]; m];
            for k in 1..=rounds {
                store.evict_idle(k);
                // A random cohort books random sparse updates.
                let c = 1 + rng.index(m / 2);
                for _ in 0..c {
                    let w = rng.index(m);
                    let (idx, val) = random_update(&mut rng, d, 1 + rng.index(6));
                    book(&mut store, &mut dense, w, k, &idx, &val, 0.05);
                }
            }
            assert!(store.evictions() > 0, "seed {seed}: nothing evicted");
            assert!(store.restores() > 0, "seed {seed}: nothing restored");
            // Every worker's ledger — resident, parked, or never
            // touched — matches the dense oracle bitwise.
            let mut out = vec![0.0f64; d];
            for w in 0..m {
                store.ledger_dense(w, &mut out);
                for j in 0..d {
                    assert_eq!(
                        out[j].to_bits(),
                        dense[w][j].to_bits(),
                        "seed {seed} w {w} j {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn withdraw_matches_dense_reference_from_any_residency() {
        let d = 32usize;
        let mut rng = Pcg64::new(0xD00D, 1);
        let mut store = StateStore::evicting(d, 3, 1);
        let mut dense = vec![vec![0.0f64; d]; 3];
        let mut h = vec![0.0f64; d];
        for k in 1..=6u32 {
            store.evict_idle(k);
            for w in 0..3 {
                if rng.uniform() < 0.6 {
                    let (idx, val) = random_update(&mut rng, d, 4);
                    book(&mut store, &mut dense, w, k, &idx, &val, 0.25);
                }
            }
        }
        // Mirror h = sum of ledgers, as the fold maintains it.
        for w in 0..3 {
            for j in 0..d {
                h[j] += dense[w][j];
            }
        }
        let mut h_ref = h.clone();
        // Worker 0 parked (evicted), worker 1 possibly resident:
        // withdraw both, against a dense-reference subtraction.
        store.evict_idle(100);
        assert!(!store.is_resident(0));
        for w in [0usize, 1] {
            store.withdraw(w, &mut h);
            for j in 0..d {
                h_ref[j] -= dense[w][j];
            }
        }
        for j in 0..d {
            assert_eq!(h[j].to_bits(), h_ref[j].to_bits());
        }
        // Withdrawn ledgers read back as zero; double-withdraw is a
        // no-op.
        let before = h.clone();
        let mut out = vec![1.0f64; d];
        store.ledger_dense(0, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
        store.withdraw(0, &mut h);
        assert_eq!(before, h);
        // Out-of-range / empty-store withdraws don't panic (the state
        // variable may be off).
        let mut empty = StateStore::resident(0, 0);
        empty.withdraw(5, &mut []);
    }

    #[test]
    fn resident_mode_is_dense_and_inert() {
        let mut store = StateStore::resident(8, 3);
        assert_eq!(store.resident_count(), 3);
        assert_eq!(store.resident_bytes(), 3 * 8 * 8);
        store.stage(1, 5, &[2, 4]); // no-op
        store.evict_idle(100); // no-op
        assert_eq!(store.evictions(), 0);
        assert_eq!(store.resident_count(), 3);
        let (slabs, slot) = store.book_view();
        assert!(slot.is_none(), "resident mode books through the identity map");
        assert_eq!(slabs.len(), 3);
        slabs[1][2] = 7.0;
        let mut h = vec![10.0f64; 8];
        store.withdraw(1, &mut h);
        assert_eq!(h[2], 3.0);
        let mut out = vec![1.0f64; 8];
        store.ledger_dense(1, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn memory_accounting_tracks_residency() {
        let d = 100usize;
        let mut store = StateStore::evicting(d, 50, 1);
        assert_eq!(store.resident_bytes(), 0);
        store.stage(7, 1, &[3, 9]);
        assert_eq!(store.resident_count(), 1);
        assert_eq!(store.resident_bytes(), d * 8);
        {
            let (slabs, slot) = store.book_view();
            let s = slot.unwrap()[7] as usize;
            slabs[s][3] = 1.5;
        }
        // Idle past the horizon: slab freed (still allocated — held on
        // the free list), one nonzero entry parked at 12 B.
        store.evict_idle(3);
        assert_eq!(store.resident_count(), 0);
        assert_eq!(store.resident_bytes(), d * 8 + 12);
        assert_eq!(store.evictions(), 1);
        // Re-admission reuses the freed slab: no new slab allocation.
        store.stage(8, 3, &[1]);
        assert_eq!(store.resident_bytes(), d * 8);
        assert_eq!(store.restores(), 0); // worker 8 had nothing parked
        store.stage(7, 3, &[4]);
        assert_eq!(store.restores(), 1);
        assert!(store.is_resident(7));
        let mut out = vec![0.0f64; d];
        store.ledger_dense(7, &mut out);
        assert_eq!(out[3], 1.5);
        assert!(store.peak_resident_bytes() >= store.resident_bytes());
    }

    #[test]
    fn merge_sorted_dedups_and_orders() {
        let mut scratch = Vec::new();
        let mut t = vec![2u32, 5, 9];
        merge_sorted(&mut t, &[1, 5, 7, 12], &mut scratch);
        assert_eq!(t, vec![1, 2, 5, 7, 9, 12]);
        merge_sorted(&mut t, &[], &mut scratch);
        assert_eq!(t, vec![1, 2, 5, 7, 9, 12]);
        // Append fast path.
        merge_sorted(&mut t, &[13, 20], &mut scratch);
        assert_eq!(t, vec![1, 2, 5, 7, 9, 12, 13, 20]);
        let mut empty: Vec<u32> = Vec::new();
        merge_sorted(&mut empty, &[4, 8], &mut scratch);
        assert_eq!(empty, vec![4, 8]);
    }

    #[test]
    fn evict_rounds_parse_contract() {
        assert_eq!(parse_evict_rounds("1"), Ok(1));
        assert_eq!(parse_evict_rounds("12"), Ok(12));
        assert!(parse_evict_rounds("0").is_err());
        assert!(parse_evict_rounds("-3").is_err());
        assert!(parse_evict_rounds("2.5").is_err());
        assert!(parse_evict_rounds("bogus").is_err());
    }
}
