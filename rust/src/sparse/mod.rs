//! Compressed sparse row (CSR) matrix — substrate for the RCV1-scale
//! experiment (Fig 7: 15181×47236, ~0.1% density), where dense storage
//! would be ~5.7 GB.

use crate::linalg;

/// CSR matrix with f64 values.
#[derive(Debug, Clone)]
pub struct CsrMat {
    pub rows: usize,
    pub cols: usize,
    /// Row start offsets into `indices`/`values`; length rows+1.
    pub indptr: Vec<usize>,
    /// Column indices, strictly increasing within a row.
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
}

impl CsrMat {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Build from per-row (col, value) lists; each row list must be sorted
    /// by column with unique columns.
    pub fn from_rows(cols: usize, rows_data: &[Vec<(u32, f64)>]) -> CsrMat {
        let rows = rows_data.len();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for row in rows_data {
            let mut last: i64 = -1;
            for &(c, v) in row {
                assert!((c as usize) < cols, "col out of range");
                assert!((c as i64) > last, "row cols must be sorted unique");
                last = c as i64;
                indices.push(c);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        CsrMat { rows, cols, indptr, indices, values }
    }

    /// Row accessor: (cols, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    /// out = A * x (dense x).
    pub fn spmv(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0;
            for k in 0..cols.len() {
                acc += vals[k] * x[cols[k] as usize];
            }
            out[i] = acc;
        }
    }

    /// out += alpha * A^T * r.
    pub fn spmv_t_acc(&self, alpha: f64, r: &[f64], out: &mut [f64]) {
        assert_eq!(r.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        for i in 0..self.rows {
            let a = alpha * r[i];
            if a == 0.0 {
                continue;
            }
            let (cols, vals) = self.row(i);
            for k in 0..cols.len() {
                out[cols[k] as usize] += a * vals[k];
            }
        }
    }

    /// Column-block slice of the transposed SpMV:
    /// `block[c − j0] += alpha · (Aᵀr)_c` for `c ∈ [j0, j0 + block.len())`.
    ///
    /// Column indices are strictly increasing within a row, so each row's
    /// entries inside the block form one contiguous subrange found with a
    /// binary search. Rows are visited in ascending order and rows with
    /// `alpha·r_i == 0` are skipped — per element this is exactly the
    /// accumulation order of [`spmv_t_acc`], which makes the blocked and
    /// pooled variants bitwise identical to the serial kernel.
    pub fn spmv_t_acc_block(&self, alpha: f64, r: &[f64], j0: usize, block: &mut [f64]) {
        assert_eq!(r.len(), self.rows);
        let j1 = j0 + block.len();
        assert!(j1 <= self.cols);
        for i in 0..self.rows {
            let a = alpha * r[i];
            if a == 0.0 {
                continue;
            }
            let (cols, vals) = self.row(i);
            let lo = cols.partition_point(|&c| (c as usize) < j0);
            for k in lo..cols.len() {
                let c = cols[k] as usize;
                if c >= j1 {
                    break;
                }
                block[c - j0] += a * vals[k];
            }
        }
    }

    /// [`spmv_t_acc`] fanned over contiguous column blocks of `out`, one
    /// per pool thread ([`spmv_t_acc_block`] each). Output bits do not
    /// depend on the thread count: every `out[j]` is owned by exactly one
    /// block and accumulates its rows in ascending order either way
    /// (pinned by `tests/prop_parallel_parity.rs`). The serial CSR walk
    /// re-streams the full d-length `out` from L2/L3 per row at RCV1
    /// scale (d = 47236 ⇒ 370 KB); the per-thread blocks stay
    /// cache-resident instead.
    pub fn spmv_t_acc_pooled(
        &self,
        alpha: f64,
        r: &[f64],
        out: &mut [f64],
        pool: &crate::util::pool::Pool,
    ) {
        assert_eq!(r.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        if pool.threads() == 1 || self.cols < 2 {
            self.spmv_t_acc(alpha, r, out);
            return;
        }
        pool.scatter_blocks(out, |j0, block| self.spmv_t_acc_block(alpha, r, j0, block));
    }

    /// Cut `[0, rows)` into contiguous row blocks greedily filled to an
    /// `nnz` budget — the shard-balancing unit of the engine's nested
    /// (worker, row-block) lanes: CSR shards can pack wildly unequal nnz
    /// into equal row counts, so lanes are balanced by work, not rows.
    /// Every block satisfies `nnz(block) ≤ budget` unless it is a single
    /// row whose own nnz exceeds the budget (a block never overshoots by
    /// more than that one row). Blocks partition the row range exactly.
    pub fn split_rows_by_nnz(&self, budget: usize) -> Vec<(usize, usize)> {
        let budget = budget.max(1);
        let mut out = Vec::new();
        let mut start = 0;
        while start < self.rows {
            let mut end = start + 1; // always take at least one row
            let mut acc = self.indptr[end] - self.indptr[start];
            while end < self.rows {
                let next = self.indptr[end + 1] - self.indptr[end];
                if acc + next > budget {
                    break;
                }
                acc += next;
                end += 1;
            }
            out.push((start, end));
            start = end;
        }
        out
    }

    /// Squared L2 norm of row i.
    pub fn row_nrm2_sq(&self, i: usize) -> f64 {
        let (_, vals) = self.row(i);
        linalg::nrm2_sq(vals)
    }

    /// Per-column sum of squared values — used for coordinate-wise
    /// Lipschitz constants of quadratic/logistic losses.
    pub fn col_sq_sums(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for k in 0..self.values.len() {
            let c = self.indices[k] as usize;
            out[c] += self.values[k] * self.values[k];
        }
        out
    }

    /// Upper bound on sigma_max(A)^2 via power iteration on A^T A.
    pub fn power_iter_ata(&self, iters: usize) -> f64 {
        self.power_iter_ata_pooled(iters, &crate::util::pool::Pool::serial())
    }

    /// [`power_iter_ata`](Self::power_iter_ata) with the transposed
    /// accumulation — the expensive half at RCV1 width — fanned over
    /// `pool` column blocks ([`spmv_t_acc_pooled`](Self::spmv_t_acc_pooled)
    /// is bitwise identical to the serial walk, so the estimate never
    /// depends on the thread count). Must not be called from inside a
    /// scatter job of the same pool.
    pub fn power_iter_ata_pooled(&self, iters: usize, pool: &crate::util::pool::Pool) -> f64 {
        let d = self.cols;
        if d == 0 || self.rows == 0 || self.nnz() == 0 {
            return 0.0;
        }
        let mut v = vec![1.0 / (d as f64).sqrt(); d];
        let mut av = vec![0.0; self.rows];
        let mut atav = vec![0.0; d];
        let mut lambda = 0.0;
        for _ in 0..iters {
            self.spmv(&v, &mut av);
            linalg::zero(&mut atav);
            self.spmv_t_acc_pooled(1.0, &av, &mut atav, pool);
            lambda = linalg::nrm2(&atav);
            if lambda <= 1e-300 {
                return 0.0;
            }
            for i in 0..d {
                v[i] = atav[i] / lambda;
            }
        }
        lambda
    }

    /// Slice out a contiguous row range as a new CSR (worker sharding).
    pub fn row_slice(&self, start: usize, end: usize) -> CsrMat {
        assert!(start <= end && end <= self.rows);
        let s = self.indptr[start];
        let e = self.indptr[end];
        let indptr = self.indptr[start..=end].iter().map(|p| p - s).collect();
        CsrMat {
            rows: end - start,
            cols: self.cols,
            indptr,
            indices: self.indices[s..e].to_vec(),
            values: self.values[s..e].to_vec(),
        }
    }

    /// Densify (tests / tiny matrices only).
    pub fn to_dense(&self) -> linalg::DenseMat {
        let mut m = linalg::DenseMat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for k in 0..cols.len() {
                m.row_mut(i)[cols[k] as usize] = vals[k];
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMat {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [0, 3, 4]]
        CsrMat::from_rows(3, &[vec![(0, 1.0), (2, 2.0)], vec![], vec![(1, 3.0), (2, 4.0)]])
    }

    #[test]
    fn structure() {
        let a = sample();
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.indptr, vec![0, 2, 2, 4]);
        assert_eq!(a.row(1).0.len(), 0);
    }

    #[test]
    fn spmv_matches_dense() {
        let a = sample();
        let x = vec![1.0, -1.0, 0.5];
        let mut out = vec![0.0; 3];
        a.spmv(&x, &mut out);
        assert_eq!(out, vec![2.0, 0.0, -1.0]);

        let dense = a.to_dense();
        let mut out2 = vec![0.0; 3];
        dense.gemv(&x, &mut out2);
        assert_eq!(out, out2);
    }

    #[test]
    fn spmv_t_matches_dense() {
        let a = sample();
        let r = vec![2.0, 5.0, -1.0];
        let mut out = vec![0.0; 3];
        a.spmv_t_acc(1.0, &r, &mut out);
        let dense = a.to_dense();
        let mut out2 = vec![0.0; 3];
        dense.gemv_t_acc(1.0, &r, &mut out2);
        assert_eq!(out, out2);
    }

    #[test]
    fn col_sq_sums_correct() {
        let a = sample();
        assert_eq!(a.col_sq_sums(), vec![1.0, 9.0, 20.0]);
    }

    #[test]
    fn row_slice_preserves_rows() {
        let a = sample();
        let b = a.row_slice(1, 3);
        assert_eq!(b.rows, 2);
        assert_eq!(b.row(0).0.len(), 0);
        assert_eq!(b.row(1).1, &[3.0, 4.0]);
        assert_eq!(b.indptr, vec![0, 0, 2]);
    }

    #[test]
    fn power_iter_matches_dense() {
        let a = sample();
        let ld = linalg::power_iter_ata(&a.to_dense(), 200);
        let ls = a.power_iter_ata(200);
        assert!((ld - ls).abs() < 1e-6 * ld.max(1.0));
    }

    #[test]
    fn spmv_t_blocked_matches_serial_bitwise() {
        // Deterministic pseudo-random CSR, awkward block boundaries.
        let d = 37;
        let rows: Vec<Vec<(u32, f64)>> = (0..23)
            .map(|i| {
                (0..d)
                    .filter(|j| (i * 7 + j * 13) % 5 == 0)
                    .map(|j| (j as u32, ((i * d + j) as f64 * 0.37).sin()))
                    .collect()
            })
            .collect();
        let a = CsrMat::from_rows(d, &rows);
        let mut r: Vec<f64> = (0..a.rows).map(|i| ((i as f64) * 0.7).cos()).collect();
        r[5] = 0.0; // zero rows must be skipped exactly
        let mut serial: Vec<f64> = (0..d).map(|j| (j as f64) * 0.01).collect();
        let mut blocked = serial.clone();
        a.spmv_t_acc(0.35, &r, &mut serial);
        let mut j0 = 0;
        for width in [1usize, 4, 13, 19] {
            let j1 = (j0 + width).min(d);
            a.spmv_t_acc_block(0.35, &r, j0, &mut blocked[j0..j1]);
            j0 = j1;
        }
        a.spmv_t_acc_block(0.35, &r, j0, &mut blocked[j0..]);
        for j in 0..d {
            assert_eq!(serial[j].to_bits(), blocked[j].to_bits(), "j={j}");
        }
    }

    #[test]
    fn spmv_t_pooled_matches_serial_bitwise() {
        use crate::util::pool::Pool;
        let d = 301;
        let rows: Vec<Vec<(u32, f64)>> = (0..50)
            .map(|i| {
                (0..d)
                    .filter(|j| (i * 11 + j * 3) % 7 == 0)
                    .map(|j| (j as u32, ((i + j) as f64 * 0.11).sin()))
                    .collect()
            })
            .collect();
        let a = CsrMat::from_rows(d, &rows);
        let r: Vec<f64> = (0..a.rows).map(|i| ((i as f64) * 1.3).sin()).collect();
        let mut serial: Vec<f64> = (0..d).map(|j| (j as f64) * -0.02).collect();
        let pooled_init = serial.clone();
        a.spmv_t_acc(1.5, &r, &mut serial);
        for threads in [1usize, 2, 4, 7] {
            let pool = Pool::new(threads);
            let mut pooled = pooled_init.clone();
            a.spmv_t_acc_pooled(1.5, &r, &mut pooled, &pool);
            for j in 0..d {
                assert_eq!(serial[j].to_bits(), pooled[j].to_bits(), "threads={threads} j={j}");
            }
        }
    }

    #[test]
    fn power_iter_pooled_matches_serial_bitwise() {
        use crate::util::pool::Pool;
        let d = 97;
        let rows: Vec<Vec<(u32, f64)>> = (0..40)
            .map(|i| {
                (0..d)
                    .filter(|j| (i * 5 + j * 2) % 7 == 0)
                    .map(|j| (j as u32, ((i * d + j) as f64 * 0.21).cos()))
                    .collect()
            })
            .collect();
        let a = CsrMat::from_rows(d, &rows);
        let serial = a.power_iter_ata(40);
        for threads in [2usize, 4] {
            let pooled = a.power_iter_ata_pooled(40, &Pool::new(threads));
            assert_eq!(serial.to_bits(), pooled.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn split_rows_by_nnz_partitions_and_respects_budget() {
        // Rows with nnz 2, 0, 2 and an 11-nnz monster row.
        let d = 16;
        let rows: Vec<Vec<(u32, f64)>> = vec![
            vec![(0, 1.0), (3, 1.0)],
            vec![],
            vec![(1, 1.0), (2, 1.0)],
            (0..11).map(|j| (j as u32, 1.0)).collect(),
            vec![(5, 1.0)],
        ];
        let a = CsrMat::from_rows(d, &rows);
        let blocks = a.split_rows_by_nnz(4);
        // Exact partition in order.
        let mut cursor = 0;
        for &(s, e) in &blocks {
            assert_eq!(s, cursor);
            assert!(e > s);
            cursor = e;
        }
        assert_eq!(cursor, a.rows);
        // Budget respected except for single monster rows.
        for &(s, e) in &blocks {
            let nnz = a.indptr[e] - a.indptr[s];
            assert!(nnz <= 4 || e - s == 1, "block {s}..{e} nnz={nnz}");
        }
        // The monster row sits alone.
        assert!(blocks.contains(&(3, 4)));
        // Empty matrix: no blocks.
        assert!(CsrMat::from_rows(4, &[]).split_rows_by_nnz(4).is_empty());
    }

    #[test]
    #[should_panic]
    fn unsorted_cols_rejected() {
        CsrMat::from_rows(3, &[vec![(2, 1.0), (0, 1.0)]]);
    }

    #[test]
    fn empty_matrix() {
        let a = CsrMat::from_rows(5, &[]);
        assert_eq!(a.rows, 0);
        assert_eq!(a.power_iter_ata(5), 0.0);
    }
}
