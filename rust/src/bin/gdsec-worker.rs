//! GD-SEC worker as a standalone process.
//!
//! Connects a real TCP socket to a running `gdsec-server`, identifies
//! itself with a `Join` hello carrying its worker id, and then runs the
//! exact same [`worker_loop`](gdsec::coordinator::worker::worker_loop)
//! the in-proc threads run — the transport is the only difference. The
//! problem shard is rebuilt locally from the seeded spec
//! ([`gdsec::coordinator::deploy::DeploySpec`]), so no training data
//! crosses the wire, only GD-SEC frames.
//!
//! ```text
//! gdsec-worker --connect 127.0.0.1:7700 --id 0 --workers 3
//! ```
//!
//! A dropped connection is not fatal: the worker reconnects with
//! capped-backoff retries and re-hellos with the last round it saw, so
//! the server's `Join` re-admission path gives it a fresh enrollment
//! snapshot. The process exits 0 only on a protocol `Shutdown`.

use gdsec::algo::engine::stale_window_from_env;
use gdsec::compress::WireFormat;
use gdsec::coordinator::deploy::DeploySpec;
use gdsec::coordinator::tcp::{self, TcpTransport};
use gdsec::coordinator::transport::FaultPlan;
use gdsec::coordinator::worker::{worker_loop, GradProvider, LoopExit, NativeProvider};
use gdsec::util::cli::{usage, Args, OptSpec};

fn opt(name: &str, help: &str, default: Option<&str>) -> OptSpec {
    OptSpec { name: name.into(), help: help.into(), default: default.map(|s| s.into()) }
}

fn main() {
    let args = match Args::from_env(false) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("gdsec-worker: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") {
        println!("{}", usage_text());
        return;
    }
    let (spec, id) = match parse(&args) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("gdsec-worker: {e}\n\n{}", usage_text());
            std::process::exit(2);
        }
    };
    let connect = args
        .get("connect")
        .map(|s| tcp::parse_addr("--connect", s))
        .or_else(tcp::connect_from_env)
        .unwrap_or_else(|| tcp::parse_addr("--connect", "127.0.0.1:7700"));

    let prob = spec.problem();
    assert!(
        id < prob.m(),
        "gdsec-worker: --id {id} out of range for --workers {}",
        prob.m()
    );
    let gdsec_cfg = spec.gdsec(&prob);
    let faults = FaultPlan::from_env().faults_for(id);
    let wire = WireFormat::from_env();
    let stale_window = stale_window_from_env();
    let local = prob.locals[id].clone();

    let mut last_seen: u32 = 0;
    loop {
        let mut end = match TcpTransport::connect(connect) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("gdsec-worker {id}: connect {connect}: {e}");
                std::process::exit(1);
            }
        };
        if !tcp::send_hello(&mut end, id as u32, last_seen) {
            eprintln!("gdsec-worker {id}: hello to {connect} failed, retrying");
            continue;
        }
        eprintln!("gdsec-worker {id}: connected to {connect} (last_seen={last_seen})");
        let shard = local.clone();
        let factory =
            Box::new(move || Box::new(NativeProvider::new(shard)) as Box<dyn GradProvider>);
        match worker_loop(
            id as u32,
            spec.workers,
            gdsec_cfg.clone(),
            factory,
            end,
            faults.clone(),
            wire,
            stale_window,
        ) {
            LoopExit::Shutdown => {
                eprintln!("gdsec-worker {id}: shutdown, exiting");
                return;
            }
            LoopExit::LinkLost { last_seen: seen } => {
                last_seen = seen;
                eprintln!("gdsec-worker {id}: link lost at round {seen}, reconnecting");
            }
        }
    }
}

fn parse(args: &Args) -> Result<(DeploySpec, usize), gdsec::util::cli::CliError> {
    let def = DeploySpec::default();
    let spec = DeploySpec {
        seed: args.get_u64("seed", def.seed)?,
        rows: args.get_usize("rows", def.rows)?,
        workers: args.get_usize("workers", def.workers)?,
        iters: def.iters, // horizon is server-driven; workers follow broadcasts
    };
    let id = args.require("id")?;
    let id = id
        .parse::<usize>()
        .map_err(|_| gdsec::util::cli::CliError(format!("--id: expected integer, got '{id}'")))?;
    Ok((spec, id))
}

fn usage_text() -> String {
    usage(
        "gdsec-worker",
        "GD-SEC worker over a real TCP link (pairs with gdsec-server)",
        &[],
        &[
            opt("connect", "server address (env GDSEC_CONNECT)", Some("127.0.0.1:7700")),
            opt("id", "worker id in 0..workers (required)", None),
            opt("workers", "fleet size; must match the server", Some("3")),
            opt("seed", "dataset seed (must match the server)", Some("17")),
            opt("rows", "dataset rows (must match the server)", Some("90")),
        ],
    )
}
