//! GD-SEC coordinator as a standalone process.
//!
//! Binds a TCP listener, waits for `--workers` hello handshakes from
//! `gdsec-worker` processes, then runs the coordinated protocol over
//! the real sockets with wall-clock quorum delays. The run spec
//! (problem seed/size, worker count, horizon) is rebuilt locally from
//! the same flags the workers receive — see
//! [`gdsec::coordinator::deploy::DeploySpec`].
//!
//! ```text
//! gdsec-server --listen 127.0.0.1:7700 --workers 3 --iters 30
//! ```
//!
//! With `--check-inproc` the server re-runs the identical spec in-proc
//! on the virtual transport after the TCP run finishes and asserts
//! bitwise parity: same final objective, same per-round payload bits,
//! same total uplink frame bytes. Any divergence exits non-zero — this
//! is the CI gate that the socket path is an accounting-faithful
//! transport swap, not a different protocol.

use gdsec::coordinator::deploy::DeploySpec;
use gdsec::coordinator::round::Quorum;
use gdsec::coordinator::scheduler::Scheduler;
use gdsec::coordinator::tcp;
use gdsec::coordinator::transport::{DelayPlan, FaultPlan, Transport};
use gdsec::coordinator::{run_native_opts, Coordinator, DegradePolicy};
use gdsec::util::cli::{usage, Args, OptSpec};
use std::net::TcpListener;

fn opt(name: &str, help: &str, default: Option<&str>) -> OptSpec {
    OptSpec { name: name.into(), help: help.into(), default: default.map(|s| s.into()) }
}

fn main() {
    let args = match Args::from_env(false) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("gdsec-server: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") {
        println!("{}", usage_text());
        return;
    }
    let spec = match spec_from(&args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("gdsec-server: {e}\n\n{}", usage_text());
            std::process::exit(2);
        }
    };
    let listen = args
        .get("listen")
        .map(|s| tcp::parse_addr("--listen", s))
        .or_else(tcp::listen_from_env)
        .unwrap_or_else(|| tcp::parse_addr("--listen", "127.0.0.1:7700"));
    let check_inproc = args.flag("check-inproc");

    let prob = spec.problem();
    let d = prob.d;
    let mut cfg = spec.coord_config(&prob);
    if check_inproc {
        // Parity is only defined against the pinned synchronous
        // trajectory: full quorum, no injected faults, no cohort
        // sampling — exactly what `run_native_opts` pins on the
        // virtual side.
        assert!(
            matches!(cfg.quorum, Quorum::All),
            "--check-inproc requires Quorum::All (unset GDSEC_QUORUM); got {:?}",
            cfg.quorum
        );
        cfg.faults = FaultPlan::default();
        cfg.degrade = DegradePolicy::Freeze;
        cfg.cohort = None;
        cfg.evict_after = None;
    }
    let gdsec_cfg = cfg.gdsec.clone();
    let iters = cfg.iters;

    let listener = TcpListener::bind(listen)
        .unwrap_or_else(|e| panic!("gdsec-server: bind {listen}: {e}"));
    eprintln!("gdsec-server: listening on {listen}, waiting for {} workers", spec.workers);
    let ends: Vec<Box<dyn Transport>> = tcp::accept_fleet(&listener, spec.workers)
        .into_iter()
        .map(|t| Box::new(t) as Box<dyn Transport>)
        .collect();
    let newcomers = tcp::spawn_acceptor(listener, spec.workers);
    eprintln!("gdsec-server: fleet of {} connected, running {} rounds", spec.workers, iters);

    let out = Coordinator::from_transports(cfg, d, ends, Some(newcomers), true).run();
    for (row, rm) in out.trace.rows.iter().zip(out.rounds.iter()) {
        println!(
            "ROUND k={} f={:.12e} quorum_k={} units_us={} payload_bits={} late={}",
            rm.round, row.fval, rm.quorum_k, rm.virtual_units, rm.payload_bits, rm.late
        );
    }
    let final_f = out.trace.rows.last().map(|r| r.fval).unwrap_or(f64::NAN);
    println!(
        "RESULT final_f={:.17e} uplink_bytes={} rounds={} dead={}",
        final_f,
        out.uplink_frame_bytes,
        out.rounds.len(),
        out.dead_workers.len()
    );

    if check_inproc {
        let reference =
            run_native_opts(&prob, gdsec_cfg, iters, Scheduler::All, Quorum::All, DelayPlan::None);
        let ref_f = reference.trace.rows.last().map(|r| r.fval).unwrap_or(f64::NAN);
        let mut ok = true;
        if final_f.to_bits() != ref_f.to_bits() {
            eprintln!("INPROC_PARITY MISMATCH final_f tcp={final_f:.17e} virtual={ref_f:.17e}");
            ok = false;
        }
        if out.uplink_frame_bytes != reference.uplink_frame_bytes {
            eprintln!(
                "INPROC_PARITY MISMATCH uplink_bytes tcp={} virtual={}",
                out.uplink_frame_bytes, reference.uplink_frame_bytes
            );
            ok = false;
        }
        if out.rounds.len() != reference.rounds.len() {
            eprintln!(
                "INPROC_PARITY MISMATCH rounds tcp={} virtual={}",
                out.rounds.len(),
                reference.rounds.len()
            );
            ok = false;
        }
        for (t, v) in out.rounds.iter().zip(reference.rounds.iter()) {
            if t.payload_bits != v.payload_bits {
                eprintln!(
                    "INPROC_PARITY MISMATCH round {} payload_bits tcp={} virtual={}",
                    t.round, t.payload_bits, v.payload_bits
                );
                ok = false;
            }
        }
        if !ok {
            std::process::exit(1);
        }
        println!("INPROC_PARITY OK");
    }
}

fn spec_from(args: &Args) -> Result<DeploySpec, gdsec::util::cli::CliError> {
    let def = DeploySpec::default();
    Ok(DeploySpec {
        seed: args.get_u64("seed", def.seed)?,
        rows: args.get_usize("rows", def.rows)?,
        workers: args.get_usize("workers", def.workers)?,
        iters: args.get_usize("iters", def.iters)?,
    })
}

fn usage_text() -> String {
    usage(
        "gdsec-server",
        "GD-SEC coordinator over real TCP links (pairs with gdsec-worker)",
        &[],
        &[
            opt("listen", "bind address (env GDSEC_LISTEN)", Some("127.0.0.1:7700")),
            opt("workers", "fleet size; must match the worker processes", Some("3")),
            opt("iters", "training rounds (plus one final eval round)", Some("30")),
            opt("seed", "dataset seed (must match the workers)", Some("17")),
            opt("rows", "dataset rows (must match the workers)", Some("90")),
            opt("check-inproc", "after the TCP run, assert bitwise parity vs in-proc", None),
        ],
    )
}
