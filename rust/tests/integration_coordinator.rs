//! Integration tests: the threaded coordinator must reproduce the serial
//! GD-SEC reference bit-for-bit (in synchronous mode — pinned with the
//! quorum explicitly at `All`, with and without injected delays, so the
//! round state machine refactor cannot drift), survive worker crashes and
//! re-admit restarted workers, fold stale updates under quorum cuts, and
//! account bytes exactly.
//!
//! Tests that pin exact trajectories set `cfg.faults`/`cfg.degrade`
//! explicitly (or go through `run_native_opts`, which pins them), so the
//! CI fault matrix (`GDSEC_FAULTS=...`) cannot perturb them; the
//! `run_native` tests deliberately inherit the ambient fault environment
//! and must stay correct under it.

use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::coordinator::round::Quorum;
use gdsec::coordinator::scheduler::{CohortPlan, Scheduler};
use gdsec::coordinator::transport::{
    duplex, DelayPlan, FaultPlan, LinkStats, Recv, RecvStatus, Transport, TransportKind,
    WorkerFaults,
};
use gdsec::coordinator::worker::{worker_loop, GradProvider, NativeProvider, ProviderFactory};
use gdsec::coordinator::{run_native_opts, CoordConfig, CoordOutcome, Coordinator, DegradePolicy};
use gdsec::data::synthetic;
use gdsec::objectives::Problem;
use std::sync::Arc;
use std::time::Duration;

fn problem() -> Problem {
    Problem::logistic(synthetic::dna_like(17, 90), 3, 0.05)
}

fn cfg_for(prob: &Problem) -> GdSecConfig {
    GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.05,
        xi: Xi::Uniform(40.0),
        ..Default::default()
    }
}

fn native_factories(prob: &Problem) -> Vec<ProviderFactory> {
    prob.locals
        .iter()
        .map(|l| {
            let local = l.clone();
            Box::new(move || Box::new(NativeProvider::new(local)) as Box<dyn GradProvider>)
                as ProviderFactory
        })
        .collect()
}

/// A fault plan crashing one worker (and optionally restarting it),
/// everything else fault-free.
fn crash_plan(m: usize, w: usize, crash_at: u32, restart_at: Option<u32>) -> FaultPlan {
    let mut workers = vec![WorkerFaults::default(); m];
    workers[w].crash_at = Some(crash_at);
    workers[w].restart_at = restart_at;
    FaultPlan { workers, ..FaultPlan::default() }
}

#[test]
fn distributed_matches_serial_bit_for_bit() {
    // Synchronous mode through the event-driven round machine: quorum
    // All AND quorum Count(M) AND quorum All under an aggressive jitter
    // delay plan must ALL be bitwise identical to the serial reference —
    // when every reply is kept, virtual arrival order cannot move a bit.
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 60;
    let serial = gdsec::algo::gdsec::run(&prob, &cfg, iters);
    for (label, quorum, delay) in [
        ("all", Quorum::All, DelayPlan::None),
        ("count=m", Quorum::Count(prob.m()), DelayPlan::None),
        ("all+jitter", Quorum::All, DelayPlan::Jitter { seed: 7, lo: 0, hi: 1000 }),
    ] {
        let dist = run_native_opts(&prob, cfg.clone(), iters, Scheduler::All, quorum, delay);
        assert_eq!(serial.rows.len(), dist.trace.rows.len());
        for (s, d) in serial.rows.iter().zip(dist.trace.rows.iter()) {
            assert_eq!(s.iter, d.iter);
            assert_eq!(
                s.fval.to_bits(),
                d.fval.to_bits(),
                "[{label}] fval diverged at iter {}: {} vs {}",
                s.iter,
                s.fval,
                d.fval
            );
            assert_eq!(s.bits, d.bits, "[{label}] bit accounting diverged at iter {}", s.iter);
            assert_eq!(s.transmissions, d.transmissions);
            assert_eq!(s.entries, d.entries);
            assert_eq!(d.stale, 0, "[{label}] synchronous round folded a stale update");
        }
    }
}

#[test]
fn distributed_matches_serial_with_soec_and_per_coord_xi() {
    let prob = problem();
    let mut cfg = cfg_for(&prob);
    cfg.error_correction = false;
    cfg.xi = Xi::scaled_by_lipschitz(10.0, &prob.coord_lipschitz());
    let iters = 40;
    let serial = gdsec::algo::gdsec::run(&prob, &cfg, iters);
    let dist = run_native_opts(&prob, cfg, iters, Scheduler::All, Quorum::All, DelayPlan::None);
    for (s, d) in serial.rows.iter().zip(dist.trace.rows.iter()) {
        assert_eq!(s.fval.to_bits(), d.fval.to_bits());
        assert_eq!(s.bits, d.bits);
    }
}

#[test]
fn quorum_straggler_converges_with_fewer_virtual_units_and_stale_folds() {
    // One hard straggler (900 virtual units vs 1). Synchronous rounds
    // wait for it every time; a K=2 quorum cuts it, folds its update one
    // round late, and must still converge to the tolerance the
    // synchronous run reaches — at a fraction of the virtual wall-clock.
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 80;
    let delay = DelayPlan::PerWorker(vec![1, 1, 900]);
    let sync =
        run_native_opts(&prob, cfg.clone(), iters, Scheduler::All, Quorum::All, delay.clone());
    let quorum = run_native_opts(&prob, cfg, iters, Scheduler::All, Quorum::Count(2), delay);

    // Convergence: the quorum run reaches the same f − f* tolerance.
    // Staleness-1 folding can cost a few rounds of progress, so the
    // target is what the synchronous run had reached by iter 60 (with a
    // 2× final-error floor against noise) — well within "the same
    // tolerance" for an 80-round run.
    let eps = sync.trace.errors()[60].max(sync.trace.final_error() * 2.0);
    assert!(eps.is_finite() && eps > 0.0);
    assert!(
        quorum.trace.final_error() <= eps,
        "quorum run missed tolerance: {} vs sync-final {} (eps {eps})",
        quorum.trace.final_error(),
        sync.trace.final_error()
    );

    // Staleness: the straggler's updates were folded, not dropped.
    let folded: u64 = quorum.rounds.iter().map(|r| r.stale_folded).sum();
    assert!(folded >= 1, "no stale update folded");
    assert_eq!(quorum.trace.total_stale(), folded);
    assert!(quorum.rounds.iter().any(|r| r.late > 0));
    assert_eq!(sync.trace.total_stale(), 0);

    // Wall-clock proxy: the synchronous run pays the straggler every
    // round; the quorum run's cut is bounded by the fast workers.
    let sync_units: u64 = sync.rounds.iter().map(|r| r.virtual_units).sum();
    let quorum_units: u64 = quorum.rounds.iter().map(|r| r.virtual_units).sum();
    assert!(
        quorum_units * 10 < sync_units,
        "quorum did not cut the straggler: {quorum_units} vs {sync_units}"
    );
    // All transmissions still accounted (the straggler pays its bits in
    // the round it transmits, on-time or not).
    assert!(quorum.trace.total_bits() > 0);
}

#[test]
fn multi_round_window_folds_aged_and_bounds_age() {
    // Staleness window 2 with one hard straggler: its cut-late updates
    // spend two rounds in transit (delivery age 2), so the pool holds
    // them across a round and folds them at their due round — ages
    // beyond the window never fold (the hard bound), the run still
    // converges, and the trace's cumulative age histogram agrees with
    // the per-round metrics.
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 80;
    let fstar = prob.estimate_fstar(2000);
    let factories = native_factories(&prob);
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, iters);
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = fstar;
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.quorum = Quorum::Count(2);
    ccfg.delay = DelayPlan::PerWorker(vec![1, 1, 900]);
    ccfg.stale_window = 2;
    ccfg.faults = FaultPlan::default(); // pin: exact fold/age assertions
    ccfg.degrade = DegradePolicy::Freeze;
    ccfg.cohort = None; // pin: the fold/age census assumes full participation
    ccfg.evict_after = None;
    ccfg.transport = TransportKind::Virtual; // pin: virtual DelayPlan semantics
    let out = Coordinator::spawn(ccfg, prob.d, factories).run();

    // Every fold is the straggler's, at delivery age 2 (its 899-unit
    // excess spans far more than one 1-unit round, clamped to S = 2).
    let folded: u64 = out.rounds.iter().map(|r| r.stale_folded).sum();
    assert!(folded >= 1, "no stale update folded");
    let mut hist = [0u64; 4];
    for r in &out.rounds {
        for (b, c) in hist.iter_mut().zip(r.stale_age_hist.iter()) {
            *b += c;
        }
    }
    assert_eq!(hist.iter().sum::<u64>(), folded, "histogram disagrees with fold count");
    assert_eq!(hist[2] + hist[3], 0, "fold older than the S=2 window");
    assert!(hist[1] >= 1, "multi-round (age 2) staleness never exercised");
    assert_eq!(out.trace.rows.last().unwrap().stale_ages, hist);
    assert_eq!(out.trace.total_stale(), folded);
    // Nothing expired here (the worker never falls physically behind).
    assert_eq!(out.rounds.iter().map(|r| r.stale_expired).sum::<u64>(), 0);

    // Still converging, still cheap in virtual time: the quorum cut
    // bounds every round at the fast workers' delay.
    let errs = out.trace.errors();
    assert!(errs.last().unwrap().is_finite());
    assert!(errs.last().unwrap() < &(errs[0] * 0.2), "{} -> {}", errs[0], errs.last().unwrap());
    assert!(out.rounds.iter().all(|r| r.virtual_units <= 1));
}

#[test]
fn quorum_dead_worker_mid_run_keeps_converging() {
    // Failure injection ON TOP of quorum rounds: worker 1 crashes (no
    // restart) and exceeds `dead_after` strikes mid-run; the round
    // machine shrinks the quorum to the live fleet and keeps folding the
    // remaining straggler's stale updates.
    let prob = problem();
    let m = prob.m();
    let cfg = cfg_for(&prob);
    let fstar = prob.estimate_fstar(2000);
    let factories = native_factories(&prob);
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, 60);
    ccfg.recv_timeout = Duration::from_millis(200);
    ccfg.dead_after = 2; // takes two strikes to die — exercises re-strikes
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = fstar;
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.quorum = Quorum::Fraction(0.5);
    ccfg.delay = DelayPlan::PerWorker(vec![0, 0, 50]);
    ccfg.faults = crash_plan(m, 1, 10, None);
    ccfg.degrade = DegradePolicy::Freeze;
    ccfg.cohort = None; // pin: the scripted death round assumes full scheduling
    ccfg.evict_after = None;
    ccfg.transport = TransportKind::Virtual; // pin: virtual DelayPlan semantics
    let out = Coordinator::spawn(ccfg, prob.d, factories).run();
    assert_eq!(out.dead_workers, vec![1]);
    let errs = out.trace.errors();
    assert!(errs.last().unwrap().is_finite());
    assert!(errs.last().unwrap() < &errs[2], "no progress after failure");
    // Quorum cuts still happened and stale updates still folded.
    assert!(out.trace.total_stale() >= 1, "quorum machine stopped folding");
    // The trace's dead column saw the death and never a rejoin.
    assert_eq!(out.trace.rows.last().unwrap().dead, 1);
    assert_eq!(out.trace.rows.last().unwrap().rejoined, 0);
}

#[test]
fn quorum_count_clamps_to_live_fleet() {
    // Regression: a fixed Count(M) quorum must clamp to the live worker
    // count once a worker dies — otherwise every post-death round would
    // wait out the full timeout for a reply that can never come (and
    // with Count > live the cut could never fire at all).
    let prob = problem();
    let m = prob.m();
    let cfg = cfg_for(&prob);
    let fstar = prob.estimate_fstar(2000);
    let factories = native_factories(&prob);
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, 40);
    ccfg.recv_timeout = Duration::from_millis(200);
    ccfg.dead_after = 1;
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = fstar;
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.quorum = Quorum::Count(m); // full-fleet quorum, then one dies
    ccfg.faults = crash_plan(m, 1, 5, None);
    ccfg.degrade = DegradePolicy::Freeze;
    ccfg.cohort = None; // pin: the wall-clock bound assumes full scheduling
    ccfg.evict_after = None;
    ccfg.transport = TransportKind::Virtual; // pin: virtual DelayPlan semantics
    let t0 = std::time::Instant::now();
    let out = Coordinator::spawn(ccfg, prob.d, factories).run();
    assert_eq!(out.dead_workers, vec![1]);
    // The survivors' rounds kept stepping: progress after the death.
    let errs = out.trace.errors();
    assert!(errs.last().unwrap().is_finite());
    assert!(errs.last().unwrap() < &errs[4], "no progress after the quorum shrank");
    // And they kept stepping FAST: only the single death round pays a
    // timeout. 35 post-death rounds at 200 ms each would take 7 s.
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "post-death rounds still waiting on the dead worker"
    );
}

#[test]
fn crash_restart_readmits_with_ec_reset() {
    // The full fault → recovery arc, deterministically scripted: worker 1
    // crashes at round 3, is declared dead, restarts at round 6, announces
    // itself with a `Join`, and is re-admitted — the server retires its
    // error-correction share and the worker re-enrolls with a fresh full
    // update. The run must end with an empty dead list, exactly one
    // rejoin on the books, and real convergence.
    let prob = problem();
    let m = prob.m();
    let cfg = cfg_for(&prob);
    let iters = 40;
    let fstar = prob.estimate_fstar(2000);
    let factories = native_factories(&prob);
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, iters);
    ccfg.recv_timeout = Duration::from_millis(300);
    ccfg.dead_after = 1;
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = fstar;
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.quorum = Quorum::All;
    ccfg.faults = crash_plan(m, 1, 3, Some(6));
    ccfg.degrade = DegradePolicy::Freeze;
    ccfg.cohort = None; // pin: the scripted crash/restart rounds assume full scheduling
    ccfg.evict_after = None;
    ccfg.transport = TransportKind::Virtual; // pin: virtual DelayPlan semantics
    let out = Coordinator::spawn(ccfg, prob.d, factories).run();

    // Recovered: dead while down, alive at the end.
    assert!(out.dead_workers.is_empty(), "restarted worker never re-admitted");
    let last = out.trace.rows.last().unwrap();
    assert_eq!(last.rejoined, 1, "exactly one Join handshake expected");
    assert_eq!(last.dead, 0);
    assert!(
        out.trace.rows.iter().any(|r| r.dead == 1),
        "the crash never showed up in the dead column"
    );
    assert_eq!(out.rounds.iter().map(|r| r.rejoined).sum::<u64>(), 1);

    // The outage is 3 rounds of one worker in 40 — convergence survives.
    let errs = out.trace.errors();
    assert!(errs.last().unwrap().is_finite());
    assert!(
        errs.last().unwrap() < &(errs[0] * 0.5),
        "{} -> {}",
        errs[0],
        errs.last().unwrap()
    );
}

#[test]
fn adaptive_wire_same_trajectory_tagged_bits() {
    // Adaptive wire format (now the default): the trajectory must be
    // bitwise equal to the paper's sparse wire (both decode to the same
    // f32 values), and every transmission's payload cost must differ
    // from the sparse run's by the 8-bit tag at most — strictly cheaper
    // than sparse + tag overall when dense rounds exist, never more than
    // 8 bits/tx more expensive.
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 30;
    let fstar = prob.estimate_fstar(2000);
    let spawn_with = |wire: gdsec::coordinator::protocol::WireFormat| {
        let factories = native_factories(&prob);
        let prob2 = prob.clone();
        let mut ccfg = CoordConfig::new(cfg.clone(), iters);
        ccfg.problem_name = prob.name.clone();
        ccfg.fstar = fstar;
        ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
        ccfg.wire = wire;
        ccfg.quorum = Quorum::All; // pin: this test compares wire formats
        ccfg.faults = FaultPlan::default(); // pin: bitwise comparison
        ccfg.degrade = DegradePolicy::Freeze;
        ccfg.cohort = None; // pin: bitwise comparison
        ccfg.evict_after = None;
    ccfg.transport = TransportKind::Virtual; // pin: virtual DelayPlan semantics
        Coordinator::spawn(ccfg, prob.d, factories).run()
    };
    let sparse = spawn_with(gdsec::coordinator::protocol::WireFormat::Sparse);
    let adaptive = spawn_with(gdsec::coordinator::protocol::WireFormat::Adaptive);

    assert_eq!(sparse.trace.rows.len(), adaptive.trace.rows.len());
    for (s, a) in sparse.trace.rows.iter().zip(adaptive.trace.rows.iter()) {
        assert_eq!(
            s.fval.to_bits(),
            a.fval.to_bits(),
            "adaptive wire changed the trajectory at iter {}",
            s.iter
        );
        assert_eq!(s.transmissions, a.transmissions);
        // Adaptive cost is bounded: at most one tag byte per transmission
        // over the sparse cost (and possibly much cheaper).
        assert!(
            a.bits <= s.bits + 8 * a.transmissions,
            "iter {}: adaptive {} vs sparse {} (+{} tags)",
            s.iter,
            a.bits,
            s.bits,
            a.transmissions
        );
    }
    // The tag is really accounted: with at least one transmitted update,
    // total adaptive bits cannot equal the sparse total exactly unless
    // dense fallbacks saved more than the tags cost.
    let tx = adaptive.trace.total_transmissions();
    assert!(tx > 0);
    assert_ne!(
        adaptive.trace.total_bits(),
        sparse.trace.total_bits(),
        "tag byte not visible in accounting"
    );
}

#[test]
fn uplink_frame_bytes_cover_payload_plus_headers() {
    // Runs under the ambient environment ON PURPOSE: the CI fault matrix
    // re-runs this with crash/restart faults injected, and the identity
    // must still hold — dropped, corrupted, drained, and `Join` frames
    // are all charged (payload or overhead), so sent bytes and accounted
    // bits never diverge.
    let prob = problem();
    let cfg = cfg_for(&prob);
    let out = gdsec::coordinator::run_native(&prob, cfg, 20, Scheduler::All);
    let payload_bits: u64 = out.rounds.iter().map(|r| r.payload_bits).sum();
    let overhead_bits: u64 = out.rounds.iter().map(|r| r.overhead_bits).sum();
    assert_eq!(
        out.uplink_frame_bytes * 8,
        payload_bits + overhead_bits,
        "byte accounting mismatch"
    );
    // Downlink counted too (θ broadcasts are large: 8 bytes/coord).
    assert!(out.downlink_frame_bytes > 0);
}

#[test]
fn round_robin_partial_participation() {
    let prob = problem();
    let cfg = cfg_for(&prob);
    let out =
        gdsec::coordinator::run_native(&prob, cfg, 80, Scheduler::RoundRobin { fraction: 0.5 });
    // fewer transmissions than full participation
    assert!(out.trace.total_transmissions() <= 80 * 2);
    // Still converging. The 2× error-halving target assumes the RR
    // half-fleet participation rate; under the CI cohort leg
    // (`GDSEC_COHORT` ambient, intersected with RR) far fewer worker
    // rounds happen, so there the claim is monotone progress — the
    // cohort leg checks the sampling/eviction machinery, not the rate.
    let errs = out.trace.errors();
    let factor =
        if std::env::var("GDSEC_COHORT").is_ok_and(|s| !s.is_empty()) { 1.0 } else { 0.5 };
    assert!(
        errs.last().unwrap() < &(errs[0] * factor),
        "{} -> {}",
        errs[0],
        errs.last().unwrap()
    );
    // No worker is dead at the END: fault-free runs never kill anyone,
    // and the CI fault matrix's crash=1@3,restart=1@6 must finish with
    // the worker re-admitted.
    assert!(out.dead_workers.is_empty());
}

#[test]
fn cohort_rounds_evict_and_readmit_with_faults() {
    // Cross-device cohort sampling composed with the fault machinery:
    // a seeded 2-of-3 cohort (so one worker sits out every round and its
    // ledger slab ages past the default idle horizon), plus a scripted
    // crash/restart of worker 1. The run must cycle the evictable state
    // store (evictions AND bitwise restores on cohort re-entry), re-admit
    // the restarted worker through the EC-safe `Join` path — including
    // withdrawing its ledger from wherever it lives, resident or parked —
    // and still make objective progress.
    let prob = problem();
    let m = prob.m();
    let cfg = cfg_for(&prob);
    let fstar = prob.estimate_fstar(2000);
    let factories = native_factories(&prob);
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, 60);
    ccfg.recv_timeout = Duration::from_millis(300);
    ccfg.dead_after = 1;
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = fstar;
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.quorum = Quorum::All;
    ccfg.faults = crash_plan(m, 1, 5, Some(9));
    ccfg.degrade = DegradePolicy::Freeze;
    // Explicit cohort (not ambient): 2 of 3 workers per round, default
    // idle horizon (1 round) via effective_horizon.
    ccfg.cohort = Some(CohortPlan::fraction(0.67, 0xC0F0));
    ccfg.evict_after = None;
    ccfg.transport = TransportKind::Virtual; // pin: virtual DelayPlan semantics
    let out = Coordinator::spawn(ccfg, prob.d, factories).run();

    // The store actually cycled: slabs were evicted when their workers
    // sat out, and parked ledgers rehydrated when they drew back in.
    assert!(out.state_evictions > 0, "cohort rounds never evicted a ledger");
    assert!(out.state_restores > 0, "no evicted ledger was ever restored");
    assert!(out.peak_state_bytes > 0);

    // The crash → restart arc completed under cohort sampling.
    assert!(out.dead_workers.is_empty(), "restarted worker never re-admitted");
    assert_eq!(out.rounds.iter().map(|r| r.rejoined).sum::<u64>(), 1);
    assert_eq!(out.trace.rows.last().unwrap().dead, 0);

    // Partial participation + an outage still optimizes.
    let errs = out.trace.errors();
    assert!(errs.last().unwrap().is_finite());
    assert!(
        errs.last().unwrap() < &errs[0],
        "no progress: {} -> {}",
        errs[0],
        errs.last().unwrap()
    );
}

#[test]
fn worker_failure_tolerated() {
    let prob = problem();
    let m = prob.m();
    let cfg = cfg_for(&prob);
    let fstar = prob.estimate_fstar(2000);
    let factories = native_factories(&prob);
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, 60);
    ccfg.recv_timeout = Duration::from_millis(200);
    ccfg.dead_after = 1;
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = fstar;
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    // Worker 1 crashes at round 10 and never comes back.
    ccfg.faults = crash_plan(m, 1, 10, None);
    ccfg.degrade = DegradePolicy::Freeze;
    ccfg.cohort = None; // pin: the scripted death round assumes full scheduling
    ccfg.evict_after = None;
    ccfg.transport = TransportKind::Virtual; // pin: virtual DelayPlan semantics
    let out = Coordinator::spawn(ccfg, prob.d, factories).run();
    assert_eq!(out.dead_workers, vec![1]);
    // Run completes and the survivors keep optimizing.
    let errs = out.trace.errors();
    assert!(errs.last().unwrap().is_finite());
    assert!(errs.last().unwrap() < &errs[2], "no progress after failure");
}

#[test]
fn all_workers_fail_run_still_terminates() {
    let prob = problem();
    let m = prob.m();
    let factories = native_factories(&prob);
    let workers = vec![WorkerFaults { crash_at: Some(1), ..WorkerFaults::default() }; m];
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg_for(&prob), 10);
    ccfg.recv_timeout = Duration::from_millis(100);
    ccfg.problem_name = prob.name.clone();
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.faults = FaultPlan { workers, ..FaultPlan::default() };
    ccfg.degrade = DegradePolicy::Freeze;
    ccfg.cohort = None; // pin: every worker must be scheduled into its crash round
    ccfg.evict_after = None;
    ccfg.transport = TransportKind::Virtual; // pin: virtual DelayPlan semantics
    let out = Coordinator::spawn(ccfg, prob.d, factories).run();
    assert_eq!(out.dead_workers.len(), m);
    // θ never moves: every recorded objective equals f(0).
    let f0 = out.trace.rows[0].fval;
    assert!(out.trace.rows.iter().all(|r| (r.fval - f0).abs() < 1e-12));
}

#[test]
fn scheduled_serial_equivalence_round_robin() {
    // The serial run_scheduled with the same schedule must match the
    // coordinator under RR (fval series; bits too).
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 50;
    let mut sched = Scheduler::RoundRobin { fraction: 0.5 };
    let m = prob.m();
    let serial =
        gdsec::algo::gdsec::run_scheduled(&prob, &cfg, iters, |k| Some(sched.active(k, m)));
    let dist = run_native_opts(
        &prob,
        cfg,
        iters,
        Scheduler::RoundRobin { fraction: 0.5 },
        Quorum::All,
        DelayPlan::None,
    );
    for (s, d) in serial.rows.iter().zip(dist.trace.rows.iter()) {
        assert!(
            (s.fval - d.fval).abs() <= 1e-12 * s.fval.abs().max(1.0),
            "iter {}: {} vs {}",
            s.iter,
            s.fval,
            d.fval
        );
        assert_eq!(s.bits, d.bits);
    }
}

/// A scripted-latency transport wrapper: behaves exactly like its inner
/// transport but sleeps before each send — real wall-clock straggling
/// over the virtual channel, so the measured-delay path is exercised
/// deterministically without sockets.
struct SleepyTransport<T: Transport> {
    inner: T,
    delay: Duration,
}

impl<T: Transport> Transport for SleepyTransport<T> {
    fn send(&mut self, frame: Vec<u8>) -> bool {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.inner.send(frame)
    }
    fn recv(&mut self) -> Recv {
        self.inner.recv()
    }
    fn recv_timeout(&mut self, timeout: Duration) -> Recv {
        self.inner.recv_timeout(timeout)
    }
    fn try_recv(&mut self) -> Option<Recv> {
        self.inner.try_recv()
    }
    fn recv_into(&mut self, buf: &mut Vec<u8>, timeout: Duration) -> RecvStatus {
        self.inner.recv_into(buf, timeout)
    }
    fn sent_stats(&self) -> &Arc<LinkStats> {
        self.inner.sent_stats()
    }
    fn rcvd_stats(&self) -> &Arc<LinkStats> {
        self.inner.rcvd_stats()
    }
}

/// Run the coordinator in measured (wall-clock) mode over in-memory
/// links, with worker 2 sleeping `slow` before every reply.
fn run_measured(prob: &Problem, quorum: Quorum, iters: usize, slow: Duration) -> CoordOutcome {
    let m = prob.m();
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg_for(prob), iters);
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = prob.estimate_fstar(2000);
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.quorum = quorum;
    ccfg.faults = FaultPlan::default();
    ccfg.degrade = DegradePolicy::Freeze;
    ccfg.cohort = None;
    ccfg.evict_after = None;
    let mut ends: Vec<Box<dyn Transport>> = Vec::with_capacity(m);
    let mut handles = Vec::with_capacity(m);
    for (w, factory) in native_factories(prob).into_iter().enumerate() {
        let (server_end, worker_end) = duplex();
        let delay = if w == 2 { slow } else { Duration::ZERO };
        let wcfg = ccfg.gdsec.clone();
        let wire = ccfg.wire;
        let sw = ccfg.stale_window;
        handles.push(std::thread::spawn(move || {
            let _ = worker_loop(
                w as u32,
                m,
                wcfg,
                factory,
                SleepyTransport { inner: worker_end, delay },
                WorkerFaults::default(),
                wire,
                sw,
            );
        }));
        ends.push(Box::new(server_end));
    }
    let out = Coordinator::from_transports(ccfg, prob.d, ends, None, true).run();
    for h in handles {
        h.join().unwrap();
    }
    out
}

#[test]
fn measured_mode_records_wall_clock_delays_and_keeps_all_quorum_bitwise() {
    // Quorum::All over measured links: waiting for everyone is still the
    // paper's synchronous protocol — the trajectory must stay bitwise
    // equal to the virtual run — but the per-round delay metric must now
    // be real microseconds dominated by the 20 ms sleeper, not virtual
    // units.
    let prob = problem();
    let iters = 6;
    let virt = run_native_opts(
        &prob,
        cfg_for(&prob),
        iters,
        Scheduler::All,
        Quorum::All,
        DelayPlan::None,
    );
    let out = run_measured(&prob, Quorum::All, iters, Duration::from_millis(20));
    assert_eq!(virt.trace.rows.len(), out.trace.rows.len());
    for (v, t) in virt.trace.rows.iter().zip(out.trace.rows.iter()) {
        assert_eq!(
            v.fval.to_bits(),
            t.fval.to_bits(),
            "measured-mode Quorum::All diverged at iter {}",
            v.iter
        );
    }
    assert!(out.rounds.iter().all(|r| r.quorum_k == prob.m() as u64));
    // Every round waited on the sleeper: ≥ 5 ms measured (20 ms nominal,
    // generous margin for scheduler noise in the fast direction only —
    // a sleep cannot complete early).
    assert!(
        out.rounds.iter().all(|r| r.virtual_units >= 5_000),
        "wall-clock delays not measured: {:?}",
        out.rounds.iter().map(|r| r.virtual_units).collect::<Vec<_>>()
    );
}

#[test]
fn adaptive_quorum_cuts_on_measured_wall_clock_delays() {
    // The discriminating property: with all-zero delay observations the
    // adaptive controller NEVER cuts below the full fleet (tau = 0 ⇒
    // every EMA passes ⇒ K = n, pinned by scheduler unit tests). So any
    // post-warm-up round with quorum_k < n proves real measured
    // microseconds reached `QuorumController::observe` — no flaky
    // latency thresholds needed.
    // 40 ms sleeper: the cut fires as long as the fast workers' reply
    // EMAs stay under ADAPT_SLACK⁻¹ · 40 ms = 20 ms — two orders of
    // magnitude above a loopback channel reply even on a loaded CI box.
    let prob = problem();
    let out = run_measured(
        &prob,
        Quorum::Adaptive { target_quantile: 0.5, min_frac: 0.25 },
        12,
        Duration::from_millis(40),
    );
    let cut_rounds: Vec<_> =
        out.rounds.iter().filter(|r| r.round >= 2 && r.quorum_k < prob.m() as u64).collect();
    assert!(
        !cut_rounds.is_empty(),
        "adaptive quorum never cut the 20 ms straggler: measured delays \
         are not reaching the controller"
    );
    // The cut really happened: some round saw a late (parked) reply.
    assert!(
        out.rounds.iter().any(|r| r.late > 0) || out.trace.total_stale() > 0,
        "no late reply ever recorded despite quorum cuts"
    );
    // And the run still converges: cutting a straggler is a latency
    // optimization, not a correctness tradeoff.
    let errs = out.trace.errors();
    assert!(errs.last().unwrap().is_finite());
    assert!(errs.last().unwrap() < &errs[0]);
}

#[test]
fn tcp_loopback_matches_virtual_bitwise() {
    // The transport-parity acceptance gate, in-process: the same spec
    // over real loopback sockets (measured wall-clock mode) and over
    // virtual channels must produce the identical trajectory bit for
    // bit AND the identical byte accounting — TCP is a transport swap,
    // not a protocol change.
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 20;
    let virt =
        run_native_opts(&prob, cfg.clone(), iters, Scheduler::All, Quorum::All, DelayPlan::None);

    let factories = native_factories(&prob);
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, iters);
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = prob.estimate_fstar(2000);
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.quorum = Quorum::All;
    ccfg.faults = FaultPlan::default();
    ccfg.degrade = DegradePolicy::Freeze;
    ccfg.cohort = None;
    ccfg.evict_after = None;
    ccfg.transport = TransportKind::Tcp;
    let tcp = Coordinator::spawn(ccfg, prob.d, factories).run();

    assert_eq!(virt.trace.rows.len(), tcp.trace.rows.len());
    for (v, t) in virt.trace.rows.iter().zip(tcp.trace.rows.iter()) {
        assert_eq!(
            v.fval.to_bits(),
            t.fval.to_bits(),
            "TCP trajectory diverged at iter {}: {} vs {}",
            v.iter,
            v.fval,
            t.fval
        );
        assert_eq!(v.bits, t.bits, "payload-bit accounting diverged at iter {}", v.iter);
        assert_eq!(v.transmissions, t.transmissions);
    }
    for (v, t) in virt.rounds.iter().zip(tcp.rounds.iter()) {
        assert_eq!(
            v.payload_bits, t.payload_bits,
            "per-round payload bits diverged at round {}",
            v.round
        );
    }
    // Frame-byte totals: TCP counts receive-side at reassembly (stats
    // exclude the 4-byte wire length prefix and the hello handshake),
    // virtual counts send-side on the shared link — equal in a clean run.
    assert_eq!(virt.uplink_frame_bytes, tcp.uplink_frame_bytes);
    assert_eq!(virt.downlink_frame_bytes, tcp.downlink_frame_bytes);
    assert!(tcp.dead_workers.is_empty());
}
