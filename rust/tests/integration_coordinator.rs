//! Integration tests: the threaded coordinator must reproduce the serial
//! GD-SEC reference bit-for-bit, survive worker failures, and account
//! bytes exactly.

use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::coordinator::scheduler::Scheduler;
use gdsec::coordinator::worker::{FailurePlan, GradProvider, NativeProvider, ProviderFactory};
use gdsec::coordinator::{CoordConfig, Coordinator};
use gdsec::data::synthetic;
use gdsec::objectives::Problem;
use std::sync::Arc;
use std::time::Duration;

fn problem() -> Problem {
    Problem::logistic(synthetic::dna_like(17, 90), 3, 0.05)
}

fn cfg_for(prob: &Problem) -> GdSecConfig {
    GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.05,
        xi: Xi::Uniform(40.0),
        ..Default::default()
    }
}

#[test]
fn distributed_matches_serial_bit_for_bit() {
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 60;
    let serial = gdsec::algo::gdsec::run(&prob, &cfg, iters);
    let dist = gdsec::coordinator::run_native(&prob, cfg, iters, Scheduler::All);

    assert_eq!(serial.rows.len(), dist.trace.rows.len());
    for (s, d) in serial.rows.iter().zip(dist.trace.rows.iter()) {
        assert_eq!(s.iter, d.iter);
        assert_eq!(
            s.fval.to_bits(),
            d.fval.to_bits(),
            "fval diverged at iter {}: {} vs {}",
            s.iter,
            s.fval,
            d.fval
        );
        assert_eq!(s.bits, d.bits, "bit accounting diverged at iter {}", s.iter);
        assert_eq!(s.transmissions, d.transmissions);
        assert_eq!(s.entries, d.entries);
    }
}

#[test]
fn distributed_matches_serial_with_soec_and_per_coord_xi() {
    let prob = problem();
    let mut cfg = cfg_for(&prob);
    cfg.error_correction = false;
    cfg.xi = Xi::scaled_by_lipschitz(10.0, &prob.coord_lipschitz());
    let iters = 40;
    let serial = gdsec::algo::gdsec::run(&prob, &cfg, iters);
    let dist = gdsec::coordinator::run_native(&prob, cfg, iters, Scheduler::All);
    for (s, d) in serial.rows.iter().zip(dist.trace.rows.iter()) {
        assert_eq!(s.fval.to_bits(), d.fval.to_bits());
        assert_eq!(s.bits, d.bits);
    }
}

#[test]
fn adaptive_wire_same_trajectory_tagged_bits() {
    // Opt-in adaptive wire format: the trajectory must be bitwise equal
    // to the default sparse wire (both decode to the same f32 values),
    // and every transmission's payload cost must differ from the sparse
    // run's by the 8-bit tag at most — strictly cheaper than
    // sparse + tag overall when dense rounds exist, never more than
    // 8 bits/tx more expensive.
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 30;
    let sparse = gdsec::coordinator::run_native(&prob, cfg.clone(), iters, Scheduler::All);

    let fstar = prob.estimate_fstar(2000);
    let factories: Vec<ProviderFactory> = prob
        .locals
        .iter()
        .map(|l| {
            let local = l.clone();
            Box::new(move || Box::new(NativeProvider::new(local)) as Box<dyn GradProvider>)
                as ProviderFactory
        })
        .collect();
    let failures = vec![FailurePlan::default(); prob.m()];
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, iters);
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = fstar;
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.wire = gdsec::coordinator::protocol::WireFormat::Adaptive;
    let adaptive = Coordinator::spawn(ccfg, prob.d, factories, failures).run();

    assert_eq!(sparse.trace.rows.len(), adaptive.trace.rows.len());
    for (s, a) in sparse.trace.rows.iter().zip(adaptive.trace.rows.iter()) {
        assert_eq!(
            s.fval.to_bits(),
            a.fval.to_bits(),
            "adaptive wire changed the trajectory at iter {}",
            s.iter
        );
        assert_eq!(s.transmissions, a.transmissions);
        // Adaptive cost is bounded: at most one tag byte per transmission
        // over the sparse cost (and possibly much cheaper).
        assert!(
            a.bits <= s.bits + 8 * a.transmissions,
            "iter {}: adaptive {} vs sparse {} (+{} tags)",
            s.iter,
            a.bits,
            s.bits,
            a.transmissions
        );
    }
    // The tag is really accounted: with at least one transmitted update,
    // total adaptive bits cannot equal the sparse total exactly unless
    // dense fallbacks saved more than the tags cost.
    let tx = adaptive.trace.total_transmissions();
    assert!(tx > 0);
    assert_ne!(
        adaptive.trace.total_bits(),
        sparse.trace.total_bits(),
        "tag byte not visible in accounting"
    );
}

#[test]
fn uplink_frame_bytes_cover_payload_plus_headers() {
    let prob = problem();
    let cfg = cfg_for(&prob);
    let out = gdsec::coordinator::run_native(&prob, cfg, 20, Scheduler::All);
    let payload_bits: u64 = out.rounds.iter().map(|r| r.payload_bits).sum();
    let overhead_bits: u64 = out.rounds.iter().map(|r| r.overhead_bits).sum();
    assert_eq!(
        out.uplink_frame_bytes * 8,
        payload_bits + overhead_bits,
        "byte accounting mismatch"
    );
    // Downlink counted too (θ broadcasts are large: 8 bytes/coord).
    assert!(out.downlink_frame_bytes > 0);
}

#[test]
fn round_robin_partial_participation() {
    let prob = problem();
    let cfg = cfg_for(&prob);
    let out =
        gdsec::coordinator::run_native(&prob, cfg, 80, Scheduler::RoundRobin { fraction: 0.5 });
    // fewer transmissions than full participation
    assert!(out.trace.total_transmissions() <= 80 * 2);
    // still converging
    let errs = out.trace.errors();
    assert!(
        errs.last().unwrap() < &(errs[0] * 0.5),
        "{} -> {}",
        errs[0],
        errs.last().unwrap()
    );
    assert!(out.dead_workers.is_empty());
}

#[test]
fn worker_failure_tolerated() {
    let prob = problem();
    let m = prob.m();
    let cfg = cfg_for(&prob);
    let fstar = prob.estimate_fstar(2000);
    let factories: Vec<ProviderFactory> = prob
        .locals
        .iter()
        .map(|l| {
            let local = l.clone();
            Box::new(move || Box::new(NativeProvider::new(local)) as Box<dyn GradProvider>)
                as ProviderFactory
        })
        .collect();
    // Worker 1 goes silent from round 10.
    let mut failures = vec![FailurePlan::default(); m];
    failures[1] = FailurePlan { silent_from_round: Some(10) };
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, 60);
    ccfg.recv_timeout = Duration::from_millis(200);
    ccfg.dead_after = 1;
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = fstar;
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    let out = Coordinator::spawn(ccfg, prob.d, factories, failures).run();
    assert_eq!(out.dead_workers, vec![1]);
    // Run completes and the survivors keep optimizing.
    let errs = out.trace.errors();
    assert!(errs.last().unwrap().is_finite());
    assert!(errs.last().unwrap() < &errs[2], "no progress after failure");
}

#[test]
fn all_workers_fail_run_still_terminates() {
    let prob = problem();
    let m = prob.m();
    let factories: Vec<ProviderFactory> = prob
        .locals
        .iter()
        .map(|l| {
            let local = l.clone();
            Box::new(move || Box::new(NativeProvider::new(local)) as Box<dyn GradProvider>)
                as ProviderFactory
        })
        .collect();
    let failures = vec![FailurePlan { silent_from_round: Some(1) }; m];
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg_for(&prob), 10);
    ccfg.recv_timeout = Duration::from_millis(100);
    ccfg.problem_name = prob.name.clone();
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    let out = Coordinator::spawn(ccfg, prob.d, factories, failures).run();
    assert_eq!(out.dead_workers.len(), m);
    // θ never moves: every recorded objective equals f(0).
    let f0 = out.trace.rows[0].fval;
    assert!(out.trace.rows.iter().all(|r| (r.fval - f0).abs() < 1e-12));
}

#[test]
fn scheduled_serial_equivalence_round_robin() {
    // The serial run_scheduled with the same schedule must match the
    // coordinator under RR (fval series; bits too).
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 50;
    let mut sched = Scheduler::RoundRobin { fraction: 0.5 };
    let m = prob.m();
    let serial =
        gdsec::algo::gdsec::run_scheduled(&prob, &cfg, iters, |k| Some(sched.active(k, m)));
    let dist = gdsec::coordinator::run_native(
        &prob,
        cfg,
        iters,
        Scheduler::RoundRobin { fraction: 0.5 },
    );
    for (s, d) in serial.rows.iter().zip(dist.trace.rows.iter()) {
        assert!(
            (s.fval - d.fval).abs() <= 1e-12 * s.fval.abs().max(1.0),
            "iter {}: {} vs {}",
            s.iter,
            s.fval,
            d.fval
        );
        assert_eq!(s.bits, d.bits);
    }
}
