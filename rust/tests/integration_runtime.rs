#![cfg(feature = "pjrt")]

//! PJRT runtime integration: load the jax/Pallas AOT artifacts, execute
//! them from Rust, and pin the compiled worker step against the native
//! Rust implementation.
//!
//! These tests need `make artifacts` to have run; they are skipped (with a
//! loud message) when the manifest is absent so `cargo test` stays usable
//! before the Python toolchain has produced artifacts.

use gdsec::algo::gdsec::{GdSecConfig, WorkerState, Xi};
use gdsec::coordinator::scheduler::Scheduler;
use gdsec::coordinator::transport::FaultPlan;
use gdsec::coordinator::worker::{GradProvider, ProviderFactory};
use gdsec::coordinator::{CoordConfig, Coordinator};
use gdsec::data::{synthetic, Features};
use gdsec::objectives::{LocalObjective, ObjectiveKind, Problem};
use gdsec::runtime::engine::{TfmEngine, WorkerScalars, XlaGradProvider, XlaWorkerStep};
use gdsec::runtime::Manifest;
use std::sync::Arc;

fn manifest() -> Option<Manifest> {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e:#}");
            None
        }
    }
}

/// Build a problem exactly matching the compiled 30x180 shard artifacts:
/// 3 workers x 30 samples, d=180 (dna-like), lambda=0.05.
fn artifact_problem(kind: ObjectiveKind) -> Problem {
    let n = if kind == ObjectiveKind::Nlls { 60 } else { 90 };
    Problem::new(kind, synthetic::dna_like(23, n), 3, 0.05)
}

fn shard_dense(l: &LocalObjective) -> (Vec<f64>, Vec<f64>) {
    match &l.shard.x {
        Features::Dense(m) => (m.data.clone(), l.shard.y.clone()),
        _ => panic!("dense shard expected"),
    }
}

fn scalars_for(prob: &Problem) -> WorkerScalars {
    WorkerScalars {
        beta: 0.02,
        m_inv: 1.0 / prob.m() as f64,
        n_inv: 1.0 / prob.n_total as f64,
        lambda: prob.lambda,
    }
}

#[test]
fn xla_worker_step_matches_native_gradient() {
    let Some(man) = manifest() else { return };
    for (kind, artifact) in [
        (ObjectiveKind::LogReg, "worker_step_logreg_30x180"),
        (ObjectiveKind::LinReg, "worker_step_linreg_30x180"),
        (ObjectiveKind::Nlls, "worker_step_nlls_20x180"),
    ] {
        let prob = artifact_problem(kind);
        let l = &prob.locals[0];
        let (x, y) = shard_dense(l);
        let mut step = XlaWorkerStep::new(man.clone(), artifact, &x, &y).unwrap();
        let d = prob.d;
        let theta: Vec<f64> = (0..d).map(|i| ((i % 13) as f64 - 6.0) * 0.02).collect();
        let zeros32 = vec![0.0f32; d];
        let zeros64 = vec![0.0f64; d];
        // xi = 0, h = e = 0, beta = 0 => wire == local gradient.
        let out = step
            .step(
                &theta,
                &theta,
                &zeros32,
                &zeros32,
                &zeros64,
                WorkerScalars { beta: 0.0, ..scalars_for(&prob) },
            )
            .unwrap();
        let mut native = vec![0.0; d];
        l.grad(&theta, &mut native);
        let native_loss = l.value(&theta);
        assert!(
            (out.loss - native_loss).abs() < 1e-4 * native_loss.abs().max(1.0),
            "{kind:?} loss: xla {} vs native {}",
            out.loss,
            native_loss
        );
        for i in 0..d {
            let w = out.wire[i] as f64;
            assert!(
                (w - native[i]).abs() < 2e-4 * native[i].abs().max(1e-3),
                "{kind:?} grad[{i}]: xla {w} vs native {}",
                native[i]
            );
        }
    }
}

#[test]
fn xla_sparsify_matches_native_worker_state() {
    // Full censoring path: run the compiled fused step with non-trivial
    // h, e, xi and compare against the native WorkerState on the SAME f32
    // gradient.
    let Some(man) = manifest() else { return };
    let prob = artifact_problem(ObjectiveKind::LogReg);
    let l = &prob.locals[1];
    let (x, y) = shard_dense(l);
    let mut step = XlaWorkerStep::new(man, "worker_step_logreg_30x180", &x, &y).unwrap();
    let d = prob.d;
    let m = prob.m();
    let theta: Vec<f64> = (0..d).map(|i| (i as f64 * 0.37).sin() * 0.1).collect();
    let theta_prev: Vec<f64> = theta.iter().map(|v| v - 1e-3).collect();
    let h: Vec<f32> = (0..d).map(|i| ((i * 7 % 11) as f32 - 5.0) * 0.001).collect();
    let e: Vec<f32> = (0..d).map(|i| ((i * 3 % 5) as f32 - 2.0) * 0.0005).collect();
    let xi = vec![200.0f64; d];
    let scal = scalars_for(&prob);
    let out = step.step(&theta, &theta_prev, &h, &e, &xi, scal).unwrap();

    // Native mirror, fed the XLA gradient to isolate the sparsify logic.
    // Reconstruct grad = wire + e_new + h − e  (EC identity: Δ = wire +
    // e_new and Δ = grad − h + e).
    let mut ws = WorkerState::new(d);
    for i in 0..d {
        ws.h[i] = h[i] as f64;
        ws.e[i] = e[i] as f64;
        ws.grad_mut()[i] =
            (out.wire[i] as f64) + (out.e_new[i] as f64) + (h[i] as f64) - (e[i] as f64);
    }
    let cfg = GdSecConfig {
        alpha: 0.0,
        beta: scal.beta,
        xi: Xi::Uniform(200.0),
        ..Default::default()
    };
    let diff: Vec<f64> = theta.iter().zip(&theta_prev).map(|(a, b)| a - b).collect();
    let up = ws.sparsify_step(&cfg, m, &diff);
    let dense = up.to_dense();
    let mut n_transmitted = 0;
    for i in 0..d {
        let native_wire = dense[i] as f32;
        assert!(
            (native_wire - out.wire[i]).abs()
                <= 4.0 * f32::EPSILON * native_wire.abs().max(1e-3),
            "wire[{i}]: native {native_wire} vs xla {}",
            out.wire[i]
        );
        if out.wire[i] != 0.0 {
            n_transmitted += 1;
        }
        assert!(
            (ws.h[i] - out.h_new[i] as f64).abs() < 1e-6,
            "h[{i}]: native {} vs xla {}",
            ws.h[i],
            out.h_new[i]
        );
    }
    // The threshold actually censored something and kept something.
    assert!(n_transmitted > 0, "everything censored");
    assert!(n_transmitted < d, "nothing censored (xi too small for test)");
}

#[test]
fn coordinator_runs_on_xla_engine_end_to_end() {
    // The full L3 coordinator with PJRT-backed providers created inside
    // worker threads: 3 workers, logreg, a handful of rounds. Trajectory
    // must track the native-provider run closely (f32 gradient rounding is
    // the only difference).
    let Some(man) = manifest() else { return };
    let prob = artifact_problem(ObjectiveKind::LogReg);
    let gd_cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.05,
        xi: Xi::Uniform(40.0),
        ..Default::default()
    };
    let iters = 15;
    let scal = scalars_for(&prob);
    let factories: Vec<ProviderFactory> = prob
        .locals
        .iter()
        .map(|l| {
            let (x, y) = shard_dense(l);
            let man = man.clone();
            Box::new(move || {
                Box::new(
                    XlaGradProvider::new(man, "worker_step_logreg_30x180", &x, &y, scal)
                        .expect("xla provider"),
                ) as Box<dyn GradProvider>
            }) as ProviderFactory
        })
        .collect();
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(gd_cfg.clone(), iters);
    ccfg.scheduler = Scheduler::All;
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = prob.estimate_fstar(2000);
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.faults = FaultPlan::default(); // pin: tracks the native run
    let out = Coordinator::spawn(ccfg, prob.d, factories).run();

    let native = gdsec::algo::gdsec::run(&prob, &gd_cfg, iters);
    assert_eq!(out.trace.rows.len(), native.rows.len());
    for (x, n) in out.trace.rows.iter().zip(native.rows.iter()) {
        assert!(
            (x.fval - n.fval).abs() < 2e-3 * n.fval.abs().max(1.0),
            "iter {}: xla {} vs native {}",
            x.iter,
            x.fval,
            n.fval
        );
    }
    // Optimization actually progressed.
    let errs = out.trace.errors();
    assert!(errs.last().unwrap() < &(errs[0] * 0.9));
}

#[test]
fn tfm_engine_loss_decreases_under_gd() {
    let Some(man) = manifest() else { return };
    let mut eng = TfmEngine::new(man).unwrap();
    let mut params = eng.init_params(7).unwrap();
    let corpus = synthetic::token_corpus(3, eng.batch, eng.seq, eng.vocab);
    let tokens: Vec<i32> = corpus.iter().flat_map(|s| s.iter().map(|&t| t as i32)).collect();
    let (l0, g0) = eng.loss_grad(&params, &tokens).unwrap();
    assert!(l0.is_finite() && l0 > 0.0);
    assert_eq!(g0.len(), eng.n_params);
    for _ in 0..5 {
        let (_, g) = eng.loss_grad(&params, &tokens).unwrap();
        for (p, gi) in params.iter_mut().zip(&g) {
            *p -= 0.25 * gi;
        }
    }
    let (l1, _) = eng.loss_grad(&params, &tokens).unwrap();
    assert!(l1 < l0, "loss did not decrease: {l0} -> {l1}");
}

#[test]
fn tfm_sparsify_artifact_censors() {
    let Some(man) = manifest() else { return };
    let mut eng = TfmEngine::new(man).unwrap();
    let d = eng.n_params;
    let grad: Vec<f32> = (0..d).map(|i| if i % 100 == 0 { 1.0 } else { 1e-6 }).collect();
    let zeros = vec![0.0f32; d];
    let tdiff = vec![0.01f32; d];
    // tau = 1000 * 0.25 * 0.01 = 2.5 > 1.0: everything censored.
    let (wire, h_new, e_new) =
        eng.sparsify(&grad, &zeros, &zeros, &tdiff, 1000.0, 0.5, 0.25).unwrap();
    assert!(wire.iter().all(|&w| w == 0.0));
    assert!(h_new.iter().all(|&x| x == 0.0));
    assert_eq!(e_new[0], 1.0); // error memory holds the full delta
    // With a small threshold (tau = 1*0.25*0.01) the 1.0 spikes survive:
    let (wire2, _, _) = eng.sparsify(&grad, &zeros, &zeros, &tdiff, 1.0, 0.5, 0.25).unwrap();
    let nnz = wire2.iter().filter(|&&w| w != 0.0).count();
    assert_eq!(nnz, d.div_ceil(100));
}
