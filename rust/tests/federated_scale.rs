//! Federated scale-out integration: the thread-free cohort harness
//! ([`gdsec::coordinator::federated`]) at the full M = 10,000 fleet the
//! paper's cross-device regime targets, plus the evict→restore bitwise
//! property over randomized cohort schedules.
//!
//! The 10k smoke is CI's proof that the tentpole configuration is real:
//! a fixed-seed 10% cohort run over a small-d sparse logistic problem
//! must converge to tolerance, keep the server's per-worker ledger state
//! far below the dense O(M·d) footprint, exercise censoring (fully
//! skipped worker-rounds) and ledger eviction/restore, and reproduce
//! bit-for-bit when re-run. Fault-plan composition with eviction is
//! pinned separately in `chaos_faults.rs`
//! (`eviction_is_bitwise_transparent_under_fault_storm`) — the virtual
//! harness here has no transport to fault.

use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::coordinator::federated::{run_federated, FederatedConfig, FederatedOutcome};
use gdsec::coordinator::scheduler::CohortPlan;
use gdsec::data::synthetic;
use gdsec::objectives::Problem;
use gdsec::util::pool::Pool;
use gdsec::util::rng::Pcg64;

fn to_bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn ten_thousand_worker_cohort_smoke() {
    const M: usize = 10_000;
    const D: usize = 64;
    const ITERS: usize = 40;
    // One sparse data row per worker: the cross-device regime (each
    // device's gradient touches a handful of coordinates).
    let prob = Problem::logistic(synthetic::rcv1_like(33, M, D, 4), M, 0.0);
    // β = 1: a worker's h_m snaps to its last transmission, so at a
    // revisit |Δ| collapses to the curvature drift its shard saw since —
    // tiny against the ξ/M = 5 relative threshold (the repo's serial
    // integration tests converge at ratio ~13) — and whole worker-rounds
    // censor: the paper's communication saving at fleet scale,
    // deterministic enough to assert on.
    let cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 1.0,
        xi: Xi::Uniform(5.0 * M as f64),
        fstar: Some(0.0),
        ..GdSecConfig::default()
    };
    let run = || -> FederatedOutcome {
        let mut fc = FederatedConfig::new(cfg.clone(), ITERS);
        fc.cohort = Some(CohortPlan::fraction(0.1, 0x5EED));
        fc.eval_every = ITERS; // one final objective evaluation
        run_federated(&prob, fc, Pool::global())
    };
    let out = run();

    // Convergence to tolerance from θ = 0 with 10% participation.
    let f0 = prob.value(&vec![0.0; prob.d]);
    let &(k_last, f_last) = out.fvals.last().expect("no evaluation recorded");
    assert_eq!(k_last, ITERS);
    assert!(f_last.is_finite());
    assert!(
        f_last < f0 * 0.9,
        "10k-worker cohort run failed to converge: f(0) = {f0} -> f({ITERS}) = {f_last}"
    );

    // O(cohort) resident state: the dense ledger would hold M·d·8 bytes.
    let dense_bytes = M * D * 8;
    assert!(out.peak_state_bytes > 0);
    assert!(
        out.peak_state_bytes < dense_bytes / 2,
        "resident state not bounded: peak {} B vs dense {} B",
        out.peak_state_bytes,
        dense_bytes
    );

    // The mechanisms really ran: transmissions happened, censoring
    // skipped whole worker-rounds, and ledgers cycled out and back.
    assert!(out.transmissions > 0);
    assert!(out.censored > 0, "no worker-round was ever fully censored");
    assert!(out.evictions > 0, "no ledger was ever evicted");
    assert!(out.restores > 0, "no evicted ledger was ever restored");

    // Fixed seed ⇒ bit-for-bit reproducible at full 10k scale.
    let again = run();
    assert_eq!(to_bits(&out.theta), to_bits(&again.theta), "10k run is not deterministic");
    assert_eq!(out.uplink_bits, again.uplink_bits);
    assert_eq!(out.transmissions, again.transmissions);
    assert_eq!(out.censored, again.censored);
    assert_eq!(out.evictions, again.evictions);
    assert_eq!(out.restores, again.restores);
}

#[test]
fn evict_restore_bitwise_across_random_cohort_schedules() {
    // Property: over randomized cohort fractions, seeds, and idle
    // horizons, a run with ledger eviction is bitwise identical to the
    // always-resident replica of the same schedule — θ, h, every
    // per-worker ledger, every worker's h_m/e_m, and the uplink
    // accounting. Eviction must be invisible to the arithmetic no matter
    // when slabs age out relative to cohort re-entry.
    let (m, iters) = (40usize, 25usize);
    let d = 32usize;
    let prob = Problem::logistic(synthetic::rcv1_like(9, 256, d, 5), m, 0.01);
    for seed in 0..4u64 {
        let mut rng = Pcg64::new(0xFED5, seed);
        let frac = rng.uniform_in(0.15, 0.6);
        let horizon = 1 + rng.index(3) as u32;
        let cseed = rng.index(1 << 30) as u64;
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.5,
            xi: Xi::Uniform(10.0),
            fstar: Some(0.0),
            ..GdSecConfig::default()
        };
        let run = |evict_after: Option<u32>| -> FederatedOutcome {
            let mut fc = FederatedConfig::new(cfg.clone(), iters);
            fc.cohort = Some(CohortPlan::fraction(frac, cseed));
            fc.evict_after = evict_after;
            fc.eval_every = 0;
            run_federated(&prob, fc, Pool::global())
        };
        let evicting = run(Some(horizon));
        let replica = run(Some(u32::MAX)); // never ages out: O(M·d) resident
        assert!(evicting.evictions > 0, "seed {seed}: horizon {horizon} never evicted");
        assert!(evicting.restores > 0, "seed {seed}: no ledger ever rehydrated");
        assert_eq!(replica.evictions, 0, "seed {seed}: replica must never evict");
        // (No memory comparison at this scale: with m = 40 near-dense
        // ledgers, parked images at 12 B/entry can outweigh the slabs
        // they replace — the O(cohort) footprint claim belongs to the
        // fleet-scale rare-feature tests, not this bitwise property.)

        assert_eq!(
            to_bits(&evicting.theta),
            to_bits(&replica.theta),
            "seed {seed}: eviction moved θ"
        );
        assert_eq!(to_bits(&evicting.h), to_bits(&replica.h), "seed {seed}: eviction moved h");
        assert_eq!(evicting.uplink_bits, replica.uplink_bits, "seed {seed}");
        assert_eq!(evicting.transmissions, replica.transmissions, "seed {seed}");
        assert_eq!(evicting.censored, replica.censored, "seed {seed}");
        let mut la = vec![0.0; d];
        let mut lb = vec![0.0; d];
        for w in 0..m {
            evicting.store.ledger_dense(w, &mut la);
            replica.store.ledger_dense(w, &mut lb);
            assert_eq!(to_bits(&la), to_bits(&lb), "seed {seed}: ledger drift at worker {w}");
            assert_eq!(
                to_bits(&evicting.workers[w].h),
                to_bits(&replica.workers[w].h),
                "seed {seed}: worker {w} h_m drift"
            );
            assert_eq!(
                to_bits(&evicting.workers[w].e),
                to_bits(&replica.workers[w].e),
                "seed {seed}: worker {w} e_m drift"
            );
        }
    }
}
