//! Wire-codec robustness properties: everything a worker can put on the
//! uplink must round-trip BIT-exactly, and everything a malicious or
//! corrupted peer can put there must be rejected cleanly (`None`, never a
//! panic or an out-of-range index reaching the aggregation path).

use gdsec::compress::{self, rle, SparseUpdate};
use gdsec::testing::{check_with, gen, PropConfig};
use gdsec::util::rng::Pcg64;

/// Random update including the degenerate densities: case-dependent
/// all-zero (nnz = 0), all-nonzero (nnz = d), and mixed.
fn random_update(rng: &mut Pcg64, case_mode: usize, d: usize) -> SparseUpdate {
    let v: Vec<f64> = match case_mode {
        0 => vec![0.0; d],
        1 => (0..d).map(|_| rng.normal() + 2.0 * rng.sign()).collect(),
        _ => gen::vec_mixed(rng, d),
    };
    SparseUpdate::from_dense(&v)
}

#[test]
fn prop_sparse_roundtrip_bit_exact() {
    let mode = std::cell::Cell::new(0usize);
    check_with(
        PropConfig { cases: 60, seed: 0xC0DEC1 },
        "encode_sparse/decode_sparse bit-exact roundtrip (incl nnz=0, nnz=d)",
        |rng| {
            let m = mode.get();
            mode.set(m + 1);
            let d = gen::len(rng, 3000);
            let u = random_update(rng, m % 3, d);
            let mut buf = Vec::new();
            compress::encode_sparse(&u, &mut buf);
            if buf.len() * 8 != compress::sparse_bits(&u) {
                return Err(format!(
                    "bit accounting: {} bytes vs {} bits",
                    buf.len(),
                    compress::sparse_bits(&u)
                ));
            }
            let (back, used) =
                compress::decode_sparse(&buf, d as u32).ok_or("decode failed".to_string())?;
            if used != buf.len() {
                return Err(format!("consumed {used} of {}", buf.len()));
            }
            if back.idx != u.idx {
                return Err("index stream mismatch".to_string());
            }
            for (k, (a, b)) in back.val.iter().zip(&u.val).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("value {k}: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sparse_truncation_rejected() {
    check_with(
        PropConfig { cases: 25, seed: 0xC0DEC2 },
        "decode_sparse rejects every strict prefix",
        |rng| {
            let d = gen::len(rng, 400);
            let u = SparseUpdate::from_dense(&gen::vec_sparse(rng, d, 0.6));
            let mut buf = Vec::new();
            compress::encode_sparse(&u, &mut buf);
            for cut in 0..buf.len() {
                if compress::decode_sparse(&buf[..cut], d as u32).is_some() {
                    return Err(format!("prefix of {cut}/{} bytes decoded", buf.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dense_truncation_rejected_and_roundtrip() {
    check_with(
        PropConfig { cases: 25, seed: 0xC0DEC3 },
        "decode_dense rejects short buffers, roundtrips f32-exact values",
        |rng| {
            let d = gen::len(rng, 600);
            let v = gen::vec_f32_exact(rng, d);
            let mut buf = Vec::new();
            compress::encode_dense(&v, &mut buf);
            let (back, used) =
                compress::decode_dense(&buf, d).ok_or("decode failed".to_string())?;
            if used != buf.len() || back != v {
                return Err("dense roundtrip mismatch".to_string());
            }
            for cut in [0, buf.len() / 2, buf.len().saturating_sub(1)] {
                if cut < buf.len() && compress::decode_dense(&buf[..cut], d).is_some() {
                    return Err(format!("short buffer of {cut} bytes decoded"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_out_of_range_indices_rejected() {
    check_with(
        PropConfig { cases: 25, seed: 0xC0DEC4 },
        "decode_sparse rejects any index ≥ dim",
        |rng| {
            let d = 2 + gen::len(rng, 400);
            let mut v = gen::vec_sparse(rng, d, 0.5);
            v[d - 1] = 1.0; // force the top index to be present
            let u = SparseUpdate::from_dense(&v);
            let mut buf = Vec::new();
            compress::encode_sparse(&u, &mut buf);
            // Exact dimension decodes; any smaller claimed dim must fail
            // (the encoded top index is then out of range).
            if compress::decode_sparse(&buf, d as u32).is_none() {
                return Err("exact-dim decode failed".to_string());
            }
            let small = 1 + rng.index(d - 1);
            if compress::decode_sparse(&buf, small as u32).is_some() {
                return Err(format!("dim {small} accepted index {}", d - 1));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_overflowing_gap_streams_rejected() {
    // A gap stream whose cumulative index passes u32::MAX would wrap to a
    // SMALLER index (a non-monotone index stream) if accepted; both the
    // gap decoder and the sparse decoder must reject it.
    check_with(
        PropConfig { cases: 25, seed: 0xC0DEC5 },
        "decode rejects gap streams that overflow / go non-monotone",
        |rng| {
            let extra = 1 + rng.index(5);
            let mut buf = Vec::new();
            rle::put_varint(&mut buf, 1 + extra as u32); // nnz
            rle::put_varint(&mut buf, u32::MAX); // idx0 = u32::MAX (legal alone)
            for _ in 0..extra {
                rle::put_varint(&mut buf, rng.below(1 << 10) as u32); // must overflow
            }
            buf.resize(buf.len() + 4 * (1 + extra), 0); // value plane
            let mut idx = Vec::new();
            if rle::decode_gaps(&buf[1..], 1 + extra, &mut idx).is_some() {
                return Err("overflowing gap stream decoded".to_string());
            }
            if compress::decode_sparse(&buf, u32::MAX).is_some() {
                return Err("decode_sparse accepted overflowing stream".to_string());
            }
            Ok(())
        },
    );
}
